"""Regenerate the §Tables appendix of EXPERIMENTS.md from the final dry-run
JSONL reports. Usage: PYTHONPATH=src python reports/build_tables.py"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
from repro.roofline.report import fmt_table, load  # noqa: E402

BASE = os.path.dirname(__file__)
EXP = os.path.join(BASE, "..", "EXPERIMENTS.md")
MARK = "(Generated from `reports/dryrun/final_*.jsonl`"


def xpod_table() -> str:
    base = {json.loads(l)["arch"]: json.loads(l)
            for l in open(os.path.join(BASE, "dryrun/xpod_base.jsonl"))}
    fl = {json.loads(l)["arch"]: json.loads(l)
          for l in open(os.path.join(BASE, "dryrun/xpod_fl.jsonl"))}
    out = ["**Cross-pod bytes per device per step (train_4k, 2×16×16): "
           "baseline all-reduce vs AE-compressed federated round**\n\n",
           "| arch | baseline cross-pod GB | FL cross-pod GB | reduction |\n",
           "|---|---:|---:|---:|\n"]
    for a, b in base.items():
        f = fl.get(a)
        if not f:
            continue
        bb = b["cross_pod_gb_per_dev"]
        ff = f["cross_pod_gb_per_dev"]
        red = bb / ff if ff else float("inf")
        out.append(f"| {a} | {bb:.4f} | {ff:.6f} | {red:,.0f}x |\n")
    return "".join(out)


def main():
    text = open(EXP).read()
    idx = text.index(MARK)
    head = text[:idx]
    parts = [head, MARK + " by `reports/build_tables.py`.)\n\n"]
    parts.append(xpod_table() + "\n")
    for fname, cap in (
        ("dryrun/final_single.jsonl",
         "Roofline baselines — all 40 (arch × shape), single-pod 16×16"),
        ("dryrun/final_multi.jsonl",
         "Multi-pod 2×16×16 — all 40 (arch × shape)"),
        ("dryrun/final_fl_multi.jsonl",
         "Federated rounds (chunked-AE pod exchange), 2×16×16"),
    ):
        rows = load(os.path.join(BASE, fname))
        parts.append(fmt_table(rows, cap) + "\n")
    open(EXP, "w").write("".join(parts))
    print("EXPERIMENTS.md §Tables rebuilt")


if __name__ == "__main__":
    main()

"""Substrate tests: optimizers, data pipeline, checkpointing, sharding rules,
HLO static analyzer."""
import os

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.checkpoint.checkpoint import load_pytree, save_pytree
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import (batches, color_imbalance_split,
                                 dirichlet_partition, mnist_like,
                                 synthetic_lm_batch)
from repro.models import sharding as shard_lib
from repro.optim.optimizers import (clip_by_global_norm, global_norm,
                                    make_optimizer)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


# ------------------------------------------------------------------ optim
@pytest.mark.parametrize("name", ["sgd", "sgdm", "sgdm_bf16", "adam",
                                  "adamw"])
def test_optimizer_reduces_quadratic(name):
    opt = make_optimizer(name, lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)  # noqa: E731
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = opt.update(params, g, state)
    assert float(loss(params)) < 0.05


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    small = {"a": jnp.full((4,), 0.01)}
    same = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(same["a"]), 0.01, rtol=1e-6)


# ------------------------------------------------------------------ data
def test_dirichlet_partition_covers_all():
    data = mnist_like(0, 500)
    parts = dirichlet_partition(0, data, 5, alpha=0.3)
    assert len(parts) == 5
    total = sum(p["x"].shape[0] for p in parts)
    assert total >= 495                     # empty-client fill may duplicate
    for p in parts:
        assert p["x"].shape[0] >= 1


def test_color_imbalance_grayscale():
    (color, gray), _ = color_imbalance_split(0, 64, n_eval=16)
    gx = np.asarray(gray["x"])
    assert np.allclose(gx[..., 0], gx[..., 1]) and \
        np.allclose(gx[..., 1], gx[..., 2])
    cx = np.asarray(color["x"])
    assert not np.allclose(cx[..., 0], cx[..., 1])


def test_batches_deterministic():
    data = mnist_like(3, 100)
    b1 = list(batches(7, data, 32))
    b2 = list(batches(7, data, 32))
    assert len(b1) == 3
    np.testing.assert_array_equal(np.asarray(b1[0]["x"]),
                                  np.asarray(b2[0]["x"]))


def test_lm_batch_shapes():
    b = synthetic_lm_batch(0, 1000, 4, 16)
    assert b["tokens"].shape == (4, 16)
    assert int(b["tokens"].max()) < 1000
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16),
                       "c": jnp.array(3, jnp.int32)}}
    path = os.path.join(tmp_path, "ck.npz")
    save_pytree(path, tree, metadata={"round": 7})
    restored, meta = load_pytree(path, tree)
    assert meta["round"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


# ------------------------------------------------------------------ sharding
def _abstract_mesh(multi=False):
    # jax changed the AbstractMesh ctor across 0.4.x: older builds take
    # (shape, axis_names), 0.4.37+ takes a tuple of (name, size) pairs
    if multi:
        dims = (("pod", 2), ("data", 16), ("model", 16))
    else:
        dims = (("data", 16), ("model", 16))
    try:
        return AbstractMesh(tuple(dims))
    except TypeError:
        return AbstractMesh(tuple(s for _, s in dims),
                            tuple(n for n, _ in dims))


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_divisible(arch, multi):
    """Every sharded dim divides the mesh axis — the GSPMD validity
    precondition for all 10 architectures on both production meshes."""
    from repro.launch.steps import param_shapes
    cfg = get_config(arch)
    mesh = _abstract_mesh(multi)
    shapes = param_shapes(cfg)
    specs = shard_lib.param_specs(shapes, mesh)

    def check(path, shp, spec):
        for dim, axis in zip(shp.shape, tuple(spec) + (None,) * 10):
            if axis is not None:
                axes = axis if isinstance(axis, tuple) else (axis,)
                total = 1
                for a in axes:
                    total *= mesh.shape[a]
                assert dim % total == 0, (path, shp.shape, spec)
    jax.tree_util.tree_map_with_path(
        lambda p, s, sp: check(p, s, sp), shapes, specs)


def test_zero1_spec_adds_data_axis():
    mesh = _abstract_mesh()
    spec = shard_lib.zero1_spec(P(None, "model"), (4096, 1024), mesh)
    assert spec == P("data", "model")
    # non-divisible dim stays unsharded
    spec2 = shard_lib.zero1_spec(P(None, "model"), (7, 1024), mesh)
    assert spec2 == P(None, "model")


def test_batch_spec_multi_pod():
    mesh = _abstract_mesh(True)
    s = shard_lib.data_spec(mesh, 256, 2)
    assert s == P(("pod", "data"), None)
    s1 = shard_lib.data_spec(mesh, 1, 2)      # batch 1: replicate
    assert s1 == P(None, None)


# ------------------------------------------------------------------ hlo parse
def test_hlo_parser_counts_trip_multiplied_dots():
    from repro.roofline.hlo_parse import analyze_hlo

    def f(x, w):
        def body(c, _):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y
    w = jnp.eye(64)
    x = jnp.ones((64, 64))
    compiled = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo(compiled.as_text())
    expected = 2 * 64 * 64 * 64 * 5          # 5 loop iterations
    assert cost.flops == pytest.approx(expected, rel=0.05)


def test_hlo_parser_collectives_synthetic():
    from repro.roofline.hlo_parse import analyze_hlo
    hlo = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256] get-tuple-element(%p), index=1
  %ar = f32[128,256] all-reduce(%x), replica_groups={}, to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256] parameter(0)
  %c = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%c, %x)
  %w = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""
    cost = analyze_hlo(hlo)
    assert cost.collective_bytes == 128 * 256 * 4 * 7
    assert cost.collective_breakdown["all-reduce"] == 128 * 256 * 4 * 7

"""Compressor API invariants — unit + hypothesis property tests."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig
from repro.core import (ChunkedAECompressor, ChunkedAEConfig,
                        ComposedCompressor, FCAECompressor,
                        IdentityCompressor, QuantizeCompressor,
                        TopKCompressor, init_chunked_ae, init_fc_ae)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


def _tree(seed=0, sizes=((7, 5), (64,), (3, 4, 2))):
    k = jax.random.PRNGKey(seed)
    return {f"p{i}": jax.random.normal(jax.random.PRNGKey(seed + i), s)
            for i, s in enumerate(sizes)}


def test_identity_roundtrip_exact():
    tree = _tree()
    decoded, stats = IdentityCompressor().roundtrip(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(decoded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats["compression_ratio"] == pytest.approx(1.0, rel=0.01)


@pytest.mark.parametrize("bits,min_ratio", [(8, 3.5), (4, 6.0)])
def test_quantize_ratio_and_error(bits, min_ratio):
    tree = _tree(1)
    comp = QuantizeCompressor(bits=bits, block=64)
    decoded, stats = comp.roundtrip(tree)
    assert stats["compression_ratio"] >= min_ratio
    flat, _ = ravel_pytree(tree)
    dflat, _ = ravel_pytree(decoded)
    qmax = 2 ** (bits - 1) - 1
    assert float(jnp.max(jnp.abs(flat - dflat))) <= float(
        jnp.max(jnp.abs(flat))) / qmax + 1e-6


def test_topk_keeps_largest():
    tree = _tree(2)
    comp = TopKCompressor(fraction=0.1)
    decoded, stats = comp.roundtrip(tree)
    flat, _ = ravel_pytree(tree)
    dflat, _ = ravel_pytree(decoded)
    k = max(1, int(flat.size * 0.1))
    kept = int(jnp.sum(dflat != 0))
    assert kept <= k
    # every kept value is exact and among the top-k magnitudes
    thresh = float(jnp.sort(jnp.abs(flat))[-k])
    nz = np.nonzero(np.asarray(dflat))[0]
    for i in nz:
        assert float(dflat[i]) == pytest.approx(float(flat[i]))
        assert abs(float(flat[i])) >= thresh - 1e-6
    assert stats["compression_ratio"] > 4.0


def test_fc_ae_compressor_shapes_and_ratio():
    cfg = AEConfig(input_dim=512, encoder_hidden=(64,), latent_dim=16)
    params = init_fc_ae(jax.random.PRNGKey(0), cfg)
    tree = _tree(3, sizes=((20, 20), (50,)))      # 450 params < 512
    comp = FCAECompressor(params, cfg)
    decoded, stats = comp.roundtrip(tree)
    assert jax.tree_util.tree_structure(decoded) == \
        jax.tree_util.tree_structure(tree)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(decoded)):
        assert a.shape == b.shape
    # latent 16 floats + orig_len vs 450 floats
    assert stats["compression_ratio"] > 20


def test_chunked_ae_compressor_and_composed():
    cfg = ChunkedAEConfig(chunk_size=128, hidden=(32,), latent_chunk=4)
    params = init_chunked_ae(jax.random.PRNGKey(0), cfg)
    tree = _tree(4, sizes=((40, 30), (200,)))
    comp = ChunkedAECompressor(params, cfg)
    decoded, stats = comp.roundtrip(tree)
    assert stats["compression_ratio"] > 20       # 128/4 = 32x nominal
    composed = ComposedCompressor(inner=comp, bits=8, block=64)
    decoded2, stats2 = composed.roundtrip(tree)
    assert stats2["compressed_bytes"] < stats["compressed_bytes"]
    assert jax.tree_util.tree_structure(decoded2) == \
        jax.tree_util.tree_structure(tree)


@hypothesis.given(st.integers(1, 2000), st.integers(0, 10 ** 6))
def test_property_quantize_roundtrip_any_length(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31), (n,)) * 2.0
    comp = QuantizeCompressor(bits=8, block=128)
    decoded, _ = comp.roundtrip({"w": x})
    err = jnp.abs(decoded["w"] - x)
    assert decoded["w"].shape == x.shape
    assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(x))) / 127 + 1e-6


@hypothesis.given(st.floats(0.01, 0.5), st.integers(0, 10 ** 6))
def test_property_topk_sparsity(frac, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31), (300,))
    comp = TopKCompressor(fraction=frac)
    decoded, _ = comp.roundtrip({"w": x})
    k = max(1, int(300 * frac))
    assert int(jnp.sum(decoded["w"] != 0)) <= k


@hypothesis.given(st.integers(1, 5000), st.integers(1, 64))
def test_property_chunking_bijection(n, latent):
    """chunk → unchunk is the identity on any-length vectors."""
    from repro.core.autoencoder import chunk_vector, unchunk_vector
    x = jnp.arange(n, dtype=jnp.float32)
    chunks, orig = chunk_vector(x, 64)
    back = unchunk_vector(chunks, orig)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

"""Grouped ragged decode→aggregate launch (DESIGN.md §11): differential
tests of the one-sweep Pallas kernel against the per-bucket kernel and the
pure-jnp oracle, the one-dispatch grouped server round against the
sequential bucket loop and the per-client decode oracle, flag resolution,
end-to-end run equivalence, and a property test (client permutation /
bucket packing order invariance) via hypothesis with the stub fallback."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import MNIST_CLASSIFIER
from repro.core import (ChunkedAECompressor, ChunkedAEConfig, FLConfig,
                        FederatedRun, QuantizeCompressor, codec,
                        init_chunked_ae, normalize_weights, partition)
from repro.core.scheduler import EncodedUpdate
from repro.kernels import ops
from repro.kernels.fused_decode_agg import (fused_decode_agg,
                                            grouped_fused_decode_agg)
from repro.kernels.ref import grouped_fused_decode_agg_ref
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=10,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


# ----------------------------------------------------------- kernel level
def _mk_buckets(seed: int, cohort: int, rungs: int, K: int = 8, N: int = 32):
    """Split a ``cohort`` across ``rungs`` buckets of ragged (C, M) shapes;
    cohort < rungs leaves trailing buckets EMPTY (zero clients) on purpose.
    Per-bucket weights are renormalized to Σ=1 (the kernel's contract)."""
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 2 + 2 * rungs)
    D = rungs
    w_stack = 0.1 * jax.random.normal(keys[0], (D, K, N), jnp.float32)
    b_stack = 0.1 * jax.random.normal(keys[1], (D, N), jnp.float32)
    sizes = [cohort // rungs + (1 if r < cohort % rungs else 0)
             for r in range(rungs)]
    Ms = [16, 24, 8, 40]
    hs, ws, dec_idx = [], [], []
    for r, C_b in enumerate(sizes):
        M = Ms[r % len(Ms)]
        hs.append(jax.random.normal(keys[2 + r], (C_b, M, K), jnp.float32))
        raw = jax.random.uniform(keys[2 + rungs + r], (C_b,)) + 0.1
        ws.append((raw / raw.sum() if C_b else raw).astype(jnp.float32))
        dec_idx.append(r)
    return hs, ws, w_stack, b_stack, dec_idx


@pytest.mark.parametrize("cohort", [1, 8, 64])
@pytest.mark.parametrize("rungs", [1, 2, 4])
def test_grouped_kernel_vs_oracle_and_per_bucket(cohort, rungs):
    hs, ws, w_stack, b_stack, dec_idx = _mk_buckets(
        cohort * 10 + rungs, cohort, rungs)
    got = grouped_fused_decode_agg(hs, ws, w_stack, b_stack, dec_idx,
                                   bc=16, interpret=True)
    want = grouped_fused_decode_agg_ref(hs, ws, w_stack, b_stack, dec_idx)
    assert len(got) == len(hs)
    for g, w in zip(got, want):
        assert g.shape == w.shape and g.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=2e-5, rtol=1e-4)
    # vs the per-bucket sequential kernel at the same client-block size:
    # the grouped launch's extra zero-weight padding contributes exact
    # zeros, so the accumulation is BIT-identical (the 1-ulp rule)
    for h, w, d, g in zip(hs, ws, dec_idx, got):
        if h.shape[0] == 0:
            assert not np.asarray(g).any()
            continue
        per = fused_decode_agg(h, w, w_stack[d], b_stack[d], bc=16,
                               interpret=True)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(per))


def test_grouped_kernel_single_client_bucket_and_dedup():
    # one single-client bucket + two buckets sharing one decoder slot
    key = jax.random.PRNGKey(3)
    k = jax.random.split(key, 6)
    K, N = 8, 32
    w_stack = 0.1 * jax.random.normal(k[0], (2, K, N), jnp.float32)
    b_stack = 0.1 * jax.random.normal(k[1], (2, N), jnp.float32)
    hs = [jax.random.normal(k[2], (1, 16, K), jnp.float32),
          jax.random.normal(k[3], (5, 24, K), jnp.float32),
          jax.random.normal(k[4], (3, 24, K), jnp.float32)]
    ws = [jnp.ones((1,), jnp.float32),
          jnp.full((5,), 0.2, jnp.float32),
          jnp.asarray([0.5, 0.25, 0.25], jnp.float32)]
    dec_idx = [0, 1, 1]                     # buckets 1 and 2 share slot 1
    got = grouped_fused_decode_agg(hs, ws, w_stack, b_stack, dec_idx,
                                   interpret=True)
    want = grouped_fused_decode_agg_ref(hs, ws, w_stack, b_stack, dec_idx)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=2e-5, rtol=1e-4)


def test_grouped_kernel_all_empty_returns_zeros():
    w_stack = jnp.ones((1, 4, 8), jnp.float32)
    b_stack = jnp.ones((1, 8), jnp.float32)
    out = grouped_fused_decode_agg(
        [jnp.zeros((0, 16, 4), jnp.float32)], [jnp.zeros((0,))],
        w_stack, b_stack, [0], interpret=True)
    assert out[0].shape == (16, 8) and not np.asarray(out[0]).any()


def test_grouped_kernel_under_jit():
    hs, ws, w_stack, b_stack, dec_idx = _mk_buckets(11, 6, 2)

    @jax.jit
    def run(hs_, ws_, wst, bst):
        return grouped_fused_decode_agg(list(hs_), list(ws_), wst, bst,
                                        dec_idx, interpret=True)

    got = run(tuple(hs), tuple(ws), w_stack, b_stack)
    want = grouped_fused_decode_agg_ref(hs, ws, w_stack, b_stack, dec_idx)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   atol=2e-5, rtol=1e-4)


# ------------------------------------------------------------ server level
SIZE = 1280
PMAP = partition.PartitionMap(groups=(("bulk", ((0, 768),)),
                                      ("head", ((768, 512),))))
CFG_HI = ChunkedAEConfig(chunk_size=128, hidden=(16,), latent_chunk=8)
CFG_LO = ChunkedAEConfig(chunk_size=128, hidden=(16,), latent_chunk=4)
PRM_HI = init_chunked_ae(jax.random.PRNGKey(20), CFG_HI)
PRM_LO = init_chunked_ae(jax.random.PRNGKey(21), CFG_LO)
SPEC_HI = partition.make_partition_spec(PMAP, {
    "bulk": codec.ChunkedAESpec(size=768, cfg=CFG_HI, use_kernel=True),
    "head": codec.QuantizeSpec(size=512, bits=8)})
SPEC_LO = partition.make_partition_spec(PMAP, {
    "bulk": codec.ChunkedAESpec(size=768, cfg=CFG_LO, use_kernel=True),
    "head": codec.QuantizeSpec(size=512, bits=4)})


def _mixed_cohort(n: int):
    rng = np.random.default_rng(5)
    encs, weights = [], []
    for i in range(n):
        flat = jnp.asarray(rng.normal(size=SIZE), jnp.float32)
        sp = SPEC_HI if i % 3 else SPEC_LO
        prm = {"bulk": PRM_HI if i % 3 else PRM_LO, "head": None}
        encs.append(EncodedUpdate(payload=codec.encode(sp, prm, flat),
                                  spec=sp, params=prm, weight=1.0 + i,
                                  stats={}, metrics={}))
        weights.append(1.0 + i)
    return encs, normalize_weights(weights)


@pytest.mark.parametrize("with_base", [False, True])
def test_grouped_server_round_matches_sequential_and_per_client(with_base):
    encs, nw = _mixed_cohort(7)
    base = (jnp.asarray(np.random.default_rng(9).normal(size=SIZE),
                        jnp.float32) if with_base else None)
    seq = partition.server_decode_aggregate(encs, nw, base,
                                            use_grouped_kernel=False)
    grp = partition.server_decode_aggregate(encs, nw, base,
                                            use_grouped_kernel=True)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(grp),
                               atol=1e-5, rtol=1e-4)
    rows = jnp.stack([codec.decode(e.spec, e.params, e.payload)
                      for e in encs])
    if base is not None:
        rows = rows - base[None, :]
    oracle = jnp.einsum("c,cp->p", jnp.asarray(nw, jnp.float32), rows)
    np.testing.assert_allclose(np.asarray(grp), np.asarray(oracle),
                               atol=1e-5, rtol=1e-4)


def test_grouped_server_round_homogeneous_is_bit_stable():
    # single bucket per group ⇒ the grouped round reduces with the full
    # cohort weights — identical math to the sequential single-bucket path
    rng = np.random.default_rng(6)
    encs, weights = [], []
    for i in range(5):
        flat = jnp.asarray(rng.normal(size=SIZE), jnp.float32)
        prm = {"bulk": PRM_HI, "head": None}
        encs.append(EncodedUpdate(payload=codec.encode(SPEC_HI, prm, flat),
                                  spec=SPEC_HI, params=prm, weight=1.0,
                                  stats={}, metrics={}))
        weights.append(1.0)
    nw = normalize_weights(weights)
    seq = partition.server_decode_aggregate(encs, nw, None,
                                            use_grouped_kernel=False)
    grp = partition.server_decode_aggregate(encs, nw, None,
                                            use_grouped_kernel=True)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(grp),
                               atol=2e-6, rtol=1e-5)


def test_grouped_flat_server_aggregate_matches_oracle():
    rng = np.random.default_rng(7)
    specs = [codec.ChunkedAESpec(size=768, cfg=CFG_HI, use_kernel=True),
             codec.ChunkedAESpec(size=768, cfg=CFG_LO, use_kernel=True),
             codec.QuantizeSpec(size=768, bits=8)]
    prms = [PRM_HI, PRM_LO, None]
    encs = []
    for i in range(9):
        flat = jnp.asarray(rng.normal(size=768), jnp.float32)
        sp, prm = specs[i % 3], prms[i % 3]
        encs.append(EncodedUpdate(payload=codec.encode(sp, prm, flat),
                                  spec=sp, params=prm, weight=2.0 + i,
                                  stats={}, metrics={}))
    nw = normalize_weights([2.0 + i for i in range(9)])
    grp = partition.grouped_flat_server_aggregate(encs, nw, None)
    rows = jnp.stack([codec.decode(e.spec, e.params, e.payload)
                      for e in encs])
    oracle = jnp.einsum("c,cp->p", jnp.asarray(nw, jnp.float32), rows)
    np.testing.assert_allclose(np.asarray(grp), np.asarray(oracle),
                               atol=1e-5, rtol=1e-4)


# ----------------------------------------------------------- flag plumbing
def test_use_grouped_default_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_GROUPED_KERNEL", raising=False)
    assert ops.use_grouped_default() is False          # off by default
    assert ops.use_grouped_default(True) is True
    assert ops.use_grouped_default(False) is False
    monkeypatch.setenv("REPRO_GROUPED_KERNEL", "1")
    assert ops.use_grouped_default() is True
    assert ops.use_grouped_default(False) is False     # override wins
    monkeypatch.setenv("REPRO_GROUPED_KERNEL", "0")
    assert ops.use_grouped_default() is False
    assert ops.use_grouped_default(True) is True


# ------------------------------------------------------------- end to end
def test_end_to_end_run_grouped_matches_sequential():
    data, ev = train_eval_split(mnist_like(0, 192), 48)
    shards = uniform_partition(0, data, 4)
    cfg_ae = ChunkedAEConfig(chunk_size=64, hidden=(8,), latent_chunk=4)
    prm = init_chunked_ae(jax.random.PRNGKey(2), cfg_ae)

    def mk(grouped):
        comps = [ChunkedAECompressor(prm, cfg_ae, use_kernel=True),
                 ChunkedAECompressor(prm, cfg_ae, use_kernel=True),
                 QuantizeCompressor(bits=8),
                 QuantizeCompressor(bits=4)]
        cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update",
                       use_grouped_kernel=grouped)
        return FederatedRun(MNIST_CLASSIFIER, shards, cfg,
                            compressors=comps, eval_data=ev)

    recs_seq = mk(False).run()
    recs_grp = mk(True).run()
    for a, b in zip(recs_seq, recs_grp):
        assert a.global_metrics.keys() == b.global_metrics.keys()
        for key in a.global_metrics:
            np.testing.assert_allclose(a.global_metrics[key],
                                       b.global_metrics[key],
                                       atol=1e-4, rtol=1e-3)
        np.testing.assert_allclose(a.bytes_up, b.bytes_up)


# ------------------------------------------------------------ property test
@hypothesis.given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8))
def test_grouped_aggregate_invariant_to_client_permutation(seed, n_clients):
    """Permuting the cohort permutes bucket discovery order AND the packing
    order of buckets into the grouped launch — the aggregate must not
    move beyond float-add reassociation noise."""
    rng = np.random.default_rng(seed)
    encs, weights = [], []
    for i in range(n_clients):
        flat = jnp.asarray(rng.normal(size=SIZE), jnp.float32)
        sp = (SPEC_HI, SPEC_LO)[rng.integers(2)]
        prm = {"bulk": PRM_HI if sp is SPEC_HI else PRM_LO, "head": None}
        encs.append(EncodedUpdate(payload=codec.encode(sp, prm, flat),
                                  spec=sp, params=prm,
                                  weight=float(rng.uniform(0.5, 2.0)),
                                  stats={}, metrics={}))
        weights.append(encs[-1].weight)
    nw = normalize_weights(weights)
    ref = partition.server_decode_aggregate(encs, nw, None,
                                            use_grouped_kernel=True)
    perm = rng.permutation(n_clients)
    got = partition.server_decode_aggregate(
        [encs[i] for i in perm], [nw[i] for i in perm], None,
        use_grouped_kernel=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)

"""Per-kernel validation: shape/dtype sweeps, interpret-mode kernel vs
pure-jnp oracle (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.autoencoder import (ChunkedAEConfig, chunked_decode,
                                    chunked_encode, init_chunked_ae)
from repro.kernels import ops, ref
from repro.kernels.fused_dense import fused_dense
from repro.kernels.quantize import dequantize_blocks_2d, quantize_blocks_2d

SHAPES = [(8, 16, 8), (100, 64, 32), (128, 128, 128), (257, 300, 65),
          (1, 4096, 8)]


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("act", ["relu", "linear"])
def test_fused_dense_sweep(M, K, N, dtype, act):
    k = jax.random.PRNGKey(M * 1000 + K + N)
    x = jax.random.normal(k, (M, K), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(1), (K, N)) * K ** -0.5
         ).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(2), (N,)).astype(dtype)
    got = fused_dense(x, w, b, act=act, interpret=True)
    want = ref.fused_dense_ref(x, w, b, act)
    atol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=atol, rtol=1e-2)


@pytest.mark.parametrize("n_blocks,block", [(1, 64), (7, 256), (64, 128),
                                            (300, 256)])
@pytest.mark.parametrize("bits", [8, 4])
def test_quantize_kernel_vs_ref(n_blocks, block, bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (n_blocks, block)) * 3.0
    q_k, s_k = quantize_blocks_2d(x, bits=bits, block=block, interpret=True)
    q_r, s_r = ref.quantize_blocks_ref(x, bits=bits)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    d_k = dequantize_blocks_2d(q_k, s_k, block=block, interpret=True)
    d_r = ref.dequantize_blocks_ref(q_r, s_r)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-6)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("n", [100, 4096, 10000])
def test_quantize_roundtrip_error_bound(bits, n):
    """|x - deq(q(x))| <= scale/2 per block — the quantization invariant."""
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 5.0
    q, s, orig = ops.quantize_blocks(x, bits=bits, block=256)
    back = ops.dequantize_blocks(q, s, bits=bits, block=256, orig_len=orig)
    assert back.shape == x.shape
    qmax = 2 ** (bits - 1) - 1
    blocks, _ = jnp.asarray(x), None
    pad = (-n) % 256
    xp = jnp.pad(x, (0, pad)).reshape(-1, 256)
    scale = jnp.max(jnp.abs(xp), 1) / qmax
    err = jnp.abs((back - x)).reshape(-1)
    per_block_bound = jnp.repeat(scale / 2 + 1e-6, 256)[:n]
    assert bool(jnp.all(err <= per_block_bound))


@pytest.mark.parametrize("chunk,hidden,latent", [(64, (32,), 4),
                                                 (256, (64, 32), 8),
                                                 (1024, (), 16)])
@pytest.mark.parametrize("n", [100, 5000])
def test_chunked_ae_kernel_matches_jnp(chunk, hidden, latent, n):
    cfg = ChunkedAEConfig(chunk_size=chunk, hidden=hidden,
                          latent_chunk=latent)
    params = init_chunked_ae(jax.random.PRNGKey(0), cfg)
    flat = jax.random.normal(jax.random.PRNGKey(1), (n,))
    z_k = ops.ae_encode(params, cfg, flat)
    z_j = chunked_encode(params, cfg, flat)
    np.testing.assert_allclose(np.asarray(z_k), np.asarray(z_j), atol=1e-5,
                               rtol=1e-4)
    d_k = ops.ae_decode(params, cfg, z_k, n)
    d_j = chunked_decode(params, cfg, z_j, n)
    assert d_k.shape == (n,)
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_j), atol=1e-5,
                               rtol=1e-4)


@pytest.mark.parametrize("C,M,K,N", [(1, 8, 4, 64), (4, 17, 8, 64),
                                     (8, 128, 32, 256), (3, 100, 64, 130)])
@pytest.mark.parametrize("bm", [32, 128])
@pytest.mark.parametrize("bc", [2, 16])     # 2: client-block padding path
def test_fused_decode_agg_kernel_vs_oracle(C, M, K, N, bm, bc):
    """The fused decode→aggregate kernel (weights folded into the final
    decoder matmul, DESIGN.md §7.3) vs the materialize-then-reduce oracle."""
    from repro.kernels.fused_decode_agg import fused_decode_agg
    h = jax.random.normal(jax.random.PRNGKey(C * 7 + M), (C, M, K))
    w = jax.random.dirichlet(jax.random.PRNGKey(1), jnp.ones(C))
    wl = jax.random.normal(jax.random.PRNGKey(2), (K, N)) * K ** -0.5
    bl = jax.random.normal(jax.random.PRNGKey(3), (N,))
    got = fused_decode_agg(h, w, wl, bl, bm=bm, bc=bc, interpret=True)
    want = ref.fused_decode_agg_ref(h, w, wl, bl)
    assert got.shape == (M, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


def test_fused_decode_agg_weighting_not_uniform():
    """A client with weight≈1 dominates: catches a kernel that averages
    instead of weighting."""
    from repro.kernels.fused_decode_agg import fused_decode_agg
    h = jnp.stack([jnp.ones((16, 8)), 100.0 * jnp.ones((16, 8))])
    w = jnp.array([0.999, 0.001])
    wl = jnp.eye(8)
    bl = jnp.zeros((8,))
    out = fused_decode_agg(h, w, wl, bl, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.full((16, 8), 0.999 + 0.1), rtol=1e-5)


@pytest.mark.parametrize("B,S,H,KV,D", [(1, 17, 2, 1, 16), (2, 64, 4, 2, 32),
                                        (1, 130, 8, 8, 64)])
@pytest.mark.parametrize("mode,window", [("causal", None), ("window", 13),
                                         ("full", None)])
def test_flash_attention_pallas_vs_oracle(B, S, H, KV, D, mode, window):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import flash_attention as flash_ref
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))
    got = flash_attention_pallas(q, k, v, mode=mode, window=window,
                                 q_block=32, kv_block=32, interpret=True)
    want = flash_ref(q, k, v, mode=mode, window=window,
                     q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_pallas_dtypes(dtype):
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import flash_attention as flash_ref
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 48, 4, 32), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 48, 2, 32), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 48, 2, 32), dtype)
    got = flash_attention_pallas(q, k, v, q_block=16, kv_block=16,
                                 interpret=True)
    want = flash_ref(q, k, v, q_chunk=16, kv_chunk=16)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 3e-5,
                               rtol=2e-2)

"""Jit-native codec protocol tests (DESIGN.md §7): spec staticness, jit/vmap
compatibility, batched-decode ≡ per-client-decode, fused decode+aggregate ≡
decode-then-weighted_mean, the shard_map variant, and the kernel dispatch /
mandatory-orig_len satellite contracts."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig
from repro.core import (ChunkedAECompressor, ChunkedAEConfig,
                        ComposedCompressor, FCAECompressor,
                        IdentityCompressor, QuantizeCompressor,
                        TopKCompressor, codec, init_chunked_ae, init_fc_ae,
                        normalize_weights, weighted_mean)

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

N = 1250                                     # deliberately chunk-ragged

_CHUNK_CFG = ChunkedAEConfig(chunk_size=128, hidden=(32,), latent_chunk=4)
_CHUNK_PARAMS = init_chunked_ae(jax.random.PRNGKey(0), _CHUNK_CFG)
_FC_CFG = AEConfig(input_dim=2048, encoder_hidden=(64,), latent_dim=16)
_FC_PARAMS = init_fc_ae(jax.random.PRNGKey(0), _FC_CFG)


def _all_compressors():
    return [
        IdentityCompressor(),
        QuantizeCompressor(bits=8, block=64),
        QuantizeCompressor(bits=4, block=64),
        TopKCompressor(fraction=0.1),
        FCAECompressor(_FC_PARAMS, _FC_CFG),
        ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG, use_kernel=False),
        ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG, use_kernel=True),
        ComposedCompressor(
            inner=ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG,
                                      use_kernel=False), bits=8, block=64),
    ]


def _ids():
    return [c.name + ("_k" if getattr(c, "use_kernel", False) else "")
            for c in _all_compressors()]


def _flat(seed, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (N,)) * scale


# ------------------------------------------------------------ spec contract
@pytest.mark.parametrize("comp", _all_compressors(), ids=_ids())
def test_spec_is_hashable_and_jit_static(comp):
    """Specs are frozen/hashable → usable as jit static args; two calls with
    the same spec hit the same compiled executable (no orig_len tracing)."""
    spec = comp.spec(N)
    assert hash(spec) == hash(comp.spec(N))
    assert spec == comp.spec(N)
    assert spec.size == N
    p = comp.codec_params()
    enc = jax.jit(codec.encode, static_argnums=0)
    dec = jax.jit(codec.decode, static_argnums=0)
    payload = enc(spec, p, _flat(0))
    out = dec(spec, p, payload)
    assert out.shape == (N,)
    # no length metadata crosses the wire: payload is spec-decodable alone
    assert "orig_len" not in payload and "size" not in payload


@pytest.mark.parametrize("comp", _all_compressors(), ids=_ids())
def test_roundtrip_under_jit_matches_eager(comp):
    spec, p = comp.spec(N), comp.codec_params()
    x = _flat(1)
    eager = codec.decode(spec, p, codec.encode(spec, p, x))
    jitted = jax.jit(
        lambda xx: codec.decode(spec, p, codec.encode(spec, p, xx)))(x)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager),
                               atol=1e-6, rtol=1e-5)


# ------------------------------------------------------- batched ≡ per-client
@pytest.mark.parametrize("comp", _all_compressors(), ids=_ids())
def test_vmap_decode_over_client_axis(comp):
    """decode is vmap-compatible over a stacked client axis and agrees with
    the per-client loop."""
    spec, p = comp.spec(N), comp.codec_params()
    payloads = [codec.encode(spec, p, _flat(i, 1.0 + i)) for i in range(4)]
    stacked = codec.stack_payloads(payloads)
    got = jax.vmap(lambda pl: codec.decode(spec, p, pl))(stacked)
    want = jnp.stack([codec.decode(spec, p, pl) for pl in payloads])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("comp", _all_compressors(), ids=_ids())
def test_decode_batched_matches_per_client(comp):
    spec, p = comp.spec(N), comp.codec_params()
    payloads = [codec.encode(spec, p, _flat(i, 1.0 + i)) for i in range(5)]
    stacked = codec.stack_payloads(payloads)
    got = codec.decode_batched(spec, p, stacked)
    want = jnp.stack([codec.decode(spec, p, pl) for pl in payloads])
    assert got.shape == (5, N)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-4)


# -------------------------------------------- fused ≡ decode + weighted_mean
@pytest.mark.parametrize("comp", _all_compressors(), ids=_ids())
@pytest.mark.parametrize("use_base", [False, True])
def test_decode_and_aggregate_matches_sequential(comp, use_base):
    """The one-call fused server path ≡ per-client decode then
    weighted_mean (the acceptance equivalence, ≤1e-5 rel)."""
    spec, p = comp.spec(N), comp.codec_params()
    weights = [512.0, 317.0, 100.0]
    payloads = [codec.encode(spec, p, _flat(i, 1.0 + i)) for i in range(3)]
    stacked = codec.stack_payloads(payloads)
    base = _flat(99, 0.5) if use_base else None
    nw = jnp.asarray(normalize_weights(weights), jnp.float32)
    got = codec.decode_and_aggregate(spec, p, stacked, nw, base)

    rows = [codec.decode(spec, p, pl) for pl in payloads]
    if base is not None:
        rows = [r - base for r in rows]
    want, = jax.tree_util.tree_leaves(
        weighted_mean([{"u": r} for r in rows], weights))
    scale = float(jnp.max(jnp.abs(want))) + 1e-6
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5 * scale, rtol=1e-5)


def test_decode_and_aggregate_per_client_params():
    """Per-client AE decoders ride a stacked params axis (params_batched)."""
    specs = [codec.FCAESpec(size=N, cfg=_FC_CFG)]
    params = [init_fc_ae(jax.random.PRNGKey(i), _FC_CFG) for i in range(3)]
    spec = specs[0]
    payloads = [codec.encode(spec, params[i], _flat(i)) for i in range(3)]
    stacked = codec.stack_payloads(payloads)
    stacked_params = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *params)
    nw = jnp.asarray(normalize_weights([1.0, 2.0, 3.0]), jnp.float32)
    got = codec.decode_and_aggregate(spec, stacked_params, stacked, nw,
                                     params_batched=True)
    want = jnp.einsum("c,cp->p", nw, jnp.stack(
        [codec.decode(spec, params[i], payloads[i]) for i in range(3)]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("comp", [QuantizeCompressor(bits=8, block=64),
                                  ChunkedAECompressor(_CHUNK_PARAMS,
                                                      _CHUNK_CFG,
                                                      use_kernel=True)],
                         ids=["quantize8", "chunked_ae_kernel"])
@pytest.mark.parametrize("cohort", [1, 5])                 # pad path: 1 dev
def test_decode_and_aggregate_sharded_matches_fused(comp, cohort):
    """shard_map client-axis variant (DESIGN.md §7.2) ≡ the fused call,
    including the zero-weight padding path when C % n_devices != 0."""
    spec, p = comp.spec(N), comp.codec_params()
    payloads = [codec.encode(spec, p, _flat(i, 1.0 + i))
                for i in range(cohort)]
    stacked = codec.stack_payloads(payloads)
    nw = jnp.asarray(normalize_weights([1.0 + i for i in range(cohort)]),
                     jnp.float32)
    fused = codec.decode_and_aggregate(spec, p, stacked, nw)
    sharded = codec.decode_and_aggregate_sharded(spec, p, stacked, nw)
    np.testing.assert_allclose(np.asarray(sharded), np.asarray(fused),
                               atol=2e-5, rtol=1e-4)


# ------------------------------------------------------ satellite contracts
def test_weighted_mean_stacked_normalizes_array_weights():
    """Same result whether weights arrive as a python list or a jax array
    (device-array weights must not silently skip normalization)."""
    from repro.core import weighted_mean_stacked
    stacked = {"a": jnp.stack([jnp.ones((3,)), 3.0 * jnp.ones((3,))])}
    from_list = weighted_mean_stacked(stacked, [2.0, 2.0])
    from_array = weighted_mean_stacked(stacked, jnp.array([2.0, 2.0]))
    np.testing.assert_allclose(np.asarray(from_list["a"]), 2.0)
    np.testing.assert_allclose(np.asarray(from_array["a"]), 2.0)
    # normalized=True trusts the caller (the fused server path contract)
    pre = weighted_mean_stacked(stacked, jnp.array([0.5, 0.5]),
                                normalized=True)
    np.testing.assert_allclose(np.asarray(pre["a"]), 2.0)


def test_dequantize_blocks_requires_orig_len():
    """orig_len is mandatory: the padded-tail default was a silent-corruption
    footgun (a forgotten slice returned block-padded garbage)."""
    from repro.kernels import ops
    q, s, orig = ops.quantize_blocks(_flat(0), bits=8, block=256)
    with pytest.raises(TypeError):
        ops.dequantize_blocks(q, s, bits=8, block=256)   # no orig_len
    with pytest.raises(ValueError):
        ops.dequantize_blocks(q, s, bits=8, block=256, orig_len=0)
    back = ops.dequantize_blocks(q, s, bits=8, block=256, orig_len=orig)
    assert back.shape == (N,)


def test_use_kernel_autoselects_from_backend(monkeypatch):
    """Kernel dispatch: backend auto-detection with env override — TPU runs
    must not silently take the pure-jnp path (and vice versa on CPU)."""
    from repro.kernels import ops
    monkeypatch.delenv("REPRO_USE_KERNEL", raising=False)
    assert ops.use_kernel_default() == (jax.default_backend() == "tpu")
    monkeypatch.setenv("REPRO_USE_KERNEL", "1")
    assert ops.use_kernel_default() is True
    monkeypatch.setenv("REPRO_USE_KERNEL", "0")
    assert ops.use_kernel_default() is False
    # explicit compressor field wins over everything
    assert ops.use_kernel_default(True) is True
    comp = ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG, use_kernel=True)
    assert comp.spec(N).use_kernel is True
    monkeypatch.delenv("REPRO_USE_KERNEL", raising=False)
    auto = ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG)
    assert auto.spec(N).use_kernel == (jax.default_backend() == "tpu")


def test_scheduler_round_uses_single_fused_call(monkeypatch):
    """The acceptance property: a scheduler round makes exactly ONE
    decode_and_aggregate call regardless of cohort size (no per-client
    decode dispatch in the round loop; error feedback is off here)."""
    from repro.configs.paper import MNIST_CLASSIFIER
    from repro.core import FLConfig, FederatedRun, SyncFedAvg
    from repro.core import scheduler as sched_mod
    from repro.data.pipeline import mnist_like, train_eval_split, \
        uniform_partition
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 3)
    calls = {"fused": 0, "decode": 0}
    real_fused = codec.decode_and_aggregate
    real_decode = codec.decode
    monkeypatch.setattr(
        sched_mod.codec, "decode_and_aggregate",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1),
                         real_fused(*a, **k))[1])
    monkeypatch.setattr(
        sched_mod.codec, "decode",
        lambda *a, **k: (calls.__setitem__("decode", calls["decode"] + 1),
                         real_decode(*a, **k))[1])
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=2, local_epochs=1,
                                payload="update"),
                       compressors=[QuantizeCompressor(bits=8)
                                    for _ in range(3)],
                       eval_data=ev, scheduler=SyncFedAvg())
    run.run()
    assert calls["fused"] == 2           # one per round
    assert calls["decode"] == 0          # zero per-client server decodes


# ------------------------------------------------------------ property tests
@hypothesis.given(st.integers(10, 3000), st.integers(0, 10 ** 6))
def test_property_quantize_codec_jit_roundtrip(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31), (n,)) * 2.0
    spec = codec.QuantizeSpec(size=n, bits=8, block=128)
    out = jax.jit(
        lambda xx: codec.decode(spec, None,
                                codec.encode(spec, None, xx)))(x)
    assert out.shape == x.shape
    assert float(jnp.max(jnp.abs(out - x))) <= \
        float(jnp.max(jnp.abs(x))) / 127 + 1e-6


@hypothesis.given(st.integers(2, 6), st.integers(0, 10 ** 6))
def test_property_fused_agg_equals_sequential_any_cohort(c, seed):
    spec = codec.QuantizeSpec(size=N, bits=8, block=64)
    payloads = [codec.encode(spec, None, _flat(seed % 2 ** 30 + i))
                for i in range(c)]
    stacked = codec.stack_payloads(payloads)
    nw = jnp.asarray(normalize_weights([1.0] * c), jnp.float32)
    got = codec.decode_and_aggregate(spec, None, stacked, nw)
    want = jnp.mean(jnp.stack([codec.decode(spec, None, pl)
                               for pl in payloads]), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@hypothesis.given(st.integers(1, 4000))
def test_property_chunked_spec_n_chunks(n):
    spec = codec.ChunkedAESpec(size=n, cfg=_CHUNK_CFG)
    assert spec.n_chunks == -(-n // _CHUNK_CFG.chunk_size)

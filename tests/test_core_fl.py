"""Federated runtime + AE training + savings-ratio analytics (paper claims
as unit tests)."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import (CIFAR_AE, MNIST_AE, MNIST_CLASSIFIER,
                                 AEConfig)
from repro.core import (FLConfig, FederatedRun, IdentityCompressor,
                        QuantizeCompressor, SavingsModel, ae_param_count,
                        fedavg, init_fc_ae, train_autoencoder, weighted_mean)
from repro.data.pipeline import (color_imbalance_split, dirichlet_partition,
                                 mnist_like)
from repro.models.classifiers import init_classifier, n_params

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


# ------------------------------------------------------------- paper counts
def test_mnist_classifier_param_count_exact():
    """Paper §4.1: the MNIST classifier has 15,910 parameters."""
    params = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)
    assert n_params(params) == 15_910


def test_cifar_ae_param_count_exact():
    """Paper §5.3: the CIFAR FC AE has 352,915,690 parameters and ~1720x."""
    assert CIFAR_AE.n_params == 352_915_690
    assert CIFAR_AE.compression_ratio == pytest.approx(1720.5, abs=0.1)


def test_mnist_ae_ratio_about_500x():
    """Paper §5.1: 32-feature encoding → about 500x."""
    assert MNIST_AE.latent_dim == 32
    assert 490 < MNIST_AE.compression_ratio < 510


# ---------------------------------------------------------------- AE training
def test_ae_training_reduces_loss():
    cfg = AEConfig(input_dim=128, encoder_hidden=(32,), latent_dim=8)
    # low-rank structured data — like weight trajectories, compressible by
    # construction (an AE cannot compress iid noise)
    z = jax.random.normal(jax.random.PRNGKey(0), (24, 4))
    basis = jax.random.normal(jax.random.PRNGKey(1), (4, 128))
    data = z @ basis + 0.01 * jax.random.normal(jax.random.PRNGKey(2),
                                                (24, 128))
    params, hist = train_autoencoder(jax.random.PRNGKey(3), cfg, data,
                                     epochs=60, batch_size=8)
    assert hist["loss"][-1] < hist["loss"][0] * 0.5
    assert ae_param_count(params) == cfg.n_params


# ---------------------------------------------------------------- aggregation
def test_weighted_mean_exact():
    t1 = {"w": jnp.ones((3,))}
    t2 = {"w": jnp.full((3,), 3.0)}
    m = weighted_mean([t1, t2], [1.0, 3.0])
    np.testing.assert_allclose(np.asarray(m["w"]), 2.5)


@hypothesis.given(st.integers(1, 5), st.integers(0, 10 ** 6))
def test_property_fedavg_identical_updates_fixed_point(n, seed):
    """FedAvg over identical updates == applying the single update."""
    k = jax.random.PRNGKey(seed % 2 ** 31)
    g = {"w": jax.random.normal(k, (4, 3))}
    u = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 3)) * 0.1}
    new = fedavg(g, [u] * n)
    np.testing.assert_allclose(np.asarray(new["w"]),
                               np.asarray(g["w"] + u["w"]), atol=1e-6)


# ---------------------------------------------------------------- savings Eq.4
def test_break_even_rounds_per_collab_decoder_is_320():
    """Paper Fig. 11: with one decoder per collaborator, break-even at 320
    communication rounds (CIFAR numbers)."""
    sm = SavingsModel(original_size=550_570, compressed_size=320,
                      autoencoder_size=352_915_690, n_decoders=1)
    # case (b): per-collaborator decoders → collabs cancels; use 1 collab
    assert sm.break_even_rounds(collabs=1) == 321  # SR>1 strictly


def test_savings_ratio_large_scale_trend():
    """Paper Fig. 10: SR grows with collaborators, ~120x around 1000
    collaborators at ~40 rounds, asymptote 1720x."""
    sm = SavingsModel(original_size=550_570, compressed_size=320,
                      autoencoder_size=352_915_690, n_decoders=1)
    sr_1000 = sm.savings_ratio(comm_rounds=40, collabs=1000)
    assert 80 < sr_1000 < 160
    assert sm.asymptotic_ratio() == pytest.approx(1720.5, abs=0.1)
    assert sm.savings_ratio(40, 10) < sm.savings_ratio(40, 100) \
        < sm.savings_ratio(40, 1000)


def test_savings_degenerate_inputs_are_guarded():
    """Satellite (bugfix): compression ratio ≤ 1, zero-width latents, and
    zero-cost decoders used to divide by zero or drive the break-even
    bisections off a meaningless ratio; negative sizes are rejected at
    construction. The documented sentinels: ``inf`` savings ratio for a
    zero denominator, ``None`` for never-breaks-even."""
    # ratio ≤ 1: never breaks even, regardless of decoder cost
    at_parity = SavingsModel(original_size=100, compressed_size=100,
                             autoencoder_size=0)
    worse = SavingsModel(original_size=100, compressed_size=200,
                         autoencoder_size=1000)
    for sm in (at_parity, worse):
        assert sm.break_even_collabs(comm_rounds=40) is None
        assert sm.break_even_rounds(collabs=40) is None
    assert at_parity.savings_ratio(40, 40) == 1.0     # no ZeroDivision

    # zero-cost decoder with a real ratio: breaks even immediately
    free = SavingsModel(original_size=100, compressed_size=10,
                        autoencoder_size=0)
    assert free.break_even_collabs(comm_rounds=1) == 1
    assert free.break_even_rounds(collabs=1) == 1
    assert free.savings_ratio(1, 1) == 10.0

    # zero-width latent + zero cost: the everything-is-free degenerate —
    # previously a ZeroDivisionError
    degenerate = SavingsModel(original_size=100, compressed_size=0,
                              autoencoder_size=0)
    assert degenerate.savings_ratio(10, 10) == float("inf")
    assert degenerate.asymptotic_ratio() == float("inf")
    assert degenerate.break_even_collabs(comm_rounds=1) == 1

    # negative sizes: rejected (previously produced negative break-evens
    # via a negative Eq.-4 denominator)
    with pytest.raises(ValueError):
        SavingsModel(original_size=100, compressed_size=-10,
                     autoencoder_size=1000)
    with pytest.raises(ValueError):
        SavingsModel(original_size=-1, compressed_size=10,
                     autoencoder_size=1000)


@hypothesis.given(st.integers(1, 500), st.integers(1, 500))
def test_property_savings_monotonic(rounds, collabs):
    sm = SavingsModel(original_size=10_000, compressed_size=10,
                      autoencoder_size=100_000, n_decoders=1)
    assert sm.savings_ratio(rounds + 1, collabs) >= \
        sm.savings_ratio(rounds, collabs)
    assert sm.savings_ratio(rounds, collabs + 1) >= \
        sm.savings_ratio(rounds, collabs)
    assert sm.savings_ratio(rounds, collabs) < sm.asymptotic_ratio()


# ---------------------------------------------------------------- FL e2e
def test_federated_two_collaborators_trains():
    """Small FL run (identity codec): global accuracy improves."""
    from repro.data.pipeline import train_eval_split
    train, eval_data = train_eval_split(mnist_like(0, 768), 256)
    # near-IID split for the smoke test (strong label skew needs many more
    # rounds to converge — the non-IID regime is exercised in the
    # color-imbalance test and the fl_color_imbalance example)
    data = dirichlet_partition(0, train, 2, alpha=10.0)
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=4, local_epochs=3, lr=3e-3),
                       eval_data=eval_data)
    hist = run.run()
    assert len(hist) == 4
    accs = [r.global_metrics["accuracy"] for r in hist]
    assert accs[-1] > 0.5
    assert accs[-1] > accs[0]              # federation makes progress
    assert hist[0].compression_ratio == pytest.approx(1.0, rel=0.05)


def test_federated_quantized_color_imbalance():
    """Paper §5.2 shape: 2 collaborators with color imbalance, compressed
    updates; both still train."""
    from repro.configs.paper import CIFAR_CLASSIFIER
    data, eval_data = color_imbalance_split(0, n_per_collab=256)
    run = FederatedRun(
        CIFAR_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, lr=2e-3),
        compressors=[QuantizeCompressor(bits=8), QuantizeCompressor(bits=8)],
        eval_data=eval_data)
    hist = run.run()
    assert hist[-1].compression_ratio > 3.5
    assert all(np.isfinite(r.global_metrics["loss"]) for r in hist)


def test_error_feedback_accumulates():
    """With an aggressive codec, error feedback must not diverge and keeps a
    residual."""
    from repro.data.pipeline import train_eval_split
    train, eval_data = train_eval_split(mnist_like(1, 384), 128)
    data = dirichlet_partition(1, train, 2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, error_feedback=True),
        compressors=[QuantizeCompressor(bits=4),
                     QuantizeCompressor(bits=4)],
        eval_data=eval_data)
    hist = run.run()
    assert run._residuals[0] is not None
    assert np.isfinite(hist[-1].global_metrics["loss"])


def test_weights_payload_ae_fl_trains():
    """Paper §5.2 protocol: AE compresses converged WEIGHTS each round; the
    federation trains under ~500x compression (the headline claim)."""
    from repro.configs.paper import MNIST_AE
    from repro.core import FCAECompressor, run_prepass
    from repro.data.pipeline import train_eval_split
    train, ev = train_eval_split(mnist_like(0, 768), 256)
    out = run_prepass(jax.random.PRNGKey(0), MNIST_CLASSIFIER, MNIST_AE,
                      train, prepass_epochs=8, ae_epochs=80)
    data = dirichlet_partition(0, train, 2, alpha=2.0)
    comp = [FCAECompressor(out["ae_params"], MNIST_AE) for _ in range(2)]
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=3, local_epochs=2,
                                payload="weights"),
                       compressors=comp, eval_data=ev)
    hist = run.run()
    accs = [r.global_metrics["accuracy"] for r in hist]
    assert accs[-1] > 0.6, accs
    assert hist[-1].compression_ratio > 400


def test_fedprox_runs():
    from repro.data.pipeline import train_eval_split
    train, ev = train_eval_split(mnist_like(2, 512), 128)
    data = dirichlet_partition(0, train, 2, alpha=0.5)
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=2, local_epochs=1,
                                aggregation="fedprox", prox_mu=0.1),
                       eval_data=ev)
    hist = run.run()
    assert np.isfinite(hist[-1].global_metrics["loss"])


def test_federated_checkpoint_roundtrip(tmp_path):
    import os
    from repro.checkpoint.checkpoint import (load_federated_state,
                                             save_federated_state)
    from repro.models.classifiers import init_classifier
    params = init_classifier(jax.random.PRNGKey(3), MNIST_CLASSIFIER)
    path = os.path.join(tmp_path, "fl.npz")
    save_federated_state(path, 17, params, extra={"note": "round17"})
    rnd, restored, meta = load_federated_state(path, params)
    assert rnd == 17 and meta["note"] == "round17"
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))

"""Struct-of-arrays client state ≡ eager ClientState (DESIGN.md §12.1).

Three layers of guarantees:

* **view semantics** — ``ClientPool``'s per-client views reproduce the
  exact ``list``/``dict`` discipline the lifecycle/ratecontrol snapshot
  code uses (append + ``del v[:-k]`` rings, get-with-default scalars);
* **differential runs** — a ``FederatedRun(soa_state=True)`` with the
  vectorized arrival engine matches the eager heap-oracle run in BYTES
  and TRAJECTORY, bit-exact, across schedulers (the ISSUE 7 acceptance
  gate at small populations);
* **checkpoint round-trip** — pool state (ring contents + cursors +
  residual block) survives ``save_federated_state``, including restoring
  a heap-engine checkpoint into a vector-engine run.

Plus the dispatch byte-accounting satellite: ``AsyncBuffered._dispatch``
now computes ``tree_bytes(global_params)`` once per model version, not
once per client per dispatch — counted via monkeypatch, with byte totals
asserted unchanged."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import MNIST_CLASSIFIER
from repro.core import scheduler as scheduler_mod
from repro.core import (AsyncBuffered, ClientPool, FLConfig, FederatedRun,
                        LatencyModel, QuantizeCompressor, SampledSync)
from repro.core.soa import RingStore, RingView
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)
from repro.models.classifiers import init_classifier

N_CLIENTS = 5
TMPL = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)


def _data(n=N_CLIENTS):
    train, ev = train_eval_split(mnist_like(0, 96), 32)
    return uniform_partition(0, train, n), ev


def _async_sched(engine):
    return AsyncBuffered(
        buffer_k=2,
        latency=LatencyModel(base=1.0, jitter=0.3, straggler_frac=0.3,
                             seed=5),
        engine=engine)


def _records_equal(a, b):
    assert a.participants == b.participants
    assert a.staleness == b.staleness
    assert a.bytes_up == b.bytes_up
    assert a.bytes_up_raw == b.bytes_up_raw
    assert a.bytes_down == b.bytes_down
    assert a.bytes_decoder == b.bytes_decoder
    assert a.sim_time == b.sim_time
    assert a.global_metrics == b.global_metrics


def _params_equal(x, y):
    for a, b in zip(jax.tree_util.tree_leaves(x),
                    jax.tree_util.tree_leaves(y)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# =====================================================================
# view semantics
# =====================================================================
def test_ring_view_list_discipline():
    """append + ``del v[:-k]`` (the one pattern lifecycle/ratecontrol use)
    matches a plain list through overwrite wraparound."""
    store = RingStore(2, depth=3)
    view, oracle = RingView(store, 1), []
    for i in range(7):
        view.append(jnp.full(4, float(i)))
        oracle.append(np.full(4, float(i)))
        del oracle[:-3]
        assert len(view) == len(oracle)
        for j in range(len(oracle)):
            np.testing.assert_array_equal(np.asarray(view[j]), oracle[j])
        np.testing.assert_array_equal(np.asarray(view[-1]), oracle[-1])
    # stacking order (what _refit consumes) matches too
    np.testing.assert_array_equal(
        np.asarray(jnp.stack(list(view))), np.stack(oracle))
    del view[:]                        # drop-everything edge case
    assert len(view) == 0 and not view


def test_client_view_scalars_and_part_dicts():
    pool = ClientPool(3, TMPL, ring_depth=4)
    v = pool[2]
    assert v.residual is None and v.ae_baseline is None
    assert v.last_refresh == -1 and v.version == 0
    v.residual = TMPL
    _params_equal(v.residual, TMPL)
    v.residual = None
    assert v.residual is None
    v.ae_baseline = 0.25
    v.version, v.last_refresh = 7, 3
    assert (v.ae_baseline, v.version, v.last_refresh) == (0.25, 7, 3)
    # part_* dict discipline: setdefault-append rings, sentinel scalars
    ring = v.part_snapshots.setdefault("dense0", [])
    ring.append(jnp.ones(6))
    assert len(v.part_snapshots["dense0"]) == 1
    assert v.part_snapshots.get("missing", []) == []
    assert "dense0" in v.part_snapshots and "missing" not in v.part_snapshots
    # a different client's lane is independent
    assert pool[0].part_snapshots.get("dense0") is None
    v.part_last_refresh["dense0"] = 5
    assert v.part_last_refresh.get("dense0", -1) == 5
    assert v.part_last_refresh.get("other", -1) == -1
    v.part_baseline["dense0"] = None
    assert v.part_baseline.get("dense0") is None
    v.part_baseline["dense0"] = 0.5
    assert v.part_baseline["dense0"] == 0.5
    # pool container surface
    assert len(pool) == 3 and len(list(pool)) == 3


def test_gather_scatter_residual_rows():
    pool = ClientPool(4, TMPL, ring_depth=2)
    rows = jnp.stack([jnp.full(pool.psize, float(i)) for i in (1, 3)])
    pool.scatter_residuals([1, 3], rows)
    got, mask = pool.gather_residuals([0, 1, 3])
    assert list(mask) == [False, True, True]
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(rows[0]))
    # view reads the scattered row back as a model-shaped pytree
    flat = np.concatenate([np.asarray(x).ravel() for x in
                           jax.tree_util.tree_leaves(pool[3].residual)])
    assert set(np.unique(flat)) == {3.0}


# =====================================================================
# differential: SoA + vector engine ≡ eager + heap oracle
# =====================================================================
@pytest.mark.parametrize("sched", ["async", "sampled"])
def test_soa_vector_matches_eager_heap(sched):
    data, ev = _data()

    def mk(soa):
        cfg = FLConfig(n_rounds=4, local_epochs=1, error_feedback=True,
                       seed=3)
        s = (_async_sched("vector" if soa else "heap") if sched == "async"
             else SampledSync(cohort=3))
        return FederatedRun(
            MNIST_CLASSIFIER, data, cfg, eval_data=ev, scheduler=s,
            compressors=[QuantizeCompressor(bits=8)
                         for _ in range(N_CLIENTS)],
            soa_state=soa)

    eager = mk(False)
    hist_e = eager.run()
    pooled = mk(True)
    hist_p = pooled.run()
    for a, b in zip(hist_e, hist_p):
        _records_equal(a, b)
    _params_equal(eager.global_params, pooled.global_params)
    # residuals (the per-client compressor state) match too
    for ce, cp in zip(eager.clients, pooled.clients):
        if ce.residual is None:
            assert cp.residual is None
        else:
            _params_equal(ce.residual, cp.residual)


# =====================================================================
# checkpoint round-trip
# =====================================================================
@pytest.mark.parametrize("save_engine,load_engine",
                         [("vector", "vector"), ("heap", "vector"),
                          ("vector", "heap")])
def test_soa_resume_and_engine_cross_restore(save_engine, load_engine,
                                             tmp_path):
    """SoA checkpoints resume bit-exact, including across engines (both
    serialize the same event-queue JSON shape)."""
    data, ev = _data()

    def mk(n_rounds, engine):
        cfg = FLConfig(n_rounds=n_rounds, local_epochs=1,
                       error_feedback=True, seed=3)
        return FederatedRun(MNIST_CLASSIFIER, data, cfg, eval_data=ev,
                            scheduler=_async_sched(engine), soa_state=True)

    full = mk(4, save_engine)
    hist_full = full.run()

    first = mk(2, save_engine)
    first.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    first.save_state(path)

    resumed = mk(2, load_engine)
    assert resumed.load_state(path) == 2
    assert isinstance(resumed.clients, ClientPool)
    hist_resumed = resumed.run()
    for a, b in zip(hist_full[2:], hist_resumed):
        _records_equal(a, b)
    _params_equal(full.global_params, resumed.global_params)


def test_pool_state_round_trip_preserves_rings_and_scalars():
    pool = ClientPool(3, TMPL, ring_depth=3)
    for i in range(5):                       # wraps the depth-3 ring
        pool[1].snapshots.append(jnp.full(4, float(i)))
    pool[1].residual = TMPL
    pool[2].dispatched = TMPL
    pool[0].part_snapshots.setdefault("g", []).append(jnp.ones(2))
    pool[0].part_last_refresh["g"] = 4
    pool[0].part_baseline["g"] = 0.125
    pool[2].ae_baseline = None
    pool[1].version = 9
    tree, meta = pool.state()
    clone = ClientPool.from_state(tree, meta, TMPL)
    assert len(clone[1].snapshots) == 3      # depth-capped, newest kept
    np.testing.assert_array_equal(np.asarray(clone[1].snapshots[-1]),
                                  np.full(4, 4.0))
    np.testing.assert_array_equal(np.asarray(clone[1].snapshots[0]),
                                  np.full(4, 2.0))
    _params_equal(clone[1].residual, TMPL)
    _params_equal(clone[2].dispatched, TMPL)
    assert clone[0].part_last_refresh["g"] == 4
    assert clone[0].part_baseline["g"] == 0.125
    assert clone[2].ae_baseline is None
    assert clone[1].version == 9
    assert clone[0].residual is None and clone[0].dispatched is None


# =====================================================================
# satellite: dispatch byte-accounting cache
# =====================================================================
def test_dispatch_broadcast_bytes_cached_per_version():
    """tree_bytes(global_params) is computed once per model version, not
    once per client per dispatch — and the recorded byte totals are
    identical to the uncached eager run."""
    data, ev = _data()

    def mk():
        cfg = FLConfig(n_rounds=3, local_epochs=1, seed=3)
        return FederatedRun(MNIST_CLASSIFIER, data, cfg, eval_data=ev,
                            scheduler=_async_sched("heap"))

    calls = {"n": 0}
    real = scheduler_mod.tree_bytes

    def counting(tree):
        calls["n"] += 1
        return real(tree)

    scheduler_mod.tree_bytes = counting
    try:
        run = mk()                      # ctor dispatches all N clients
        reset_calls = calls["n"]
        hist = run.run()
    finally:
        scheduler_mod.tree_bytes = real
    # reset: N dispatches share ONE tree_bytes call (version 0)
    assert reset_calls == 1
    # each round: one call per new version (the re-dispatch batch) plus
    # the scheduler-independent model_bytes probes — never per client.
    # 3 rounds × (1 cached-miss + ...) stays far under N per round.
    assert calls["n"] - reset_calls <= 2 * len(hist)

    # byte totals equal a fresh (uninstrumented) run's
    ref = mk()
    ref_hist = ref.run()
    for a, b in zip(hist, ref_hist):
        assert a.bytes_down == b.bytes_down
        assert a.bytes_down_raw == b.bytes_down_raw
        assert a.bytes_up == b.bytes_up

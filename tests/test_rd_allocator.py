"""Lagrangian rate-distortion allocation (DESIGN.md §15.3): convex-hull
pruning units, λ-sweep water-filling units, hypothesis properties (budget
feasibility, client-order invariance), the RD ≡ greedy differential
contract on affine equal-slope curves, and the end-to-end RD ≥ greedy
accuracy-per-byte check on a Dirichlet label-skew split."""
try:
    import hypothesis
    import hypothesis.strategies as st
    _HAVE_HYPOTHESIS = True
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
    _HAVE_HYPOTHESIS = False
import numpy as np
import pytest

from repro.configs.paper import MNIST_CLASSIFIER
from repro.core import (ByteBudget, FLConfig, FederatedRun,
                        IdentityCompressor, QuantizeCompressor, RDBudget)
from repro.core.ratecontrol import _hull_prune, _rd_topup, _rd_waterfill
from repro.data.pipeline import (dirichlet_partition, mnist_like,
                                 train_eval_split, uniform_partition)

P = 15_910                               # MNIST classifier param count


def _pointwise_ladder(n_clients):
    return [[QuantizeCompressor(bits=4), QuantizeCompressor(bits=8),
             IdentityCompressor()] for _ in range(n_clients)]


# ---------------------------------------------------- hull pruning units
def test_hull_prune_drops_dominated_and_concave_points():
    # rung 1 is concave (skipping it buys more distortion per byte), rung 3
    # is dominated (pricier than rung 2, no less distorted)
    pts = [(0, 0.0, 0.0, 10.0), (1, 4.0, 4.0, 9.0), (2, 8.0, 8.0, 0.0),
           (3, 9.0, 9.0, 0.5)]
    hull = _hull_prune(pts)
    assert [p[0] for p in hull] == [0, 2]


def test_hull_prune_keeps_convex_and_collinear_points():
    convex = [(0, 0.0, 0.0, 10.0), (1, 1.0, 1.0, 4.0), (2, 3.0, 3.0, 1.0)]
    assert [p[0] for p in _hull_prune(convex)] == [0, 1, 2]
    # collinear chain: single-rung steps must survive (the greedy
    # differential contract depends on stepping rung-by-rung)
    collinear = [(0, 0.0, 0.0, 9.0), (1, 1.0, 1.0, 6.0),
                 (2, 2.0, 2.0, 3.0), (3, 3.0, 3.0, 0.0)]
    assert [p[0] for p in _hull_prune(collinear)] == [0, 1, 2, 3]


def test_hull_prune_orders_by_price_not_rung():
    # an AE rung whose amortized decoder ship makes it pricier than a
    # bigger pointwise rung sorts by its PRICE position
    pts = [(0, 1.0, 1.0, 5.0), (1, 2.0, 6.0, 4.0), (2, 4.0, 4.0, 0.5)]
    hull = _hull_prune(pts)
    assert [p[0] for p in hull] == [0, 2]   # rung 1 dominated at price 6


# ------------------------------------------------------- water-fill units
def test_waterfill_spends_budget_in_gain_order():
    curves = {
        "a": ([(0, 0.0, 0.0, 10.0), (1, 1.0, 1.0, 5.0),
               (2, 2.0, 2.0, 4.0)], 0.0),   # gains 5, then 1
        "b": ([(0, 0.0, 0.0, 10.0), (1, 1.0, 1.0, 7.0)], 0.0),  # gain 3
    }
    take, lam = _rd_waterfill(curves, budget=2.0, fixed_spend=0.0)
    assert take == {"a": 1, "b": 1}      # gain-5 then gain-3; gain-1 waits
    assert lam == pytest.approx(3.0)
    take, lam = _rd_waterfill(curves, budget=3.0, fixed_spend=0.0)
    assert take == {"a": 2, "b": 1}
    assert lam == pytest.approx(1.0)


def test_waterfill_below_floor_returns_none():
    curves = {0: ([(0, 5.0, 5.0, 1.0)], 0.0), 1: ([(0, 5.0, 5.0, 1.0)], 0.0)}
    assert _rd_waterfill(curves, budget=9.0, fixed_spend=0.0) == (None, None)
    assert _rd_waterfill(curves, budget=4.0, fixed_spend=6.0) == (None, None)
    take, lam = _rd_waterfill(curves, budget=10.0, fixed_spend=0.0)
    assert take == {0: 0, 1: 0} and lam is None


def test_waterfill_feasibility_uses_cost_not_price():
    # the AE step's price (ship-amortized) is huge, but its true uplink
    # cost fits: the budget check must use cost, the ordering price
    curves = {
        "ae": ([(0, 0.0, 0.0, 10.0), (1, 2.0, 50.0, 1.0)], 0.0),
        "pw": ([(0, 0.0, 0.0, 10.0), (1, 2.0, 2.0, 8.0)], 0.0),
    }
    take, lam = _rd_waterfill(curves, budget=2.0, fixed_spend=0.0)
    # price orders the pointwise step first (gain 1.0 vs 9/50=0.18); the
    # one affordable step goes to it
    assert take == {"pw": 1, "ae": 0}
    take, _ = _rd_waterfill(curves, budget=4.0, fixed_spend=0.0)
    assert take == {"pw": 1, "ae": 1}    # both fit in true cost bytes


# -------------------------------------------- integer-allocation top-up
def test_topup_spends_stranded_budget_on_pruned_interior_rung():
    """Decoder-ship pricing bends the curve concave at the middle rung,
    so the hull keeps only the 0→2 jump — which never fits the budget.
    Without the top-up every lane strands at the floor with 75% of the
    budget unspent while greedy's one-rung walk reaches all-rung-1; the
    top-up must recover exactly that allocation from the pruned interior
    points (DESIGN.md §15.3)."""
    pts = {ln: [(0, 32.0, 32.0, 1.0), (1, 128.0, 135_628.0, 0.6),
                (2, 512.0, 136_012.0, 0.1)] for ln in range(4)}
    curves = {ln: (_hull_prune(p), 0.0) for ln, p in pts.items()}
    for hull, _ in curves.values():
        assert [q[0] for q in hull] == [0, 2]    # rung 1 pruned (concave)
    budget = 4 * 32.0 + 4 * (128.0 - 32.0)       # all-rung-1, greedy-reachable
    alloc, lam = _rd_waterfill(curves, budget, 0.0)
    chosen = {ln: curves[ln][0][i] for ln, i in alloc.items()}
    assert all(p[0] == 0 for p in chosen.values()) and lam is None
    spent = sum(p[1] for p in chosen.values())
    tlam = _rd_topup(pts, chosen, budget, spent)
    assert [chosen[ln][0] for ln in range(4)] == [1, 1, 1, 1]
    assert tlam == pytest.approx(0.4 / (135_628.0 - 32.0))
    # insertion order of the lanes must not change the outcome
    chosen2 = {ln: curves[ln][0][i] for ln, i in reversed(alloc.items())}
    pts2 = {ln: pts[ln] for ln in reversed(list(pts))}
    tlam2 = _rd_topup(pts2, chosen2, budget, spent)
    assert chosen2 == chosen and tlam2 == pytest.approx(tlam)


def test_topup_noop_when_hull_sweep_exhausts_budget():
    pts = {"a": [(0, 0.0, 0.0, 10.0), (1, 1.0, 1.0, 5.0),
                 (2, 2.0, 2.0, 4.0)],
           "b": [(0, 0.0, 0.0, 10.0), (1, 1.0, 1.0, 7.0)]}
    curves = {ln: (_hull_prune(p), 0.0) for ln, p in pts.items()}
    alloc, _ = _rd_waterfill(curves, 2.0, 0.0)
    chosen = {ln: curves[ln][0][i] for ln, i in alloc.items()}
    spent = sum(p[1] for p in chosen.values())
    assert _rd_topup(pts, chosen, 2.0, spent) is None
    assert {ln: p[0] for ln, p in chosen.items()} == {"a": 1, "b": 1}


# ------------------------------------------------ hypothesis properties
def _curve_sets_impl(draw):
    n_lanes = draw(st.integers(min_value=1, max_value=4))
    curves = {}
    floor = 0.0
    for ln in range(n_lanes):
        n_pts = draw(st.integers(min_value=1, max_value=4))
        costs = sorted(draw(st.lists(
            st.integers(min_value=0, max_value=50), min_size=n_pts,
            max_size=n_pts, unique=True)))
        dists = sorted(draw(st.lists(
            st.floats(min_value=0.0, max_value=10.0, allow_nan=False,
                      width=32), min_size=n_pts, max_size=n_pts,
            unique=True)), reverse=True)
        pts = [(k, float(c), float(c), d)
               for k, (c, d) in enumerate(zip(costs, dists))]
        curves[ln] = (_hull_prune(pts), float(draw(st.floats(
            min_value=0.0, max_value=1.0, allow_nan=False, width=32))))
        floor += curves[ln][0][0][1]
    budget = float(draw(st.integers(min_value=0, max_value=250)))
    return curves, budget, floor


# the stub's st.composite returns None (the skipped tests never draw), so
# only wrap when real hypothesis is importable
_curve_sets = (st.composite(_curve_sets_impl) if _HAVE_HYPOTHESIS
               else (lambda: None))


@hypothesis.given(_curve_sets())
@hypothesis.settings(deadline=None, max_examples=100)
def test_waterfill_allocation_never_exceeds_budget(case):
    curves, budget, floor = case
    take, lam = _rd_waterfill(curves, budget, 0.0)
    if take is None:
        assert floor > budget
        return
    spent = sum(hull[take[ln]][1] for ln, (hull, _) in curves.items())
    assert spent <= budget
    # hull indices are valid and start positions are reachable
    for ln, (hull, _) in curves.items():
        assert 0 <= take[ln] < len(hull)


@hypothesis.given(_curve_sets(), st.randoms())
@hypothesis.settings(deadline=None, max_examples=100)
def test_waterfill_invariant_to_client_insertion_order(case, rng):
    """The allocation is a function of the curves, not of the order the
    cohort was enumerated in (ISSUE: permutation invariance)."""
    curves, budget, _ = case
    take, lam = _rd_waterfill(curves, budget, 0.0)
    lanes = list(curves)
    rng.shuffle(lanes)
    shuffled = {ln: curves[ln] for ln in lanes}
    take2, lam2 = _rd_waterfill(shuffled, budget, 0.0)
    assert take == take2
    assert (lam is None and lam2 is None) or lam == pytest.approx(lam2)


# --------------------------------------- RD ≡ greedy differential contract
def _bound_pair(budget_warmup=0.0):
    """Two identically-seeded 4-client federations, one per policy, run
    for one warmup round with a can't-move budget so both controllers
    hold identical state (snapshots, rungs) at plan time."""
    train, ev = train_eval_split(mnist_like(0, 320), 64)
    data = uniform_partition(0, train, 4)

    def mk(rc):
        run = FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=1, local_epochs=1, payload="update"),
            eval_data=ev, ratecontrol=rc)
        run.run()
        return run

    bb = ByteBudget(ladder=_pointwise_ladder(4), budget=budget_warmup,
                    min_snapshots=1)
    rd = RDBudget(ladder=_pointwise_ladder(4), budget=budget_warmup,
                  min_snapshots=1)
    return (bb, mk(bb)), (rd, mk(rd))


def test_rd_matches_greedy_on_affine_equal_slope_curves():
    """On distortion curves affine in bytes with one shared slope, every
    hull step carries the same marginal gain, so the λ sweep degenerates
    to greedy's drift-ranked passes — the two policies must plan
    IDENTICAL moves at every budget (the differential contract that makes
    greedy the RD oracle on this curve family)."""
    (bb, run_bb), (rd, run_rd) = _bound_pair()
    costs = bb._costs
    a = {0: 1.0, 1: 0.8, 2: 0.6, 3: 0.4}     # per-client drift intercepts
    slope = 5e-6

    def fake(self):
        def probe(run, lanes):
            return np.array([[a[ci] - slope * costs[k] for ci in lanes]
                             for k in range(3)])
        return probe

    bb._probe_all = fake(bb)
    rd._probe_all = fake(rd)
    d01, d12 = costs[1] - costs[0], costs[2] - costs[1]
    floor = 4 * costs[0]
    budgets = [floor - 1, floor, floor + d01, floor + 2 * d01 + 1,
               floor + 4 * d01, floor + 4 * d01 + d12,
               floor + 4 * (d01 + d12), float("inf")]
    for start in ([0, 0, 0, 0], [2, 0, 1, 0]):
        for b in budgets:
            bb._rung[:] = start
            rd._rung[:] = start
            bb.budget = rd.budget = b
            moves_bb = bb.plan(run_bb, 5, [0, 1, 2, 3])
            moves_rd = rd.plan(run_rd, 5, [0, 1, 2, 3])
            assert moves_rd == moves_bb, (start, b)
            # client order must not matter to either policy
            assert rd.plan(run_rd, 5, [3, 1, 0, 2]) == moves_rd


def test_rd_beats_greedy_on_unequal_slope_curves():
    """Where the contract does NOT hold — per-byte gains differing across
    clients — the water-fill buys more total distortion reduction per
    byte than drift-ranked greedy: the reason RDBudget exists."""
    (bb, run_bb), (rd, run_rd) = _bound_pair()
    costs = bb._costs
    # client 0 drifts most but its curve saturates (upgrades buy little);
    # clients 1-3 drift less with steep curves (upgrades buy a lot)
    errs = {0: [0.9, 0.89, 0.88], 1: [0.8, 0.2, 0.1],
            2: [0.7, 0.2, 0.1], 3: [0.6, 0.2, 0.1]}

    def fake(run, lanes):
        return np.array([[errs[ci][k] for ci in lanes] for k in range(3)])

    bb._probe_all = fake
    rd._probe_all = fake
    budget = 4 * costs[0] + (costs[1] - costs[0])  # one upgrade fits
    bb.budget = rd.budget = budget

    def reduction(rc, run):
        moves = rc.plan(run, 5, [0, 1, 2, 3])
        alloc = {ci: moves.get(ci, 0) for ci in range(4)}
        return sum(errs[ci][0] - errs[ci][k] for ci, k in alloc.items())

    gain_bb = reduction(bb, run_bb)
    gain_rd = reduction(rd, run_rd)
    assert gain_bb == pytest.approx(0.01)    # greedy lifts the big drifter
    assert gain_rd == pytest.approx(0.6)     # RD lifts the steep curve
    assert gain_rd > gain_bb


# ----------------------------------- end-to-end Pareto check (Dirichlet)
def test_rd_accuracy_per_byte_matches_or_beats_greedy_on_dirichlet():
    """Acceptance: on a label-skew split under the same finite uplink
    budget, RDBudget's accuracy per uplink byte is no worse than greedy
    ByteBudget's (it may coincide when probed curves are near-affine)."""
    train, ev = train_eval_split(mnist_like(0, 512), 128)
    data = dirichlet_partition(1, train, 4, alpha=0.5)
    ladder = _pointwise_ladder(4)
    costs_probe = ByteBudget(ladder=_pointwise_ladder(4))
    # budget: floor plus two q8 upgrades' worth of marginal bytes

    def run_policy(cls):
        rc = cls(ladder=_pointwise_ladder(4), budget=1.0,
                 min_snapshots=1)
        run = FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=3, local_epochs=1, payload="update",
                     batch_size=16),
            eval_data=ev, ratecontrol=rc)
        rc.budget = (4 * rc._costs[0]
                     + 2 * (rc._costs[1] - rc._costs[0]))
        hist = run.run()
        acc = hist[-1].global_metrics["accuracy"]
        up = sum(r.bytes_up for r in hist)
        return acc, up, hist

    acc_bb, up_bb, _ = run_policy(ByteBudget)
    acc_rd, up_rd, hist_rd = run_policy(RDBudget)
    assert up_rd > 0 and up_bb > 0
    assert acc_rd / up_rd >= (acc_bb / up_bb) * (1 - 1e-9)
    # both planned within the same budget envelope per round
    del costs_probe, ladder

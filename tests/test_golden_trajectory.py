"""Golden-trajectory regression fixture: a small recorded seed-config run
(per-round metrics + byte totals, committed at tests/golden/sync_q8.json)
replayed against the live code, so silent numeric drift introduced by a
future refactor fails loudly instead of slipping through relative-only
equivalence tests (each of which compares two implementations of the SAME
commit and so cannot see a drift both share).

Byte totals are integer-exact (codec wire formats are deterministic).
Metrics are floats crossing jit/XLA versions and platforms, so they get a
small absolute+relative band rather than the in-process 1-ulp rule:
atol=2e-5 / rtol=2e-4 is ~20× looser than observed same-machine jit
variation (~1e-6) and ~100× tighter than any real numeric regression seen
so far (lr changes, reduction reorderings move metrics at the 1e-2 level).

Regenerate (only after an INTENTIONAL trajectory change, with the reason
in the commit message):  PYTHONPATH=src python tests/test_golden_trajectory.py
"""
import json
import os

import numpy as np

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden", "sync_q8.json")


def _run():
    """The recorded configuration: the seed scheduler (SyncFedAvg), q8
    codec, update payload + error feedback — deliberately the plainest
    trajectory in the repo, so a drift here indicts core math, not policy."""
    from repro.configs.paper import MNIST_CLASSIFIER
    from repro.core import FLConfig, FederatedRun, QuantizeCompressor
    from repro.data.pipeline import (mnist_like, train_eval_split,
                                     uniform_partition)
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 3)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update",
                 error_feedback=True, seed=0),
        compressors=[QuantizeCompressor(bits=8) for _ in range(3)],
        eval_data=ev)
    hist = run.run()
    return [{
        "round": r.round,
        "bytes_up": r.bytes_up,
        "bytes_up_raw": r.bytes_up_raw,
        "bytes_down": r.bytes_down,
        "bytes_decoder": r.bytes_decoder,
        "compression_ratio": r.compression_ratio,
        "loss": float(r.global_metrics["loss"]),
        "accuracy": float(r.global_metrics["accuracy"]),
    } for r in hist]


def test_golden_trajectory_replays():
    with open(GOLDEN) as f:
        golden = json.load(f)
    live = _run()
    assert len(live) == len(golden["rounds"])
    for want, got in zip(golden["rounds"], live):
        assert got["round"] == want["round"]
        # byte accounting is exact: any change is a wire-format change
        for k in ("bytes_up", "bytes_up_raw", "bytes_down",
                  "bytes_decoder"):
            assert got[k] == want[k], (k, got[k], want[k])
        for k in ("compression_ratio", "loss", "accuracy"):
            np.testing.assert_allclose(
                got[k], want[k], atol=2e-5, rtol=2e-4,
                err_msg=f"golden drift in {k!r} at round {got['round']} — "
                        "if intentional, regenerate tests/golden/ (see "
                        "module docstring)")


if __name__ == "__main__":
    os.makedirs(os.path.dirname(GOLDEN), exist_ok=True)
    with open(GOLDEN, "w") as f:
        json.dump({"config": "SyncFedAvg x3 clients, q8, update+EF, "
                             "2 rounds, 1 epoch, mnist_like(0,256)/64",
                   "rounds": _run()}, f, indent=1)
    print(f"wrote {GOLDEN}")

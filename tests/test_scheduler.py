"""Scalable-runtime tests (DESIGN.md §6): scheduler equivalences, byte
accounting, error-feedback state threading, and the vmap cohort path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import MNIST_CLASSIFIER
from repro.core import (AsyncBuffered, FLConfig, FederatedRun, LatencyModel,
                        QuantizeCompressor, SampledSync, SyncFedAvg, fedavg,
                        local_train, local_train_batched, tree_bytes)
from repro.data.pipeline import (dirichlet_partition, mnist_like,
                                 train_eval_split)


def _federation(n_clients, seed=0, n=512, n_eval=128, alpha=5.0):
    train, ev = train_eval_split(mnist_like(seed, n), n_eval)
    return dirichlet_partition(seed, train, n_clients, alpha=alpha), ev


# ----------------------------------------------------- seed equivalence
def test_sync_fedavg_reproduces_seed_loop():
    """The default scheduler must equal the pre-refactor FederatedRun.run
    body (re-implemented inline here): same bytes exactly, same params to
    float tolerance. Tolerance, not bit-for-bit: the server now decodes and
    aggregates the whole cohort in ONE jitted call (DESIGN.md §7), and XLA
    reassociates the fused subtract+reduce — a ≤1-ulp difference vs the
    sequential per-client dispatch chain this loop executes."""
    data, ev = _federation(2, alpha=10.0)
    cfg = FLConfig(n_rounds=2, local_epochs=2, lr=2e-3, error_feedback=True)
    comps = [QuantizeCompressor(bits=8) for _ in range(2)]
    run = FederatedRun(MNIST_CLASSIFIER, data, cfg,
                       compressors=comps, eval_data=ev)
    hist = run.run()

    # --- the seed loop, verbatim -------------------------------------
    from repro.models.classifiers import init_classifier
    gp = init_classifier(jax.random.PRNGKey(cfg.seed), MNIST_CLASSIFIER)
    residuals = [None, None]
    ref_comps = [QuantizeCompressor(bits=8) for _ in range(2)]
    for r in range(cfg.n_rounds):
        updates, weights = [], []
        bytes_up = 0.0
        for ci, d in enumerate(data):
            local, _, h = local_train(
                gp, MNIST_CLASSIFIER, d, epochs=cfg.local_epochs,
                lr=cfg.lr, batch_size=cfg.batch_size,
                seed=cfg.seed * 997 + r, optimizer=cfg.optimizer,
                prox_mu=0.0, anchor=gp)
            payload = local                       # payload == "weights"
            if residuals[ci] is not None:
                payload = jax.tree_util.tree_map(
                    lambda u, res: u + res, payload, residuals[ci])
            decoded, stats = ref_comps[ci].roundtrip(payload)
            residuals[ci] = jax.tree_util.tree_map(
                lambda u, dd: u - dd, payload, decoded)
            decoded = jax.tree_util.tree_map(
                lambda w, g: w - g, decoded, gp)
            updates.append(decoded)
            weights.append(float(d["x"].shape[0]))
            bytes_up += stats["compressed_bytes"]
        gp = fedavg(gp, updates, weights, cfg.server_lr)
        assert hist[r].bytes_up == bytes_up
        for a, b in zip(jax.tree_util.tree_leaves(run.global_params)
                        if r == cfg.n_rounds - 1 else [],
                        jax.tree_util.tree_leaves(gp)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-5)


# ----------------------------------------------------- sampled sync
def test_sampled_sync_byte_accounting_hand_computed():
    """Identity codec, cohort of 2: uplink AND downlink must equal exactly
    cohort * n_params * 4 bytes (float32 both directions)."""
    data, ev = _federation(4)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, scheduler=SampledSync(cohort=2))
    hist = run.run()
    model_bytes = tree_bytes(run.global_params)       # 15,910 * 4
    assert model_bytes == 15_910 * 4
    for rec in hist:
        assert len(rec.participants) == 2
        assert rec.bytes_up == pytest.approx(2 * model_bytes)
        assert rec.bytes_up_raw == pytest.approx(2 * model_bytes)
        assert rec.bytes_down == pytest.approx(2 * model_bytes)
        assert rec.bytes_down_raw == rec.bytes_down
        assert rec.compression_ratio == pytest.approx(1.0, rel=0.01)
    tot = run.total_bytes()
    assert tot["bytes_total"] == pytest.approx(2 * 2 * 2 * model_bytes)


def test_sampled_sync_vmap_matches_loop():
    """The §6.4 vmap cohort hot path must produce the same federation as
    the sequential per-client loop (same data, same shared seed). Uses
    equal-size shards and asserts the fast path actually engaged — a
    ragged federation would silently compare the loop to itself."""
    from repro.data.pipeline import uniform_partition
    train, ev = train_eval_split(mnist_like(0, 512), 128)
    data = uniform_partition(0, train, 6)
    cfg = FLConfig(n_rounds=2, local_epochs=1, lr=2e-3, payload="update")
    runs = {}
    for use_vmap in (True, False):
        sched = SampledSync(cohort=3, use_vmap=use_vmap)
        run = FederatedRun(MNIST_CLASSIFIER, data, cfg, eval_data=ev,
                           scheduler=sched)
        runs[use_vmap] = run.run()
        if use_vmap:
            assert sched.vmap_rounds == 2 and sched.loop_rounds == 0
        else:
            assert sched.vmap_rounds == 0 and sched.loop_rounds == 2
    for a, b in zip(runs[True], runs[False]):
        assert a.participants == b.participants
        assert a.global_metrics["accuracy"] == pytest.approx(
            b.global_metrics["accuracy"], abs=0.02)
        assert a.global_metrics["loss"] == pytest.approx(
            b.global_metrics["loss"], rel=1e-3)


def test_local_train_batched_matches_sequential():
    data, _ = _federation(3, n=400, n_eval=100, alpha=100.0)
    # equal-shape shards for stacking
    n_min = min(d["x"].shape[0] for d in data)
    data = [{k: v[:n_min] for k, v in d.items()} for d in data]
    stacked = {k: jnp.stack([d[k] for d in data]) for k in data[0]}
    from repro.models.classifiers import init_classifier
    params = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)

    batched, metrics = local_train_batched(
        params, MNIST_CLASSIFIER, stacked, epochs=2, lr=1e-3,
        batch_size=32, seed=7)
    assert len(metrics) == 3
    for ci, d in enumerate(data):
        seq, _, _ = local_train(params, MNIST_CLASSIFIER, d, epochs=2,
                                lr=1e-3, batch_size=32, seed=7)
        got = jax.tree_util.tree_map(lambda x, i=ci: x[i], batched)
        for a, b in zip(jax.tree_util.tree_leaves(got),
                        jax.tree_util.tree_leaves(seq)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-4, rtol=1e-4)


# ----------------------------------------------------- async buffered
def test_async_zero_jitter_reproduces_sync_trajectory():
    """buffer_k == N with a degenerate latency model: every flush drains all
    clients at staleness 0 → identical metrics and bytes to SyncFedAvg."""
    data, ev = _federation(4)
    cfg = FLConfig(n_rounds=3, local_epochs=1, lr=2e-3)
    sync = FederatedRun(MNIST_CLASSIFIER, data, cfg, eval_data=ev,
                        scheduler=SyncFedAvg()).run()
    asyn = FederatedRun(
        MNIST_CLASSIFIER, data, cfg, eval_data=ev,
        scheduler=AsyncBuffered(buffer_k=4, latency=LatencyModel())).run()
    for a, b in zip(sync, asyn):
        assert a.global_metrics == b.global_metrics
        assert a.bytes_up == b.bytes_up
        assert a.bytes_down == b.bytes_down
        assert sorted(b.participants) == a.participants
        assert all(s == 0 for s in b.staleness)


def test_async_stragglers_report_staleness():
    data, ev = _federation(8)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, lr=2e-3),
        eval_data=ev,
        scheduler=AsyncBuffered(
            buffer_k=4,
            latency=LatencyModel(jitter=0.5, straggler_frac=0.25,
                                 straggler_mult=8.0)))
    hist = run.run()
    # fast clients lap the federation: some later-round update is stale
    assert any(s > 0 for rec in hist[1:] for s in rec.staleness)
    # stragglers (clients 0 and 1) never make a K=4 buffer this early
    assert all(ci not in rec.participants
               for rec in hist for ci in (0, 1))
    assert hist[-1].sim_time > 0.0
    assert np.isfinite(hist[-1].global_metrics["loss"])


def test_latency_model_draws_are_distinct_across_pairs():
    """Satellite (bugfix): the old ad-hoc hash ``(seed*7919 + c*104729 + d)
    mod 2^31`` collided across (client, dispatch) pairs at large N, so
    distinct dispatches silently drew identical jitter. The SeedSequence
    path must give a distinct draw per pair."""
    lm = LatencyModel(jitter=0.5, seed=0)
    draws = {lm.sample(c, d, 10 ** 6)
             for c in range(500) for d in range(4)}
    assert len(draws) == 500 * 4

    # the legacy hash, by contrast, demonstrably collides: 104729 is odd,
    # so k = 104729^(-1) mod 2^31 exists and (client + k, dispatch) lands
    # on exactly (client, dispatch + 1)'s stream — distinct pairs, one
    # RandomState, identical draws
    legacy = LatencyModel(jitter=0.5, seed=0, legacy_hash=True)
    k = pow(104729, -1, 2 ** 31)
    for c, d in ((0, 0), (123, 2)):
        assert legacy.sample(c + k, d, 10 ** 6) == \
            legacy.sample(c, d + 1, 10 ** 6)
    # ...while the SeedSequence path separates those same pairs
    assert lm.sample(0 + k, 0, 10 ** 6) != lm.sample(0, 1, 10 ** 6)


def test_latency_model_legacy_flag_reproduces_old_draws():
    """The compat flag must reproduce the pre-fix stream bit-for-bit (for
    pinned simulated traces)."""
    lm = LatencyModel(base=2.0, jitter=0.25, seed=3, legacy_hash=True)
    for c, d in ((0, 0), (5, 2), (17, 1)):
        rng = np.random.RandomState((3 * 7919 + c * 104729 + d) % 2 ** 31)
        want = 2.0 * (1.0 + 0.25 * (2.0 * rng.rand() - 1.0))
        assert lm.sample(c, d, 32) == want


def test_async_byte_accounting_survives_save_load(tmp_path):
    """Satellite (bugfix): a mid-run save/load used to (a) drop the
    dispatched-but-unrecorded ``_pending_down`` bytes and (b) re-dispatch
    the whole federation, re-charging a broadcast the uninterrupted run
    never shipped. With the event loop persisted (DESIGN.md §9.3), the
    resumed run's byte totals AND trajectory equal the uninterrupted
    run's."""
    from repro.data.pipeline import uniform_partition
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 6)

    def mk(n_rounds):
        return FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=n_rounds, local_epochs=1, payload="update"),
            eval_data=ev,
            scheduler=AsyncBuffered(
                buffer_k=2, latency=LatencyModel(jitter=0.4)))

    full = mk(4)
    hist_full = full.run()
    first = mk(2)
    hist_first = first.run()
    path = f"{tmp_path}/async_bytes.npz"
    first.save_state(path)
    resumed = mk(2)
    assert resumed.load_state(path) == 2
    hist_resumed = resumed.run()

    spliced = hist_first + hist_resumed
    assert len(spliced) == len(hist_full)
    for a, b in zip(hist_full, spliced):
        assert a.round == b.round
        assert a.bytes_down == b.bytes_down
        assert a.bytes_up == b.bytes_up
        assert a.participants == b.participants
        assert a.staleness == b.staleness
        assert a.global_metrics == b.global_metrics
    assert sum(r.bytes_down for r in hist_full) == \
        sum(r.bytes_down for r in spliced)
    np.testing.assert_allclose(
        np.asarray(jax.flatten_util.ravel_pytree(full.global_params)[0]),
        np.asarray(jax.flatten_util.ravel_pytree(resumed.global_params)[0]),
        atol=0, rtol=0)


def test_error_feedback_residual_survives_unsampled_rounds():
    """A client's EF residual is scheduler state, not round state: it must
    persist untouched across rounds where the client is not sampled."""
    data, ev = _federation(4)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, error_feedback=True,
                 payload="update"),
        compressors=[QuantizeCompressor(bits=4) for _ in range(4)],
        eval_data=ev, scheduler=SampledSync(cohort=2))
    sched = run.scheduler
    seen = {}
    for r in range(4):
        cohort = set(sched.sampled(r))
        before = {ci: run.clients[ci].residual for ci in range(4)}
        sched.run_round(r)
        for ci in range(4):
            if ci in cohort:
                assert run.clients[ci].residual is not None
                seen[ci] = run.clients[ci].residual
            elif before[ci] is not None:
                # unsampled: the exact same residual object, unmodified
                assert run.clients[ci].residual is before[ci]
    assert len(seen) >= 3        # sampling actually rotated clients
    # back-compat view stays live
    assert run._residuals == [c.residual for c in run.clients]

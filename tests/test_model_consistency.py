"""Correctness invariants of the model zoo: decode matches full forward,
mixers match naive recurrences, flash attention matches exact attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill
from repro.models.attention import decode_attention, flash_attention
from repro.models.rglru import (init_rglru_state, rglru_decode,
                                rglru_forward, init_rglru_block)
from repro.models.ssm import ssd_scan

FAMILIES = ["llama3-8b", "minicpm3-4b", "dbrx-132b", "mamba2-2.7b",
            "recurrentgemma-9b", "whisper-medium", "phi-3-vision-4.2b",
            "stablelm-1.6b"]


def _batch(cfg, B, S, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.encdec.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k, (B, cfg.vlm.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_full_forward(arch):
    """Autoregressive invariant: decoding token S after prefilling S tokens
    equals the last-position logits of a full (S+1)-token forward."""
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    full = _batch(cfg, B, S + 1, seed=3)
    part = dict(full, tokens=full["tokens"][:, :S])
    _, cache = prefill(params, cfg, part, cache_len=32)
    lg_dec, _ = decode_step(params, cfg, full["tokens"][:, S:S + 1], cache)
    lg_full, _ = prefill(params, cfg, full, cache_len=33)
    np.testing.assert_allclose(lg_dec, lg_full, atol=2e-5, rtol=2e-3)


def test_flash_attention_matches_exact():
    k = jax.random.PRNGKey(0)
    B, S, H, KV, D = 2, 37, 4, 2, 16
    q = jax.random.normal(k, (B, S, H, D))
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, KV, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, KV, D))

    def exact(mode, window=None):
        G = H // KV
        qg = q.reshape(B, S, KV, G, D)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kk) * D ** -0.5
        qi = jnp.arange(S)[:, None]
        kj = jnp.arange(S)[None, :]
        if mode == "causal":
            mask = kj <= qi
        elif mode == "window":
            mask = (kj <= qi) & (kj > qi - window)
        else:
            mask = jnp.ones((S, S), bool)
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
        return out.reshape(B, S, H, D)

    for mode, window in [("causal", None), ("window", 9), ("full", None)]:
        got = flash_attention(q, kk, v, mode=mode, window=window,
                              q_chunk=8, kv_chunk=8)
        np.testing.assert_allclose(got, exact(mode, window), atol=2e-5,
                                   rtol=1e-4, err_msg=mode)


def test_decode_attention_ring_positions():
    """Ring-buffer cache: only in-window positions contribute."""
    k = jax.random.PRNGKey(0)
    B, W, KV, D, H = 1, 8, 1, 8, 2
    q = jax.random.normal(k, (B, 1, H, D))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, W, KV, D))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, W, KV, D))
    # positions: ring holds absolute positions 12..19, current index 19
    pos = jnp.arange(12, 20)[None, :]
    out = decode_attention(q, kc, vc, index=jnp.int32(19), positions=pos,
                           window=4)
    # manual: only positions 16..19 attend
    mask = (pos[0] <= 19) & (pos[0] > 15)
    qg = q.reshape(B, KV, H, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kc) * D ** -0.5
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    want = jnp.einsum("bkgs,bskd->bkgd", p, vc).reshape(B, 1, H, D)
    np.testing.assert_allclose(out, want, atol=1e-5)


def test_ssd_scan_matches_naive_recurrence():
    """Chunked SSD (dual form) == sequential SSM recurrence."""
    k = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 2, 23, 4, 8, 2, 6
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    B = jax.random.normal(jax.random.PRNGKey(2), (b, s, g, n)) * 0.5
    C = jax.random.normal(jax.random.PRNGKey(3), (b, s, g, n)) * 0.5

    y_chunk, final = ssd_scan(x, dA, B, C, chunk=5)

    hg = h // g
    Bh = jnp.repeat(B, hg, axis=2)
    Ch = jnp.repeat(C, hg, axis=2)
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        decay = jnp.exp(dA[:, t])                     # (b,h)
        state = state * decay[..., None, None] \
            + x[:, t][..., None] * Bh[:, t][:, :, None, :]
        ys.append(jnp.einsum("bhpn,bhn->bhp", state, Ch[:, t]))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(y_chunk, y_naive, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(final, state, atol=1e-4, rtol=1e-3)


def test_rglru_scan_matches_stepwise():
    cfg = get_config("recurrentgemma-9b").reduced()
    k = jax.random.PRNGKey(0)
    p = init_rglru_block(k, cfg)
    B, S = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    y_full, final_state = rglru_forward(p, x, cfg)
    st = init_rglru_state(cfg, B)
    ys = []
    for t in range(S):
        y_t, st = rglru_decode(p, x[:, t:t + 1], cfg, st)
        ys.append(y_t)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_full, y_step, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(final_state["h"], st["h"], atol=1e-4,
                               rtol=1e-3)


def test_sliding_window_decode_long_context():
    """Dense arch with window fallback: decode with a ring cache stays
    consistent with a full-cache decode over the last `window` tokens."""
    cfg = get_config("llama3-8b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S, W = 1, 24, 8
    batch = _batch(cfg, B, S + 1, seed=5)
    part = {"tokens": batch["tokens"][:, :S]}
    # ring (windowed) prefill+decode
    _, ring_cache = prefill(params, cfg, part, cache_len=S + 4, window=W)
    lg_ring, _ = decode_step(params, cfg, batch["tokens"][:, S:S + 1],
                             ring_cache, window=W)
    # reference: full cache, same window mask
    _, full_cache = prefill(params, cfg, part, cache_len=S + 4)
    lg_full, _ = decode_step(params, cfg, batch["tokens"][:, S:S + 1],
                             full_cache, window=W)
    np.testing.assert_allclose(lg_ring, lg_full, atol=3e-5, rtol=3e-3)

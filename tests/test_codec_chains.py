"""Composable codec stacks (DESIGN.md §13): ChainSpec validation, the
stage-ops protocol, single-stage-chain ≡ bare-codec bitwise equality,
ComposedSpec-as-alias differential compatibility, FedZip-direction stages
(top-k prefix, k-means codebook, entropy-priced wire size), the measured-
bytes channel, scatter/kernel fused aggregation oracles, grouped-partition
equivalence, and chain stacks end-to-end through every scheduler, the
rate-control ladders, and bit-exact checkpoint resume."""
import os

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (ByteBudget, ChainCompressor, ChainSpec,
                        ChunkedAECompressor, ChunkedAEConfig,
                        ComposedCompressor, EntropySpec, FCAECompressor,
                        FLConfig, FederatedRun, IdentityCompressor,
                        KMeansCompressor, KMeansSpec, PartitionedCompressor,
                        QuantizeCompressor, SampledSync, AsyncBuffered,
                        TopKCompressor, by_layer_partition, codec,
                        init_chunked_ae, init_fc_ae, normalize_weights,
                        partition_ladder, tree_bytes, wire_bytes)
from repro.core import autoencoder as ae
from repro.core.codec import (IdentitySpec, QuantizeSpec, TopKSpec,
                              composed_chain, is_shape_static,
                              measured_bytes, stage_out_size)
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)
from repro.models.classifiers import init_classifier

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=15,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

N = 1250                                     # deliberately chunk-ragged

_CHUNK_CFG = ChunkedAEConfig(chunk_size=128, hidden=(32,), latent_chunk=4)
_CHUNK_PARAMS = init_chunked_ae(jax.random.PRNGKey(0), _CHUNK_CFG)
_FC_CFG = AEConfig(input_dim=2048, encoder_hidden=(64,), latent_dim=16)
_FC_PARAMS = init_fc_ae(jax.random.PRNGKey(0), _FC_CFG)


def _flat(seed, n=N, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n,)) * scale


def _roundtrip(comp, flat):
    spec = comp.spec(flat.shape[0])
    params = comp.codec_params()
    payload = codec.encode(spec, params, flat)
    return spec, params, payload


# ----------------------------------------------------- ChainSpec contract
def test_chain_validation_errors():
    q = QuantizeSpec(size=100, bits=8)
    tk = TopKSpec(size=1000, k=100)
    with pytest.raises(ValueError):
        ChainSpec(())                                    # empty
    with pytest.raises(TypeError):
        ChainSpec((ChainSpec((tk,)), q))                 # nested chain
    with pytest.raises(ValueError):
        ChainSpec((EntropySpec(),))                      # entropy leads
    with pytest.raises(ValueError):
        ChainSpec((tk, EntropySpec(), q))                # entropy mid-chain
    with pytest.raises(ValueError):
        ChainSpec((q, QuantizeSpec(size=100)))           # terminal-only first
    with pytest.raises(ValueError):
        ChainSpec((KMeansSpec(size=100), q))             # terminal-only first
    with pytest.raises(ValueError):
        ChainSpec((tk, QuantizeSpec(size=7)))            # size mismatch
    with pytest.raises(ValueError):
        fc = codec.FCAESpec(size=100, cfg=_FC_CFG)
        ChainSpec((tk, codec.ChunkedAESpec(size=100, cfg=_CHUNK_CFG),
                   fc))                                  # two AE stages
    # valid chains are frozen, hashable, jit-static
    c = ChainSpec((tk, q))
    assert hash(c) == hash(ChainSpec((tk, q)))
    assert c.size == 1000 and c.vector_stages == (tk, q)


def test_stage_out_size_protocol():
    assert stage_out_size(TopKSpec(size=1000, k=64)) == 64
    assert stage_out_size(IdentitySpec(size=77)) == 77
    assert stage_out_size(codec.ChunkedAESpec(size=1000, cfg=_CHUNK_CFG)) \
        == 8 * _CHUNK_CFG.latent_chunk
    assert stage_out_size(QuantizeSpec(size=100)) is None
    assert stage_out_size(KMeansSpec(size=100)) is None


# ----------------------------------- single-stage chain ≡ bare codec (bit)
def _bare_compressors():
    return [
        IdentityCompressor(),
        QuantizeCompressor(bits=8, block=64),
        QuantizeCompressor(bits=4, block=64),
        TopKCompressor(fraction=0.1),
        KMeansCompressor(k=16, iters=4),
        FCAECompressor(_FC_PARAMS, _FC_CFG),
        ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG, use_kernel=False),
    ]


@pytest.mark.parametrize("comp", _bare_compressors(),
                         ids=lambda c: c.name)
def test_single_stage_chain_bitwise(comp):
    """A 1-stage chain must be bit-identical to the bare codec at every
    entry point — wrapping a codec in the combinator is a no-op."""
    flat = _flat(1)
    bare_spec, params, bare_pl = _roundtrip(comp, flat)
    chain = ChainSpec((bare_spec,))
    cparams = None if params is None else (params,)
    chain_pl = codec.encode(chain, cparams, flat)
    assert set(chain_pl) == {"s0"}
    for k in bare_pl:
        np.testing.assert_array_equal(np.asarray(bare_pl[k]),
                                      np.asarray(chain_pl["s0"][k]))
    np.testing.assert_array_equal(
        np.asarray(codec.decode(bare_spec, params, bare_pl)),
        np.asarray(codec.decode(chain, cparams, chain_pl)))
    # batched decode + fused aggregate, 3-client cohort
    pls = [codec.encode(bare_spec, params, _flat(s)) for s in (1, 2, 3)]
    stacked_b = codec.stack_payloads(pls)
    stacked_c = codec.stack_payloads(
        [{"s0": pl} for pl in pls])
    np.testing.assert_array_equal(
        np.asarray(codec.decode_batched(bare_spec, params, stacked_b)),
        np.asarray(codec.decode_batched(chain, cparams, stacked_c)))
    w = jnp.asarray(normalize_weights([1.0, 2.0, 3.0]), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(codec.decode_and_aggregate(bare_spec, params,
                                              stacked_b, w)),
        np.asarray(codec.decode_and_aggregate(chain, cparams,
                                              stacked_c, w)))
    assert wire_bytes(chain, cparams) == wire_bytes(bare_spec, params)


# -------------------------------------- ComposedSpec alias (differential)
def _composed_reference(inner_spec, ae_params, bits, block, flat):
    """The pre-refactor ComposedSpec encode/decode, copied as the oracle:
    AE-encode, flatten the latents, blockwise-quantize → {z_q, z_scales};
    decode dequantizes and AE-decodes."""
    from repro.kernels import ops
    z = ae.chunked_encode(ae_params, inner_spec.cfg, flat)
    q, scales, _ = ops.quantize_blocks(z.reshape(-1), bits=bits, block=block)
    payload = {"z_q": q, "z_scales": scales}
    n_latent = z.size
    z_hat = ops.dequantize_blocks(q, scales, bits=bits, block=block,
                                  orig_len=n_latent)
    dec = ae.chunked_decode(ae_params, inner_spec.cfg,
                            z_hat.reshape(z.shape), inner_spec.size)
    return payload, dec


def test_composed_alias_bitwise_vs_old_path():
    """ComposedSpec canonicalizes through the 2-stage chain but must keep
    its historical payload keys and bit-exact numerics — pre-refactor
    payloads and golden trajectories stay valid."""
    comp = ComposedCompressor(
        inner=ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG,
                                  use_kernel=False), bits=8, block=64)
    flat = _flat(5)
    spec, params, payload = _roundtrip(comp, flat)
    assert isinstance(spec, codec.ComposedSpec)
    assert set(payload) == {"z_q", "z_scales"}        # historical wire keys
    ref_pl, ref_dec = _composed_reference(spec.inner, params, spec.bits,
                                          spec.block, flat)
    np.testing.assert_array_equal(np.asarray(payload["z_q"]),
                                  np.asarray(ref_pl["z_q"]))
    np.testing.assert_array_equal(np.asarray(payload["z_scales"]),
                                  np.asarray(ref_pl["z_scales"]))
    np.testing.assert_array_equal(
        np.asarray(codec.decode(spec, params, payload)),
        np.asarray(ref_dec))
    # the canonical chain is the same computation under namespaced keys
    chain = composed_chain(spec)
    chain_pl = codec.encode(chain, (params, None), flat)
    np.testing.assert_array_equal(np.asarray(payload["z_q"]),
                                  np.asarray(chain_pl["s1"]["q"]))
    np.testing.assert_array_equal(
        np.asarray(codec.decode(spec, params, payload)),
        np.asarray(codec.decode(chain, (params, None), chain_pl)))
    assert wire_bytes(spec, params) == wire_bytes(chain, (params, None))


def test_composed_batched_decode_matches_sequential():
    comp = ComposedCompressor(
        inner=ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG,
                                  use_kernel=False), bits=8, block=64)
    spec = comp.spec(N)
    params = comp.codec_params()
    pls = [codec.encode(spec, params, _flat(s)) for s in range(3)]
    rows = codec.decode_batched(spec, params, codec.stack_payloads(pls))
    for i, pl in enumerate(pls):
        np.testing.assert_array_equal(
            np.asarray(rows[i]), np.asarray(codec.decode(spec, params, pl)))


# --------------------------------------------- wire pricing + measured
def test_wire_bytes_requires_ae_params():
    """Regression: pricing an AE-bearing spec with ``params=None`` used to
    crash inside eval_shape with an opaque tracer error — it must raise a
    clear ValueError naming the fix."""
    for spec in (codec.FCAESpec(size=N, cfg=_FC_CFG),
                 codec.ChunkedAESpec(size=N, cfg=_CHUNK_CFG),
                 codec.ComposedSpec(
                     inner=codec.ChunkedAESpec(size=N, cfg=_CHUNK_CFG)),
                 ChainSpec((TopKSpec(size=N, k=128),
                            codec.ChunkedAESpec(size=128,
                                                cfg=_CHUNK_CFG)))):
        with pytest.raises(ValueError, match="codec_params"):
            wire_bytes(spec, None)
    # pointwise chains price fine without params
    assert wire_bytes(ChainSpec((TopKSpec(size=N, k=128),
                                 QuantizeSpec(size=128, block=64)))) > 0


def test_chain_wire_bytes_matches_real_encode():
    comps = [
        ChainCompressor([TopKCompressor(fraction=0.1),
                         QuantizeCompressor(bits=8, block=64)]),
        ChainCompressor([TopKCompressor(fraction=0.2),
                         KMeansCompressor(k=16, iters=4)]),
        ChainCompressor([TopKCompressor(fraction=0.3),
                         ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG),
                         QuantizeCompressor(bits=8, block=64)]),
    ]
    flat = _flat(7)
    for comp in comps:
        spec, params, payload = _roundtrip(comp, flat)
        assert is_shape_static(spec)
        assert wire_bytes(spec, params) == tree_bytes(payload), comp.name
        assert measured_bytes(spec, payload) == tree_bytes(payload)


def test_entropy_measured_channel():
    """EntropySpec never changes the payload, only the measured price:
    measured ≤ dense always, < dense for genuinely low-entropy codes, and
    the spec is flagged shape-non-static so planners ignore it."""
    dense = ChainCompressor([TopKCompressor(fraction=0.1),
                             KMeansCompressor(k=8, iters=4)])
    coded = ChainCompressor([TopKCompressor(fraction=0.1),
                             KMeansCompressor(k=8, iters=4)],
                            entropy_coded=True)
    flat = _flat(9)
    spec_d, _, pl_d = _roundtrip(dense, flat)
    spec_c, _, pl_c = _roundtrip(coded, flat)
    assert is_shape_static(spec_d) and not is_shape_static(spec_c)
    assert isinstance(spec_c.stages[-1], EntropySpec)
    # identical device payload: entropy is a pricing stage, not a transform
    for k in pl_d:
        for kk in pl_d[k]:
            np.testing.assert_array_equal(np.asarray(pl_d[k][kk]),
                                          np.asarray(pl_c[k][kk]))
    m = measured_bytes(spec_c, pl_c)
    assert m <= tree_bytes(pl_c)
    # 8-symbol codes at uint8: entropy coding must beat a byte per code
    assert m < tree_bytes(pl_c)
    # decode is byte-for-byte the dense chain's
    np.testing.assert_array_equal(
        np.asarray(codec.decode(spec_d, None, pl_d)),
        np.asarray(codec.decode(spec_c, None, pl_c)))


# ---------------------------------------------- fused aggregation oracles
def _seq_oracle(spec, params, pls, w, base=None):
    rows = [codec.decode(spec, params, pl) for pl in pls]
    out = None
    for wi, row in zip(w, rows):
        r = row if base is None else row - base
        c = jnp.float32(wi) * r.astype(jnp.float32)
        out = c if out is None else out + c
    return out


@pytest.mark.parametrize("with_base", [False, True])
def test_topk_scatter_aggregate_matches_oracle(with_base):
    """Scatter-terminal chains (DESIGN.md §13.4) reduce by one weighted
    scatter-add — must match the sequential per-client decode oracle."""
    comp = ChainCompressor([TopKCompressor(fraction=0.1),
                            QuantizeCompressor(bits=8, block=64)])
    spec = comp.spec(N)
    pls = [codec.encode(spec, None, _flat(s)) for s in range(4)]
    w = normalize_weights([1.0, 2.0, 3.0, 4.0])
    base = _flat(99) if with_base else None
    got = codec.decode_and_aggregate(
        spec, None, codec.stack_payloads(pls),
        jnp.asarray(w, jnp.float32), base)
    want = _seq_oracle(spec, None, pls, w, base)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-5)


def test_kernel_terminal_chain_aggregate_matches_oracle():
    """A quantized kernel-path AE chain still takes the fused Pallas
    decode→aggregate branch; numerics match the sequential oracle."""
    kcomp = ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG, use_kernel=True)
    comp = ChainCompressor([kcomp, QuantizeCompressor(bits=8, block=64)])
    spec = comp.spec(N)
    params = comp.codec_params()
    assert codec.kernel_terminal_ae(spec) is not None
    pls = [codec.encode(spec, params, _flat(s)) for s in range(3)]
    w = normalize_weights([2.0, 1.0, 1.0])
    got = codec.decode_and_aggregate(
        spec, params, codec.stack_payloads(pls),
        jnp.asarray(w, jnp.float32))
    want = _seq_oracle(spec, params, pls, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    # sparsified chains must NOT claim the kernel branch: their terminal
    # decode transform is a scatter, not an AE expansion
    sc = ChainCompressor([TopKCompressor(fraction=0.3), kcomp]).spec(N)
    assert codec.kernel_terminal_ae(sc) is None


def test_kmeans_roundtrip_and_warm_start():
    flat = _flat(11, n=512)
    spec = KMeansSpec(size=512, k=8, iters=6)
    pl = codec.encode(spec, None, flat)
    assert pl["codes"].dtype == jnp.uint8
    assert pl["codebook"].shape == (8,)
    dec = codec.decode(spec, None, pl)
    assert dec.shape == (512,)
    # reconstruction maps every element to its nearest centroid
    cb = np.asarray(pl["codebook"])
    err = np.abs(np.asarray(flat) - np.asarray(dec))
    best = np.min(np.abs(np.asarray(flat)[:, None] - cb[None, :]), axis=1)
    np.testing.assert_allclose(err, best, atol=1e-6)
    # warm start: a checkpointed codebook seeds Lloyd — more steps from the
    # cold fit can only lower distortion (Lloyd is monotone)
    warm = codec.encode(spec, {"codebook": pl["codebook"]}, flat)
    warm_dec = codec.decode(spec, None, warm)
    cold_mse = float(np.mean(err ** 2))
    warm_mse = float(np.mean((np.asarray(flat) - np.asarray(warm_dec)) ** 2))
    assert warm_mse <= cold_mse + 1e-9


# ------------------------------------------- property: random stage stacks
def _stack_menu():
    return [
        lambda: ChainCompressor([TopKCompressor(fraction=0.1)]),
        lambda: ChainCompressor([TopKCompressor(fraction=0.2),
                                 QuantizeCompressor(bits=8, block=64)]),
        lambda: ChainCompressor([TopKCompressor(fraction=0.2),
                                 QuantizeCompressor(bits=4, block=64)]),
        lambda: ChainCompressor([TopKCompressor(fraction=0.2),
                                 KMeansCompressor(k=8, iters=3)]),
        lambda: ChainCompressor([IdentityCompressor(),
                                 QuantizeCompressor(bits=8, block=64)]),
        lambda: ChainCompressor([TopKCompressor(fraction=0.3),
                                 ChunkedAECompressor(_CHUNK_PARAMS,
                                                     _CHUNK_CFG)]),
        lambda: ChainCompressor([TopKCompressor(fraction=0.3),
                                 ChunkedAECompressor(_CHUNK_PARAMS,
                                                     _CHUNK_CFG),
                                 QuantizeCompressor(bits=8, block=64)]),
        lambda: ChainCompressor([TopKCompressor(fraction=0.2),
                                 QuantizeCompressor(bits=8, block=64)],
                                entropy_coded=True),
    ]


@hypothesis.given(st.integers(0, 7), st.sampled_from([257, 1250]),
                  st.integers(0, 10 ** 6))
def test_property_random_stack_roundtrip(which, n, seed):
    """Any menu stack at any size: fixed payload shapes/dtypes, jit-clean
    decode, batched ≡ sequential decode, fused aggregate ≡ oracle, and
    exact wire pricing for shape-static stacks."""
    comp = _stack_menu()[which]()
    flat = _flat(seed % 97, n=n)
    spec, params, payload = _roundtrip(comp, flat)
    dec = codec.decode(spec, params, payload)
    assert dec.shape == (n,) and dec.dtype == jnp.float32
    jit_dec = jax.jit(codec.decode, static_argnums=0)(spec, params, payload)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(jit_dec))
    pls = [payload, codec.encode(spec, params, _flat((seed + 1) % 97, n=n))]
    rows = codec.decode_batched(spec, params, codec.stack_payloads(pls))
    for i, pl in enumerate(pls):
        np.testing.assert_allclose(
            np.asarray(rows[i]),
            np.asarray(codec.decode(spec, params, pl)),
            atol=1e-6, rtol=1e-6)
    w = normalize_weights([3.0, 1.0])
    got = codec.decode_and_aggregate(spec, params, codec.stack_payloads(pls),
                                     jnp.asarray(w, jnp.float32))
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_seq_oracle(spec, params, pls, w)),
                               atol=1e-5, rtol=1e-4)
    if is_shape_static(spec):
        assert wire_bytes(spec, params) == tree_bytes(payload)
        assert measured_bytes(spec, payload) == tree_bytes(payload)
    else:
        assert measured_bytes(spec, payload) <= tree_bytes(payload)


# --------------------------------------- grouped partition path (chains)
TMPL = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)
PM = by_layer_partition(TMPL)
N_CLIENTS = 3


def _fed_data():
    train, ev = train_eval_split(mnist_like(0, 128), 32)
    return uniform_partition(0, train, N_CLIENTS), ev


def _mixed_stack_compressors():
    """A per-layer partition whose groups carry DIFFERENT stacks: the first
    group a sparsified AE chain, the others plain q8."""
    names = list(PM.names)
    comps = {}
    for i, name in enumerate(names):
        if i == 0:
            comps[name] = ChainCompressor(
                [TopKCompressor(fraction=0.3),
                 ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG,
                                     use_kernel=True),
                 QuantizeCompressor(bits=8, block=64)])
        else:
            comps[name] = QuantizeCompressor(bits=8)
    return [PartitionedCompressor(
        PM, {n: c for n, c in comps.items()}) for _ in range(N_CLIENTS)]


def _mk_mixed_run(data, ev, grouped):
    cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update",
                   error_feedback=True, use_grouped_kernel=grouped)
    return FederatedRun(MNIST_CLASSIFIER, data, cfg,
                        compressors=_mixed_stack_compressors(),
                        eval_data=ev)


def test_mixed_stack_partition_grouped_equals_sequential():
    """Acceptance: a PartitionSpec whose groups carry different stacks runs
    through the grouped one-dispatch server path, bit-identical to the
    sequential per-bucket path (chains included)."""
    data, ev = _fed_data()
    seq = _mk_mixed_run(data, ev, grouped=False)
    hist_s = seq.run()
    grp = _mk_mixed_run(data, ev, grouped=True)
    hist_g = grp.run()
    for x, y in zip(jax.tree_util.tree_leaves(seq.global_params),
                    jax.tree_util.tree_leaves(grp.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for a, b in zip(hist_s, hist_g):
        assert a.bytes_up == b.bytes_up
        assert a.bytes_up_measured == b.bytes_up_measured


# ------------------------------------------- end-to-end through the stack
def _chain_comps(n_clients):
    return [ChainCompressor([TopKCompressor(fraction=0.3),
                             ChunkedAECompressor(_CHUNK_PARAMS, _CHUNK_CFG),
                             QuantizeCompressor(bits=8, block=64)])
            for _ in range(n_clients)]


@pytest.mark.parametrize("sched", ["sync", "sampled", "async"])
def test_chain_e2e_schedulers_bytes_reconcile(sched):
    """Acceptance: a sparsify→AE→q8 chain runs under every scheduler, and
    every round's recorded uplink equals the static wire price times the
    participants — planned and observed bytes can never diverge for
    shape-static stacks (measured channel included)."""
    data, ev = _fed_data()
    scheduler = {"sync": None,
                 "sampled": SampledSync(cohort=2),
                 "async": AsyncBuffered(buffer_k=2)}[sched]
    cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update",
                   error_feedback=True)
    run = FederatedRun(MNIST_CLASSIFIER, data, cfg,
                       compressors=_chain_comps(N_CLIENTS),
                       eval_data=ev, scheduler=scheduler)
    hist = run.run()
    comp = run.compressors[0]
    price = wire_bytes(comp.spec(ravel_pytree(run.global_params)[0].size),
                       comp.codec_params())
    for rec in hist:
        n_part = len(rec.participants)
        assert rec.bytes_up == price * n_part
        assert rec.bytes_up_measured == rec.bytes_up
        assert rec.bytes_up < rec.bytes_up_raw
        assert np.isfinite(rec.compression_ratio)


def test_chain_e2e_resume_bit_exact(tmp_path):
    """Acceptance: save/load mid-run with chain compressors reproduces the
    uninterrupted trajectory bit-exactly (EF residuals, chain params and
    byte accounting all survive the checkpoint)."""
    data, ev = _fed_data()

    def mk(n_rounds):
        cfg = FLConfig(n_rounds=n_rounds, local_epochs=1, payload="update",
                       error_feedback=True)
        return FederatedRun(MNIST_CLASSIFIER, data, cfg,
                            compressors=_chain_comps(N_CLIENTS),
                            eval_data=ev)

    full = mk(2)
    hist_full = full.run()
    first = mk(1)
    first.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    first.save_state(path)
    resumed = mk(1)
    assert resumed.load_state(path) == 1
    hist_resumed = resumed.run()
    for x, y in zip(jax.tree_util.tree_leaves(full.global_params),
                    jax.tree_util.tree_leaves(resumed.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for a, b in zip(hist_full[1:], hist_resumed):
        assert a.bytes_up == b.bytes_up
        assert a.bytes_up_measured == b.bytes_up_measured


# ------------------------------------------- AE lifecycle through chains
def test_lifecycle_refits_chained_ae():
    """A chained AE refits on its true encode distribution: snapshots fold
    through the chain prefix (``codec.ae_stage_input``) and the refreshed
    decoder ships + is charged, exactly like a bare-AE lane."""
    from repro.core import AELifecycle
    data, ev = _fed_data()
    comps = _chain_comps(N_CLIENTS)
    before = jax.tree_util.tree_leaves(comps[0].ae_compressor().params)
    before = [np.asarray(x).copy() for x in before]
    # batch_size must fit the per-client shard (~32 samples) or local
    # training takes zero steps and every snapshot is the zero update
    cfg = FLConfig(n_rounds=3, local_epochs=1, payload="update",
                   error_feedback=True, batch_size=16)
    run = FederatedRun(
        MNIST_CLASSIFIER, data, cfg, compressors=comps, eval_data=ev,
        lifecycle=AELifecycle(refresh_every=2, min_snapshots=1,
                              refresh_epochs=2, batch_size=4))
    hist = run.run()
    refit_rounds = [r for r in hist if r.round > 0 and r.ae_syncs]
    assert refit_rounds, "chained AE never refit"
    assert all(r.bytes_decoder > 0 for r in refit_rounds)
    after = jax.tree_util.tree_leaves(run.compressors[0]
                                      .ae_compressor().params)
    assert any(not np.array_equal(a, np.asarray(b))
               for a, b in zip(before, after)), "refit left params unchanged"


# ---------------------------------------------- rate-control chain rungs
def _chain_ladder(n_clients):
    """Ascending-cost ladder whose rungs are chains: topk(5%)→q8 below
    topk(20%)→q8 below plain q8."""
    return [[ChainCompressor([TopKCompressor(fraction=0.05),
                              QuantizeCompressor(bits=8, block=64)]),
             ChainCompressor([TopKCompressor(fraction=0.2),
                              QuantizeCompressor(bits=8, block=64)]),
             QuantizeCompressor(bits=8)] for _ in range(n_clients)]


def test_chain_rungs_ladder_resume_bit_exact(tmp_path):
    """Acceptance: chain rungs ride the generic ladder machinery under a
    ByteBudget controller, and controller state (including per-rung chain
    codec params) restores bit-exactly."""
    data, ev = _fed_data()

    def mk(n_rounds):
        cfg = FLConfig(n_rounds=n_rounds, local_epochs=1, payload="update")
        return FederatedRun(
            MNIST_CLASSIFIER, data, cfg, compressors=None, eval_data=ev,
            ratecontrol=ByteBudget(ladder=_chain_ladder(N_CLIENTS),
                                   budget=float("inf"), min_snapshots=1))

    full = mk(2)
    hist_full = full.run()
    assert all(rec.controller == "byte_budget" for rec in hist_full)
    first = mk(1)
    first.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    first.save_state(path)
    resumed = mk(1)
    assert resumed.load_state(path) == 1
    hist_resumed = resumed.run()
    for x, y in zip(jax.tree_util.tree_leaves(full.global_params),
                    jax.tree_util.tree_leaves(resumed.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for a, b in zip(hist_full[1:], hist_resumed):
        assert a.bytes_up == b.bytes_up
        assert a.spec_switches == b.spec_switches


def test_chain_rungs_partition_ladder_binds_and_runs():
    """Chain rungs inside a per-(client,partition) ladder under one shared
    ByteBudget: binds (ascending per-group costs) and runs."""
    data, ev = _fed_data()
    rungs = {}
    for i, name in enumerate(PM.names):
        if i == 0:
            rungs[name] = [
                lambda ci, n: ChainCompressor(
                    [TopKCompressor(fraction=0.05),
                     QuantizeCompressor(bits=8, block=64)]),
                lambda ci, n: QuantizeCompressor(bits=8)]
        else:
            rungs[name] = [lambda ci, n: QuantizeCompressor(bits=4),
                           lambda ci, n: QuantizeCompressor(bits=8)]
    ladder = partition_ladder(N_CLIENTS, PM, rungs)
    cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update")
    run = FederatedRun(
        MNIST_CLASSIFIER, data, cfg, compressors=None, eval_data=ev,
        ratecontrol=ByteBudget(ladder=ladder, partition=PM,
                               budget=float("inf"), min_snapshots=1))
    hist = run.run()
    assert len(hist) == 2
    assert all(np.isfinite(rec.bytes_up) and rec.bytes_up > 0
               for rec in hist)


# ------------------------------- pre-refactor checkpoint compat (composed)
def test_composed_controller_checkpoint_restores(tmp_path):
    """ComposedCompressor rungs keep the historical bare-AE-params
    checkpoint convention (not chain tuples): controller state written with
    it restores through the new ``set_codec_params`` lane bit-exactly."""
    data, ev = _fed_data()
    P = ravel_pytree(TMPL)[0].size
    ccfg = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=8)

    def ladder():
        out = []
        for ci in range(N_CLIENTS):
            prm = init_chunked_ae(jax.random.PRNGKey(7), ccfg)
            out.append([
                ComposedCompressor(
                    inner=ChunkedAECompressor(prm, ccfg), bits=8, block=64),
                QuantizeCompressor(bits=8)])
        return out

    def mk(n_rounds):
        cfg = FLConfig(n_rounds=n_rounds, local_epochs=1, payload="update")
        return FederatedRun(
            MNIST_CLASSIFIER, data, cfg, compressors=None, eval_data=ev,
            ratecontrol=ByteBudget(ladder=ladder(), budget=float("inf"),
                                   min_snapshots=1))

    full = mk(2)
    full.run()
    first = mk(1)
    first.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    first.save_state(path)
    resumed = mk(1)
    assert resumed.load_state(path) == 1
    # the restored rung's codec params are the bare AE pytree, applied to
    # the inner compressor (the historical convention)
    comp = resumed.ratecontrol._comps[0][0]
    assert isinstance(comp, ComposedCompressor)
    assert comp.codec_params() is not None
    resumed.run()
    for x, y in zip(jax.tree_util.tree_leaves(full.global_params),
                    jax.tree_util.tree_leaves(resumed.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

"""Analytic decode→aggregate roofline (repro.roofline.analysis, DESIGN.md
§11.3): the four server-aggregation variants produce finite arithmetic
intensities and %-of-roof placements on synthetic shapes, with the traffic
ordering the kernels were built to achieve."""
import math

import pytest

from repro.roofline.analysis import decode_agg_roofline

VARIANTS = ("loop", "vmap", "fused", "grouped")

SHAPES = [
    dict(cohort=8, n_chunks=128, latent=8, hidden=(32,), chunk=256),
    dict(cohort=64, n_chunks=120, latent=4, hidden=(32,), chunk=256,
         n_buckets=2),
    dict(cohort=1, n_chunks=1, latent=2, hidden=(), chunk=8),
    dict(cohort=256, n_chunks=4096, latent=8, hidden=(64, 32), chunk=512,
         n_buckets=4),
]


@pytest.mark.parametrize("shape", SHAPES)
def test_all_variants_finite_and_positive(shape):
    roof = decode_agg_roofline(**shape)
    for v in VARIANTS:
        r = roof[v]
        for field in ("flops", "hbm_bytes", "arith_intensity",
                      "pct_of_roof"):
            assert math.isfinite(r[field]) and r[field] > 0, (v, field, r)
        assert 0.0 < r["pct_of_roof"] <= 100.0
        assert r["bound"] in ("memory", "compute")
        assert r["launches"] >= 1
    assert math.isfinite(roof["machine"]["ridge_intensity"])


def test_variant_ordering_matches_design():
    roof = decode_agg_roofline(cohort=64, n_chunks=128, latent=8,
                               hidden=(32,), chunk=256, n_buckets=2)
    # same decoder math everywhere
    assert len({roof[v]["flops"] for v in VARIANTS}) == 1
    # traffic strictly shrinks loop → vmap → fused → grouped (the fused
    # paths never materialize the C× reconstruction block; the grouped
    # launch additionally dedupes decoder-stack reads)
    assert roof["loop"]["hbm_bytes"] > roof["vmap"]["hbm_bytes"]
    assert roof["vmap"]["hbm_bytes"] > roof["fused"]["hbm_bytes"]
    assert roof["fused"]["hbm_bytes"] > roof["grouped"]["hbm_bytes"]
    # so intensity (and roof placement) strictly improves
    assert (roof["grouped"]["arith_intensity"]
            > roof["fused"]["arith_intensity"]
            > roof["vmap"]["arith_intensity"])
    # launch accounting: C·B, B, B, 1
    assert roof["loop"]["launches"] == 64 * 2
    assert roof["vmap"]["launches"] == roof["fused"]["launches"] == 2
    assert roof["grouped"]["launches"] == 1


def test_rejects_degenerate_shapes():
    with pytest.raises(AssertionError):
        decode_agg_roofline(cohort=0, n_chunks=1, latent=1, hidden=(),
                            chunk=8)

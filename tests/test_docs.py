"""Documentation consistency: every ``DESIGN.md §x`` citation in src/ must
resolve to a real section heading, and the reader-facing docs must exist
and cross-link each other."""
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(name):
    with open(os.path.join(ROOT, name)) as f:
        return f.read()


def _design_anchors():
    """Section labels defined by DESIGN.md headings: '3', '3.1', ...,
    'Arch-applicability', 'Perf iteration log'."""
    anchors = set()
    for line in _read("DESIGN.md").splitlines():
        m = re.match(r"#+\s*§(\S+)", line)
        if m:
            anchors.add(m.group(1).strip())
    return anchors


def _cited_sections():
    """Every §x cited next to a DESIGN.md mention anywhere under src/."""
    cites = set()
    pat_after = re.compile(r"§([\w.-]+[\w])[^\w]*?in DESIGN\.md")
    pat_before = re.compile(r"DESIGN\.md\s*§([\w.-]+[\w])")
    for dirpath, _, files in os.walk(os.path.join(ROOT, "src")):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            text = _read(os.path.join(dirpath, fname))
            for pat in (pat_after, pat_before):
                cites.update(pat.findall(text))
    return cites


def test_every_design_citation_resolves():
    anchors = _design_anchors()
    assert anchors, "DESIGN.md has no § headings"
    cited = _cited_sections()
    assert cited, "expected DESIGN.md citations in src/"
    unresolved = {c for c in cited
                  if c not in anchors
                  # §3 may be cited as §3.x-style prose ("§3, 'assumption
                  # changes'"); a parent anchor resolves the citation too
                  and c.split(".")[0] not in anchors}
    assert not unresolved, f"dangling DESIGN.md citations: {unresolved}"


def test_readme_covers_entry_points():
    readme = _read("README.md")
    assert "python -m pytest -x -q" in readme          # tier-1 command
    assert "examples/quickstart.py" in readme
    assert "examples/fl_async_sampling.py" in readme
    assert "DESIGN.md" in readme
    # Eq. 4 savings-ratio formula is stated
    assert "CompressedSize" in readme and "OriginalSize" in readme


def test_docs_cross_link():
    assert "README.md" in _read("DESIGN.md")
    assert "DESIGN.md" in _read("CHANGES.md")

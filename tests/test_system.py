"""End-to-end system tests: the paper's full pipeline on CPU-sized configs.

1. Pre-pass → AE training → FC-AE-compressed FL (the paper's architecture,
   Figs. 2-3) reaching working accuracy.
2. The distributed FL round step (chunked-AE over the pod axis) executes on a
   degenerate (1,1,1) mesh and produces finite updated params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES
from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (FCAECompressor, FLConfig, FederatedRun, run_prepass,
                        validation_model_curve, fc_reconstruct)
from repro.data.pipeline import dirichlet_partition, mnist_like


@pytest.fixture(scope="module")
def prepass_result():
    ae_cfg = AEConfig(input_dim=15_910, encoder_hidden=(64,), latent_dim=32)
    data = mnist_like(0, 512)
    return run_prepass(jax.random.PRNGKey(0), MNIST_CLASSIFIER, ae_cfg,
                       data, prepass_epochs=12, ae_epochs=200), ae_cfg, data


def test_prepass_produces_weights_dataset(prepass_result):
    out, ae_cfg, _ = prepass_result
    assert out["weights_dataset"].shape == (12, 15_910)
    assert out["ae_history"]["loss"][-1] < out["ae_history"]["loss"][0]
    assert out["decoder_params"] > 0


def test_validation_model_tracks_original(prepass_result):
    """Paper §5.1 validation model: AE-predicted weights give a similar
    accuracy curve to the original weights (Figs. 5/7)."""
    out, ae_cfg, data = prepass_result
    curve = validation_model_curve(
        MNIST_CLASSIFIER, out["weights_dataset"],
        lambda w: fc_reconstruct(out["ae_params"], ae_cfg, w), data)
    orig = np.array(curve["original_acc"])
    pred = np.array(curve["predicted_acc"])
    # the final-epoch reconstruction must stay within 15 acc points
    assert abs(orig[-1] - pred[-1]) < 0.15
    assert pred[-1] > 0.5


def test_fl_with_ae_compression_end_to_end(prepass_result):
    """The paper's full FL setup: AE-compressed updates, 2 collaborators."""
    out, ae_cfg, _ = prepass_result
    # AE trained on raw weights also codes updates reasonably only if
    # trained on deltas; for the system test we train on weights and
    # compress weights-style payloads (paper's §5.2 protocol).
    from repro.data.pipeline import train_eval_split
    train, eval_data = train_eval_split(mnist_like(1, 768), 256)
    data = dirichlet_partition(0, train, 2, alpha=1.0)
    comp = [FCAECompressor(out["ae_params"], ae_cfg) for _ in range(2)]
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=2, local_epochs=1,
                                error_feedback=True),
                       compressors=comp, eval_data=eval_data)
    hist = run.run()
    assert hist[-1].compression_ratio > 300      # ~497x nominal
    assert np.isfinite(hist[-1].global_metrics["loss"])
    totals = run.total_bytes()
    assert totals["effective_ratio"] > 300


def test_distributed_fl_round_degenerate_mesh():
    """The chunked-AE pod-axis round step lowers AND executes on a (1,1,1)
    mesh — same code path the 512-chip dry-run compiles."""
    from repro.configs import get_config
    from repro.core.distributed import build_fl_round_step
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
    from repro.models import init_params
    from repro.models import sharding as shard_lib
    from repro.optim.optimizers import make_optimizer
    import dataclasses

    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    cfg = get_config("llama3-8b").reduced()
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16,
                                global_batch=2)
    ae_cfg = ChunkedAEConfig(chunk_size=128, hidden=(32,), latent_chunk=4)
    bundle = build_fl_round_step(cfg, shape, mesh, ae_cfg)

    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer(cfg.optimizer, cfg.learning_rate,
                         weight_decay=cfg.weight_decay,
                         grad_clip=cfg.grad_clip)
    opt_state = opt.init(params)
    ae_params = init_chunked_ae(jax.random.PRNGKey(1), ae_cfg)
    k = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(k, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(k, (2, 16), 0, cfg.vocab_size)}
    with mesh:
        jitted = jax.jit(
            bundle.fn,
            in_shardings=shard_lib.named(mesh, bundle.in_shardings),
            out_shardings=shard_lib.named(mesh, bundle.out_shardings))
        new_params, new_opt, metrics = jitted(params, opt_state, ae_params,
                                              batch)
    assert jnp.isfinite(metrics["loss"])
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(new_params)[0]
    assert l0.shape == l1.shape
    assert not jnp.allclose(l0, l1)


def test_input_specs_cover_all_shapes():
    """input_specs exist for every (arch × shape) — the dry-run contract."""
    from repro.configs import get_config
    from repro.launch.steps import batch_shapes, cache_shapes
    cfg = get_config("llama3-8b")
    for name, shape in SHAPES.items():
        b = batch_shapes(cfg, shape)
        assert b["tokens"].shape == (shape.global_batch, shape.seq_len)
        if shape.mode == "decode":
            c = cache_shapes(cfg, shape)
            assert c["index"].shape == ()

"""Per-architecture smoke tests: a REDUCED variant of each assigned arch
(≤2 layers, d_model ≤ 512, ≤4 experts) runs one forward/train step and one
decode step on CPU; asserts output shapes and finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import (decode_step, init_params, prefill, train_loss)
from repro.optim.optimizers import make_optimizer


def _batch(cfg, B=2, S=16, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k, (B, cfg.encdec.n_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            k, (B, cfg.vlm.n_image_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    opt = make_optimizer("adamw", 1e-3, grad_clip=1.0)
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            train_loss, has_aux=True)(p, cfg, b)
        p, s = opt.update(p, grads, s)
        return p, s, loss, metrics

    params2, state, loss, metrics = step(params, state, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss"
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params)[0]
    l1 = jax.tree_util.tree_leaves(params2)[0]
    assert not jnp.allclose(l0, l1)
    # a second step still finite (optimizer state exercised)
    _, _, loss2, _ = step(params2, state, batch)
    assert jnp.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    del batch["labels"]
    logits, cache = jax.jit(
        lambda p, b: prefill(p, cfg, b, cache_len=S + 4))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits))
    tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
    step = jax.jit(lambda p, t, c: decode_step(p, cfg, t, c))
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert logits.shape == (B, cfg.padded_vocab)
        assert jnp.all(jnp.isfinite(logits))
        tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(
            jnp.int32)
    assert int(cache["index"]) == S + 3


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    table = {
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "mamba2-2.7b": (64, 2560, 1, 1, 0, 50280),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
    }
    L, D, H, KV, FF, V = table[cfg.name]
    assert cfg.n_layers == L and cfg.d_model == D
    assert cfg.n_heads == H and cfg.n_kv_heads == KV
    assert cfg.d_ff == FF and cfg.vocab_size == V
    if cfg.name == "llama4-maverick-400b-a17b":
        assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 1
    if cfg.name == "dbrx-132b":
        assert cfg.moe.n_experts == 16 and cfg.moe.top_k == 4
    if cfg.name == "mamba2-2.7b":
        assert cfg.ssm.d_state == 128

"""Tests for the §Perf framework features: activation-sharding context,
2D inference sharding, decomposed-score attention, roofline model-FLOPs,
and chunk-size invariance of the SSD scan."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models.attention import flash_attention
from repro.models.partition_ctx import activation_sharding, \
    constrain_activations
from repro.models.ssm import ssd_scan

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


def test_constrain_activations_noop_without_context():
    x = jnp.ones((2, 4, 8))
    y = constrain_activations(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_constrain_activations_applies_in_context():
    """Under a 1-device mesh the constraint must be a semantic no-op."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(24, dtype=jnp.float32).reshape(2, 4, 3)
    with mesh:
        with activation_sharding(("data",), "model"):
            y = jax.jit(constrain_activations)(x)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fully_shard_adds_data_axis_to_big_leaves():
    from repro.launch.steps import param_shapes
    from repro.models import sharding as shard_lib
    # jax changed the AbstractMesh ctor across 0.4.x: older builds take
    # (shape, axis_names), 0.4.37+ takes a tuple of (name, size) pairs
    try:
        mesh = AbstractMesh((("data", 16), ("model", 16)))
    except TypeError:
        mesh = AbstractMesh((16, 16), ("data", "model"))
    cfg = get_config("llama3-8b")
    shapes = param_shapes(cfg)
    specs = shard_lib.param_specs(shapes, mesh)
    specs2 = shard_lib.fully_shard(specs, shapes, mesh)
    flat1 = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    flat2 = jax.tree_util.tree_leaves(
        specs2, is_leaf=lambda x: isinstance(x, P))
    more = sum(1 for a, b in zip(flat1, flat2)
               if a != b and "data" in str(b))
    assert more > 0
    # all still divisibility-valid
    def check(shp, spec):
        for dim, axis in zip(shp.shape, tuple(spec) + (None,) * 8):
            if axis is not None:
                axes = axis if isinstance(axis, tuple) else (axis,)
                tot = 1
                for a in axes:
                    tot *= mesh.shape[a]
                assert dim % tot == 0
    jax.tree_util.tree_map(check, shapes, specs2)


def test_flash_attention_extra_qk_matches_concat():
    """Decomposed scores == concatenated q/k (the MLA formulation)."""
    k = jax.random.PRNGKey(0)
    B, S, H, D, P2 = 2, 33, 4, 16, 8
    q1 = jax.random.normal(k, (B, S, H, D))
    k1 = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, D))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, D))
    q2 = jax.random.normal(jax.random.PRNGKey(3), (B, S, H, P2))
    k2 = jax.random.normal(jax.random.PRNGKey(4), (B, S, P2))

    scale = (D + P2) ** -0.5
    got = flash_attention(q1, k1, v, extra_qk=(q2, k2), scale=scale,
                          q_chunk=16, kv_chunk=16)
    q_cat = jnp.concatenate([q1, q2], axis=-1)
    k_cat = jnp.concatenate(
        [k1, jnp.broadcast_to(k2[:, :, None, :], (B, S, H, P2))], axis=-1)
    want = flash_attention(q_cat, k_cat, v, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-3)


@hypothesis.given(st.sampled_from([2, 3, 5, 7, 16, 23]))
def test_ssd_scan_chunk_invariance(chunk):
    """SSD output must not depend on the chunk size (dual-form identity)."""
    k = jax.random.PRNGKey(0)
    b, s, h, p, g, n = 1, 24, 2, 4, 1, 3
    x = jax.random.normal(k, (b, s, h, p)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1),
                                            (b, s, h)))
    B = jax.random.normal(jax.random.PRNGKey(2), (b, s, g, n)) * 0.5
    C = jax.random.normal(jax.random.PRNGKey(3), (b, s, g, n)) * 0.5
    y_ref, st_ref = ssd_scan(x, dA, B, C, chunk=s)
    y, stt = ssd_scan(x, dA, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(stt), np.asarray(st_ref),
                               atol=1e-4, rtol=1e-3)


def test_model_flops_estimates_positive_and_ordered():
    from repro.roofline.analysis import attention_flops, model_flops
    cfg = get_config("llama3-8b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_prefill = model_flops(cfg, SHAPES["prefill_32k"])
    f_decode = model_flops(cfg, SHAPES["decode_32k"])
    assert f_train > f_prefill > f_decode > 0
    # at 32k, attention is a large fraction of prefill (≥35% for llama3;
    # higher for wider-headed archs like deepseek)
    assert attention_flops(cfg, SHAPES["prefill_32k"]) > \
        0.25 * f_prefill
    # ssm arch: no attention flops
    assert attention_flops(get_config("mamba2-2.7b"),
                           SHAPES["prefill_32k"]) == 0.0


def test_compressed_fraction_matches_config():
    from repro.core.autoencoder import ChunkedAEConfig
    from repro.core.distributed import compressed_fraction
    ae = ChunkedAEConfig(chunk_size=512, hidden=(64,), latent_chunk=16)
    tree = {"w": jnp.zeros((1024, 512))}         # divides evenly
    frac = compressed_fraction(tree, ae)
    assert frac == pytest.approx(16 / 512, rel=1e-6)

"""AE training lifecycle (DESIGN.md §8): scan-trainer ≡ eager-oracle
equivalence, cohort-vmap ≡ sequential fits, warm-start semantics, tail-batch
inclusion, decoder-sync accounting across all three schedulers, Eq. 4–6
reconciliation, and client-state checkpoint round-trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (AELifecycle, AsyncBuffered, FCAECompressor, FLConfig,
                        FederatedRun, LatencyModel, QuantizeCompressor,
                        SampledSync, SavingsModel, SyncFedAvg,
                        decoder_sync_bytes, train_autoencoder,
                        train_autoencoder_cohort, train_autoencoder_eager,
                        train_autoencoder_scan)
from repro.core import autoencoder as ae
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)

AE_CFG = AEConfig(input_dim=128, encoder_hidden=(32,), latent_dim=8)


def _weights_data(n, seed=0, dim=128):
    """Low-rank structured rows — weight-trajectory-like, compressible."""
    z = jax.random.normal(jax.random.PRNGKey(seed), (n, 4))
    basis = jax.random.normal(jax.random.PRNGKey(seed + 1), (4, dim))
    noise = jax.random.normal(jax.random.PRNGKey(seed + 2), (n, dim))
    return z @ basis + 0.01 * noise


def _tree_close(a, b, **kw):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


# ------------------------------------------------- scan ≡ eager (tentpole)
@pytest.mark.parametrize("n", [26, 29])   # train 21 (tail of 5) / 24 (÷8)
def test_scan_trainer_matches_eager_oracle(n):
    """The lax.scan trainer must reproduce the eager loop — params AND the
    full history — for both a divisible and a trailing-partial-batch train
    set. Float tolerance, not bit-for-bit (repo convention: one fused XLA
    computation reassociates, ~1 ulp per op chain)."""
    data = _weights_data(n)
    kw = dict(epochs=30, batch_size=8)
    pe, he = train_autoencoder_eager(jax.random.PRNGKey(3), AE_CFG, data,
                                     **kw)
    ps, hs = train_autoencoder_scan(jax.random.PRNGKey(3), AE_CFG, data,
                                    **kw)
    _tree_close(pe, ps, atol=1e-5, rtol=1e-4)
    assert set(he) == set(hs)
    for k in he:
        np.testing.assert_allclose(he[k], hs[k], atol=1e-5, rtol=1e-4)


def test_train_autoencoder_dispatches_scan_by_default():
    data = _weights_data(12)
    p_default, _ = train_autoencoder(jax.random.PRNGKey(0), AE_CFG, data,
                                     epochs=5)
    p_scan, _ = train_autoencoder_scan(jax.random.PRNGKey(0), AE_CFG, data,
                                       epochs=5)
    _tree_close(p_default, p_scan, atol=0, rtol=0)


def test_eager_trainer_includes_trailing_partial_batch():
    """Regression (bugfix): with n_train=10, bs=8 the seed loop ran ONE
    8-row batch per epoch and silently dropped 2 samples; both trainers
    must now step twice per epoch (the Adam step count is observable via
    bias correction — compare against a hand-rolled two-batch epoch)."""
    data = _weights_data(13)              # val 2 → train 11, bs 8 → 8 + 3
    # one epoch so the batch partition is the only degree of freedom
    pe, he = train_autoencoder_eager(jax.random.PRNGKey(5), AE_CFG, data,
                                     epochs=1, batch_size=8)
    # hand-rolled oracle: same split/shuffle, explicit [0:8] + [8:11]
    params, train_set, _val, k_shuf, bs = ae._train_setup(
        jax.random.PRNGKey(5), AE_CFG, data, kind="fc", batch_size=8,
        val_fraction=0.2, init=None, refit_normalizer=None)
    assert train_set.shape[0] == 11 and bs == 8
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)
    _, k = jax.random.split(k_shuf)
    shuffled = train_set[jax.random.permutation(k, 11)]
    losses = []
    for t, sl in ((1, slice(0, 8)), (2, slice(8, 11))):
        loss, g = jax.value_and_grad(
            lambda p, x: ae.ae_loss(p, AE_CFG, x, "fc"))(params, shuffled[sl])
        g = dict(g, norm=jax.tree_util.tree_map(jnp.zeros_like, g["norm"]))
        params, m, v = ae._adam_update(params, g, m, v, t, 3e-3)
        losses.append(float(loss))
    # jitted-vs-unjitted op chains differ at ~1e-5; a dropped tail batch
    # would differ at the Adam-step scale (~lr = 3e-3), 100x above this
    _tree_close(pe, params, atol=2e-5, rtol=1e-4)
    assert he["loss"][0] == pytest.approx(sum(losses) / 2, rel=1e-5)


def test_scan_trainer_conv_kind_matches_eager():
    cfg = ae.ConvAEConfig(channels=(4,), kernel=5, stride=4,
                          latent_channels=1)
    data = _weights_data(10, dim=64)
    kw = dict(kind="conv", epochs=8, batch_size=4)
    pe, he = train_autoencoder_eager(jax.random.PRNGKey(1), cfg, data, **kw)
    ps, hs = train_autoencoder_scan(jax.random.PRNGKey(1), cfg, data, **kw)
    _tree_close(pe, ps, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(he["loss"], hs["loss"], atol=1e-5, rtol=1e-4)


# ------------------------------------------------- cohort vmap ≡ sequential
def test_cohort_vmap_matches_sequential_scan_fits():
    C = 4
    rngs = jax.random.split(jax.random.PRNGKey(7), C)
    datasets = jnp.stack([_weights_data(18, seed=10 * i) for i in range(C)])
    kw = dict(epochs=20, batch_size=8)
    stacked, hist = train_autoencoder_cohort(rngs, AE_CFG, datasets, **kw)
    assert np.asarray(hist["loss"]).shape == (C, 20)
    for ci in range(C):
        p1, h1 = train_autoencoder_scan(rngs[ci], AE_CFG, datasets[ci], **kw)
        got = jax.tree_util.tree_map(lambda x, ci=ci: x[ci], stacked)
        _tree_close(got, p1, atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(hist["loss"][ci]), h1["loss"],
                                   atol=1e-5, rtol=1e-4)


# ------------------------------------------------- warm-start semantics
def test_warm_start_keeps_normalizer_and_resets_moments():
    """init= warms the weights only (DESIGN.md §8.1): normalizer untouched
    unless refit_normalizer=True, Adam bias correction restarts at t=1
    (first-step update magnitude ≈ lr, the fresh-moments signature)."""
    data = _weights_data(20)
    p0, _ = train_autoencoder_scan(jax.random.PRNGKey(0), AE_CFG, data,
                                   epochs=10)
    drifted = data * 3.0
    # batch_size ≥ n_train ⇒ exactly ONE Adam step in the probe epoch
    warm, _ = train_autoencoder_scan(jax.random.PRNGKey(1), AE_CFG, drifted,
                                     epochs=1, batch_size=16, init=p0)
    assert float(warm["norm"]["std"]) == float(p0["norm"]["std"])
    assert float(warm["norm"]["mean"]) == float(p0["norm"]["mean"])
    refit, _ = train_autoencoder_scan(jax.random.PRNGKey(1), AE_CFG, drifted,
                                      epochs=1, batch_size=16, init=p0,
                                      refit_normalizer=True)
    assert float(refit["norm"]["std"]) != float(p0["norm"]["std"])
    # fresh bias-corrected Adam: the first step is ~lr per coordinate
    # (m̂/(√v̂+ε) ≈ ±1) and never exceeds it; a stale carried-over t would
    # leave m̂ un-boosted and the step far below lr
    delta = np.abs(np.asarray(warm["enc"][0]["w"] - p0["enc"][0]["w"]))
    assert 0.5 * 3e-3 < np.median(delta[delta > 0]) <= 3e-3 * 1.01


def test_warm_start_continues_training_from_init():
    """The previously-uncovered init= path must actually warm-start: a
    short refit from trained params beats the same budget from scratch."""
    data = _weights_data(24)
    p0, _ = train_autoencoder_scan(jax.random.PRNGKey(0), AE_CFG, data,
                                   epochs=40)
    drifted = data * 1.2
    _, h_warm = train_autoencoder_scan(jax.random.PRNGKey(2), AE_CFG,
                                       drifted, epochs=5, init=p0)
    _, h_cold = train_autoencoder_scan(jax.random.PRNGKey(2), AE_CFG,
                                       drifted, epochs=5)
    assert h_warm["loss"][-1] < h_cold["loss"][-1]


# ------------------------------------------------- lifecycle + accounting
def _ae_comps(n, ae_cfg):
    """Untrained per-client AEs — codec quality is irrelevant to the
    accounting under test, and skipping the pre-pass keeps this fast."""
    return [FCAECompressor(
        ae.init_fc_ae(jax.random.PRNGKey(100 + i), ae_cfg), ae_cfg)
        for i in range(n)]


MNIST_AE_SMALL = AEConfig(input_dim=15_910, encoder_hidden=(16,),
                          latent_dim=8)


def _lifecycle_run(scheduler, n_rounds=3, n_clients=4, lifecycle=None):
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, n_clients)
    comps = _ae_comps(n_clients, MNIST_AE_SMALL)
    lc = lifecycle if lifecycle is not None else AELifecycle(
        refresh_every=1, min_snapshots=1, refresh_epochs=2, batch_size=4)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=n_rounds, local_epochs=1, payload="weights"),
        compressors=comps, eval_data=ev, scheduler=scheduler, lifecycle=lc)
    return run, run.run()


@pytest.mark.parametrize("make_sched", [
    SyncFedAvg,
    lambda: SampledSync(cohort=2),
    lambda: AsyncBuffered(buffer_k=4, latency=LatencyModel()),
], ids=["sync", "sampled", "async"])
def test_every_scheduler_charges_decoder_syncs_to_bytes_down(make_sched):
    """Acceptance: each scheduler's bytes_down must include the decoder
    bytes of every AE sync (initial ship + refreshes), itemized in
    bytes_decoder/ae_syncs, with per-sync bytes equal to the shipped
    decoder tree exactly."""
    run, hist = _lifecycle_run(make_sched())
    per_sync = decoder_sync_bytes(run.compressors[0].params)
    total_syncs = 0
    for rec in hist:
        assert rec.ae_syncs is not None
        assert set(rec.ae_syncs) <= set(rec.participants)
        assert rec.bytes_decoder == pytest.approx(
            len(rec.ae_syncs) * per_sync)
        assert rec.bytes_down == rec.bytes_down_raw
        # downlink = model broadcast to participants + decoder syncs
        assert rec.bytes_down >= rec.bytes_decoder
        if rec.ae_syncs:
            assert rec.bytes_down > rec.bytes_decoder  # broadcast still there
        total_syncs += len(rec.ae_syncs)
    # round 0 ships every participant's initial decoder; refresh_every=1
    # refits on every later participation
    assert total_syncs > len(hist[0].participants)
    assert run.total_bytes()["bytes_decoder"] == pytest.approx(
        sum(r.bytes_decoder for r in hist))


def test_decoder_sync_bytes_reconcile_with_savings_model():
    """Satellite: observed per-refresh bytes must match Eq. 5/6's
    DecoderSize (AutoencoderSize/2) up to the documented structural gap
    (decoder-half bias asymmetry + the 2-scalar normalizer, ≲5%)."""
    run, hist = _lifecycle_run(SyncFedAvg(), n_rounds=3)
    model = SavingsModel(
        original_size=15_910, compressed_size=MNIST_AE_SMALL.latent_dim,
        autoencoder_size=ae.ae_param_count(run.compressors[0].params),
        n_decoders=4)
    report = run.savings_report(model)
    assert report["decoder_syncs"] == sum(len(r.ae_syncs) for r in hist)
    per_sync = decoder_sync_bytes(run.compressors[0].params)
    assert report["observed_decoder_bytes"] == pytest.approx(
        report["decoder_syncs"] * per_sync)
    assert report["decoder_rel_err"] < 0.05
    assert report["savings_rel_err"] < 0.05
    assert report["observed_savings_ratio"] > 0


def test_lifecycle_refresh_updates_compressor_params_and_baseline():
    run, hist = _lifecycle_run(SyncFedAvg(), n_rounds=2)
    # refresh_every=1: every client refit in round 1 → params moved
    assert hist[1].ae_syncs == [0, 1, 2, 3]
    for ci in range(4):
        st = run.clients[ci]
        assert st.last_refresh == 1
        assert st.ae_baseline is not None and np.isfinite(st.ae_baseline)
        assert 1 <= len(st.snapshots) <= 8


def test_drift_trigger_plumbing():
    """drift_ratio triggers exactly when the relative reconstruction error
    exceeds ratio × baseline: a huge ratio never refits, an always-under
    ratio refits every round once min_snapshots is met."""
    never = AELifecycle(drift_ratio=1e9, min_snapshots=1, refresh_epochs=2)
    _, hist = _lifecycle_run(SyncFedAvg(), n_rounds=3, lifecycle=never)
    assert [r.ae_syncs for r in hist] == [[0, 1, 2, 3], [], []]
    always = AELifecycle(drift_ratio=0.0, min_snapshots=1, refresh_epochs=2,
                         batch_size=4)
    _, hist = _lifecycle_run(SyncFedAvg(), n_rounds=3, lifecycle=always)
    assert hist[1].ae_syncs == [0, 1, 2, 3]
    assert hist[2].ae_syncs == [0, 1, 2, 3]


def test_lifecycle_refreshes_chunked_ae_on_chunk_rows():
    """The chunked AE refits its shared funnel on every chunk of every
    snapshot (DESIGN.md §8.2) — and its decoder syncs are charged the same
    way as the FC AE's."""
    from repro.core import ChunkedAECompressor
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae

    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 2)
    ccfg = ChunkedAEConfig(chunk_size=2048, hidden=(16,), latent_chunk=4)
    comps = [ChunkedAECompressor(
        init_chunked_ae(jax.random.PRNGKey(i), ccfg), ccfg, use_kernel=False)
        for i in range(2)]
    before = [jax.tree_util.tree_map(jnp.copy, c.params) for c in comps]
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        compressors=comps, eval_data=ev,
        lifecycle=AELifecycle(refresh_every=1, min_snapshots=1,
                              refresh_epochs=2, batch_size=4))
    hist = run.run()
    assert hist[1].ae_syncs == [0, 1]
    per_sync = decoder_sync_bytes(comps[0].params)
    assert hist[1].bytes_decoder == pytest.approx(2 * per_sync)
    for c, b in zip(comps, before):       # refit actually moved the params
        assert any(
            not np.allclose(np.asarray(x), np.asarray(y))
            for x, y in zip(jax.tree_util.tree_leaves(c.params["dec"]),
                            jax.tree_util.tree_leaves(b["dec"])))


def test_lifecycle_ignores_pointwise_codecs():
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        compressors=[QuantizeCompressor(bits=8) for _ in range(2)],
        eval_data=ev,
        lifecycle=AELifecycle(refresh_every=1, min_snapshots=1))
    hist = run.run()
    for rec in hist:
        assert rec.ae_syncs == [] and rec.bytes_decoder == 0.0
    assert all(c.snapshots == [] for c in run.clients)


# ------------------------------------------------- checkpoint round-trips
def test_client_state_checkpoint_roundtrip(tmp_path):
    """Satellite (bugfix): save/load must persist per-client ClientState —
    EF residuals, AE snapshot buffers, and lifecycle scalars."""
    from repro.checkpoint.checkpoint import (load_federated_state,
                                             save_federated_state)
    run, _ = _lifecycle_run(SyncFedAvg(), n_rounds=2)
    path = os.path.join(tmp_path, "state.npz")
    save_federated_state(path, 2, run.global_params, clients=run.clients)
    rnd, gp, meta = load_federated_state(path, run.global_params)
    assert rnd == 2
    _tree_close(gp, run.global_params, atol=0, rtol=0)
    restored = meta["client_states"]
    assert len(restored) == len(run.clients)
    for got, want in zip(restored, run.clients):
        assert got.version == want.version
        assert got.last_refresh == want.last_refresh
        assert got.ae_baseline == pytest.approx(want.ae_baseline)
        assert len(got.snapshots) == len(want.snapshots)
        for a, b in zip(got.snapshots, want.snapshots):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if want.residual is None:
            assert got.residual is None
        else:
            _tree_close(got.residual, want.residual, atol=0, rtol=0)


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Satellite (bugfix): a 2+2-round run checkpointed in the middle must
    equal the 4-round run — in particular the error-feedback residuals must
    survive the round-trip (the seed checkpoint silently reset them)."""
    train, ev = train_eval_split(mnist_like(0, 384), 128)
    data = uniform_partition(0, train, 2)

    def mk(n_rounds):
        return FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=n_rounds, local_epochs=1,
                     error_feedback=True, payload="update"),
            compressors=[QuantizeCompressor(bits=4) for _ in range(2)],
            eval_data=ev)

    full = mk(4)
    hist_full = full.run()
    first = mk(2)
    first.run()
    assert first.clients[0].residual is not None    # EF state exists to lose
    path = os.path.join(tmp_path, "resume.npz")
    first.save_state(path)
    resumed = mk(2)
    assert resumed.load_state(path) == 2
    hist_resumed = resumed.run()
    _tree_close(full.global_params, resumed.global_params, atol=0, rtol=0)
    for a, b in zip(hist_full[2:], hist_resumed):
        assert a.round == b.round
        assert a.bytes_up == b.bytes_up
        assert a.global_metrics == b.global_metrics


def test_async_scheduler_resumes_without_crashing(tmp_path):
    """Regression: load_state replaces ``run.clients``, but AsyncBuffered's
    event heap was dispatched against the ORIGINAL ClientState objects at
    bind time — without ``on_restore`` the first resumed round trained on
    ``dispatched=None`` and computed negative staleness (0**-0.5 crash).
    Async resume restarts the simulation from dispatch (documented)."""
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 4)

    def mk():
        return FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=2, local_epochs=1, payload="update"),
            eval_data=ev,
            scheduler=AsyncBuffered(buffer_k=2, latency=LatencyModel()))

    first = mk()
    first.run()
    path = os.path.join(tmp_path, "async.npz")
    first.save_state(path)
    resumed = mk()
    assert resumed.load_state(path) == 2
    hist = resumed.run()
    assert [r.round for r in hist] == [2, 3]
    for rec in hist:
        assert all(s >= 0 for s in rec.staleness)
        assert np.isfinite(rec.global_metrics["loss"])


def test_resume_restores_refitted_ae_codec_params(tmp_path):
    """A lifecycle refit MOVES the compressors' AE params; a resume that
    rebuilt them from the pre-pass would silently revert every decoder
    (while last_refresh/ae_baseline still described the refit one).
    save_state/load_state must round-trip the codec params and reproduce
    the uninterrupted run."""
    train, ev = train_eval_split(mnist_like(0, 256), 64)
    data = uniform_partition(0, train, 2)

    def mk(n_rounds):
        return FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=n_rounds, local_epochs=1, payload="weights"),
            compressors=_ae_comps(2, MNIST_AE_SMALL), eval_data=ev,
            lifecycle=AELifecycle(refresh_every=1, min_snapshots=1,
                                  refresh_epochs=2, batch_size=4))

    full = mk(4)
    hist_full = full.run()
    first = mk(2)
    first.run()
    assert first.clients[0].last_refresh == 1      # a refit happened
    path = os.path.join(tmp_path, "resume_ae.npz")
    first.save_state(path)
    resumed = mk(2)                                 # pre-pass compressors...
    assert resumed.load_state(path) == 2            # ...restored to refit
    for got, want in zip(resumed.compressors, first.compressors):
        _tree_close(got.params, want.params, atol=0, rtol=0)
    hist_resumed = resumed.run()
    _tree_close(full.global_params, resumed.global_params, atol=0, rtol=0)
    for a, b in zip(hist_full[2:], hist_resumed):
        assert a.round == b.round
        assert a.ae_syncs == b.ae_syncs
        assert a.bytes_decoder == b.bytes_decoder
        assert a.global_metrics == b.global_metrics

"""Per-layer codec partitions (DESIGN.md §10): partition-map invariants,
encode→decode round trips, wire-byte pricing, identity-partition ≡ flat
equivalence (unit + property-based via hypothesis, stub fallback), the
grouped fused server path's call accounting, per-partition lifecycle
decoder ships, per-partition savings reconciliation, and per-(client,
partition) rate control."""
try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:       # dev extra absent: property tests skip
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (AELifecycle, ByteBudget, ChunkedAECompressor,
                        ChunkedAEConfig, ComposedCompressor,
                        DistortionTarget, FCAECompressor, FLConfig,
                        FederatedRun, IdentityCompressor,
                        PartitionedCompressor, QuantizeCompressor,
                        SampledSync, SavingsModel, SyncFedAvg,
                        TopKCompressor, by_layer_partition,
                        by_leaf_partition, codec, decoder_sync_bytes,
                        identity_partition, init_chunked_ae, init_fc_ae,
                        partition, partition_ladder, tree_bytes,
                        wire_bytes, wire_bytes_by_group)
from repro.core import autoencoder as ae
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)
from repro.models.classifiers import init_classifier

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=15,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

TMPL = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)
P = int(ravel_pytree(TMPL)[0].size)                       # 15910


def _tree_close(a, b, **kw):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def _federation(n_clients, n=256, n_eval=64):
    train, ev = train_eval_split(mnist_like(0, n), n_eval)
    return uniform_partition(0, train, n_clients), ev


def _compressor_for(kind: str, size: int, seed: int = 0):
    """One sub-compressor per codec family, sized for a partition group —
    the six-way zoo the partition layer must compose with."""
    if kind == "identity":
        return IdentityCompressor()
    if kind == "q8":
        return QuantizeCompressor(bits=8, block=64)
    if kind == "q4":
        return QuantizeCompressor(bits=4, block=64)
    if kind == "topk":
        return TopKCompressor(fraction=0.1)
    if kind == "fc_ae":
        cfg = AEConfig(input_dim=max(size, 8), encoder_hidden=(8,),
                       latent_dim=4)
        return FCAECompressor(init_fc_ae(jax.random.PRNGKey(seed), cfg),
                              cfg)
    if kind == "chunked_ae":
        cfg = ChunkedAEConfig(chunk_size=32, hidden=(8,), latent_chunk=4)
        return ChunkedAECompressor(init_chunked_ae(
            jax.random.PRNGKey(seed), cfg), cfg, use_kernel=False)
    if kind == "composed":
        cfg = ChunkedAEConfig(chunk_size=32, hidden=(8,), latent_chunk=4)
        return ComposedCompressor(ChunkedAECompressor(init_chunked_ae(
            jax.random.PRNGKey(seed), cfg), cfg, use_kernel=False), bits=8)
    raise ValueError(kind)


ALL_KINDS = ("identity", "q8", "q4", "topk", "fc_ae", "chunked_ae",
             "composed")


# ----------------------------------------------------- map/spec invariants
def test_partition_map_rejects_gaps_overlaps_and_duplicates():
    with pytest.raises(AssertionError, match="gap/overlap"):
        partition.PartitionMap(groups=(("a", ((0, 4),)), ("b", ((5, 3),))))
    with pytest.raises(AssertionError, match="gap/overlap"):
        partition.PartitionMap(groups=(("a", ((0, 4),)), ("b", ((2, 4),))))
    with pytest.raises(AssertionError, match="duplicate"):
        partition.PartitionMap(groups=(("a", ((0, 4),)), ("a", ((4, 4),))))


def test_partition_spec_rejects_mis_sized_group_codec():
    pm = partition.PartitionMap(groups=(("a", ((0, 8),)), ("b", ((8, 4),))))
    with pytest.raises(AssertionError, match="sized"):
        partition.make_partition_spec(
            pm, {"a": codec.QuantizeSpec(size=7),
                 "b": codec.QuantizeSpec(size=4)})


def test_builders_tile_the_model_exactly():
    for pm in (identity_partition(TMPL), by_leaf_partition(TMPL),
               by_layer_partition(TMPL)):
        assert pm.size == P
        assert sum(pm.group_size(n) for n in pm.names) == P
    assert by_layer_partition(TMPL).names == ("dense0", "dense1")


def test_partition_spec_is_hashable_jit_static():
    pm = by_layer_partition(TMPL)
    spec = partition.make_partition_spec(
        pm, {n: codec.QuantizeSpec(size=pm.group_size(n))
             for n in pm.names})
    assert hash(spec) == hash(spec)
    flat = jax.random.normal(jax.random.PRNGKey(0), (P,))
    out = jax.jit(lambda x: codec.decode(
        spec, None, codec.encode(spec, None, x)))(flat)
    assert out.shape == (P,) and out.dtype == flat.dtype


# ------------------------------------------------- round trips and pricing
@pytest.mark.parametrize("kinds", [
    ("q8", "identity"), ("fc_ae", "q4"), ("chunked_ae", "topk"),
    ("composed", "q8")])
def test_mixed_partition_roundtrip_and_wire_bytes(kinds):
    """Mixed per-layer specs: encode→decode preserves shape/dtype, and the
    eval-shape price — per group and total — equals the real encode's
    bytes (the single pricing rule, DESIGN.md §9.1/§10.3)."""
    pm = by_layer_partition(TMPL)
    comp = PartitionedCompressor(pm, {
        name: _compressor_for(kind, pm.group_size(name), seed=i)
        for i, (name, kind) in enumerate(zip(pm.names, kinds))})
    flat = jax.random.normal(jax.random.PRNGKey(1), (P,)) * 0.1
    spec = comp.spec(P)
    params = comp.codec_params()
    payload = codec.encode(spec, params, flat)
    assert set(payload) == set(pm.names)
    decoded = codec.decode(spec, params, payload)
    assert decoded.shape == flat.shape and decoded.dtype == flat.dtype
    by_group = wire_bytes_by_group(spec, params)
    assert sum(by_group.values()) == wire_bytes(spec, params)
    for name in pm.names:
        assert by_group[name] == tree_bytes(payload[name])


def test_identity_partition_decode_is_bitexact_flat():
    """The compatibility partition: encode/decode through a single
    all-leaves group must be bit-identical to the flat codec path for
    every codec family."""
    pm = identity_partition(TMPL)
    flat = jax.random.normal(jax.random.PRNGKey(2), (P,)) * 0.1
    for kind in ALL_KINDS:
        sub = _compressor_for(kind, P)
        pcomp = PartitionedCompressor(pm, {"all": sub})
        d_part = codec.decode(pcomp.spec(P), pcomp.codec_params(),
                              codec.encode(pcomp.spec(P),
                                           pcomp.codec_params(), flat))
        d_flat = codec.decode(sub.spec(P), sub.codec_params(),
                              codec.encode(sub.spec(P), sub.codec_params(),
                                           flat))
        assert bool(jnp.all(d_part == d_flat)), kind


def test_partitioned_decode_batched_matches_per_client():
    pm = by_layer_partition(TMPL)
    comp = PartitionedCompressor(pm, {"dense0": QuantizeCompressor(bits=8),
                                      "dense1": IdentityCompressor()})
    spec = comp.spec(P)
    flats = [jax.random.normal(jax.random.PRNGKey(i), (P,)) for i in range(3)]
    payloads = [codec.encode(spec, None, f) for f in flats]
    rows = codec.decode_batched(spec, None, codec.stack_payloads(payloads))
    want = jnp.stack([codec.decode(spec, None, pl) for pl in payloads])
    np.testing.assert_allclose(np.asarray(rows), np.asarray(want),
                               atol=1e-6, rtol=1e-5)


# ----------------------------------------------------------- property tests
@hypothesis.given(st.data())
def test_property_random_partition_roundtrip_invariants(data):
    """Random pytrees × random partition maps × all six codec families:
    encode→decode keeps shape/dtype, payload keys match group names, and
    ``wire_bytes`` (total and per group) equals the real encode's bytes."""
    n_leaves = data.draw(st.integers(1, 4), label="n_leaves")
    shapes = [data.draw(st.sampled_from([(7,), (24,), (5, 9), (16, 4)]),
                        label=f"shape{i}") for i in range(n_leaves)]
    tree = {f"leaf{i}": jax.random.normal(jax.random.PRNGKey(i), s)
            for i, s in enumerate(shapes)}
    flat, _ = ravel_pytree(tree)
    # random grouping: each leaf assigned a bucket label, buckets → groups
    labels = [data.draw(st.integers(0, min(i, 2)), label=f"grp{i}")
              for i in range(n_leaves)]
    pm = partition.by_layer_partition(
        tree, key_fn=lambda path: f"g{labels[int(path.split('/')[0][4:])]}")
    kinds = {name: data.draw(st.sampled_from(ALL_KINDS),
                             label=f"kind_{name}") for name in pm.names}
    comp = PartitionedCompressor(pm, {
        name: _compressor_for(kinds[name], pm.group_size(name))
        for name in pm.names})
    spec = comp.spec(pm.size)
    params = comp.codec_params()
    payload = codec.encode(spec, params, flat)
    assert set(payload) == set(pm.names)
    decoded = codec.decode(spec, params, payload)
    assert decoded.shape == flat.shape and decoded.dtype == flat.dtype
    by_group = wire_bytes_by_group(spec, params)
    for name in pm.names:
        assert by_group[name] == tree_bytes(payload[name])
    assert wire_bytes(spec, params) == tree_bytes(payload)


@hypothesis.given(st.integers(2, 60), st.sampled_from(ALL_KINDS),
                  st.integers(0, 10 ** 6))
def test_property_identity_partition_equals_flat(n, kind, seed):
    """For ANY size and codec family, the identity partition's round trip
    is bit-identical to the flat codec's."""
    tree = {"w": jax.random.normal(
        jax.random.PRNGKey(seed % 2 ** 31), (n,))}
    flat, _ = ravel_pytree(tree)
    pm = partition.identity_partition(tree)
    sub = _compressor_for(kind, n, seed=seed % 97)
    pcomp = PartitionedCompressor(pm, {"all": sub})
    d_part = codec.decode(pcomp.spec(n), pcomp.codec_params(),
                          codec.encode(pcomp.spec(n),
                                       pcomp.codec_params(), flat))
    d_flat = codec.decode(sub.spec(n), sub.codec_params(),
                          codec.encode(sub.spec(n), sub.codec_params(),
                                       flat))
    assert bool(jnp.all(d_part == d_flat))


@hypothesis.given(st.integers(2, 5), st.integers(0, 10 ** 6))
def test_property_partitioned_fused_agg_equals_sequential(c, seed):
    """Partitioned fused decode→aggregate over a random cohort equals the
    sequential per-client decode + weighted mean (the repo's 1-ulp rule)."""
    pm = by_layer_partition(TMPL)
    spec = partition.make_partition_spec(
        pm, {"dense0": codec.QuantizeSpec(size=pm.group_size("dense0")),
             "dense1": codec.IdentitySpec(size=pm.group_size("dense1"))})
    flats = [jax.random.normal(
        jax.random.PRNGKey((seed + i) % 2 ** 31), (P,)) for i in range(c)]
    payloads = [codec.encode(spec, None, f) for f in flats]
    w = jnp.asarray([1.0 / c] * c, jnp.float32)
    got = codec.decode_and_aggregate(spec, None,
                                     codec.stack_payloads(payloads), w)
    want = jnp.mean(jnp.stack([codec.decode(spec, None, pl)
                               for pl in payloads]), axis=0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-5)


# ------------------------------------- scheduler equivalence (acceptance)
@pytest.mark.parametrize("make_sched", [
    lambda: None,                                  # SyncFedAvg default
    lambda: SampledSync(cohort=2),
], ids=["sync", "sampled"])
def test_identity_partition_run_matches_flat_run(make_sched):
    """Acceptance: identity-partition runs reproduce today's flat
    trajectories at the 1-ulp tolerance rule (records AND params), for the
    sync schedulers. (AsyncBuffered is covered by the resume matrix — its
    event loop is scheduler state, not codec state.)"""
    data, ev = _federation(3)
    cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update",
                   error_feedback=True)

    def mk(partitioned_):
        comps = []
        for _ in range(3):
            if partitioned_:
                comps.append(PartitionedCompressor(
                    identity_partition(TMPL),
                    {"all": QuantizeCompressor(bits=8)}))
            else:
                comps.append(QuantizeCompressor(bits=8))
        return FederatedRun(MNIST_CLASSIFIER, data, cfg, compressors=comps,
                            eval_data=ev, scheduler=make_sched())

    flat_run, part_run = mk(False), mk(True)
    h_flat, h_part = flat_run.run(), part_run.run()
    _tree_close(flat_run.global_params, part_run.global_params,
                atol=1e-6, rtol=1e-5)
    for a, b in zip(h_flat, h_part):
        assert a.bytes_up == b.bytes_up
        assert a.bytes_up_raw == b.bytes_up_raw
        assert a.bytes_down == b.bytes_down
        assert a.compression_ratio == pytest.approx(b.compression_ratio)
        for k, v in a.global_metrics.items():
            assert b.global_metrics[k] == pytest.approx(v, abs=1e-6)


def test_async_identity_partition_run_matches_flat_run():
    from repro.core import AsyncBuffered, LatencyModel
    data, ev = _federation(3)
    cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update")

    def mk(partitioned_):
        comps = [(PartitionedCompressor(identity_partition(TMPL),
                                        {"all": QuantizeCompressor(bits=8)})
                  if partitioned_ else QuantizeCompressor(bits=8))
                 for _ in range(3)]
        return FederatedRun(
            MNIST_CLASSIFIER, data, cfg, compressors=comps, eval_data=ev,
            scheduler=AsyncBuffered(buffer_k=2,
                                    latency=LatencyModel(jitter=0.3)))

    flat_run, part_run = mk(False), mk(True)
    h_flat, h_part = flat_run.run(), part_run.run()
    _tree_close(flat_run.global_params, part_run.global_params,
                atol=1e-6, rtol=1e-5)
    for a, b in zip(h_flat, h_part):
        assert a.bytes_up == b.bytes_up
        assert a.participants == b.participants
        assert a.staleness == b.staleness


def test_two_partition_run_hits_fused_path_once_per_group(monkeypatch):
    """Acceptance: a 2-partition MLP run takes the grouped fused server
    path exactly once per (partition, spec) group per round — here clients
    mix per-layer rungs so dense0 splits into {q8, q4} buckets and dense1
    stays one {identity} bucket: 3 fused calls per round, never a
    per-client decode."""
    from repro.core import scheduler as sched_mod
    data, ev = _federation(3)
    pm = by_layer_partition(TMPL)

    def mk(ci):
        return PartitionedCompressor(pm, {
            "dense0": QuantizeCompressor(bits=8 if ci < 2 else 4),
            "dense1": IdentityCompressor()})

    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        compressors=[mk(ci) for ci in range(3)], eval_data=ev)
    calls = {"fused": 0, "decode": 0}
    real_fused = codec.decode_and_aggregate
    monkeypatch.setattr(
        sched_mod.codec, "decode_and_aggregate",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1),
                         real_fused(*a, **k))[1])
    real_decode = codec.decode
    monkeypatch.setattr(
        sched_mod.codec, "decode",
        lambda *a, **k: (calls.__setitem__("decode", calls["decode"] + 1),
                         real_decode(*a, **k))[1])
    hist = run.run()
    # (dense0, q8) + (dense0, q4) + (dense1, identity) = 3 per round
    assert calls["fused"] == 3 * len(hist)
    assert calls["decode"] == 0
    assert all(np.isfinite(r.global_metrics["loss"]) for r in hist)


def test_partitioned_heterogeneous_cohort_matches_sequential_oracle():
    """Grouped fused dispatch ≡ sequential per-client decode + weighted
    mean under mixed per-layer specs AND per-client AE params (the §9.2
    contract, one level down)."""
    from repro.core import scheduler as sched_mod
    from repro.core.aggregate import apply_update, weighted_mean
    from repro.core.scheduler import EncodedUpdate

    data, ev = _federation(3)
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=1, local_epochs=1,
                                payload="weights"), eval_data=ev)
    g_flat, unravel = ravel_pytree(run.global_params)
    pm = by_layer_partition(TMPL)
    d0 = pm.group_size("dense0")
    ae_cfg = AEConfig(input_dim=d0, encoder_hidden=(16,), latent_dim=8)
    comps = [PartitionedCompressor(pm, {
        "dense0": FCAECompressor(
            init_fc_ae(jax.random.PRNGKey(10 + i), ae_cfg), ae_cfg),
        "dense1": QuantizeCompressor(bits=8 if i else 4)})
        for i in range(3)]
    flats = [g_flat * (1.0 + 0.01 * (i + 1)) for i in range(3)]
    weights = [10.0, 20.0, 30.0]
    encoded = []
    for comp, flat, w in zip(comps, flats, weights):
        spec = comp.spec(P)
        params = comp.codec_params()
        encoded.append(EncodedUpdate(
            payload=codec.encode(spec, params, flat), spec=spec,
            params=params, weight=w, stats={}, metrics={}))
    got = sched_mod._server_aggregate(run, encoded, weights)
    rows = [codec.decode(e.spec, e.params, e.payload) - g_flat
            for e in encoded]
    mean = weighted_mean([unravel(r) for r in rows], weights)
    want = apply_update(run.global_params, mean, run.cfg.server_lr)
    _tree_close(got, want, atol=1e-5, rtol=1e-4)


def test_partitioned_cohort_requires_shared_structure():
    from repro.core import scheduler as sched_mod
    from repro.core.scheduler import EncodedUpdate
    data, ev = _federation(2)
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=1, local_epochs=1,
                                payload="update"), eval_data=ev)
    flat = jnp.zeros((P,), jnp.float32)
    mk = lambda pm: PartitionedCompressor(
        pm, {n: IdentityCompressor() for n in pm.names})
    encoded = []
    for comp in (mk(by_layer_partition(TMPL)), mk(by_leaf_partition(TMPL))):
        spec = comp.spec(P)
        encoded.append(EncodedUpdate(
            payload=codec.encode(spec, None, flat), spec=spec, params=None,
            weight=1.0, stats={}, metrics={}))
    with pytest.raises(AssertionError, match="partition structure"):
        sched_mod._server_aggregate(run, encoded, [1.0, 1.0])


# ------------------------------------ per-partition lifecycle + reconcile
def test_partitioned_lifecycle_ships_and_refreshes_per_group():
    """Each AE-backed group buffers its OWN payload segment, ships its own
    initial decoder (ae_syncs carries (client, group) lanes), and
    refreshes on its own cadence without dragging other groups along."""
    data, ev = _federation(2)
    pm = by_layer_partition(TMPL)
    d0 = pm.group_size("dense0")
    ae_cfg = AEConfig(input_dim=d0, encoder_hidden=(16,), latent_dim=8)

    def mk(ci):
        return PartitionedCompressor(pm, {
            "dense0": FCAECompressor(
                init_fc_ae(jax.random.PRNGKey(ci), ae_cfg), ae_cfg),
            "dense1": QuantizeCompressor(bits=8)})

    comps = [mk(ci) for ci in range(2)]
    before = [jax.tree_util.tree_map(
        jnp.copy, comps[ci].compressors["dense0"].params)
        for ci in range(2)]
    lc = AELifecycle(refresh_every=2, min_snapshots=1, refresh_epochs=2,
                     batch_size=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="weights"),
        compressors=comps, eval_data=ev, lifecycle=lc)
    hist = run.run()
    per = decoder_sync_bytes(comps[0].compressors["dense0"].params)
    assert hist[0].ae_syncs == [(0, "dense0"), (1, "dense0")]
    assert hist[0].bytes_decoder == pytest.approx(2 * per)
    # cadence 2: refreshed in round 2, only the AE group re-ships
    assert hist[2].ae_syncs == [(0, "dense0"), (1, "dense0")]
    for ci in range(2):
        st = run.clients[ci]
        assert set(st.part_snapshots) == {"dense0"}
        assert st.part_snapshots["dense0"][-1].shape == (d0,)
        assert st.part_last_refresh["dense0"] == 2
        assert st.part_baseline["dense0"] is not None
        moved = any(
            not np.allclose(np.asarray(x), np.asarray(y))
            for x, y in zip(
                jax.tree_util.tree_leaves(
                    comps[ci].compressors["dense0"].params["dec"]),
                jax.tree_util.tree_leaves(before[ci]["dec"])))
        assert moved, "per-group refit did not move the group's params"


def test_partitioned_savings_reconcile_sums_per_group_ships():
    """Satellite fix: reconcile under partitioning counts each group's
    ships against its OWN DecoderSize and apportions raw uplink by
    OriginalSize share — gap within the documented structural bound."""
    data, ev = _federation(2)
    pm = by_layer_partition(TMPL)
    d0, d1 = pm.group_size("dense0"), pm.group_size("dense1")
    ae_cfg = AEConfig(input_dim=d0, encoder_hidden=(64,), latent_dim=16)

    def mk(ci):
        return PartitionedCompressor(pm, {
            "dense0": FCAECompressor(
                init_fc_ae(jax.random.PRNGKey(ci), ae_cfg), ae_cfg),
            "dense1": IdentityCompressor()})

    lc = AELifecycle(min_snapshots=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="weights"),
        compressors=[mk(ci) for ci in range(2)], eval_data=ev,
        lifecycle=lc)
    hist = run.run()
    syncs = [s for r in hist for s in (r.ae_syncs or [])]
    assert syncs == [(0, "dense0"), (1, "dense0")]
    models = {
        "dense0": SavingsModel(original_size=d0,
                               compressed_size=ae_cfg.latent_dim,
                               autoencoder_size=ae_cfg.n_params,
                               n_decoders=2),
        "dense1": SavingsModel(original_size=d1, compressed_size=d1,
                               autoencoder_size=0, n_decoders=0)}
    report = run.savings_report(models)
    assert report["decoder_syncs"] == 2.0
    assert report["decoder_rel_err"] < 0.01       # hidden=64: <1% gap
    assert report["savings_rel_err"] < 0.01


def test_flat_reconcile_rejects_lane_syncs_mismatch():
    """A per-partition model mapping demands (client, group) sync entries;
    feeding it a flat run's int entries must fail loudly, not mis-count —
    and vice versa: a single SavingsModel on a partitioned history would
    count every per-group ship as a full-model decoder."""
    from repro.core.savings import reconcile
    models = {"all": SavingsModel(original_size=100, compressed_size=10,
                                  autoencoder_size=40, n_decoders=2)}
    flat_rec = type("R", (), {"bytes_up": 10.0, "bytes_up_raw": 100.0,
                              "bytes_decoder": 4.0, "ae_syncs": [0, 1]})()
    with pytest.raises(AssertionError, match="client, group"):
        reconcile(models, [flat_rec])
    part_rec = type("R", (), {"bytes_up": 10.0, "bytes_up_raw": 100.0,
                              "bytes_decoder": 4.0,
                              "ae_syncs": [(0, "a"), (1, "a")]})()
    with pytest.raises(AssertionError, match="SavingsModel"):
        reconcile(models["all"], [part_rec])


# ------------------------------------------ per-(client, partition) ladders
def _pointwise_rungs(pm):
    return {name: [lambda ci, n: QuantizeCompressor(bits=4),
                   lambda ci, n: QuantizeCompressor(bits=8),
                   lambda ci, n: IdentityCompressor()]
            for name in pm.names}


def test_partition_ladder_walks_lanes_independently():
    """DistortionTarget over per-partition ladders: each (client, group)
    lane walks on its own segment's distortion — switch records carry the
    lane, and next-round uplink reflects the per-group rungs."""
    data, ev = _federation(2)
    pm = by_layer_partition(TMPL)
    rc = DistortionTarget(ladder=partition_ladder(2, pm,
                                                  _pointwise_rungs(pm)),
                          partition=pm, target=1e-12, margin=1e-3,
                          min_snapshots=1, cooldown=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    # target 1e-12 is below every rung's error: every lane steps up once
    assert sorted(hist[0].spec_switches) == [
        ((0, "dense0"), 0, 1), ((0, "dense1"), 0, 1),
        ((1, "dense0"), 0, 1), ((1, "dense1"), 0, 1)]
    # round 1 walks every lane one more rung (q8 is still over target)
    assert all(rc.rung_of_group(ci, n) == 2
               for ci in range(2) for n in pm.names)
    assert hist[1].bytes_up > hist[0].bytes_up
    # pointwise rungs ship no decoders
    assert all(r.bytes_decoder == 0.0 for r in hist)


def test_partition_byte_budget_shares_one_budget_across_lanes():
    """ByteBudget over lanes: with an unbounded budget every lane tops
    out; with a budget below the all-cheapest floor every lane pins to
    rung 0 — the budget is one pool, not per-group."""
    data, ev = _federation(2)
    pm = by_layer_partition(TMPL)
    rc = ByteBudget(ladder=partition_ladder(2, pm, _pointwise_rungs(pm)),
                    partition=pm, budget=float("inf"), min_snapshots=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    assert all(rc.rung_of_group(ci, n) == 2
               for ci in range(2) for n in pm.names)
    floor = sum(rc.wire_cost_group(n, 0) for n in pm.names) * 2
    rc2 = ByteBudget(ladder=partition_ladder(2, pm, _pointwise_rungs(pm)),
                     partition=pm, budget=floor - 1, min_snapshots=1)
    run2 = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc2)
    run2.run()
    assert all(rc2.rung_of_group(ci, n) == 0
               for ci in range(2) for n in pm.names)


def test_partition_ladder_ae_switch_refits_group_and_ships_decoder():
    """A lane switching onto an AE rung refits THAT group's AE on the
    group's snapshot ring and ships only that group's decoder."""
    data, ev = _federation(2)
    pm = by_layer_partition(TMPL)
    d0 = pm.group_size("dense0")
    ae_cfg = AEConfig(input_dim=d0, encoder_hidden=(16,), latent_dim=8)
    def _ae_rung(ci, n):
        comp = FCAECompressor(
            init_fc_ae(jax.random.PRNGKey(40 + ci), ae_cfg), ae_cfg)
        # step-downs require a fitted neighbor (DESIGN.md §15.2); this test
        # targets the refit-and-ship mechanics at switch time, so mark the
        # rung prefit as a prepass-seeded ladder would be
        comp.prefit = True
        return comp

    rungs = {
        "dense0": [_ae_rung,
                   lambda ci, n: IdentityCompressor()],
        "dense1": [lambda ci, n: QuantizeCompressor(bits=8)]}
    rc = DistortionTarget(ladder=partition_ladder(2, pm, rungs),
                          partition=pm, target=1e30, margin=2.0,
                          min_snapshots=1, cooldown=1, initial_rung=1,
                          refit_epochs=2, refit_batch=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="weights"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    # huge target + margin: dense0 lanes step DOWN onto the AE rung
    assert sorted(hist[0].spec_switches) == [
        ((0, "dense0"), 1, 0), ((1, "dense0"), 1, 0)]
    assert sorted(hist[0].ae_syncs) == [(0, "dense0"), (1, "dense0")]
    assert hist[0].bytes_decoder > 0
    for ci in range(2):
        assert rc.rung_of_group(ci, "dense0") == 0
        assert rc.rung_of_group(ci, "dense1") == 0
        assert run.clients[ci].part_last_refresh["dense0"] == 0


def test_partition_ladder_requires_matching_groups():
    data, ev = _federation(2)
    pm = by_layer_partition(TMPL)
    bad = partition_ladder(2, pm, _pointwise_rungs(pm))
    del bad[1]["dense1"]
    with pytest.raises(AssertionError, match="ladder groups"):
        FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=1, local_epochs=1, payload="update"),
            eval_data=ev,
            ratecontrol=DistortionTarget(ladder=bad, partition=pm))

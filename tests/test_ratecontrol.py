"""Adaptive rate control (DESIGN.md §9): fixed-rate trajectory
preservation, distortion-target ladder walking, byte-budget greedy
allocation, heterogeneous-cohort group-by-spec fused dispatch vs the
sequential oracle, switch-time refit + decoder-ship accounting, wire-byte
pricing, and bit-exact checkpoint resume of controller state."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper import MNIST_CLASSIFIER, AEConfig
from repro.core import (ByteBudget, DistortionTarget, FCAECompressor,
                        FLConfig, FederatedRun, FixedRate,
                        IdentityCompressor, QuantizeCompressor,
                        RateController, SampledSync, SavingsModel,
                        TopKCompressor, codec, decoder_sync_bytes,
                        fc_ae_ladder, normalize_weights, tree_bytes,
                        weighted_mean, wire_bytes)
from repro.core import autoencoder as ae
from repro.data.pipeline import (dirichlet_partition, mnist_like,
                                 train_eval_split, uniform_partition)

P = 15_910                               # MNIST classifier param count


def _federation(n_clients, n=256, n_eval=64):
    train, ev = train_eval_split(mnist_like(0, n), n_eval)
    return uniform_partition(0, train, n_clients), ev


def _tree_close(a, b, **kw):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), **kw)


def _pointwise_ladder(n_clients):
    """q4 → q8 → identity: ascending uplink cost, descending distortion,
    no AE params — the deterministic ladder for policy-logic tests."""
    return [[QuantizeCompressor(bits=4), QuantizeCompressor(bits=8),
             IdentityCompressor()] for _ in range(n_clients)]


def _ae_ladder(n_clients, latents=(8, 32), hidden=(16,), seed=0):
    return fc_ae_ladder(n_clients, P, latent_dims=latents, hidden=hidden,
                        seed=seed)


# ------------------------------------------------------- wire-byte pricing
def test_wire_bytes_matches_real_encodes():
    """The budget planner's static price must equal the observed payload
    bytes for every codec family — planned and observed uplink can never
    diverge (DESIGN.md §9.1)."""
    n = 1000
    flat = jax.random.normal(jax.random.PRNGKey(0), (n,))
    ae_cfg = AEConfig(input_dim=1024, encoder_hidden=(32,), latent_dim=8)
    comps = [
        IdentityCompressor(),
        QuantizeCompressor(bits=8),
        QuantizeCompressor(bits=4),
        TopKCompressor(fraction=0.05),
        FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(1), ae_cfg), ae_cfg),
    ]
    from repro.core import ComposedCompressor
    comps.append(ComposedCompressor(comps[-1], bits=8))
    for comp in comps:
        spec = comp.spec(n)
        planned = wire_bytes(spec, comp.codec_params())
        observed = tree_bytes(codec.encode(spec, comp.codec_params(), flat))
        assert planned == observed, comp.name


# --------------------------------------------------- FixedRate equivalence
def test_fixed_rate_preserves_trajectory_exactly():
    """Acceptance: attaching FixedRate must not change the federation —
    params bit-equal, metrics and uplink bytes identical to a
    controller-less run (the controller only observes)."""
    data, ev = _federation(3)
    cfg = FLConfig(n_rounds=2, local_epochs=1, payload="update",
                   error_feedback=True)

    def mk(rc):
        return FederatedRun(
            MNIST_CLASSIFIER, data, cfg,
            compressors=[QuantizeCompressor(bits=8) for _ in range(3)],
            eval_data=ev, ratecontrol=rc)

    base_run = mk(None)
    base = base_run.run()
    fixed_run = mk(FixedRate())
    fixed = fixed_run.run()
    _tree_close(base_run.global_params, fixed_run.global_params,
                atol=0, rtol=0)
    for a, b in zip(base, fixed):
        assert a.global_metrics == b.global_metrics
        assert a.bytes_up == b.bytes_up
        assert a.bytes_down == b.bytes_down       # pointwise: no AE charges
        assert a.controller is None and b.controller == "fixed"
        assert a.spec_switches is None and b.spec_switches == []


def test_fixed_rate_ae_ladder_charges_initial_decoders_only():
    """With AE rungs and no lifecycle, FixedRate still owes the honest
    initial decoder ships (Eq. 5/6) — once per client, never again — and
    the trajectory is untouched relative to the same compressors run bare
    plus a lifecycle-less accounting delta."""
    data, ev = _federation(2)
    ladder = _ae_ladder(2)
    rc = FixedRate(ladder=ladder, initial_rung=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="weights"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    per_sync = decoder_sync_bytes(ladder[0][1].params)
    assert hist[0].ae_syncs == [0, 1]
    assert hist[0].bytes_decoder == pytest.approx(2 * per_sync)
    for rec in hist[1:]:
        assert rec.bytes_decoder == 0.0 and rec.ae_syncs == []
    assert all(rec.spec_switches == [] for rec in hist)
    # everyone pinned on the initial rung
    assert [rc.rung_of(ci) for ci in range(2)] == [1, 1]


# ----------------------------------------------- DistortionTarget walking
def test_distortion_target_walks_up_and_holds():
    """With the target placed between rung-0 and rung-1 observed error,
    every client must step up exactly one rung and then hold (the cheaper
    neighbor stays over margin*target, the current rung under target)."""
    data, ev = _federation(3)
    rc = DistortionTarget(ladder=_pointwise_ladder(3), target=5e-9,
                          margin=1e-3, min_snapshots=1, cooldown=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    assert sorted(hist[0].spec_switches) == [(0, 0, 1), (1, 0, 1),
                                             (2, 0, 1)]
    for rec in hist[1:]:
        assert rec.spec_switches == []
    assert [rc.rung_of(ci) for ci in range(3)] == [1, 1, 1]
    # pointwise ladder: switches ship no decoders
    assert all(rec.bytes_decoder == 0.0 for rec in hist)
    # next-round uplink reflects the new rung (q8 > q4 bytes)
    assert hist[1].bytes_up > hist[0].bytes_up


def test_distortion_target_steps_down_with_hysteresis():
    """Starting over-provisioned (identity rung) with a loose target, the
    controller must walk down — one rung per cooldown window — because the
    cheaper neighbor measures under margin*target."""
    data, ev = _federation(2)
    rc = DistortionTarget(ladder=_pointwise_ladder(2), target=0.5,
                          margin=0.9, min_snapshots=1, cooldown=1,
                          initial_rung=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    assert sorted(hist[0].spec_switches) == [(0, 2, 1), (1, 2, 1)]
    assert sorted(hist[1].spec_switches) == [(0, 1, 0), (1, 1, 0)]
    assert [rc.rung_of(ci) for ci in range(2)] == [0, 0]


def test_distortion_target_cooldown_limits_switch_rate():
    data, ev = _federation(2)
    rc = DistortionTarget(ladder=_pointwise_ladder(2), target=0.5,
                          margin=0.9, min_snapshots=1, cooldown=10,
                          initial_rung=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    # one switch per client, then the cooldown blocks further moves
    assert len(hist[0].spec_switches) == 2
    assert all(rec.spec_switches == [] for rec in hist[1:])


# --------------------------------------------------- ByteBudget allocation
def test_byte_budget_respects_budget_and_floor():
    data, ev = _federation(4)
    ladder = _pointwise_ladder(4)
    costs = [wire_bytes(ladder[0][k].spec(P)) for k in range(3)]

    # budget below the all-cheapest floor: everyone stays/returns to rung 0
    rc = ByteBudget(ladder=ladder, budget=costs[0] * 4 - 1, min_snapshots=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    assert [rc.rung_of(ci) for ci in range(4)] == [0, 0, 0, 0]

    # unbounded budget: everyone reaches the top rung
    rc2 = ByteBudget(ladder=_pointwise_ladder(4), budget=float("inf"),
                     min_snapshots=1)
    run2 = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc2)
    run2.run()
    assert [rc2.rung_of(ci) for ci in range(4)] == [2, 2, 2, 2]


def test_byte_budget_spends_marginal_bytes_on_largest_drift():
    """With room for exactly two rung-1 upgrades, the two clients with the
    largest current-rung reconstruction error must get them — the planned
    allocation equals a hand-computed greedy on the same scores, and the
    planned cost stays within budget."""
    data, ev = _federation(4)
    ladder = _pointwise_ladder(4)
    costs = [wire_bytes(ladder[0][k].spec(P)) for k in range(3)]
    budget = 2 * costs[1] + 2 * costs[0]
    rc = ByteBudget(ladder=ladder, budget=budget, min_snapshots=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    scores = {ci: rc._rung_err(run, ci, 0, run.clients[ci].snapshots[-1])
              for ci in range(4)}
    want_upgraded = sorted(sorted(scores, key=lambda ci: -scores[ci])[:2])
    got_upgraded = sorted(ci for ci in range(4) if rc.rung_of(ci) == 1)
    assert got_upgraded == want_upgraded
    planned = sum(rc.wire_cost(rc.rung_of(ci)) for ci in range(4))
    assert planned <= budget


# ---------------------------------- heterogeneous cohorts: group-by-spec
def _encoded_for(comp, flat, weight):
    from repro.core.scheduler import EncodedUpdate
    spec = comp.spec(flat.shape[0])
    params = comp.codec_params()
    return EncodedUpdate(payload=codec.encode(spec, params, flat),
                         spec=spec, params=params, weight=weight,
                         stats={}, metrics={})


@pytest.mark.parametrize("payload", ["update", "weights"])
def test_heterogeneous_cohort_matches_sequential_oracle(payload, monkeypatch):
    """Acceptance (satellite): a cohort mixing ladder rungs must be grouped
    by spec — one fused decode→aggregate call per group — and still match
    the sequential per-client decode + weighted_mean oracle to the repo's
    1-ulp tolerance rule (atol=1e-6/rtol=1e-5)."""
    from repro.core import scheduler as sched_mod
    from repro.core.aggregate import apply_update

    data, ev = _federation(4, n=320, n_eval=64)
    run = FederatedRun(MNIST_CLASSIFIER, data,
                       FLConfig(n_rounds=1, local_epochs=1, payload=payload),
                       eval_data=ev)
    g_flat, unravel = jax.flatten_util.ravel_pytree(run.global_params)

    from repro.core import ChunkedAECompressor
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae

    ae_cfg8 = AEConfig(input_dim=P, encoder_hidden=(16,), latent_dim=8)
    ae_cfg32 = AEConfig(input_dim=P, encoder_hidden=(16,), latent_dim=32)
    ccfg = ChunkedAEConfig(chunk_size=2048, hidden=(16,), latent_chunk=4)
    comps = [
        QuantizeCompressor(bits=8),
        QuantizeCompressor(bits=4),
        FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(1), ae_cfg8),
                       ae_cfg8),
        FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(2), ae_cfg8),
                       ae_cfg8),          # same spec, different params
        FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(3), ae_cfg32),
                       ae_cfg32),
        # kernel-path chunked AE: its fused branch denorms and subtracts
        # base assuming Σw=1 — the group renormalization must hold for it
        ChunkedAECompressor(init_chunked_ae(jax.random.PRNGKey(4), ccfg),
                            ccfg, use_kernel=True),
    ]
    flats = [g_flat * (1.0 + 0.01 * (i + 1)) for i in range(len(comps))]
    weights = [float(10 * (i + 1)) for i in range(len(comps))]
    encoded = [_encoded_for(c, f, w)
               for c, f, w in zip(comps, flats, weights)]

    calls = {"fused": 0}
    real_fused = codec.decode_and_aggregate
    monkeypatch.setattr(
        sched_mod.codec, "decode_and_aggregate",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1),
                         real_fused(*a, **k))[1])
    got = sched_mod._server_aggregate(run, encoded, weights)
    # 5 distinct specs (the two latent-8 AEs share one): 5 fused calls
    assert calls["fused"] == 5

    # sequential oracle: per-client decode, subtract base, weighted mean
    rows = [codec.decode(e.spec, e.params, e.payload) for e in encoded]
    if payload == "weights":
        rows = [r - g_flat for r in rows]
    mean = weighted_mean([unravel(r) for r in rows], weights)
    want = apply_update(run.global_params, mean, run.cfg.server_lr)
    _tree_close(got, want, atol=1e-6, rtol=1e-5)


def test_mid_walk_rounds_aggregate_heterogeneous_rungs(monkeypatch):
    """End-to-end: force a cohort whose clients sit on different ladder
    rungs (switch only client 0) and check the round still completes via
    grouped fused dispatch with finite metrics."""
    from repro.core import scheduler as sched_mod
    data, ev = _federation(3)

    class SwitchOne(RateController):
        name = "switch_one"

        def plan(self, run, r, participants):
            return {0: 1} if r == 0 else {}

    rc = SwitchOne(ladder=_pointwise_ladder(3), min_snapshots=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    calls = {"fused": 0}
    real_fused = codec.decode_and_aggregate
    monkeypatch.setattr(
        sched_mod.codec, "decode_and_aggregate",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1),
                         real_fused(*a, **k))[1])
    hist = run.run()
    assert hist[0].spec_switches == [(0, 0, 1)]
    assert [rc.rung_of(ci) for ci in range(3)] == [1, 0, 0]
    # rounds 0: 1 call; rounds 1-2: q8 group + q4 group = 2 calls each
    assert calls["fused"] == 5
    assert all(np.isfinite(r.global_metrics["loss"]) for r in hist)


# --------------------------------- switch-time refits + decoder accounting
def test_ae_rung_switch_refits_and_ships_decoder():
    """A switch onto an AE rung must (a) move that rung's params (the
    warm-start refit on the snapshot buffer ran), (b) ship the new decoder
    — bytes_decoder charged at exactly the shipped tree's size, client
    listed in ae_syncs — and (c) update last_refresh/ae_baseline."""
    data, ev = _federation(2)
    ladder = _ae_ladder(2)
    before = [jax.tree_util.tree_map(jnp.copy, ladder[ci][1].params)
              for ci in range(2)]
    rc = DistortionTarget(ladder=ladder, target=1e-12, min_snapshots=1,
                          refit_epochs=2, refit_batch=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="weights"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    # round 0: initial rung-0 decoders ship AND the switch ships rung 1 —
    # two syncs per client (ae_syncs is a multiset of ships)
    assert hist[0].ae_syncs == [0, 0, 1, 1]
    assert hist[0].spec_switches == [(0, 0, 1), (1, 0, 1)]
    per0 = decoder_sync_bytes(ladder[0][0].params)
    per1 = decoder_sync_bytes(ladder[0][1].params)
    assert hist[0].bytes_decoder == pytest.approx(2 * per0 + 2 * per1)
    for ci in range(2):
        assert rc.rung_of(ci) == 1
        st = run.clients[ci]
        assert st.last_refresh == 0
        assert st.ae_baseline is not None and np.isfinite(st.ae_baseline)
        moved = any(
            not np.allclose(np.asarray(x), np.asarray(y))
            for x, y in zip(
                jax.tree_util.tree_leaves(ladder[ci][1].params["dec"]),
                jax.tree_util.tree_leaves(before[ci]["dec"])))
        assert moved, "switch-time refit did not move the rung params"


def test_switch_reconciles_with_savings_model():
    """Acceptance: savings.reconcile stays honest including rung-switch
    decoder re-ships — gap within the documented structural error when the
    ladder shares its hidden stack (close per-rung decoder sizes)."""
    data, ev = _federation(2)
    ladder = _ae_ladder(2, latents=(16, 32), hidden=(16,))
    rc = DistortionTarget(ladder=ladder, target=1e-12, min_snapshots=1,
                          refit_epochs=2, refit_batch=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="weights"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    syncs = sum(len(r.ae_syncs or []) for r in hist)
    assert syncs == 4                       # 2 initial + 2 switch re-ships
    mean_ae = (ae.ae_param_count(ladder[0][0].params)
               + ae.ae_param_count(ladder[0][1].params)) // 2
    model = SavingsModel(original_size=P, compressed_size=16,
                        autoencoder_size=mean_ae, n_decoders=2)
    report = run.savings_report(model)
    assert report["decoder_syncs"] == syncs
    # structural Eq. 6 gap (decoder bias asymmetry) is ~3% at hidden=16 —
    # same documented bound as test_ae_lifecycle's reconcile test; the
    # hidden=64 example reconciles at <1%
    assert report["decoder_rel_err"] < 0.05
    assert report["savings_rel_err"] < 0.05


def test_controller_composes_with_lifecycle():
    """With an AELifecycle attached, the lifecycle owns initial ships and
    refreshes; the controller owns switches — both charge the same record
    without double-counting (ae_syncs is the union)."""
    from repro.core import AELifecycle
    data, ev = _federation(2)
    ladder = _ae_ladder(2)
    rc = DistortionTarget(ladder=ladder, target=1e-12, min_snapshots=1,
                          refit_epochs=2, refit_batch=2)
    lc = AELifecycle(refresh_every=100, min_snapshots=1, refresh_epochs=2,
                     batch_size=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="weights"),
        eval_data=ev, lifecycle=lc, ratecontrol=rc)
    hist = run.run()
    per0 = decoder_sync_bytes(ladder[0][0].params)
    per1 = decoder_sync_bytes(ladder[0][1].params)
    assert hist[0].ae_syncs == [0, 0, 1, 1]
    assert hist[0].bytes_decoder == pytest.approx(2 * per0 + 2 * per1)


# -------------------------------------------------- checkpointing / resume
def test_rate_control_checkpoint_bitexact_resume(tmp_path):
    """Controller state (rung occupancy, cooldowns, every ladder rung's
    params) must survive save/load: a 1+1-round resumed run reproduces the
    2-round uninterrupted run bit-exactly — records, switches, decoder
    bytes, and final params."""
    data, ev = _federation(2)

    def mk(n_rounds):
        rc = DistortionTarget(ladder=_ae_ladder(2), target=1e-12,
                              min_snapshots=1, refit_epochs=2,
                              refit_batch=2)
        return FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=n_rounds, local_epochs=1, payload="weights"),
            eval_data=ev, ratecontrol=rc), rc

    full, _ = mk(2)
    hist_full = full.run()
    first, rc_first = mk(1)
    first.run()
    assert list(rc_first._rung) == [1, 1]         # the switch happened pre-save
    path = os.path.join(tmp_path, "rc.npz")
    first.save_state(path)

    resumed, rc_res = mk(1)
    assert list(rc_res._rung) == [0, 0]           # fresh ladder starts at rung 0
    assert resumed.load_state(path) == 1
    assert list(rc_res._rung) == [1, 1]
    for ci in range(2):
        assert resumed.compressors[ci] is rc_res._comps[ci][1]
        # the refit rung-1 params came back, not the fresh init
        _tree_close(resumed.compressors[ci].params,
                    first.compressors[ci].params, atol=0, rtol=0)
    hist_resumed = resumed.run()
    _tree_close(full.global_params, resumed.global_params, atol=0, rtol=0)
    for a, b in zip(hist_full[1:], hist_resumed):
        assert a.round == b.round
        assert a.spec_switches == b.spec_switches
        assert a.bytes_decoder == b.bytes_decoder
        assert a.bytes_up == b.bytes_up
        assert a.global_metrics == b.global_metrics


def test_byte_budget_prices_cooldown_clients_into_the_plan():
    """A participant frozen by cooldown still encodes next round at its
    current rung: the greedy must count that spend, not treat it as free
    and over-allocate upgrades to the movable clients."""
    data, ev = _federation(2)
    ladder = _pointwise_ladder(2)
    costs = [wire_bytes(ladder[0][k].spec(P)) for k in range(3)]
    rc = ByteBudget(ladder=ladder, budget=costs[2] + costs[1], cooldown=5,
                    min_snapshots=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()                      # snapshots exist for both clients now
    # put client 0 on the identity rung, frozen by a fresh switch; keep
    # client 1 movable on the cheapest rung
    rc._rung = np.array([2, 0])
    rc._last_switch = np.array([1, -(10 ** 9)])
    moves = rc.plan(run, 2, [0, 1])
    # client 0's rung-2 spend leaves exactly costs[1] for client 1: the
    # plan may lift it to rung 1 but NOT to rung 2 (which would fit only
    # if the frozen client were mispriced as free)
    assert moves == {1: 1}
    planned = costs[2] + costs[moves[1]]
    assert planned <= rc.budget


def test_fixed_rate_never_buffers_snapshots():
    """FixedRate cannot switch, so it must not accumulate model-sized
    snapshot buffers (memory + checkpoint dead weight)."""
    data, ev = _federation(2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=FixedRate(ladder=_pointwise_ladder(2)))
    run.run()
    assert all(c.snapshots == [] for c in run.clients)


def test_load_state_refuses_controller_presence_mismatch(tmp_path):
    """A checkpoint saved without a controller cannot restore codec params
    into a controller-bearing run (and vice versa) — silent params revert
    is the bug class this guards; it must raise instead."""
    data, ev = _federation(2)
    cfg = FLConfig(n_rounds=1, local_epochs=1, payload="update")
    plain = FederatedRun(
        MNIST_CLASSIFIER, data, cfg,
        compressors=[QuantizeCompressor(bits=8) for _ in range(2)],
        eval_data=ev)
    plain.run()
    path = os.path.join(tmp_path, "plain.npz")
    plain.save_state(path)
    with_rc = FederatedRun(
        MNIST_CLASSIFIER, data, cfg, eval_data=ev,
        ratecontrol=FixedRate(ladder=_pointwise_ladder(2)))
    with pytest.raises(ValueError, match="rate-controller mismatch"):
        with_rc.load_state(path)

    rc_run = FederatedRun(
        MNIST_CLASSIFIER, data, cfg, eval_data=ev,
        ratecontrol=FixedRate(ladder=_pointwise_ladder(2)))
    rc_run.run()
    path2 = os.path.join(tmp_path, "rc.npz")
    rc_run.save_state(path2)
    plain2 = FederatedRun(
        MNIST_CLASSIFIER, data, cfg,
        compressors=[QuantizeCompressor(bits=8) for _ in range(2)],
        eval_data=ev)
    with pytest.raises(ValueError, match="rate-controller mismatch"):
        plain2.load_state(path2)


def test_ladder_with_mismatched_rung_specs_is_rejected():
    data, ev = _federation(2)
    ladder = _pointwise_ladder(2)
    ladder[1][0] = QuantizeCompressor(bits=8)     # client 1 rung 0 differs
    with pytest.raises(AssertionError, match="spec differs"):
        FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=1, local_epochs=1, payload="update"),
            eval_data=ev, ratecontrol=FixedRate(ladder=ladder))


# ------------------------------------- batched probing (DESIGN.md §15.1)
def test_batched_probe_matches_single_probe_oracle():
    """The one-dispatch (rung × lane) distortion matrix must equal the
    per-(lane, rung) blocking probes it replaced — `_rung_err` is kept
    exactly as this differential oracle."""
    data, ev = _federation(3)
    rc = DistortionTarget(ladder=_pointwise_ladder(3), target=5e-9,
                          margin=1e-3, min_snapshots=1, cooldown=1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    lanes = [0, 1, 2]
    errs = rc._probe_all(run, lanes)
    assert errs.shape == (3, 3)
    for k in range(3):
        for j, ci in enumerate(lanes):
            want = rc._rung_err(run, ci, k, run.clients[ci].snapshots[-1])
            np.testing.assert_allclose(errs[k, j], want, rtol=1e-6,
                                       atol=1e-12)
    # the current-rung row is cached for the async distortion discount
    for ci in lanes:
        assert rc.distortion_of(ci) == pytest.approx(
            float(errs[int(rc._rung[ci]), ci]))


@pytest.mark.parametrize("kind", ["distortion", "bytebudget", "rd"])
def test_plan_probes_in_one_dispatch_per_round(kind, monkeypatch):
    """Sync-count regression (the §15.1 bugfix): planning must never fall
    back to the per-(lane, rung) blocking probes, and the batched dispatch
    count is exactly one per planned round."""
    from repro.core import RDBudget

    def boom(*a, **k):                    # pragma: no cover - must not run
        raise AssertionError("per-lane blocking probe called during plan")

    monkeypatch.setattr(RateController, "_rung_err", boom)
    monkeypatch.setattr(RateController, "_lane_rung_err", boom)
    data, ev = _federation(3)
    rc = {
        "distortion": lambda: DistortionTarget(
            ladder=_pointwise_ladder(3), target=5e-9, margin=1e-3,
            min_snapshots=1, cooldown=1),
        "bytebudget": lambda: ByteBudget(
            ladder=_pointwise_ladder(3), budget=float("inf"),
            min_snapshots=1),
        "rd": lambda: RDBudget(
            ladder=_pointwise_ladder(3), budget=float("inf"),
            min_snapshots=1),
    }[kind]()
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=3, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    assert rc.probe_dispatches == 3       # one batched dispatch per round


def test_partitioned_plan_probes_one_dispatch_per_group(monkeypatch):
    """Partitioned twin: segment sizes differ per group, so the batched
    probe costs one dispatch per (round, group) — never per lane."""
    from repro.core import (RDBudget, by_layer_partition, partition_ladder)
    from repro.models.classifiers import init_classifier

    def boom(*a, **k):                    # pragma: no cover - must not run
        raise AssertionError("per-lane blocking probe called during plan")

    monkeypatch.setattr(RateController, "_rung_err", boom)
    monkeypatch.setattr(RateController, "_lane_rung_err", boom)
    pm = by_layer_partition(init_classifier(jax.random.PRNGKey(0),
                                            MNIST_CLASSIFIER))
    rungs = {name: [lambda ci, n: QuantizeCompressor(bits=4),
                    lambda ci, n: QuantizeCompressor(bits=8),
                    lambda ci, n: IdentityCompressor()]
             for name in pm.names}
    rc = RDBudget(ladder=partition_ladder(2, pm, rungs), partition=pm,
                  budget=float("inf"), min_snapshots=1)
    data, ev = _federation(2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=2, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    assert rc.probe_dispatches == 2 * len(pm.names)


# --------------------------- decoder flapping hysteresis (DESIGN.md §15.4)
class _FlappingBudget(ByteBudget):
    """Test double: a budget oscillating between `hi` (room for the AE
    rung) and `lo` (the all-q4 floor) every round — the boundary-hover
    that used to re-ship decoders on every upward flip."""

    def __init__(self, hi, lo, **kw):
        super().__init__(**kw)
        self.hi, self.lo = hi, lo

    def plan(self, run, r, participants):
        self.budget = self.hi if r % 2 == 0 else self.lo
        return super().plan(run, r, participants)


def _flap_ladder(n_clients):
    """q4 → big-latent AE → identity: the AE rung costs MORE than q4, so
    a budget flap moves clients on/off an AE rung (shipping decoders)."""
    cfg = AEConfig(input_dim=P, encoder_hidden=(16,), latent_dim=2560)
    return [[QuantizeCompressor(bits=4),
             FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(7 + ci), cfg),
                            cfg),
             IdentityCompressor()] for ci in range(n_clients)]


def _flap_run(hysteresis, n_rounds=6):
    data, ev = _federation(2)
    ladder = _flap_ladder(2)
    costs = [wire_bytes(ladder[0][k].spec(P), ladder[0][k].codec_params())
             for k in range(3)]
    assert costs[0] < costs[1] < costs[2]
    rc = _FlappingBudget(hi=2 * costs[1], lo=2 * costs[0], ladder=ladder,
                         min_snapshots=1, switch_hysteresis=hysteresis,
                         refit_epochs=1, refit_batch=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=n_rounds, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    per_ship = decoder_sync_bytes(ladder[0][1].params)
    return hist, per_ship


def test_byte_budget_hysteresis_pins_decoder_bytes_under_flapping():
    """Regression (the §15.4 bugfix): under a period-2 budget flap,
    legacy greedy (hysteresis=0) re-ships every client's decoder every
    up-flip — 6 ships over 6 rounds. With the default hysteresis=2 the
    up-flip is blocked until the lane has sat 2 rounds, pinning total
    decoder traffic to exactly 4 ships (rounds 0, 1, 4, 5 switch; only
    the AE-ward moves ship)."""
    hist_flappy, per_ship = _flap_run(hysteresis=0)
    ships_flappy = sum(len(r.ae_syncs) for r in hist_flappy)
    bytes_flappy = sum(r.bytes_decoder for r in hist_flappy)
    assert ships_flappy == 6              # every even round re-ships both
    assert bytes_flappy == pytest.approx(6 * per_ship)

    hist_hyst, per_ship = _flap_run(hysteresis=2)
    ships_hyst = sum(len(r.ae_syncs) for r in hist_hyst)
    bytes_hyst = sum(r.bytes_decoder for r in hist_hyst)
    assert ships_hyst == 4                # rounds 0 and 4 only
    assert bytes_hyst == pytest.approx(4 * per_ship)
    assert bytes_hyst < bytes_flappy
    # downgrades (off the AE rung) are never blocked: no budget overshoot
    for rec in hist_hyst:
        if rec.round % 2 == 1:            # lo rounds end all-q4
            assert rec.spec_switches == [] or all(
                s[2] == 0 for s in rec.spec_switches)


# ------------------------------- unfit-rung gating (DESIGN.md §15.2)
def test_byte_budget_unfit_current_rung_cannot_win_bytes():
    """First-rounds window: a client sitting on a never-fitted AE rung
    reports garbage distortion — its score must clamp to 0 so it cannot
    out-bid an honestly-probed client for the one affordable upgrade."""
    cfg = AEConfig(input_dim=P, encoder_hidden=(16,), latent_dim=32)
    ladder = [[FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(20 + ci),
                                            cfg), cfg),
               QuantizeCompressor(bits=8), IdentityCompressor()]
              for ci in range(2)]
    ladder[0][0].prefit = True            # client 0's AE came from a fit
    costs = [wire_bytes(ladder[0][k].spec(P), ladder[0][k].codec_params())
             for k in range(3)]
    rc = ByteBudget(ladder=ladder, budget=costs[0] + costs[1],
                    min_snapshots=1, refit_epochs=1, refit_batch=2)
    data, ev = _federation(2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    run.run()
    # client 1's unprobed-garbage reading would have won by magnitude;
    # the fitted gate zeroes it, so client 0 takes the upgrade
    assert rc.rung_of(0) == 1
    assert rc.rung_of(1) == 0


def test_distortion_target_step_down_requires_fitted_neighbor():
    """A step DOWN must be blocked while the cheaper neighbor has never
    been fitted (its tiny garbage reading must not qualify); marking the
    rung fitted unblocks the exact same reading."""
    cfg = AEConfig(input_dim=P, encoder_hidden=(16,), latent_dim=32)
    ladder = [[FCAECompressor(ae.init_fc_ae(jax.random.PRNGKey(30), cfg),
                              cfg),
               QuantizeCompressor(bits=8)]]
    rc = DistortionTarget(ladder=ladder, target=0.5, margin=0.9,
                          min_snapshots=1, cooldown=1, initial_rung=1)
    data, ev = _federation(1)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        eval_data=ev, ratecontrol=rc)
    hist = run.run()
    assert hist[0].spec_switches == []    # neighbor unfit: hold
    rc._probe_all = lambda run, lanes: np.full((2, len(lanes)), 1e-12)
    assert rc.plan(run, 5, [0]) == {}     # still unfit, still held
    rc._fitted[0, 0] = True
    assert rc.plan(run, 5, [0]) == {0: 0}  # same reading, now trusted


def test_rd_budget_holds_unfit_lanes_then_moves_when_seeded():
    """RDBudget never allocates onto (or away from) never-fitted AE rungs:
    a fresh-init ladder stays frozen at rung 0 through the first-rounds
    window, while the same ladder marked pre-fitted tops out under an
    unbounded budget."""
    from repro.core import RDBudget
    data, ev = _federation(2)

    def mk(prefit):
        ladder = _ae_ladder(2)
        if prefit:
            for row in ladder:
                for comp in row:
                    comp.prefit = True
        rc = RDBudget(ladder=ladder, budget=float("inf"), min_snapshots=1,
                      refit_epochs=1, refit_batch=2)
        run = FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=2, local_epochs=1, payload="update"),
            eval_data=ev, ratecontrol=rc)
        if prefit:
            # fresh random AEs measure seed-luck garbage; pin a monotone
            # curve (rung 1 strictly better) so the hull keeps both rungs
            rc._probe_all = lambda run, lanes: np.array(
                [[0.5] * len(lanes), [0.1] * len(lanes)])
        return rc, run.run()

    rc, hist = mk(prefit=False)
    assert all(rec.spec_switches == [] for rec in hist)
    assert [rc.rung_of(ci) for ci in range(2)] == [0, 0]
    assert rc.last_lambda is None         # no honest curve, no sweep
    assert hist[1].bytes_decoder == 0.0   # nothing re-ships after round 0

    rc2, hist2 = mk(prefit=True)
    assert [rc2.rung_of(ci) for ci in range(2)] == [1, 1]
    assert sorted(hist2[0].spec_switches) == [(0, 0, 1), (1, 0, 1)]


def test_controller_with_sampled_scheduler_switches_participants_only():
    """Partial participation: only sampled clients may switch (decisions
    are end-of-round over the observed cohort)."""
    data, ev = _federation(4)
    rc = DistortionTarget(ladder=_pointwise_ladder(4), target=5e-9,
                          margin=1e-3, min_snapshots=1)
    sched = SampledSync(cohort=2)
    run = FederatedRun(
        MNIST_CLASSIFIER, data,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        eval_data=ev, scheduler=sched, ratecontrol=rc)
    hist = run.run()
    switched = {s[0] for s in hist[0].spec_switches}
    assert switched <= set(hist[0].participants)
    unsampled = set(range(4)) - set(hist[0].participants)
    for ci in unsampled:
        assert rc.rung_of(ci) == 0

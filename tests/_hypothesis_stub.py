"""Fallback for the optional ``hypothesis`` dependency (the ``dev`` extra in
pyproject.toml).

A bare ``pytest.importorskip("hypothesis")`` at module scope would skip the
*entire* test module, losing its plain unit tests too. Instead, modules that
mix unit and property tests do::

    try:
        import hypothesis
        import hypothesis.strategies as st
    except ModuleNotFoundError:
        from _hypothesis_stub import hypothesis, st

With the stub bound, ``@hypothesis.given(...)`` marks just the property
tests as skipped (same effect importorskip has, scoped per-test), while the
unit tests in the same file still collect and run. The stub mirrors exactly
the slice of the hypothesis API these tests touch: ``given``, ``settings``
profiles, ``HealthCheck``, and arbitrary ``st.<strategy>(...)`` calls.
"""
import pytest


class _Settings:
    """No-op stand-ins for hypothesis.settings profile management."""

    def __call__(self, *args, **kwargs):            # @hypothesis.settings(...)
        return lambda fn: fn

    @staticmethod
    def register_profile(*args, **kwargs):
        pass

    @staticmethod
    def load_profile(*args, **kwargs):
        pass


class _Hypothesis:
    settings = _Settings()
    HealthCheck = ()                    # list(HealthCheck) → []

    @staticmethod
    def given(*args, **kwargs):
        return pytest.mark.skip(
            reason="hypothesis not installed (pip install -e '.[dev]')")


class _Strategies:
    """Any st.integers()/st.floats()/st.sampled_from()/... returns None —
    the value is never used because ``given`` skips the test."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


hypothesis = _Hypothesis()
st = _Strategies()

"""Differential resume-equivalence matrix (DESIGN.md §10.4): one grid over
{SyncFedAvg, SampledSync, AsyncBuffered} × {no controller, DistortionTarget,
ByteBudget, RDBudget} × {flat, partitioned} asserting that saving mid-run and
resuming reproduces the uninterrupted run in BYTES and TRAJECTORY — final
params bit-exact, per-round byte accounting and metrics equal. This one
test collapses the per-feature resume checks into a single grid and closes
the previously-untested cells (e.g. controllers × async, anything ×
partitioned)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.flatten_util import ravel_pytree

from repro.configs.paper import MNIST_CLASSIFIER
from repro.core import (AsyncBuffered, ByteBudget, DistortionTarget,
                        FLConfig, FederatedRun, IdentityCompressor,
                        LatencyModel, PartitionedCompressor,
                        QuantizeCompressor, RDBudget, SampledSync,
                        by_layer_partition, partition_ladder)
from repro.data.pipeline import (mnist_like, train_eval_split,
                                 uniform_partition)
from repro.models.classifiers import init_classifier

N_CLIENTS = 3
TMPL = init_classifier(jax.random.PRNGKey(0), MNIST_CLASSIFIER)
PM = by_layer_partition(TMPL)


def _data():
    train, ev = train_eval_split(mnist_like(0, 128), 32)
    return uniform_partition(0, train, N_CLIENTS), ev


def _scheduler(kind):
    return {
        "sync": lambda: None,                       # SyncFedAvg default
        "sampled": lambda: SampledSync(cohort=2),
        "async": lambda: AsyncBuffered(
            buffer_k=2, latency=LatencyModel(jitter=0.3)),
        "async-vector": lambda: AsyncBuffered(
            buffer_k=2, latency=LatencyModel(jitter=0.3),
            engine="vector"),
    }[kind]()


def _flat_ladder():
    return [[QuantizeCompressor(bits=4), QuantizeCompressor(bits=8),
             IdentityCompressor()] for _ in range(N_CLIENTS)]


def _part_ladder():
    rungs = {name: [lambda ci, n: QuantizeCompressor(bits=4),
                    lambda ci, n: QuantizeCompressor(bits=8),
                    lambda ci, n: IdentityCompressor()]
             for name in PM.names}
    return partition_ladder(N_CLIENTS, PM, rungs)


def _controller(kind, layout):
    if kind == "none":
        return None
    ladder = _part_ladder() if layout == "partitioned" else _flat_ladder()
    pm = PM if layout == "partitioned" else None
    if kind == "distortion":
        # target between observed q4 and q8 segment errors so some lanes
        # genuinely move mid-grid (switch state must survive the resume)
        return DistortionTarget(ladder=ladder, partition=pm, target=5e-9,
                                margin=1e-3, min_snapshots=1, cooldown=1)
    if kind == "rd":
        # unbounded budget: the water-fill walks lanes upward round by
        # round, so rung occupancy, fitted flags, cached distortions and
        # λ state all change across the save point
        return RDBudget(ladder=ladder, partition=pm, budget=float("inf"),
                        min_snapshots=1)
    assert kind == "bytebudget"
    return ByteBudget(ladder=ladder, partition=pm, budget=float("inf"),
                      min_snapshots=1)


def _compressors(layout):
    if layout == "partitioned":
        # mixed per-layer pointwise specs: exercises the grouped fused
        # path and the partitioned payload/codec state across the resume
        return [PartitionedCompressor(PM, {
            "dense0": QuantizeCompressor(bits=8),
            "dense1": IdentityCompressor()}) for _ in range(N_CLIENTS)]
    return [QuantizeCompressor(bits=8) for _ in range(N_CLIENTS)]


def _mk(sched, rc, layout, n_rounds, data, ev, soa=False):
    # batch_size must divide into the 32-sample shards or local training
    # runs zero batches and every cell degenerates to zero updates (no
    # drift → controllers never move → the grid tests nothing)
    cfg = FLConfig(n_rounds=n_rounds, local_epochs=1, batch_size=16,
                   payload="update", error_feedback=(rc == "none"))
    controller = _controller(rc, layout)
    return FederatedRun(
        MNIST_CLASSIFIER, data, cfg,
        compressors=(None if controller is not None
                     else _compressors(layout)),
        eval_data=ev, scheduler=_scheduler(sched), ratecontrol=controller,
        soa_state=soa)


def _run_cell(sched, rc, layout, tmp_path, soa=False):
    data, ev = _data()
    full = _mk(sched, rc, layout, 2, data, ev, soa=soa)
    hist_full = full.run()

    first = _mk(sched, rc, layout, 1, data, ev, soa=soa)
    first.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    first.save_state(path)

    resumed = _mk(sched, rc, layout, 1, data, ev, soa=soa)
    assert resumed.load_state(path) == 1
    hist_resumed = resumed.run()

    # trajectory: final params bit-exact
    for x, y in zip(jax.tree_util.tree_leaves(full.global_params),
                    jax.tree_util.tree_leaves(resumed.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # bytes + records: resumed round 2 ≡ uninterrupted round 2
    for a, b in zip(hist_full[1:], hist_resumed):
        assert a.round == b.round
        assert a.bytes_up == b.bytes_up
        assert a.bytes_up_raw == b.bytes_up_raw
        assert a.bytes_down == b.bytes_down
        assert a.bytes_decoder == b.bytes_decoder
        assert a.ae_syncs == b.ae_syncs
        assert a.participants == b.participants
        assert a.spec_switches == b.spec_switches
        assert a.staleness == b.staleness
        assert a.sim_time == b.sim_time
        assert a.global_metrics == b.global_metrics


@pytest.mark.parametrize("layout", ["flat", "partitioned"])
@pytest.mark.parametrize("rc", ["none", "distortion", "bytebudget", "rd"])
@pytest.mark.parametrize("sched", ["sync", "sampled", "async"])
def test_resume_matrix_bytes_and_trajectory(sched, rc, layout, tmp_path):
    _run_cell(sched, rc, layout, tmp_path)


@pytest.mark.parametrize("layout", ["flat", "partitioned"])
@pytest.mark.parametrize("rc", ["none", "distortion", "bytebudget", "rd"])
@pytest.mark.parametrize("sched", ["sampled", "async-vector"])
def test_resume_matrix_soa(sched, rc, layout, tmp_path):
    """The §12.1/§12.2 cells: struct-of-arrays client state (ring
    snapshots + residual block round-trip through the checkpoint) and the
    vectorized arrival engine, under the same bytes+trajectory bar."""
    _run_cell(sched, rc, layout, tmp_path, soa=True)


# =====================================================================
# task-generic cells (DESIGN.md §14): LMDeltaTask save/load with a real
# transformer pytree, eager and SoA, plus eager↔SoA cross-restore
# =====================================================================
from repro.configs.base import ArchConfig          # noqa: E402
from repro.core import LMDeltaTask                 # noqa: E402
from repro.data.pipeline import synthetic_lm_batch  # noqa: E402

LM_CFG = ArchConfig(name="resume-lm", family="dense", n_layers=1,
                    d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
                    d_ff=64, vocab_size=64, tie_embeddings=True,
                    param_dtype="float32", compute_dtype="float32",
                    remat=False, zero1=False)


def _lm_data():
    shards = [synthetic_lm_batch(seed=10 + i, vocab_size=64, batch=4,
                                 seq_len=16) for i in range(N_CLIENTS)]
    ev = synthetic_lm_batch(seed=99, vocab_size=64, batch=4, seq_len=16)
    return shards, ev


def _mk_lm(sched, n_rounds, data, ev, soa=False):
    cfg = FLConfig(n_rounds=n_rounds, local_epochs=1, batch_size=2,
                   payload="update", error_feedback=True)
    return FederatedRun(
        LMDeltaTask(LM_CFG), data, cfg,
        compressors=[QuantizeCompressor(bits=8) for _ in range(N_CLIENTS)],
        eval_data=ev, scheduler=_scheduler(sched), soa_state=soa)


def _run_lm_cell(sched, tmp_path, soa=False, resume_soa=None):
    """Same bar as _run_cell over transformer params; ``resume_soa``
    (when not None) constructs the resuming run with a different state
    layout than the saving one — the checkpoint's layout must win."""
    if resume_soa is None:
        resume_soa = soa
    data, ev = _lm_data()
    full = _mk_lm(sched, 2, data, ev, soa=soa)
    hist_full = full.run()

    first = _mk_lm(sched, 1, data, ev, soa=soa)
    first.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    first.save_state(path)

    resumed = _mk_lm(sched, 1, data, ev, soa=resume_soa)
    assert resumed.load_state(path) == 1
    hist_resumed = resumed.run()

    for x, y in zip(jax.tree_util.tree_leaves(full.global_params),
                    jax.tree_util.tree_leaves(resumed.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for a, b in zip(hist_full[1:], hist_resumed):
        assert a.round == b.round
        assert a.bytes_up == b.bytes_up
        assert a.bytes_up_raw == b.bytes_up_raw
        assert a.bytes_down == b.bytes_down
        assert a.participants == b.participants
        assert a.staleness == b.staleness
        assert a.sim_time == b.sim_time
        assert a.global_metrics == b.global_metrics


@pytest.mark.parametrize("sched", ["sync", "sampled", "async"])
def test_resume_matrix_lm(sched, tmp_path):
    _run_lm_cell(sched, tmp_path)


@pytest.mark.parametrize("sched", ["sampled", "async-vector"])
def test_resume_matrix_lm_soa(sched, tmp_path):
    _run_lm_cell(sched, tmp_path, soa=True)


@pytest.mark.parametrize("save_soa,load_soa", [(False, True), (True, False)])
def test_resume_matrix_lm_cross_restore(save_soa, load_soa, tmp_path):
    """Checkpoint layout — not the resuming run's ctor flag — decides the
    restore format, task-generically (DESIGN.md §12.4 over §14 pytrees)."""
    _run_lm_cell("sync", tmp_path, soa=save_soa, resume_soa=load_soa)

"""ClientTask protocol (DESIGN.md §14): the ClassifierTask differential
(task-wrapped runs bit-identical to config-passing runs), task-keyed
checkpoints, the by_role_partition property over the whole config zoo, and
LMDeltaTask basics."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ArchConfig
from repro.configs.paper import MNIST_CLASSIFIER
from repro.core import (ClassifierTask, FLConfig, FederatedRun,
                        LMDeltaTask, QuantizeCompressor, SampledSync,
                        by_role_partition, role_of_path)
from repro.data.pipeline import (mnist_like, synthetic_lm_batch,
                                 train_eval_split, uniform_partition)
from repro.models import init_params, param_count

N_CLIENTS = 3

LM_CFG = ArchConfig(name="task-lm", family="dense", n_layers=1, d_model=32,
                    n_heads=2, n_kv_heads=2, head_dim=16, d_ff=64,
                    vocab_size=64, tie_embeddings=True,
                    param_dtype="float32", compute_dtype="float32",
                    remat=False, zero1=False)


def _clf_data():
    train, ev = train_eval_split(mnist_like(0, 128), 32)
    return uniform_partition(0, train, N_CLIENTS), ev


def _mk_clf(task_or_cfg, n_rounds, data, ev, scheduler=None):
    cfg = FLConfig(n_rounds=n_rounds, local_epochs=1, payload="update",
                   error_feedback=True)
    return FederatedRun(
        task_or_cfg, data, cfg,
        compressors=[QuantizeCompressor(bits=8) for _ in range(N_CLIENTS)],
        eval_data=ev, scheduler=scheduler)


# =====================================================================
# differential: explicit ClassifierTask ≡ the pre-task config ctor
# =====================================================================
@pytest.mark.parametrize("sched", ["sync", "sampled"])
def test_classifier_task_bit_identical(sched):
    data, ev = _clf_data()
    mk_sched = {"sync": lambda: None,
                "sampled": lambda: SampledSync(cohort=2)}[sched]
    a = _mk_clf(MNIST_CLASSIFIER, 2, data, ev, scheduler=mk_sched())
    b = _mk_clf(ClassifierTask(MNIST_CLASSIFIER), 2, data, ev,
                scheduler=mk_sched())
    ha, hb = a.run(), b.run()
    for x, y in zip(jax.tree_util.tree_leaves(a.global_params),
                    jax.tree_util.tree_leaves(b.global_params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for ra, rb in zip(ha, hb):
        assert ra.bytes_up == rb.bytes_up
        assert ra.bytes_up_raw == rb.bytes_up_raw
        assert ra.bytes_down == rb.bytes_down
        assert ra.participants == rb.participants
        assert ra.global_metrics == rb.global_metrics


def test_classifier_shim_sets_task_and_clf_cfg():
    data, ev = _clf_data()
    run = _mk_clf(MNIST_CLASSIFIER, 1, data, ev)
    assert isinstance(run.task, ClassifierTask)
    assert run.clf_cfg is MNIST_CLASSIFIER


def test_classifier_batched_path_gates_on_ragged_shapes():
    data, _ = _clf_data()
    task = ClassifierTask(MNIST_CLASSIFIER)
    cfg = FLConfig(local_epochs=1)
    params = task.init_params(jax.random.PRNGKey(0))
    out = task.local_update_batched(params, data, cfg, seed=0,
                                    anchor=params)
    assert out is not None and len(out) == len(data)
    ragged = [data[0], {k: v[:-1] for k, v in data[1].items()}]
    assert task.local_update_batched(params, ragged, cfg, seed=0,
                                     anchor=params) is None


# =====================================================================
# task-keyed checkpoints
# =====================================================================
def test_checkpoint_task_mismatch_refused(tmp_path):
    data, ev = _clf_data()
    run = _mk_clf(MNIST_CLASSIFIER, 1, data, ev)
    run.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    run.save_state(path)

    shards = [synthetic_lm_batch(seed=i, vocab_size=64, batch=4, seq_len=16)
              for i in range(N_CLIENTS)]
    lm = FederatedRun(
        LMDeltaTask(LM_CFG), shards,
        FLConfig(n_rounds=1, local_epochs=1, payload="update"),
        compressors=[QuantizeCompressor(bits=8) for _ in range(N_CLIENTS)])
    with pytest.raises(ValueError, match="task mismatch"):
        lm.load_state(path)


def test_checkpoint_roundtrip_keeps_task_key(tmp_path):
    data, ev = _clf_data()
    run = _mk_clf(ClassifierTask(MNIST_CLASSIFIER), 1, data, ev)
    run.run()
    path = os.path.join(tmp_path, "ckpt.npz")
    run.save_state(path)
    again = _mk_clf(MNIST_CLASSIFIER, 1, data, ev)   # shim-built task
    assert again.load_state(path) == 1               # same key → accepted


# =====================================================================
# by_role_partition tiles every zoo config's param tree
# =====================================================================
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_by_role_partition_tiles_zoo(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    pmap = by_role_partition(params)     # PartitionMap asserts tiling
    assert pmap.size == param_count(params)
    assert set(pmap.names) <= {"embedding", "attention", "mlp", "norm"}
    assert "other" not in pmap.names
    # every family has all four roles present
    assert {"embedding", "norm"} <= set(pmap.names)


def test_role_of_path_vocabulary():
    assert role_of_path("embed") == "embedding"
    assert role_of_path("lm_head") == "embedding"
    assert role_of_path("final_norm") == "norm"
    assert role_of_path("layers/attn/wq") == "attention"
    assert role_of_path("layers/sub0/mixer/conv_w") == "attention"
    assert role_of_path("layers/ffn/w1") == "mlp"
    assert role_of_path("layers/sub1/mlp/w1") == "mlp"
    assert role_of_path("layers/ln1/scale") == "norm"
    assert role_of_path("something/unknown") == "other"


# =====================================================================
# LMDeltaTask basics
# =====================================================================
def test_lm_task_requires_update_payload():
    shards = [synthetic_lm_batch(seed=i, vocab_size=64, batch=4, seq_len=16)
              for i in range(N_CLIENTS)]
    with pytest.raises(ValueError, match="payload"):
        FederatedRun(LMDeltaTask(LM_CFG), shards,
                     FLConfig(n_rounds=1, payload="weights"))


def test_lm_task_surface():
    task = LMDeltaTask(LM_CFG)
    data = synthetic_lm_batch(seed=0, vocab_size=64, batch=8, seq_len=16)
    assert task.num_examples(data) == 8
    assert task.data_weight(data) == 8.0
    batches = list(task.make_batches(0, data, batch_size=4))
    assert sum(b["tokens"].shape[0] for b in batches) == 8
    params = task.init_params(jax.random.PRNGKey(0))
    metrics = task.evaluate(params, data)
    assert np.isfinite(metrics["ce_loss"])
    cfg = FLConfig(local_epochs=1, batch_size=4)
    local, m = task.local_update(params, data, cfg, seed=0, anchor=params)
    assert np.isfinite(m["ce_loss"])
    # training moved the params
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree_util.tree_leaves(local),
                                jax.tree_util.tree_leaves(params)))
    assert moved


def test_lm_task_freeze_roles_zero_delta():
    task = LMDeltaTask(LM_CFG, freeze_roles=("embedding",))
    data = synthetic_lm_batch(seed=0, vocab_size=64, batch=4, seq_len=16)
    params = task.init_params(jax.random.PRNGKey(0))
    cfg = FLConfig(local_epochs=1, batch_size=2)
    local, _ = task.local_update(params, data, cfg, seed=0, anchor=params)
    np.testing.assert_array_equal(np.asarray(local["embed"]),
                                  np.asarray(params["embed"]))
    assert float(jnp.abs(local["layers"]["ffn"]["w_gate"]
                         - params["layers"]["ffn"]["w_gate"]).max()) > 0

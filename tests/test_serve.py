"""Serving-pipeline invariants (DESIGN.md §12.3).

The serve step is one donated jitted computation; these tests pin the
properties the throughput numbers rely on: FedBuff bookkeeping invariants
hold round over round (clock monotone, version increments, exactly one
in-flight dispatch per client), synthetic payloads have exactly the
encode-shape structure, donation actually recycles buffers, and the step
is deterministic (same config ⇒ same trajectory)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.core.serve import (ServeConfig, init_state, make_step,
                              round_bytes, run_serve, synthetic_payloads)

Q8 = codec.QuantizeSpec(size=512, bits=8, block=128)


def _cfg(**kw):
    base = dict(n_clients=64, buffer_k=8, spec=Q8, jitter=0.4,
                straggler_frac=0.1, seed=1)
    base.update(kw)
    return ServeConfig(**base)


def test_step_invariants_over_rounds():
    cfg = _cfg()
    step = make_step(cfg)
    state = init_state(cfg)
    prev_clock = -1.0
    for r in range(6):
        state = step(state)
        # version increments once per ingest round
        assert int(state["version"]) == r + 1
        # clock is monotone and equals the max popped arrival so far
        clock = float(state["clock"])
        assert clock >= prev_clock
        prev_clock = clock
        # every client has exactly one in-flight dispatch: all times
        # finite, all seqs distinct, next_seq advanced by k per round
        times = np.asarray(state["times"])
        assert np.all(np.isfinite(times))
        seqs = np.asarray(state["seqs"])
        assert len(np.unique(seqs)) == cfg.n_clients
        assert int(state["next_seq"]) == cfg.n_clients + (r + 1) * cfg.buffer_k
        # re-dispatched clients arrive after the clock
        assert np.all(times[seqs >= int(state["next_seq"]) - cfg.buffer_k]
                      >= clock)
        # client versions never exceed the global version
        assert np.asarray(state["versions"]).max() <= int(state["version"])


@pytest.mark.parametrize("spec", [
    Q8,
    codec.IdentitySpec(size=256),
    codec.TopKSpec(size=1024, k=64),
])
def test_synthetic_payloads_match_encode_structure(spec):
    """Payloads must be drop-in for real encoded cohorts: same treedef,
    per-leaf shapes = (k, *encode_shape), same dtypes — so the fused
    decode path compiles and prices identically."""
    k = 4
    want = jax.eval_shape(lambda f: codec.encode(spec, None, f),
                          jax.ShapeDtypeStruct((spec.size,), jnp.float32))
    got = synthetic_payloads(spec, None, k, jax.random.PRNGKey(0))
    w_leaves, w_def = jax.tree_util.tree_flatten(want)
    g_leaves, g_def = jax.tree_util.tree_flatten(got)
    assert w_def == g_def
    for w, g in zip(w_leaves, g_leaves):
        assert g.shape == (k, *w.shape)
        assert g.dtype == w.dtype
    # and the real decode consumes them without retracing errors
    rows = codec.decode_batched(spec, None, got)
    assert rows.shape == (k, spec.size)


def test_step_deterministic():
    cfg = _cfg()
    sa = init_state(cfg)
    sb = init_state(cfg)
    step_a, step_b = make_step(cfg), make_step(cfg)
    for _ in range(4):
        sa, sb = step_a(sa), step_b(sb)
    np.testing.assert_array_equal(np.asarray(sa["global_flat"]),
                                  np.asarray(sb["global_flat"]))
    np.testing.assert_array_equal(np.asarray(sa["times"]),
                                  np.asarray(sb["times"]))


def test_donation_consumes_input_state():
    """donate_argnums=0 really donates: the passed-in state's buffers are
    invalidated after the call (the double-buffering contract)."""
    cfg = _cfg(n_clients=32, buffer_k=4)
    step = make_step(cfg)
    state = init_state(cfg)
    out = step(state)
    assert state["global_flat"].is_deleted()
    # the returned generation is live and usable
    out2 = step(out)
    assert not out2["global_flat"].is_deleted()


def test_run_serve_report_and_bytes():
    cfg = _cfg(n_clients=128, buffer_k=16)
    state, report = run_serve(cfg, n_rounds=3, warmup=1)
    # 1 warmup + 3 timed rounds
    assert int(state["version"]) == 4
    assert report["rounds_per_sec"] > 0
    assert report["round_bytes"] == round_bytes(cfg)
    assert report["bytes_per_sec"] == pytest.approx(
        report["rounds_per_sec"] * report["round_bytes"])
    assert report["sim_time"] > 0


def test_global_flat_seed_passthrough():
    """A caller-provided flat model seeds the loop (the examples path)."""
    cfg = _cfg(n_clients=32, buffer_k=4)
    g0 = jnp.full(Q8.size, 2.0)
    state = init_state(cfg, global_flat=g0)
    np.testing.assert_array_equal(np.asarray(state["global_flat"]),
                                  np.asarray(g0))


def test_shard_single_device_matches_unsharded():
    """shard=True agrees with the plain fused path up to reduction-order
    float drift (the sharded path sums weighted rows via einsum + psum)."""
    if jax.device_count() != 1:
        pytest.skip("tolerance calibrated for the 1-device mesh")
    cfg_p = _cfg(n_clients=32, buffer_k=8, shard=False)
    cfg_s = _cfg(n_clients=32, buffer_k=8, shard=True)
    sa, sb = init_state(cfg_p), init_state(cfg_s)
    step_p, step_s = make_step(cfg_p), make_step(cfg_s)
    for _ in range(3):
        sa, sb = step_p(sa), step_s(sb)
    np.testing.assert_allclose(np.asarray(sa["global_flat"]),
                               np.asarray(sb["global_flat"]),
                               rtol=1e-4, atol=1e-4)

"""Vectorized arrival engine ≡ heapq oracle (DESIGN.md §12.2).

The engine's contract is *order-exactness*: ``pop_k`` returns exactly what
K sequential ``heapq.heappop`` calls on ``(time, seq, ci)`` tuples would —
same clients, same order, same float64 times — across random populations,
latency models, and interleaved push/pop schedules. The property test
drives both implementations through identical random schedules (pushes via
``LatencyModel.sample`` so real latency streams, ties included, are
exercised); unit tests pin the edge cases (FIFO ties, boundary ties at the
K-th time, checkpoint round-trip, the device-side ``pop_k_device``
agreement)."""
import heapq

import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ModuleNotFoundError:                       # pragma: no cover
    from _hypothesis_stub import hypothesis, st

from repro.core.arrival import ArrivalEngine, pop_k_device
from repro.core.scheduler import LatencyModel


class HeapOracle:
    """The original AsyncBuffered event queue, verbatim semantics."""

    def __init__(self):
        self.heap = []
        self.seq = 0

    def push(self, ci, t):
        heapq.heappush(self.heap, (t, self.seq, ci))
        self.seq += 1

    def pop_k(self, k):
        out = []
        for _ in range(k):
            t, _, ci = heapq.heappop(self.heap)
            out.append((t, ci))
        return out


def _drive(n, buffer_k, lat: LatencyModel, n_rounds: int):
    """Run the FedBuff dispatch discipline (all clients at t=0, drain K,
    re-dispatch exactly those K) through both queues in lockstep and
    return both pop traces."""
    eng, orc = ArrivalEngine(n), HeapOracle()
    clock_e = clock_o = 0.0
    dispatch = {ci: 0 for ci in range(n)}
    for ci in range(n):
        t = lat.sample(ci, dispatch[ci], n)
        eng.push(ci, clock_e + t)
        orc.push(ci, clock_o + t)
    trace_e, trace_o = [], []
    for _ in range(n_rounds):
        k = min(buffer_k, eng.in_flight())
        pe, po = eng.pop_k(k), orc.pop_k(k)
        trace_e.append(pe)
        trace_o.append(po)
        clock_e = max([clock_e] + [t for t, _ in pe])
        clock_o = max([clock_o] + [t for t, _ in po])
        for _, ci in po:
            dispatch[ci] += 1
            t = lat.sample(ci, dispatch[ci], n)
            eng.push(ci, clock_e + t)
            orc.push(ci, clock_o + t)
    return trace_e, trace_o


@hypothesis.settings(deadline=None, max_examples=30)
@hypothesis.given(
    n=st.integers(min_value=1, max_value=40),
    buffer_k=st.integers(min_value=1, max_value=40),
    jitter=st.floats(min_value=0.0, max_value=0.9),
    straggler_frac=st.floats(min_value=0.0, max_value=0.5),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_engine_matches_heap_oracle(n, buffer_k, jitter, straggler_frac,
                                    seed):
    lat = LatencyModel(base=1.0, jitter=jitter,
                       straggler_frac=straggler_frac, seed=seed)
    trace_e, trace_o = _drive(n, min(buffer_k, n), lat, n_rounds=6)
    assert trace_e == trace_o   # same clients, same order, same float64 t


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(
    n=st.integers(min_value=2, max_value=30),
    buffer_k=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2 ** 16),
)
def test_engine_staleness_weights_match(n, buffer_k, seed):
    """Equal pop sets at equal versions ⇒ equal staleness vectors ⇒ equal
    staleness weights — asserted through the actual weight function."""
    from repro.core.aggregate import staleness_weights
    lat = LatencyModel(base=1.0, jitter=0.4, seed=seed)
    trace_e, trace_o = _drive(n, min(buffer_k, n), lat, n_rounds=5)
    version = {ci: 0 for ci in range(n)}
    for r, (pe, po) in enumerate(zip(trace_e, trace_o)):
        stales_e = [r - version[ci] for _, ci in pe]
        stales_o = [r - version[ci] for _, ci in po]
        we = staleness_weights([1.0] * len(pe), stales_e, 0.5)
        wo = staleness_weights([1.0] * len(po), stales_o, 0.5)
        assert we == wo
        for _, ci in po:
            version[ci] = r + 1


# ---------------------------------------------------------------------
# deterministic unit tests (always run, hypothesis installed or not)
# ---------------------------------------------------------------------
def test_fifo_tie_break():
    """Equal arrival times pop in dispatch (seq) order — the heap's FIFO
    tie-break, which staleness weighting relies on."""
    eng = ArrivalEngine(4)
    for ci in [2, 0, 3, 1]:             # seq order ≠ index order
        eng.push(ci, 1.0)
    assert eng.pop_k(4) == [(1.0, 2), (1.0, 0), (1.0, 3), (1.0, 1)]


def test_boundary_tie_at_kth_time():
    """Ties AT the K-th smallest time resolve by seq, not index."""
    eng = ArrivalEngine(5)
    eng.push(4, 5.0)     # seq 0
    eng.push(3, 5.0)     # seq 1
    eng.push(2, 5.0)     # seq 2
    eng.push(0, 1.0)     # seq 3
    eng.push(1, 9.0)     # seq 4
    # k=2: times {1.0, 5.0×3, 9.0}; the 5.0 tie at the boundary goes to
    # the earliest-dispatched client (4), not the smallest index
    assert eng.pop_k(2) == [(1.0, 0), (5.0, 4)]
    assert eng.pop_k(2) == [(5.0, 3), (5.0, 2)]
    assert eng.in_flight() == 1


def test_push_many_matches_sequential_pushes():
    a, b = ArrivalEngine(6), ArrivalEngine(6)
    cis, ts = [4, 1, 5], [3.0, 2.0, 2.0]
    for ci, t in zip(cis, ts):
        a.push(ci, t)
    b.push_many(cis, ts)
    assert a.pop_k(3) == b.pop_k(3)
    assert a.next_seq == b.next_seq


def test_double_dispatch_asserts():
    eng = ArrivalEngine(2)
    eng.push(0, 1.0)
    with pytest.raises(AssertionError):
        eng.push(0, 2.0)
    with pytest.raises(AssertionError):
        eng.pop_k(2)                      # only one in flight


def test_entries_round_trip():
    """entries()/from_entries round-trips the same JSON shape AsyncBuffered
    persists — heap- and vector-engine checkpoints are interchangeable."""
    eng = ArrivalEngine(5)
    eng.push_many([3, 0, 4], [2.0, 1.0, 2.0])
    eng.pop_k(1)
    entries = eng.entries()
    assert all(len(e) == 3 for e in entries)
    clone = ArrivalEngine.from_entries(5, entries, eng.next_seq)
    assert clone.pop_k(2) == eng.pop_k(2)
    assert clone.next_seq == eng.next_seq


def test_pop_k_device_agrees_with_host_engine():
    """The jit-native first-K (serve pipeline) selects the same clients in
    the same order as the exact host engine when times are f32-exact."""
    rng = np.random.RandomState(0)
    n, k = 64, 9
    times = rng.randint(0, 8, size=n).astype(np.float64)  # force ties
    eng = ArrivalEngine(n)
    order = rng.permutation(n)
    for ci in order:
        eng.push(int(ci), float(times[ci]))
    seqs = eng.seqs.copy()
    d_times, d_idx = pop_k_device(
        jnp.asarray(eng.times, jnp.float32),
        jnp.asarray(seqs, jnp.int32), k)
    host = eng.pop_k(k)
    assert [int(ci) for _, ci in host] == [int(i) for i in d_idx]
    assert [float(t) for t, _ in host] == [float(t) for t in d_times]

"""Benchmark-trajectory artifacts and the regression gate (DESIGN.md §11.3):
BENCH_*.json schema, CSV/JSON row agreement, the ERROR-row-before-partial-rows
contract for generator tables, check_regression threshold/rescale/missing-
baseline behavior, and validity of the committed baselines (including the
grouped-kernel acceptance number they carry)."""
import io
import json
import os
import re
import sys

import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO_ROOT not in sys.path:       # benchmarks/ is a namespace package
    sys.path.insert(0, REPO_ROOT)

from benchmarks import check_regression, run as bench_run  # noqa: E402

BASELINE_DIR = os.path.join(REPO_ROOT, "benchmarks", "baselines")


def _fake_tables(monkeypatch, tables):
    import benchmarks.tables as tables_mod
    monkeypatch.setattr(tables_mod, "ALL_TABLES", tables)
    monkeypatch.setattr(tables_mod, "ROOFLINES", {}, raising=False)


# --------------------------------------------------------- run.py --json
def test_json_and_csv_agree_row_for_row(tmp_path, monkeypatch, capsys):
    rows = [("alpha", 12.34, "d1"), ("beta", 56.78, "d2")]
    _fake_tables(monkeypatch, [("fake", lambda: rows)])
    bench_run.main(["--tables", "fake", "--json", str(tmp_path)])
    out = capsys.readouterr().out.strip().splitlines()
    assert out[0] == "name,us_per_call,derived"
    csv_rows = [line.split(",", 2) for line in out[1:]]
    doc = json.load(open(tmp_path / "BENCH_fake.json"))
    assert doc["schema"] == 1
    assert doc["name"] == "fake"
    assert isinstance(doc["git_rev"], str) and doc["git_rev"]
    assert isinstance(doc["backend"], str) and doc["backend"]
    assert len(doc["rows"]) == len(csv_rows) == len(rows)
    for jrow, crow, orig in zip(doc["rows"], csv_rows, rows):
        assert jrow["name"] == crow[0] == orig[0]
        assert jrow["us_per_call"] == orig[1]        # full precision in JSON
        assert float(crow[1]) == pytest.approx(orig[1], abs=0.05)
        assert jrow["derived"] == crow[2] == orig[2]


def test_generator_table_error_emits_error_row_not_partial_rows(
        tmp_path, monkeypatch, capsys):
    """A table implemented as a generator that raises mid-iteration must
    produce the single ERROR row — not a partial prefix of clean-looking
    rows followed by a crash (the old harness iterated outside the try)."""
    def gen_table():
        yield ("first", 1.0, "ok")
        raise RuntimeError("boom mid-table")

    _fake_tables(monkeypatch, [("gen", gen_table)])
    with pytest.raises(SystemExit) as exc:
        bench_run.main(["--tables", "gen", "--json", str(tmp_path)])
    assert exc.value.code == 1
    out = capsys.readouterr().out.strip().splitlines()
    assert out[0] == "name,us_per_call,derived"
    assert len(out) == 2                       # ERROR row only, no partials
    assert out[1].startswith("gen,0,ERROR:") and "boom mid-table" in out[1]
    doc = json.load(open(tmp_path / "BENCH_gen.json"))
    assert doc["rows"] == [] and "boom mid-table" in doc["error"]


def test_error_table_exit_code_and_other_tables_still_run(
        monkeypatch, capsys):
    _fake_tables(monkeypatch, [
        ("bad", lambda: (_ for _ in ()).throw(ValueError("nope"))),
        ("good", lambda: [("row", 1.0, "fine")])])
    with pytest.raises(SystemExit):
        bench_run.main(["--tables", "all"])
    out = capsys.readouterr().out
    assert "bad,0,ERROR:" in out and "row,1.0,fine" in out


def test_artifact_includes_registered_roofline(tmp_path, monkeypatch):
    import benchmarks.tables as tables_mod
    monkeypatch.setattr(tables_mod, "ALL_TABLES",
                        [("fake", lambda: [("r", 1.0, "d")])])
    monkeypatch.setattr(tables_mod, "ROOFLINES",
                        {"fake": lambda: {"grouped": {"launches": 1}}},
                        raising=False)
    bench_run.main(["--tables", "fake", "--json", str(tmp_path)])
    doc = json.load(open(tmp_path / "BENCH_fake.json"))
    assert doc["roofline"] == {"grouped": {"launches": 1}}


def test_repeats_keeps_per_row_min(tmp_path, monkeypatch, capsys):
    """--repeats N runs the table N times and publishes the per-row minimum
    (min-of-many ≈ the machine floor; single shots jitter past the gate's
    20% threshold). Non-timed info rows keep their first occurrence."""
    calls = {"n": 0}

    def flaky_table():
        calls["n"] += 1
        k = calls["n"]
        return [("fast", 100.0 + 50.0 * (k % 2), f"run{k}"),   # 150,100,150
                ("slow", 300.0 - 10.0 * k, f"run{k}"),         # 290,280,270
                ("info", 0.0, "first" if k == 1 else "later")]

    _fake_tables(monkeypatch, [("fake", flaky_table)])
    bench_run.main(["--tables", "fake", "--json", str(tmp_path),
                    "--repeats", "3"])
    assert calls["n"] == 3
    out = capsys.readouterr().out.strip().splitlines()
    assert out[1:] == ["fast,100.0,run2", "slow,270.0,run3", "info,0.0,first"]
    doc = json.load(open(tmp_path / "BENCH_fake.json"))
    assert [r["us_per_call"] for r in doc["rows"]] == [100.0, 270.0, 0.0]

    merged = bench_run.merge_min_rows([[("a", 5.0, "x")]])
    assert merged == [("a", 5.0, "x")]


# ------------------------------------------------------- check_regression
def _doc(name, rows):
    return {"schema": 1, "name": name, "git_rev": "abc", "backend": "cpu",
            "rows": [{"name": n, "us_per_call": us, "derived": ""}
                     for n, us in rows]}


def test_regression_detected_at_25_but_not_15_percent():
    base = _doc("t", [("a", 10000.0), ("b", 10000.0)])
    cur_25 = _doc("t", [("a", 12500.0), ("b", 10000.0)])
    cur_15 = _doc("t", [("a", 11500.0), ("b", 10000.0)])
    regs, _ = check_regression.compare(base, cur_25, threshold=0.20)
    assert len(regs) == 1 and "t/a" in regs[0]
    regs, _ = check_regression.compare(base, cur_15, threshold=0.20)
    assert regs == []


def test_rescale_forgives_uniformly_slower_machine():
    names = ["a", "b", "c", "d", "e"]
    base = _doc("t", [(n, 10000.0) for n in names])
    # a 1.5x-slower runner is not a regression...
    cur = _doc("t", [(n, 15000.0) for n in names])
    regs, notes = check_regression.compare(base, cur, threshold=0.20)
    assert regs == [] and any("rescale" in n for n in notes)
    # ...but one row moving against its table-mates on that runner is
    cur["rows"][0]["us_per_call"] = 25000.0
    regs, _ = check_regression.compare(base, cur, threshold=0.20)
    assert len(regs) == 1 and "t/a" in regs[0]
    # small tables (<4 rows) skip the median rescale: a 2-row table with
    # one +25% row must still be flagged
    base2 = _doc("t", [("a", 10000.0), ("b", 10000.0)])
    cur2 = _doc("t", [("a", 12500.0), ("b", 10000.0)])
    regs, notes = check_regression.compare(base2, cur2, threshold=0.20)
    assert len(regs) == 1 and not any("rescale" in n for n in notes)


def test_min_delta_floor_guards_subresolution_rows():
    """A fast row crossing +20% on pure timer jitter (tens of µs of delta)
    must NOT flag; the same relative slip on a slow row, or a 2× blowup on
    the fast row (delta well past the floor), must."""
    base = _doc("t", [("fast", 400.0), ("slow", 10000.0)])
    jitter = _doc("t", [("fast", 490.0), ("slow", 10000.0)])   # +90µs: noise
    regs, _ = check_regression.compare(base, jitter, threshold=0.20)
    assert regs == []
    blowup = _doc("t", [("fast", 800.0), ("slow", 10000.0)])   # 2x: real
    regs, _ = check_regression.compare(base, blowup, threshold=0.20)
    assert len(regs) == 1 and "t/fast" in regs[0]
    slow_reg = _doc("t", [("fast", 400.0), ("slow", 12500.0)])
    regs, _ = check_regression.compare(base, slow_reg, threshold=0.20)
    assert len(regs) == 1 and "t/slow" in regs[0]
    # floor is tunable down to zero for exact gating
    regs, _ = check_regression.compare(base, jitter, threshold=0.20,
                                       min_delta_us=0.0)
    assert len(regs) == 1


def test_zero_us_and_unmatched_rows_are_skipped():
    base = _doc("t", [("a", 100.0), ("info", 0.0), ("gone", 50.0)])
    cur = _doc("t", [("a", 100.0), ("info", 0.0), ("new", 50.0)])
    regs, notes = check_regression.compare(base, cur)
    assert regs == []
    joined = "\n".join(notes)
    assert "gone" in joined and "new" in joined and "info" not in joined


def test_missing_baseline_tolerated_with_warning(tmp_path):
    cur_dir = tmp_path / "cur"
    cur_dir.mkdir()
    (cur_dir / "BENCH_newtable.json").write_text(
        json.dumps(_doc("newtable", [("a", 1.0)])))
    out = io.StringIO()
    n = check_regression.check_dirs(str(tmp_path / "nobase"), str(cur_dir),
                                    out=out)
    assert n == 0 and "WARNING: no baseline" in out.getvalue()


def test_check_dirs_end_to_end_exit_paths(tmp_path):
    base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
    base_dir.mkdir(), cur_dir.mkdir()
    (base_dir / "BENCH_t.json").write_text(
        json.dumps(_doc("t", [("a", 10000.0), ("b", 10000.0)])))
    (cur_dir / "BENCH_t.json").write_text(
        json.dumps(_doc("t", [("a", 13000.0), ("b", 10000.0)])))
    out = io.StringIO()
    assert check_regression.check_dirs(str(base_dir), str(cur_dir),
                                       out=out) == 1
    assert "REGRESSION" in out.getvalue()
    with pytest.raises(SystemExit):
        check_regression.main(["--baseline", str(base_dir),
                               "--current", str(cur_dir)])
    # passing current == baseline is clean
    out = io.StringIO()
    assert check_regression.check_dirs(str(base_dir), str(base_dir),
                                       out=out) == 0


# ----------------------------------------------------- committed baselines
@pytest.mark.parametrize("table", ["fl_decode_agg", "fl_partition"])
def test_committed_baseline_is_valid(table):
    path = os.path.join(BASELINE_DIR, f"BENCH_{table}.json")
    assert os.path.exists(path), (
        f"missing committed baseline {path} — regenerate with "
        f"`python -m benchmarks.run --tables {table} "
        f"--json benchmarks/baselines`")
    doc = check_regression.load_artifact(path)
    assert doc["name"] == table and "error" not in doc
    timed = {r["name"]: r["us_per_call"] for r in doc["rows"]
             if r["us_per_call"] > 0}
    assert len(timed) >= 4              # enough rows for median rescaling
    assert "roofline" in doc            # ROOFLINES-registered tables


def test_committed_baseline_codec_stacks_is_valid():
    """fl_codec_stacks (DESIGN.md §13.5) has a committed baseline with
    enough rows for median rescaling; no roofline (not a ROOFLINES
    table). Every row's derived field carries the stack's wire fraction —
    chained stacks must price strictly below the bare q8 row."""
    path = os.path.join(BASELINE_DIR, "BENCH_fl_codec_stacks.json")
    assert os.path.exists(path), (
        "missing committed baseline — regenerate with "
        "`python -m benchmarks.run --tables fl_codec_stacks "
        "--json benchmarks/baselines`")
    doc = check_regression.load_artifact(path)
    assert doc["name"] == "fl_codec_stacks" and "error" not in doc
    timed = {r["name"]: r["us_per_call"] for r in doc["rows"]
             if r["us_per_call"] > 0}
    assert len(timed) >= 4
    fracs = {r["name"]: float(re.search(r"wire ([\d.]+)x", r["derived"])
                              .group(1)) for r in doc["rows"]}
    assert fracs["topk_q8_c8"] < fracs["q8_c8"]
    assert fracs["ae_q8_kernel_c8"] < fracs["q8_c8"]


def test_committed_baseline_proves_grouped_overhead_bound():
    """The PR's acceptance number: at cohort 64 the grouped one-dispatch
    round holds the mixed-rung partition overhead to ≤1.3× the flat
    single-spec path (the sequential bucket loop measured 1.5–4.9×)."""
    doc = check_regression.load_artifact(
        os.path.join(BASELINE_DIR, "BENCH_fl_partition.json"))
    row = next(r for r in doc["rows"]
               if r["name"] == "decode_agg_part2_mixed_grouped_c64")
    m = re.search(r"overhead=([\d.]+)x", row["derived"])
    assert m, row["derived"]
    assert float(m.group(1)) <= 1.3


def test_committed_baseline_rate_control_pareto_is_valid():
    """fl_rate_control (DESIGN.md §15.6) carries the rate-control Pareto
    frontier: timed policy rows for the regression gate (≥4 for median
    rescaling) plus zero-µs per-round ``pareto_*`` frontier rows the gate
    skips. The acceptance claim rides in the artifact itself: at the
    matched uplink budget, the Lagrangian RDBudget's final-round accuracy
    is no worse than greedy ByteBudget's."""
    path = os.path.join(BASELINE_DIR, "BENCH_fl_rate_control.json")
    assert os.path.exists(path), (
        "missing committed baseline — regenerate with "
        "`python -m benchmarks.run --tables fl_rate_control "
        "--json benchmarks/baselines`")
    doc = check_regression.load_artifact(path)
    assert doc["name"] == "fl_rate_control" and "error" not in doc
    assert "roofline" not in doc        # not a ROOFLINES table
    rows = {r["name"]: r for r in doc["rows"]}
    timed = [n for n, r in rows.items() if r["us_per_call"] > 0]
    assert len(timed) >= 4              # enough rows for median rescaling
    for policy in ("fixed_r0", "fixed_r1", "fixed_r2", "distortion_target",
                   "byte_budget", "rd_budget"):
        assert f"rate_{policy}" in timed

    def acc(name):
        m = re.search(r"acc=([\d.]+)", rows[name]["derived"])
        assert m, rows[name]["derived"]
        return float(m.group(1))

    assert acc("rate_rd_budget") >= acc("rate_byte_budget")
    # per-round frontier rows: zero-µs (gate-skipped), one per policy per
    # round, monotone cumulative uplink
    pareto = sorted(n for n in rows if n.startswith("pareto_"))
    assert pareto and all(rows[n]["us_per_call"] == 0.0 for n in pareto)
    for policy in ("byte_budget", "rd_budget", "fixed_r0"):
        per_round = sorted(n for n in pareto
                           if n.startswith(f"pareto_{policy}_r"))
        assert len(per_round) >= 2
        ups = [float(re.search(r"cum_up_kB=([\d.]+)",
                               rows[n]["derived"]).group(1))
               for n in per_round]
        assert ups == sorted(ups)
    # the λ trace survives into the artifact for the RD rows
    assert any("lambda=" in rows[n]["derived"]
               for n in pareto if n.startswith("pareto_rd_budget"))


def test_committed_baseline_roofline_shape():
    doc = check_regression.load_artifact(
        os.path.join(BASELINE_DIR, "BENCH_fl_decode_agg.json"))
    roof = doc["roofline"]
    for variant in ("loop", "vmap", "fused", "grouped"):
        assert roof[variant]["launches"] >= 1
    assert roof["grouped"]["launches"] == 1
    assert roof["grouped"]["hbm_bytes"] <= roof["fused"]["hbm_bytes"]
    assert roof["fused"]["hbm_bytes"] < roof["vmap"]["hbm_bytes"]

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per row). Default sizes are
CPU-bounded; REPRO_BENCH_FULL=1 runs paper-scale versions. Select subsets
with ``python -m benchmarks.run --tables mnist_ae,savings_ratio``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="all",
                    help="comma-separated table names (or 'all')")
    ap.add_argument("--list", action="store_true",
                    help="print available table names and exit")
    args = ap.parse_args()

    from benchmarks.tables import ALL_TABLES
    if args.list:
        for name, _ in ALL_TABLES:
            print(name)
        return
    selected = {t.strip() for t in args.tables.split(",")}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in ALL_TABLES:
        if args.tables != "all" and name not in selected:
            continue
        t0 = time.perf_counter()
        try:
            rows = fn()
        except Exception as e:                        # noqa: BLE001
            print(f"{name},0,ERROR: {e!r}")
            failures += 1
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}")
        print(f"# table {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (one line per row). Default sizes are
CPU-bounded; REPRO_BENCH_FULL=1 runs paper-scale versions. Select subsets
with ``python -m benchmarks.run --tables mnist_ae,savings_ratio``.

``--json DIR`` additionally persists one ``BENCH_<table>.json`` artifact per
table — the benchmark-trajectory format (schema below) that
``benchmarks/check_regression.py`` diffs against the committed baselines in
``benchmarks/baselines/`` to gate perf regressions in CI (DESIGN.md §11.3).
The JSON rows are exactly the CSV rows (asserted row-for-row in
tests/test_bench_artifacts.py): one measurement, two sinks.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from typing import List, Optional, Tuple

SCHEMA_VERSION = 1


def _git_rev() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:                                  # noqa: BLE001
        return "unknown"


def _backend() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:                                  # noqa: BLE001
        return "unknown"


def write_artifact(json_dir: str, name: str,
                   rows: List[Tuple[str, float, str]],
                   error: Optional[str] = None) -> str:
    """Persist one table's measurements as ``BENCH_<name>.json``. Rows keep
    full float precision here (the CSV prints one decimal); ``roofline`` is
    attached for tables registered in ``tables.ROOFLINES`` — the analytic
    placement of each decode→aggregate variant against the memory roof
    (repro.roofline.analysis, DESIGN.md §11.3)."""
    doc = {
        "schema": SCHEMA_VERSION,
        "name": name,
        "git_rev": _git_rev(),
        "backend": _backend(),
        "rows": [
            {"name": rname, "us_per_call": us, "derived": derived}
            for rname, us, derived in rows
        ],
    }
    if error is not None:
        doc["error"] = error
    try:
        from benchmarks.tables import ROOFLINES
        roof_fn = ROOFLINES.get(name)
    except Exception:                                  # noqa: BLE001
        roof_fn = None
    if roof_fn is not None and error is None:
        try:
            doc["roofline"] = roof_fn()
        except Exception as e:                         # noqa: BLE001
            doc["roofline"] = {"error": repr(e)}
    os.makedirs(json_dir, exist_ok=True)
    path = os.path.join(json_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def merge_min_rows(
        all_rows: List[List[Tuple[str, float, str]]],
) -> List[Tuple[str, float, str]]:
    """Fold repeated table runs into one row set: rows are matched by name and
    the fastest ``us_per_call`` (with its derived string) wins. Min-of-many
    converges to the machine's true floor, which is what the regression gate
    needs — single-shot timings on a shared host jitter well past the 20%
    threshold (DESIGN.md §11.3). Non-timed rows (us<=0) keep their first
    occurrence; row order follows the first repeat."""
    merged: dict = {}
    order: List[str] = []
    for rows in all_rows:
        for rname, us, derived in rows:
            if rname not in merged:
                merged[rname] = (rname, us, derived)
                order.append(rname)
            else:
                _, best, _ = merged[rname]
                if us > 0 and (best <= 0 or us < best):
                    merged[rname] = (rname, us, derived)
    return [merged[n] for n in order]


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--tables", default="all",
                    help="comma-separated table names (or 'all')")
    ap.add_argument("--list", action="store_true",
                    help="print available table names and exit")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="also write BENCH_<table>.json artifacts to DIR")
    ap.add_argument("--repeats", type=int, default=1, metavar="N",
                    help="run each table N times and keep the per-row minimum"
                         " (use >=3 when generating baselines or gating)")
    args = ap.parse_args(argv)

    from benchmarks.tables import ALL_TABLES
    if args.list:
        for name, _ in ALL_TABLES:
            print(name)
        return
    selected = {t.strip() for t in args.tables.split(",")}
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in ALL_TABLES:
        if args.tables != "all" and name not in selected:
            continue
        t0 = time.perf_counter()
        try:
            # materialize INSIDE the try: a generator table that raises
            # mid-iteration must produce the ERROR row, not leak a partial
            # CSV prefix that parses as a clean (shorter) table
            rows_runs = [[tuple(r) for r in fn()]
                         for _ in range(max(1, args.repeats))]
            rows = (merge_min_rows(rows_runs) if len(rows_runs) > 1
                    else rows_runs[0])
        except Exception as e:                        # noqa: BLE001
            print(f"{name},0,ERROR: {e!r}")
            failures += 1
            if args.json:
                write_artifact(args.json, name, [], error=repr(e))
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.1f},{derived}")
        if args.json:
            write_artifact(args.json, name, rows)
        print(f"# table {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

"""One benchmark per paper table/figure + framework microbenches.

Every function returns a list of CSV rows (name, us_per_call, derived).
``derived`` carries the table's headline quantity (compression ratio,
accuracy delta, savings ratio, ...). Sizes are CPU-bounded by default;
set REPRO_BENCH_FULL=1 for the paper-scale versions.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, str]
FULL = os.environ.get("REPRO_BENCH_FULL", "0") == "1"


def _timeit(fn: Callable, n: int = 5) -> float:
    fn()                                   # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


def _timeit_min(fn: Callable, n: int = 5, warmup: bool = True) -> float:
    """Best-of-n (not mean): dispatch costs are what the server-path and
    trainer tables compare, and min is robust to CI scheduler noise. Set
    ``warmup=False`` for paths that re-trace every call (their compile IS
    the measured cost)."""
    if warmup:
        fn()                               # warmup / compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


# =====================================================================
# Fig. 4/5 — MNIST classifier AE: train, compress, validation model
# =====================================================================
def table_mnist_ae() -> List[Row]:
    from repro.configs.paper import MNIST_AE, MNIST_CLASSIFIER
    from repro.core import (FCAECompressor, fc_reconstruct, run_prepass,
                            validation_model_curve)
    from repro.data.pipeline import mnist_like

    epochs = 30 if FULL else 12
    ae_epochs = 300 if FULL else 120
    data = mnist_like(0, 2048 if FULL else 768)
    t0 = time.perf_counter()
    out = run_prepass(jax.random.PRNGKey(0), MNIST_CLASSIFIER, MNIST_AE,
                      data, prepass_epochs=epochs, ae_epochs=ae_epochs)
    wall = (time.perf_counter() - t0) * 1e6

    comp = FCAECompressor(out["ae_params"], MNIST_AE)
    _, stats = comp.roundtrip(out["model_params"])
    curve = validation_model_curve(
        MNIST_CLASSIFIER, out["weights_dataset"],
        lambda w: fc_reconstruct(out["ae_params"], MNIST_AE, w), data)
    acc_delta = abs(curve["original_acc"][-1] - curve["predicted_acc"][-1])
    rows = [
        ("fig4_mnist_ae_train_acc", wall,
         f"ae_acc={out['ae_history']['accuracy'][-1]:.3f} "
         f"val_acc={out['ae_history']['val_accuracy'][-1]:.3f} "
         f"(paper: 0.78/0.94)"),
        ("fig5_mnist_validation_model", wall,
         f"orig_acc={curve['original_acc'][-1]:.3f} "
         f"pred_acc={curve['predicted_acc'][-1]:.3f} delta={acc_delta:.3f}"),
        ("tab_mnist_compression_ratio", 0.0,
         f"ratio={stats['compression_ratio']:.0f}x (paper: ~500x, "
         f"latent=32)"),
    ]
    return rows


# =====================================================================
# Fig. 6/7 — CIFAR classifier AE (paper-exact 353M-param AE)
# =====================================================================
def table_cifar_ae() -> List[Row]:
    from repro.configs.paper import CIFAR_CLASSIFIER, cifar_ae_for
    from repro.core import (FCAECompressor, fc_reconstruct, run_prepass,
                            validation_model_curve)
    from repro.data.pipeline import cifar_like
    from repro.models.classifiers import init_classifier, n_params

    probe = init_classifier(jax.random.PRNGKey(0), CIFAR_CLASSIFIER)
    P = n_params(probe)
    ae_cfg = cifar_ae_for(P)
    epochs = 40 if FULL else 10
    ae_epochs = 60 if FULL else 50
    data = cifar_like(0, 1024 if FULL else 384)
    t0 = time.perf_counter()
    out = run_prepass(jax.random.PRNGKey(0), CIFAR_CLASSIFIER, ae_cfg, data,
                      prepass_epochs=epochs, ae_epochs=ae_epochs)
    wall = (time.perf_counter() - t0) * 1e6
    comp = FCAECompressor(out["ae_params"], ae_cfg)
    _, stats = comp.roundtrip(out["model_params"])
    curve = validation_model_curve(
        CIFAR_CLASSIFIER, out["weights_dataset"][-4:],
        lambda w: fc_reconstruct(out["ae_params"], ae_cfg, w), data)
    return [
        ("fig6_cifar_ae_train", wall,
         f"ae_params={ae_cfg.n_params} (paper: 352,915,690 @550,570) "
         f"loss={out['ae_history']['loss'][-1]:.5f}"),
        ("fig7_cifar_validation_model", wall,
         f"orig_acc={curve['original_acc'][-1]:.3f} "
         f"pred_acc={curve['predicted_acc'][-1]:.3f}"),
        ("tab_cifar_compression_ratio", 0.0,
         f"ratio={stats['compression_ratio']:.0f}x (paper: ~1720x, "
         f"latent=320)"),
    ]


# =====================================================================
# Fig. 8/9 — 2-collaborator color/grayscale FL under AE compression
# =====================================================================
def table_fl_color_imbalance() -> List[Row]:
    from repro.configs.paper import CIFAR_CLASSIFIER, cifar_ae_for
    from repro.core import (FCAECompressor, FLConfig, FederatedRun,
                            run_prepass)
    from repro.data.pipeline import cifar_like, color_imbalance_split
    from repro.models.classifiers import init_classifier, n_params

    P = n_params(init_classifier(jax.random.PRNGKey(0), CIFAR_CLASSIFIER))
    ae_cfg = cifar_ae_for(P)
    n_rounds = 40 if FULL else 6
    local_epochs = 5 if FULL else 2
    datasets, eval_data = color_imbalance_split(0, 1024 if FULL else 256)

    # per-collaborator pre-pass (paper Fig. 2), AE trained on local weights
    comps = []
    for ci, d in enumerate(datasets):
        out = run_prepass(jax.random.PRNGKey(10 + ci), CIFAR_CLASSIFIER,
                          ae_cfg, d, prepass_epochs=10 if FULL else 8,
                          ae_epochs=40 if FULL else 30)
        comps.append(FCAECompressor(out["ae_params"], ae_cfg))

    t0 = time.perf_counter()
    run = FederatedRun(CIFAR_CLASSIFIER, datasets,
                       FLConfig(n_rounds=n_rounds, local_epochs=local_epochs,
                                payload="weights"),   # paper §5.2 protocol
                       compressors=comps,
                       eval_data=eval_data)
    hist = run.run()
    wall = (time.perf_counter() - t0) * 1e6
    accs = [r.global_metrics["accuracy"] for r in hist]
    totals = run.total_bytes()
    return [
        ("fig8_9_fl_sawtooth", wall,
         f"rounds={n_rounds} acc_first={accs[0]:.3f} acc_last={accs[-1]:.3f} "
         f"ratio={hist[-1].compression_ratio:.0f}x (paper: 1720x, trains ok)"),
        ("fig8_9_fl_bytes", 0.0,
         f"bytes_up={totals['bytes_up']:.0f} raw={totals['bytes_up_raw']:.0f} "
         f"effective_ratio={totals['effective_ratio']:.0f}x"),
    ]


# =====================================================================
# Fig. 10/11 — savings-ratio trade-off + break-even points (Eq. 4-6)
# =====================================================================
def table_savings_ratio() -> List[Row]:
    from repro.core import SavingsModel
    sm_a = SavingsModel(original_size=550_570, compressed_size=320,
                        autoencoder_size=352_915_690, n_decoders=1)
    rows = [("fig10_sr_case_a", 0.0,
             f"SR(40r,1000c)={sm_a.savings_ratio(40, 1000):.0f} "
             f"(paper: ~120x beyond 1000 collabs) "
             f"break_even_collabs@8r={sm_a.break_even_collabs(8)} "
             f"(paper: 40)")]
    # case (b): one decoder per collaborator — collabs cancel
    for c in (10, 100, 1000):
        sm_b = SavingsModel(original_size=550_570, compressed_size=320,
                            autoencoder_size=352_915_690, n_decoders=c)
        rows.append((f"fig11_sr_case_b_{c}collabs", 0.0,
                     f"break_even_rounds={sm_b.break_even_rounds(c)} "
                     f"(paper: 320) SR(1000r)="
                     f"{sm_b.savings_ratio(1000, c):.0f}"))
    rows.append(("tab_asymptote", 0.0,
                 f"asymptotic={sm_a.asymptotic_ratio():.0f}x (paper: ~1720x)"))
    return rows


# =====================================================================
# Beyond paper — codec comparison on one FL task
# =====================================================================
def table_codec_comparison() -> List[Row]:
    from repro.configs.paper import MNIST_AE, MNIST_CLASSIFIER
    from repro.core import (FCAECompressor, FLConfig, FederatedRun,
                            IdentityCompressor, QuantizeCompressor,
                            TopKCompressor, run_prepass)
    from repro.data.pipeline import dirichlet_partition, mnist_like

    from repro.data.pipeline import train_eval_split
    train, eval_data = train_eval_split(mnist_like(0, 1024), 256)
    data = dirichlet_partition(0, train, 2, alpha=1.0)
    out = run_prepass(jax.random.PRNGKey(0), MNIST_CLASSIFIER, MNIST_AE,
                      data[0], prepass_epochs=8, ae_epochs=60)
    # deltas suit the pointwise codecs; the AE codes weights (its
    # pre-pass training distribution) per the paper's protocol
    codecs = {
        "identity": (lambda: IdentityCompressor(), "update"),
        "quant8": (lambda: QuantizeCompressor(bits=8), "update"),
        "quant4": (lambda: QuantizeCompressor(bits=4), "update"),
        "topk5pct": (lambda: TopKCompressor(fraction=0.05), "update"),
        "fc_ae": (lambda: FCAECompressor(out["ae_params"], MNIST_AE),
                  "weights"),
    }
    rows = []
    for name, (mk, payload) in codecs.items():
        t0 = time.perf_counter()
        run = FederatedRun(MNIST_CLASSIFIER, data,
                           FLConfig(n_rounds=4 if FULL else 3,
                                    local_epochs=1, error_feedback=True,
                                    payload=payload),
                           compressors=[mk() for _ in data],
                           eval_data=eval_data)
        hist = run.run()
        wall = (time.perf_counter() - t0) * 1e6
        totals = run.total_bytes()
        rows.append((f"codec_{name}", wall,
                     f"acc={hist[-1].global_metrics['accuracy']:.3f} "
                     f"ratio={totals['effective_ratio']:.0f}x"))
    return rows


# =====================================================================
# §4.2 — dynamic AE: latent width vs ratio vs reconstruction quality
# =====================================================================
def table_dynamic_tradeoff() -> List[Row]:
    """The paper's central knob: 'the compression ratio ... can be modified
    based on the accuracy requirements' — sweep the bottleneck width."""
    from repro.configs.paper import AEConfig, MNIST_CLASSIFIER
    from repro.core import run_prepass, train_autoencoder
    from repro.data.pipeline import mnist_like

    data = mnist_like(0, 512)
    out = run_prepass(
        jax.random.PRNGKey(0), MNIST_CLASSIFIER,
        AEConfig(input_dim=15_910, encoder_hidden=(64,), latent_dim=32),
        data, prepass_epochs=10, ae_epochs=1)      # dataset only
    dataset = out["weights_dataset"]
    rows = []
    for latent in (8, 32, 128, 512):
        cfg = AEConfig(input_dim=15_910, encoder_hidden=(64,),
                       latent_dim=latent)
        t0 = time.perf_counter()
        params, hist = train_autoencoder(jax.random.PRNGKey(1), cfg,
                                         dataset, epochs=60)
        wall = (time.perf_counter() - t0) * 1e6
        rows.append((f"dynamic_latent_{latent}", wall,
                     f"ratio={cfg.compression_ratio:.0f}x "
                     f"val_loss={hist['val_loss'][-1]:.5f} "
                     f"val_acc={hist['val_accuracy'][-1]:.3f}"))
    return rows


# =====================================================================
# appendix — convolutional AE alternative (paper §4.3)
# =====================================================================
def table_conv_ae() -> List[Row]:
    from repro.configs.paper import AEConfig, MNIST_CLASSIFIER
    from repro.core import (ConvAEConfig, ae_param_count, conv_decode,
                            conv_encode, init_conv_ae, run_prepass,
                            train_autoencoder)
    from repro.data.pipeline import mnist_like

    data = mnist_like(0, 512)
    out = run_prepass(
        jax.random.PRNGKey(0), MNIST_CLASSIFIER,
        AEConfig(input_dim=15_910, encoder_hidden=(64,), latent_dim=32),
        data, prepass_epochs=10, ae_epochs=1)
    dataset = out["weights_dataset"]
    pad = (-dataset.shape[1]) % 64
    dataset = jnp.pad(dataset, ((0, 0), (0, pad)))

    cfg = ConvAEConfig(channels=(8, 16), kernel=9, stride=8,
                       latent_channels=1)
    t0 = time.perf_counter()
    params, hist = train_autoencoder(jax.random.PRNGKey(1), cfg, dataset,
                                     kind="conv", epochs=40)
    wall = (time.perf_counter() - t0) * 1e6
    z = conv_encode(params, cfg, dataset[:1])
    ratio = dataset.shape[1] / z.size
    fc_params = 2 * 15_910 * 64                    # FC AE first-layer scale
    return [
        ("appendix_conv_ae", wall,
         f"ratio={ratio:.0f}x ae_params={ae_param_count(params)} "
         f"(FC-AE first layer alone: {fc_params}) "
         f"val_loss={hist['val_loss'][-1]:.5f}"),
    ]


# =====================================================================
# kernel microbenches (interpret-mode on CPU; TPU-native on TPU)
# =====================================================================
def table_kernels() -> List[Row]:
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
    from repro.kernels import ops

    rows = []
    cfg = ChunkedAEConfig(chunk_size=4096, hidden=(512,), latent_chunk=8)
    params = init_chunked_ae(jax.random.PRNGKey(0), cfg)
    flat = jax.random.normal(jax.random.PRNGKey(1), (1 << 20,))

    enc = jax.jit(lambda f: ops.ae_encode(params, cfg, f))
    z = enc(flat)
    rows.append(("kernel_ae_encode_1M", _timeit(
        lambda: jax.block_until_ready(enc(flat))),
        f"ratio={cfg.compression_ratio:.0f}x latent={z.shape}"))
    dec = jax.jit(lambda zz: ops.ae_decode(params, cfg, zz, flat.size))
    rows.append(("kernel_ae_decode_1M", _timeit(
        lambda: jax.block_until_ready(dec(z))), "fused dense chain"))

    q8 = jax.jit(lambda f: ops.quantize_blocks(f, bits=8, block=256))
    rows.append(("kernel_quantize8_1M", _timeit(
        lambda: jax.block_until_ready(q8(flat)[0])), "blockwise absmax"))
    return rows


# =====================================================================
# scalable runtime (DESIGN.md §6) — scheduler wall-time + byte accounting
# =====================================================================
def table_fl_schedulers() -> List[Row]:
    """Sync vs sampled(vmap) vs sampled(loop) vs async-buffered: per-round
    wall time and total up/down traffic on the same 16-client federation.
    The vmap-vs-loop pair is the §6.4 batching claim measured directly."""
    from repro.configs.paper import MNIST_CLASSIFIER, SMOKE_SCALE_SCENARIO
    from repro.core import (AsyncBuffered, FLConfig, FederatedRun,
                            LatencyModel, SampledSync, SyncFedAvg)
    from repro.data.pipeline import (mnist_like, train_eval_split,
                                     uniform_partition)

    sc = SMOKE_SCALE_SCENARIO
    n_clients = sc.n_clients if FULL else 8
    cohort = sc.cohort if FULL else 4
    rounds = sc.rounds if FULL else 2
    train, ev = train_eval_split(mnist_like(0, 2048 if FULL else 1024), 256)
    # equal shards so sampled_vmap really measures the vmap path (a ragged
    # dirichlet federation would silently fall back to the loop)
    data = uniform_partition(0, train, n_clients)
    cfg = FLConfig(n_rounds=rounds, local_epochs=1, lr=2e-3,
                   payload="update")

    schedulers = [
        ("sync_fedavg", SyncFedAvg),
        ("sampled_vmap", lambda: SampledSync(cohort=cohort, use_vmap=True)),
        ("sampled_loop", lambda: SampledSync(cohort=cohort,
                                             use_vmap=False)),
        ("async_buffered", lambda: AsyncBuffered(
            buffer_k=sc.buffer_k,
            latency=LatencyModel(jitter=sc.latency_jitter,
                                 straggler_frac=sc.straggler_frac,
                                 straggler_mult=sc.straggler_mult))),
    ]
    rows: List[Row] = []
    for name, make_sched in schedulers:
        # warmup pass on a throwaway run so one-time jit compilation does
        # not pollute the timed rounds (schedulers are one-run objects, so
        # each pass gets a fresh instance)
        warm_cfg = FLConfig(n_rounds=1, local_epochs=1, lr=2e-3,
                            payload="update")
        FederatedRun(MNIST_CLASSIFIER, data, warm_cfg, eval_data=ev,
                     scheduler=make_sched()).run()
        sched = make_sched()
        run = FederatedRun(MNIST_CLASSIFIER, data, cfg, eval_data=ev,
                           scheduler=sched)
        t0 = time.perf_counter()
        hist = run.run()
        us_per_round = (time.perf_counter() - t0) / rounds * 1e6
        tot = run.total_bytes()
        vmap_note = ""
        if isinstance(sched, SampledSync):
            vmap_note = f" vmap_rounds={sched.vmap_rounds}/{rounds}"
        rows.append((f"scheduler_{name}", us_per_round,
                     f"acc={hist[-1].global_metrics['accuracy']:.3f} "
                     f"up={tot['bytes_up'] / 1e3:.0f}kB "
                     f"down={tot['bytes_down'] / 1e3:.0f}kB{vmap_note}"))
    return rows


# =====================================================================
# batched server decode→aggregate (DESIGN.md §7) — per-client loop vs
# one-call vmap vs fused Pallas kernel vs shard_map, across cohort sizes
# =====================================================================
def table_fl_decode_agg() -> List[Row]:
    """The aggregator's round hot path measured directly: decode one
    ChunkedAE payload per cohort client and FedAvg the results. ``loop`` is
    the seed server (per-client decode dispatch + Python accumulation);
    ``fused`` is one jitted ``codec.decode_and_aggregate`` (vmap-batched
    decode + einsum on the jnp path, the Pallas fused decode→aggregate
    kernel on the kernel path); ``shard_map`` splits the client axis over
    the local device mesh (1 device on CPU CI — measures dispatch, not
    scaling). On CPU the kernels run in interpret mode."""
    from repro.core import codec, normalize_weights
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae

    model = (1 << 20) if FULL else (1 << 15)          # flat update length
    cfg = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=8)
    params = init_chunked_ae(jax.random.PRNGKey(0), cfg)
    jnp_spec = None
    rows: List[Row] = []
    for cohort in (8, 64, 256):
        comp_spec = codec.ChunkedAESpec(size=model, cfg=cfg,
                                        use_kernel=True)
        jnp_spec = codec.ChunkedAESpec(size=model, cfg=cfg,
                                       use_kernel=False)
        flat = jax.random.normal(jax.random.PRNGKey(1), (model,))
        payloads = [codec.encode(jnp_spec, params, flat * (1 + 0.01 * i))
                    for i in range(cohort)]
        stacked = codec.stack_payloads(payloads)
        weights = normalize_weights([float(i + 1) for i in range(cohort)])
        nw = jnp.asarray(weights, jnp.float32)

        def loop():                        # the seed server path
            acc = jnp.zeros((model,), jnp.float32)
            for w, p in zip(weights, payloads):
                acc = acc + w * codec.decode(jnp_spec, params, p)
            return jax.block_until_ready(acc)

        def batched():
            return jax.block_until_ready(
                codec.decode_and_aggregate(jnp_spec, params, stacked, nw))

        def fused():
            return jax.block_until_ready(
                codec.decode_and_aggregate(comp_spec, params, stacked, nw))

        def sharded():
            return jax.block_until_ready(
                codec.decode_and_aggregate_sharded(jnp_spec, params,
                                                   stacked, nw))

        t_loop = _timeit_min(loop)
        t_batch = _timeit_min(batched)
        t_fused = _timeit_min(fused)
        t_shard = _timeit_min(sharded)
        rows += [
            (f"decode_agg_loop_c{cohort}", t_loop,
             f"per-client dispatch x{cohort}"),
            (f"decode_agg_vmap_c{cohort}", t_batch,
             f"speedup={t_loop / max(t_batch, 1e-9):.1f}x vs loop"),
            (f"decode_agg_fused_c{cohort}", t_fused,
             f"speedup={t_loop / max(t_fused, 1e-9):.1f}x vs loop "
             f"(pallas kernel{', interpret' if jax.default_backend() != 'tpu' else ''})"),
            (f"decode_agg_shard_c{cohort}", t_shard,
             f"speedup={t_loop / max(t_shard, 1e-9):.1f}x vs loop "
             f"({len(jax.devices())} dev)"),
        ]
    return rows


# =====================================================================
# jit-native AE trainer (DESIGN.md §8.1) — eager loop vs lax.scan vs
# cohort-vmap, across cohort sizes
# =====================================================================
def table_ae_train() -> List[Row]:
    """The AE-lifecycle hot path measured directly: fit C clients' AEs on
    their snapshot buffers. ``eager`` is the per-batch Python loop (one
    dispatch + one host sync per batch, re-jitted per call — the oracle);
    ``scan`` is the jit-native trainer called per client; ``cohort`` fits
    all C in ONE vmapped dispatch. Best-of-n timing (CI noise); compile is
    excluded by warmup for the scan/cohort paths. The eager loop gets NO
    warmup on purpose — it rebuilds its jitted closures every call, so a
    warmup pass would amortize nothing and only double the slowest leg;
    per-call re-jit is part of the cost being measured."""
    from repro.configs.paper import AEConfig
    from repro.core import (train_autoencoder_cohort, train_autoencoder_eager,
                            train_autoencoder_scan)

    cfg = AEConfig(input_dim=256, encoder_hidden=(64,), latent_dim=8)
    epochs = 60 if FULL else 30
    n_snap = 24                            # paper-scale: tens of snapshots
    z = jax.random.normal(jax.random.PRNGKey(0), (64, n_snap, 4))
    basis = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.input_dim))
    all_data = z @ basis + 0.01 * jax.random.normal(
        jax.random.PRNGKey(2), (64, n_snap, cfg.input_dim))
    rows: List[Row] = []
    for cohort in (1, 8, 64):
        datasets = all_data[:cohort]
        rngs = jax.random.split(jax.random.PRNGKey(3), cohort)

        def eager():
            for ci in range(cohort):
                train_autoencoder_eager(rngs[ci], cfg, datasets[ci],
                                        epochs=epochs)

        def scan():
            for ci in range(cohort):
                train_autoencoder_scan(rngs[ci], cfg, datasets[ci],
                                       epochs=epochs)

        def cohort_vmap():
            _, hist = train_autoencoder_cohort(rngs, cfg, datasets,
                                               epochs=epochs)
            jax.block_until_ready(hist["loss"])

        # the eager loop is the slow path under test: no warmup (see
        # docstring) and a single timed pass at the big cohorts
        t_eager = _timeit_min(eager, n=1 if cohort > 1 else 3, warmup=False)
        t_scan = _timeit_min(scan, n=3)
        t_cohort = _timeit_min(cohort_vmap, n=3)
        rows += [
            (f"ae_train_eager_c{cohort}", t_eager,
             f"per-batch host syncs x{cohort} clients"),
            (f"ae_train_scan_c{cohort}", t_scan,
             f"speedup={t_eager / max(t_scan, 1e-9):.1f}x vs eager"),
            (f"ae_train_cohort_c{cohort}", t_cohort,
             f"speedup={t_eager / max(t_cohort, 1e-9):.1f}x vs eager "
             f"(one vmapped dispatch)"),
        ]
    return rows


# =====================================================================
# adaptive rate control (DESIGN.md §9) — accuracy-vs-bytes Pareto frontier
# on the Dirichlet non-IID split: fixed rungs vs the adaptive policies
# =====================================================================
def table_fl_rate_control() -> List[Row]:
    """Every fixed ladder rung vs DistortionTarget vs ByteBudget vs
    Lagrangian RDBudget on the same non-IID federation: the frontier the
    paper's 'can be modified based on the accuracy requirements' claim
    (§4.2) promises. Each policy row reports final accuracy, uplink
    bytes, decoder-sync bytes (rung-switch re-ships included), and the
    rung switches taken — an adaptive policy earns its place by landing
    below the fixed-rung frontier (fewer total bytes at matched
    accuracy). The zero-µs ``pareto_*`` rows emit the per-round frontier
    (accuracy vs cumulative bytes at matched budgets, greedy vs RD vs
    fixed) into the committed JSON artifact; the regression gate only
    times positive-µs rows, so these ride along as data
    (DESIGN.md §15.6)."""
    from repro.configs.paper import MNIST_CLASSIFIER
    from repro.core import (ByteBudget, DistortionTarget, FLConfig,
                            FederatedRun, FixedRate, RDBudget,
                            fc_ae_ladder, run_prepass, train_autoencoder)
    from repro.configs.paper import AEConfig
    from repro.data.pipeline import (dirichlet_partition, mnist_like,
                                     train_eval_split)

    n_clients = 4
    latents = (8, 32, 128)
    # hidden must be ≥ the widest latent: a narrower hidden layer
    # bottlenecks every rung to the same effective capacity and rung
    # fidelity stops ordering by latent width (the frontier collapses)
    hidden = (128,)
    rounds = 6 if FULL else 3
    train, ev = train_eval_split(mnist_like(0, 1024 if FULL else 512), 128)
    data = dirichlet_partition(0, train, n_clients, alpha=0.5,
                               min_per_client=16)

    # one pre-pass per client for the weights dataset, then every ladder
    # rung's AE trained on it (paper Fig. 2 protocol, per rung; enough
    # epochs that rung fidelity orders by latent width — an undertrained
    # ladder turns the frontier into noise). The pre-pass MUST start from
    # the same initial global params the federated run below inits with
    # (FLConfig.seed): AEs trained on a foreign init's trajectory price a
    # basin the run never visits — every rung probes garbage and the
    # frontier degenerates (DESIGN.md §15.6)
    from repro.models.classifiers import init_classifier
    P = 15_910
    init0 = init_classifier(jax.random.PRNGKey(FLConfig().seed),
                            MNIST_CLASSIFIER)
    params = []
    for ci in range(n_clients):
        out = run_prepass(jax.random.PRNGKey(10 + ci), MNIST_CLASSIFIER,
                          AEConfig(input_dim=P, encoder_hidden=hidden,
                                   latent_dim=latents[0]),
                          data[ci], prepass_epochs=24, ae_epochs=1,
                          init_params=init0)
        row = []
        for latent in latents:
            cfg = AEConfig(input_dim=P, encoder_hidden=hidden,
                           latent_dim=latent)
            p, _ = train_autoencoder(jax.random.PRNGKey(100 + ci), cfg,
                                     out["weights_dataset"], epochs=300)
            row.append(p)
        params.append(row)

    def ladder():
        return fc_ae_ladder(n_clients, P, latent_dims=latents,
                            hidden=hidden, params=params)

    policies = [(f"fixed_r{k}", lambda k=k: FixedRate(ladder=ladder(),
                                                      initial_rung=k))
                for k in range(len(latents))]
    matched_budget = n_clients * latents[1] * 4.0   # greedy ≡ RD budgets
    policies += [
        ("distortion_target", lambda: DistortionTarget(
            ladder=ladder(), target=0.15, min_snapshots=2, cooldown=2,
            refit_epochs=20, refit_batch=4)),
        ("byte_budget", lambda: ByteBudget(
            ladder=ladder(), budget=matched_budget,
            min_snapshots=2, refit_epochs=20, refit_batch=4)),
        ("rd_budget", lambda: RDBudget(
            ladder=ladder(), budget=matched_budget, cooldown=2,
            min_snapshots=2, refit_epochs=20, refit_batch=4)),
    ]
    rows: List[Row] = []
    pareto: List[Row] = []
    for name, mk in policies:
        t0 = time.perf_counter()
        rc = mk()
        run = FederatedRun(
            MNIST_CLASSIFIER, data,
            FLConfig(n_rounds=rounds, local_epochs=2, payload="weights"),
            eval_data=ev, ratecontrol=rc)
        hist = run.run()
        wall = (time.perf_counter() - t0) * 1e6
        tot = run.total_bytes()
        switches = sum(len(r.spec_switches or []) for r in hist)
        rows.append((f"rate_{name}", wall,
                     f"acc={hist[-1].global_metrics['accuracy']:.3f} "
                     f"up={tot['bytes_up'] / 1e3:.1f}kB "
                     f"dec={tot['bytes_decoder'] / 1e3:.0f}kB "
                     f"switches={switches}"))
        lam_by_round = dict(getattr(rc, "lambda_trace", []))
        cum_up = cum_dec = 0.0
        for rec in hist:
            cum_up += rec.bytes_up
            cum_dec += rec.bytes_decoder or 0.0
            lam_r = lam_by_round.get(rec.round)
            lam = (f" lambda={lam_r:.3e}"
                   if name == "rd_budget" and lam_r is not None else "")
            pareto.append((
                f"pareto_{name}_r{rec.round}", 0.0,
                f"acc={rec.global_metrics['accuracy']:.4f} "
                f"cum_up_kB={cum_up / 1e3:.2f} "
                f"cum_dec_kB={cum_dec / 1e3:.2f}{lam}"))
    return rows + pareto


# =====================================================================
# roofline summary (reads the dry-run reports if present)
# =====================================================================
def table_roofline_summary() -> List[Row]:
    base = os.path.join(os.path.dirname(__file__), "..", "reports", "dryrun")
    rows: List[Row] = []
    for fname, tag in (("final_single.jsonl", "single"),
                       ("final_multi.jsonl", "multi"),
                       ("final_fl_multi.jsonl", "fl")):
        path = os.path.join(base, fname)
        if not os.path.exists(path):
            rows.append((f"roofline_{tag}", 0.0, "dry-run report not found "
                         "(run repro.launch.dryrun first)"))
            continue
        with open(path) as f:
            recs = [json.loads(line) for line in f]
        dom = {}
        for r in recs:
            dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
        rows.append((f"roofline_{tag}", 0.0,
                     f"{len(recs)} configs dominant={dom}"))
    return rows


# =====================================================================
# per-layer codec partitions (DESIGN.md §10) — flat vs partitioned server
# decode→aggregate, homogeneous and mixed-rung cohorts
# =====================================================================
def table_fl_partition() -> List[Row]:
    """The §10.2 grouped fused server path measured against the flat
    single-spec path on the same cohort: ``flat`` is one
    ``decode_and_aggregate`` over the whole update; ``part2`` is the same
    cohort under a 2-group partition (bulk + head, both q8 — one fused
    call per group inlined into one jitted dispatch); ``part2_mixed`` is a
    heterogeneous cohort (half the clients on q8, half on q4 for the bulk
    group) through ``partition.server_decode_aggregate`` — one fused call
    per (partition, spec) bucket, and ``part2_mixed_grouped`` the same
    cohort through the one-dispatch grouped round (DESIGN.md §11.2).
    ``partae_mixed[_grouped]`` swaps the bulk group to two kernel-path
    chunked-AE rungs (a rate-control ladder shape): sequential = one Pallas
    launch per AE bucket; grouped = all AE buckets in ONE grouped ragged
    launch. Partitioning costs the extra per-group dispatches + the scatter
    epilogue; this table keeps that overhead honest next to
    ``fl_decode_agg``."""
    from repro.core import codec, normalize_weights, partition
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
    from repro.core.scheduler import EncodedUpdate

    model = (1 << 20) if FULL else (1 << 15)
    head = model // 16
    bulk = model - head
    pmap = partition.PartitionMap(groups=(
        ("bulk", ((0, bulk),)), ("head", ((model - head, head),))))
    rows: List[Row] = []
    flat_spec = codec.QuantizeSpec(size=model)
    part_spec = partition.make_partition_spec(pmap, {
        "bulk": codec.QuantizeSpec(size=bulk),
        "head": codec.QuantizeSpec(size=head)})
    spec_q4_bulk = partition.make_partition_spec(pmap, {
        "bulk": codec.QuantizeSpec(size=bulk, bits=4),
        "head": codec.QuantizeSpec(size=head)})
    # two kernel-path AE rungs for the bulk group — a per-partition
    # rate-control ladder in miniature (latent 8 vs 4 per 256-chunk)
    cfg_hi = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=8)
    cfg_lo = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=4)
    prm_hi = init_chunked_ae(jax.random.PRNGKey(7), cfg_hi)
    prm_lo = init_chunked_ae(jax.random.PRNGKey(8), cfg_lo)
    spec_ae_hi = partition.make_partition_spec(pmap, {
        "bulk": codec.ChunkedAESpec(size=bulk, cfg=cfg_hi, use_kernel=True),
        "head": codec.QuantizeSpec(size=head)})
    spec_ae_lo = partition.make_partition_spec(pmap, {
        "bulk": codec.ChunkedAESpec(size=bulk, cfg=cfg_lo, use_kernel=True),
        "head": codec.QuantizeSpec(size=head)})
    for cohort in (8, 64):
        flats = [jax.random.normal(jax.random.PRNGKey(i), (model,))
                 for i in range(cohort)]
        weights = normalize_weights([float(i + 1) for i in range(cohort)])
        nw = jnp.asarray(weights, jnp.float32)
        flat_stacked = codec.stack_payloads(
            [codec.encode(flat_spec, None, f) for f in flats])
        part_stacked = codec.stack_payloads(
            [codec.encode(part_spec, None, f) for f in flats])
        mixed = [
            EncodedUpdate(
                payload=codec.encode(
                    part_spec if i % 2 else spec_q4_bulk, None, f),
                spec=(part_spec if i % 2 else spec_q4_bulk), params=None,
                weight=weights[i], stats={}, metrics={})
            for i, f in enumerate(flats)]
        ae_mixed = []
        for i, f in enumerate(flats):
            sp = spec_ae_hi if i % 2 else spec_ae_lo
            prm = {"bulk": prm_hi if i % 2 else prm_lo, "head": None}
            ae_mixed.append(EncodedUpdate(
                payload=codec.encode(sp, prm, f), spec=sp, params=prm,
                weight=weights[i], stats={}, metrics={}))

        def flat_path():
            return jax.block_until_ready(
                codec.decode_and_aggregate(flat_spec, None, flat_stacked,
                                           nw))

        def part_path():
            return jax.block_until_ready(
                codec.decode_and_aggregate(part_spec, None, part_stacked,
                                           nw))

        def part_mixed():
            return jax.block_until_ready(
                partition.server_decode_aggregate(mixed, weights, None))

        def part_mixed_grouped():
            return jax.block_until_ready(
                partition.server_decode_aggregate(
                    mixed, weights, None, use_grouped_kernel=True))

        def partae_mixed():
            return jax.block_until_ready(
                partition.server_decode_aggregate(ae_mixed, weights, None))

        def partae_mixed_grouped():
            return jax.block_until_ready(
                partition.server_decode_aggregate(
                    ae_mixed, weights, None, use_grouped_kernel=True))

        t_flat = _timeit_min(flat_path)
        t_part = _timeit_min(part_path)
        t_mix = _timeit_min(part_mixed)
        t_mix_g = _timeit_min(part_mixed_grouped)
        t_ae = _timeit_min(partae_mixed)
        t_ae_g = _timeit_min(partae_mixed_grouped)
        rows += [
            (f"decode_agg_flat_c{cohort}", t_flat, "single spec"),
            (f"decode_agg_part2_c{cohort}", t_part,
             f"overhead={t_part / max(t_flat, 1e-9):.2f}x vs flat "
             "(2 groups, 1 jitted call)"),
            (f"decode_agg_part2_mixed_c{cohort}", t_mix,
             f"overhead={t_mix / max(t_flat, 1e-9):.2f}x vs flat "
             "(3 (partition, spec) buckets)"),
            (f"decode_agg_part2_mixed_grouped_c{cohort}", t_mix_g,
             f"overhead={t_mix_g / max(t_flat, 1e-9):.2f}x vs flat "
             "(grouped: 1 dispatch)"),
            (f"decode_agg_partae_mixed_c{cohort}", t_ae,
             "2 AE rungs + q8 head, sequential buckets"),
            (f"decode_agg_partae_mixed_grouped_c{cohort}", t_ae_g,
             f"speedup={t_ae / max(t_ae_g, 1e-9):.2f}x vs sequential "
             "(1 grouped ragged launch for both AE buckets)"),
        ]
    return rows


# =====================================================================
# per-role codec partitions over a real transformer pytree (DESIGN.md §14)
# — client-side encode + server decode→aggregate at reduced zoo shapes
# =====================================================================
def table_fl_llm() -> List[Row]:
    """The ``examples/llm_federated.py`` hot paths priced at benchmark
    cohorts: a reduced ``configs/`` transformer is partitioned with
    ``by_role_partition`` (embedding/attention/MLP on kernel-path chunked
    AEs, norms on q8) and the table measures (a) one client's partitioned
    encode, (b) the server decode→aggregate over the cohort — flat q8
    baseline, per-role sequential buckets, and the one-dispatch grouped
    round that folds all three AE buckets into a single ragged Pallas
    launch. Non-FULL shrinks the arch below ``reduced()`` so cohort×model
    stays CPU-CI-sized; FULL runs the example's actual reduced shapes."""
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.core import codec, normalize_weights, partition
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
    from repro.core.scheduler import EncodedUpdate
    from repro.models import init_params

    cfg = get_config("llama3-8b").reduced()
    if not FULL:
        cfg = _dc.replace(cfg, d_model=128, n_heads=4, n_kv_heads=4,
                          d_ff=256, vocab_size=512)
    params = init_params(jax.random.PRNGKey(0), cfg)
    pmap = partition.by_role_partition(params)
    ae_cfg = ChunkedAEConfig(chunk_size=256, hidden=(64,), latent_chunk=8)
    prm = {name: (init_chunked_ae(jax.random.PRNGKey(7), ae_cfg)
                  if name != "norm" else None) for name in pmap.names}
    role_spec = partition.make_partition_spec(pmap, {
        name: (codec.ChunkedAESpec(size=pmap.group_size(name), cfg=ae_cfg,
                                   use_kernel=True) if name != "norm" else
               codec.QuantizeSpec(size=pmap.group_size(name)))
        for name in pmap.names})
    model = pmap.size
    flat_spec = codec.QuantizeSpec(size=model)
    rows: List[Row] = [(f"llm_role_partition", 0.0,
                        f"{cfg.name}: {model} params, "
                        f"{ {n: pmap.group_size(n) for n in pmap.names} }")]

    flat = jax.random.normal(jax.random.PRNGKey(0), (model,)) * 1e-3

    def client_encode():
        return jax.block_until_ready(
            codec.encode(role_spec, prm, flat)["embedding"]["z"])

    rows.append(("llm_encode_role_ae", _timeit_min(client_encode),
                 "one client's partitioned encode (3 AE roles + q8 norm)"))

    for cohort in (8, 32):
        flats = [jax.random.normal(jax.random.PRNGKey(i), (model,)) * 1e-3
                 for i in range(cohort)]
        weights = normalize_weights([float(i + 1) for i in range(cohort)])
        nw = jnp.asarray(weights, jnp.float32)
        flat_stacked = codec.stack_payloads(
            [codec.encode(flat_spec, None, f) for f in flats])
        encoded = [EncodedUpdate(payload=codec.encode(role_spec, prm, f),
                                 spec=role_spec, params=prm,
                                 weight=weights[i], stats={}, metrics={})
                   for i, f in enumerate(flats)]

        def flat_path():
            return jax.block_until_ready(
                codec.decode_and_aggregate(flat_spec, None, flat_stacked,
                                           nw))

        def role_seq():
            return jax.block_until_ready(
                partition.server_decode_aggregate(encoded, weights, None))

        def role_grouped():
            return jax.block_until_ready(
                partition.server_decode_aggregate(
                    encoded, weights, None, use_grouped_kernel=True))

        t_flat = _timeit_min(flat_path)
        t_seq = _timeit_min(role_seq)
        t_grp = _timeit_min(role_grouped)
        rows += [
            (f"llm_decode_agg_flat_q8_c{cohort}", t_flat, "flat q8 baseline"),
            (f"llm_decode_agg_role_c{cohort}", t_seq,
             f"overhead={t_seq / max(t_flat, 1e-9):.2f}x vs flat "
             "(sequential (role, spec) buckets)"),
            (f"llm_decode_agg_role_grouped_c{cohort}", t_grp,
             f"speedup={t_seq / max(t_grp, 1e-9):.2f}x vs sequential "
             "(3 AE roles in 1 grouped ragged launch)"),
        ]
    return rows


# =====================================================================
# analytic rooflines attached to the BENCH_*.json artifacts
# (benchmarks/run.py --json; repro.roofline.analysis, DESIGN.md §11.3)
# =====================================================================
def _roofline_fl_decode_agg() -> dict:
    model = (1 << 20) if FULL else (1 << 15)
    from repro.roofline.analysis import decode_agg_roofline
    return decode_agg_roofline(cohort=64, n_chunks=model // 256, latent=8,
                               hidden=(32,), chunk=256, n_buckets=1)


def _roofline_fl_partition() -> dict:
    model = (1 << 20) if FULL else (1 << 15)
    bulk = model - model // 16
    from repro.roofline.analysis import decode_agg_roofline
    return decode_agg_roofline(cohort=64, n_chunks=bulk // 256, latent=8,
                               hidden=(32,), chunk=256, n_buckets=2)


# =====================================================================
# streaming serve throughput (DESIGN.md §12.3) — sustained ingest
# rounds/sec at large population × large cohort
# =====================================================================
def table_fl_serve() -> List[Row]:
    """The million-client ingest loop: ``run_serve`` drives the donated
    jitted step (device-side first-K pop → synthetic encoded cohort →
    fused decode→aggregate → re-dispatch) and reports sustained
    rounds/sec and ingested uplink bytes/sec. Population is 10^5 clients
    (10^6 under REPRO_BENCH_FULL); per-round HOST work is one dispatch of
    a cached executable regardless of N or cohort. Cohorts follow ISSUE 7
    (256 / 4096 / 65536); the 65536-cohort row shrinks the model so
    cohort×model stays CPU-CI-sized — the row prices the pop/re-dispatch
    machinery at extreme K, not bulk decode FLOPs. ``ae`` swaps in the
    chunked-AE codec (jnp path — the Pallas kernel interprets on CPU) and
    ``shard`` runs the cohort axis through shard_map (1 device on CI —
    dispatch overhead, not scaling)."""
    from repro.core import codec
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
    from repro.core.serve import ServeConfig, run_serve

    n = 1_000_000 if FULL else 100_000
    model = (1 << 16) if FULL else (1 << 12)
    rows: List[Row] = []

    def serve_row(name, spec, cohort, params=None, shard=False,
                  n_rounds=2):
        cfg = ServeConfig(n_clients=n, buffer_k=cohort, spec=spec,
                          jitter=0.4, straggler_frac=0.05, seed=0,
                          shard=shard)
        _, rep = run_serve(cfg, n_rounds=n_rounds, codec_params=params,
                           warmup=1)
        rows.append((name, rep["us_per_round"],
                     f"{rep['rounds_per_sec']:.2f} r/s "
                     f"{rep['bytes_per_sec'] / 1e6:.1f} MB/s N={n}"))

    q8 = codec.QuantizeSpec(size=model, bits=8, block=256)
    serve_row("serve_q8_c256", q8, 256, n_rounds=3)
    serve_row("serve_q8_c4096", q8, 4096)
    # extreme cohort: keep cohort×model ≈ 16M so one CPU core sustains it
    big_model = (1 << 10) if FULL else (1 << 8)
    serve_row("serve_q8_c65536",
              codec.QuantizeSpec(size=big_model, bits=8, block=big_model),
              65536)

    ae_cfg = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=8)
    ae_params = init_chunked_ae(jax.random.PRNGKey(0), ae_cfg)
    serve_row("serve_ae_c256",
              codec.ChunkedAESpec(size=model, cfg=ae_cfg,
                                  use_kernel=False),
              256, params=ae_params, n_rounds=3)
    serve_row("serve_shard_c256", q8, 256, shard=True, n_rounds=3)
    return rows


# =====================================================================
# composable codec stacks (DESIGN.md §13) — chained server aggregation
# =====================================================================
def table_fl_codec_stacks() -> List[Row]:
    """Chain stacks on the fused server path, bare vs chained cohorts at
    8/64 clients. ``q8`` is the bare pointwise baseline; ``topk_q8`` is the
    scatter-terminal chain (one weighted scatter-add, dense rows never
    built) against its sequential per-client oracle ``topk_q8_seq``;
    ``ae_q8_kernel`` is the kernel-terminal chain (quantized latents →
    fused Pallas decode→aggregate); ``mixed_grouped`` reduces a two-rung
    chain-ladder cohort through the one-dispatch grouped round vs the
    group-by-spec sequential loop ``mixed_seq``. ``derived`` reports the
    stack's wire size as a fraction of raw — the uplink the chain buys."""
    from repro.core import codec, normalize_weights, partition
    from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
    from repro.core.scheduler import EncodedUpdate

    model = (1 << 20) if FULL else (1 << 15)
    raw_bytes = model * 4
    rows: List[Row] = []

    q8 = codec.QuantizeSpec(size=model, bits=8, block=256)
    k = model // 20
    topk_q8 = codec.ChainSpec((
        codec.TopKSpec(size=model, k=k),
        codec.QuantizeSpec(size=k, bits=8, block=64)))
    cfg_hi = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=8)
    cfg_lo = ChunkedAEConfig(chunk_size=256, hidden=(32,), latent_chunk=4)
    prm_hi = init_chunked_ae(jax.random.PRNGKey(7), cfg_hi)
    prm_lo = init_chunked_ae(jax.random.PRNGKey(8), cfg_lo)

    def ae_chain(cfg):
        spec = codec.ChunkedAESpec(size=model, cfg=cfg, use_kernel=True)
        n_lat = spec.n_chunks * cfg.latent_chunk
        return codec.ChainSpec((
            spec, codec.QuantizeSpec(size=n_lat, bits=8, block=64)))

    ae_hi, ae_lo = ae_chain(cfg_hi), ae_chain(cfg_lo)

    def frac(spec, params=None):
        return codec.wire_bytes(spec, params) / raw_bytes

    for cohort in (8, 64):
        flats = [jax.random.normal(jax.random.PRNGKey(i), (model,))
                 for i in range(cohort)]
        weights = normalize_weights([float(i + 1) for i in range(cohort)])
        nw = jnp.asarray(weights, jnp.float32)

        def agg_row(name, spec, params, wire_frac):
            stacked = codec.stack_payloads(
                [codec.encode(spec, params, f) for f in flats])

            def fn():
                return jax.block_until_ready(codec.decode_and_aggregate(
                    spec, params, stacked, nw))

            rows.append((f"{name}_c{cohort}", _timeit_min(fn),
                         f"wire {wire_frac:.3f}x raw"))
            return stacked

        agg_row("q8", q8, None, frac(q8))
        tk_stacked = agg_row("topk_q8", topk_q8, None, frac(topk_q8))
        agg_row("ae_q8_kernel", ae_hi, (prm_hi, None),
                frac(ae_hi, (prm_hi, None)))

        # sequential per-client oracle for the scatter-terminal chain
        tk_payloads = [codec.encode(topk_q8, None, f) for f in flats]

        def topk_seq():
            out = None
            for wi, pl in zip(weights, tk_payloads):
                c = jnp.float32(wi) * codec.decode(topk_q8, None, pl)
                out = c if out is None else out + c
            return jax.block_until_ready(out)

        rows.append((f"topk_q8_seq_c{cohort}", _timeit_min(topk_seq),
                     f"wire {frac(topk_q8):.3f}x raw"))
        del tk_stacked

        # two-rung chain-ladder cohort: grouped one-dispatch vs group-by-
        # spec sequential loop (the scheduler's two heterogeneous paths)
        mixed = [EncodedUpdate(
            payload=codec.encode(ae_hi if i % 2 else ae_lo,
                                 ((prm_hi if i % 2 else prm_lo), None), f),
            spec=(ae_hi if i % 2 else ae_lo),
            params=((prm_hi if i % 2 else prm_lo), None),
            weight=weights[i], stats={}, metrics={})
            for i, f in enumerate(flats)]

        def mixed_grouped():
            return jax.block_until_ready(
                partition.grouped_flat_server_aggregate(
                    mixed, weights, None))

        def mixed_seq():
            out = None
            groups: dict = {}
            for i, e in enumerate(mixed):
                groups.setdefault(e.spec, []).append(i)
            for spec, idx in groups.items():
                s_g = sum(weights[i] for i in idx)
                w_g = jnp.asarray([weights[i] / s_g for i in idx],
                                  jnp.float32)
                stacked = codec.stack_payloads(
                    [mixed[i].payload for i in idx])
                part = codec.decode_and_aggregate(
                    spec, mixed[idx[0]].params, stacked, w_g)
                contrib = jnp.float32(s_g) * part
                out = contrib if out is None else out + contrib
            return jax.block_until_ready(out)

        mixed_frac = (frac(ae_hi, (prm_hi, None))
                      + frac(ae_lo, (prm_lo, None))) / 2
        rows.append((f"mixed_grouped_c{cohort}", _timeit_min(mixed_grouped),
                     f"wire {mixed_frac:.3f}x raw"))
        rows.append((f"mixed_seq_c{cohort}", _timeit_min(mixed_seq),
                     f"wire {mixed_frac:.3f}x raw"))
    return rows


ROOFLINES = {
    "fl_decode_agg": _roofline_fl_decode_agg,
    "fl_partition": _roofline_fl_partition,
}


ALL_TABLES = [
    ("mnist_ae", table_mnist_ae),
    ("cifar_ae", table_cifar_ae),
    ("fl_color_imbalance", table_fl_color_imbalance),
    ("savings_ratio", table_savings_ratio),
    ("dynamic_tradeoff", table_dynamic_tradeoff),
    ("conv_ae", table_conv_ae),
    ("codec_comparison", table_codec_comparison),
    ("kernels", table_kernels),
    ("fl_schedulers", table_fl_schedulers),
    ("fl_decode_agg", table_fl_decode_agg),
    ("ae_train", table_ae_train),
    ("fl_rate_control", table_fl_rate_control),
    ("fl_partition", table_fl_partition),
    ("fl_llm", table_fl_llm),
    ("fl_codec_stacks", table_fl_codec_stacks),
    ("fl_serve", table_fl_serve),
    ("roofline_summary", table_roofline_summary),
]

"""Perf regression gate over the persisted benchmark trajectory.

Diffs the ``BENCH_<table>.json`` artifacts a fresh ``benchmarks.run --json``
produced against the committed baselines in ``benchmarks/baselines/`` and
exits 1 when any row slowed down by more than ``--threshold`` (default 20%)
**after machine rescaling** (DESIGN.md §11.3).

Rescaling: CI runners and the baseline machine differ in raw speed, so a
uniform shift of every row is machine noise, not a regression. With ≥4
matched rows the per-row ratios are divided by their median before the
threshold test — a real regression moves one kernel's row against its
table-mates, a slow runner moves them all together. Small tables (<4 rows)
skip rescaling (a median over 2–3 rows would absorb the very regression it
should expose) and compare raw ratios.

Missing baselines are tolerated with a warning (new tables land before
their first committed baseline); rows with ``us_per_call <= 0`` (ERROR /
info-only rows) are skipped on either side.

Sub-resolution rows: a row must also slow down by more than
``--min-delta-us`` (default 150µs) in rescaled absolute terms. Timer
resolution on a shared host is tens of µs, so a 400µs row can cross +20%
on pure jitter; a *real* regression on such a fast row that matters will
clear the floor easily (2× of 400µs is a 400µs delta). The floor never
masks rows slow enough for 20% to be measurable.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

DEFAULT_THRESHOLD = 0.20
DEFAULT_MIN_DELTA_US = 150.0
MIN_ROWS_FOR_RESCALE = 4


def load_artifact(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    assert doc.get("schema") == 1, f"{path}: unknown schema {doc.get('schema')}"
    return doc


def _timed_rows(doc: Dict) -> Dict[str, float]:
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])
            if float(r["us_per_call"]) > 0.0}


def compare(baseline: Dict, current: Dict, *,
            threshold: float = DEFAULT_THRESHOLD,
            rescale: bool = True,
            min_delta_us: float = DEFAULT_MIN_DELTA_US,
            ) -> Tuple[List[str], List[str]]:
    """Compare one table's artifacts → (regressions, notes). A regression
    line names the row, the baseline/current µs, and the (rescaled) ratio;
    notes cover skipped rows and the rescale factor applied."""
    base_rows = _timed_rows(baseline)
    cur_rows = _timed_rows(current)
    matched = sorted(set(base_rows) & set(cur_rows))
    notes: List[str] = []
    only_base = sorted(set(base_rows) - set(cur_rows))
    only_cur = sorted(set(cur_rows) - set(base_rows))
    if only_base:
        notes.append(f"rows only in baseline (skipped): {only_base}")
    if only_cur:
        notes.append(f"rows only in current (skipped): {only_cur}")
    if not matched:
        notes.append("no matched timed rows — nothing compared")
        return [], notes
    ratios = {n: cur_rows[n] / base_rows[n] for n in matched}
    scale = 1.0
    if rescale and len(matched) >= MIN_ROWS_FOR_RESCALE:
        ordered = sorted(ratios.values())
        mid = len(ordered) // 2
        scale = (ordered[mid] if len(ordered) % 2
                 else 0.5 * (ordered[mid - 1] + ordered[mid]))
        notes.append(f"machine rescale factor (median ratio): {scale:.3f}")
    regressions = []
    for n in matched:
        rel = ratios[n] / scale
        delta = cur_rows[n] / scale - base_rows[n]
        if rel > 1.0 + threshold and delta > min_delta_us:
            regressions.append(
                f"{current['name']}/{n}: {base_rows[n]:.1f}us -> "
                f"{cur_rows[n]:.1f}us ({rel:.2f}x rescaled, "
                f"threshold {1.0 + threshold:.2f}x)")
    return regressions, notes


def check_dirs(baseline_dir: str, current_dir: str, *,
               threshold: float = DEFAULT_THRESHOLD,
               rescale: bool = True,
               min_delta_us: float = DEFAULT_MIN_DELTA_US,
               out=sys.stdout) -> int:
    """Walk every BENCH_*.json in ``current_dir`` against its baseline.
    Returns the total regression count (the process exit code)."""
    cur_paths = sorted(glob.glob(os.path.join(current_dir, "BENCH_*.json")))
    if not cur_paths:
        print(f"WARNING: no BENCH_*.json artifacts in {current_dir}",
              file=out)
        return 0
    total = 0
    for cur_path in cur_paths:
        fname = os.path.basename(cur_path)
        base_path = os.path.join(baseline_dir, fname)
        current = load_artifact(cur_path)
        if current.get("error"):
            print(f"WARNING: {fname}: current run errored "
                  f"({current['error']}) — not compared", file=out)
            continue
        if not os.path.exists(base_path):
            print(f"WARNING: no baseline for {fname} in {baseline_dir} — "
                  "tolerated (commit one to start gating it)", file=out)
            continue
        baseline = load_artifact(base_path)
        regs, notes = compare(baseline, current, threshold=threshold,
                              rescale=rescale, min_delta_us=min_delta_us)
        for note in notes:
            print(f"  [{fname}] {note}", file=out)
        for reg in regs:
            print(f"REGRESSION: {reg}", file=out)
        if not regs:
            print(f"OK: {fname} ({len(_timed_rows(current))} timed rows)",
                  file=out)
        total += len(regs)
    return total


def main(argv: Optional[List[str]] = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline",
                    default=os.path.join(os.path.dirname(
                        os.path.abspath(__file__)), "baselines"),
                    help="directory of committed BENCH_*.json baselines")
    ap.add_argument("--current", required=True,
                    help="directory of freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="relative slowdown that fails the gate "
                         "(0.20 = +20%%)")
    ap.add_argument("--no-rescale", action="store_true",
                    help="disable median machine rescaling")
    ap.add_argument("--min-delta-us", type=float,
                    default=DEFAULT_MIN_DELTA_US,
                    help="absolute rescaled slowdown a row must also exceed "
                         "(guards sub-resolution rows against timer jitter)")
    args = ap.parse_args(argv)
    n = check_dirs(args.baseline, args.current, threshold=args.threshold,
                   rescale=not args.no_rescale,
                   min_delta_us=args.min_delta_us)
    if n:
        print(f"{n} benchmark regression(s) beyond "
              f"+{args.threshold * 100:.0f}%")
        raise SystemExit(1)
    print("benchmark trajectory: no regressions")


if __name__ == "__main__":
    main()

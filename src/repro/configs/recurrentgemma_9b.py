"""recurrentgemma-9b [hybrid] — arXiv:2402.19427 (Griffin).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000. Repeating pattern of
two RG-LRU residual blocks followed by one local-attention block (window
2048); 38 = 12 x (R,R,A) + 2 trailing recurrent layers. GeGLU MLP, RMSNorm,
head_dim=256 MQA on the attention layers. long_500k decode runs natively:
state = RG-LRU hidden + a 2048-token local window cache.
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    attn_type="gqa",
    rope_theta=10000.0,
    activation="geglu",
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, window=2048,
                      pattern=("rglru", "rglru", "attn")),
    long_context_window=None,          # native sub-quadratic
)

"""whisper-medium [audio] — arXiv:2212.04356.

24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865. Encoder-decoder: 24
encoder + 24 decoder layers, GELU MLP, LayerNorm, learned positions (encoder
positions are sinusoidal in the original; the dry-run treats both as learned
tables of the right shape). The mel-spectrogram + conv frontend is a STUB —
``input_specs()`` feeds precomputed frame embeddings (B, 1500, 1024).
"""
from repro.configs.base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=24,                       # decoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    attn_type="gqa",
    rope_theta=0.0,                    # no rope; learned absolute positions
    norm_type="layernorm",
    activation="gelu",
    encdec=EncDecConfig(n_encoder_layers=24, n_frames=1500),
)

"""llama4-maverick-400b-a17b [moe] — hf:meta-llama/Llama-4-Scout-17B-16E family.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128 experts top-1
with a Llama-4-style shared expert (early-fusion multimodal in the real model;
the assignment exercises the text trunk — image tokens would enter through the
same embedding stream).

Memory policy: at ~740B weights (128 experts x 48 layers) this arch trains
with bf16 params + bf16-momentum SGD and ZeRO-1 state sharding so a single
16x16 v5e pod holds params+state; AdamW variants fit at 2-pod scale
(EXPERIMENTS.md §Dry-run has the byte accounting).
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    attn_type="gqa",
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                  capacity_factor=1.25, shared_expert=True),
    rope_theta=500000.0,
    activation="swiglu",
    optimizer="sgdm_bf16",
    param_dtype="bfloat16",
)

"""phi-3-vision-4.2b [vlm] — hf:microsoft/Phi-3-vision-128k-instruct.

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064. phi3-mini trunk + CLIP
vision tower. The ViT/projector is a STUB — ``input_specs()`` supplies
precomputed patch embeddings (B, 576, 3072) merged at image-token positions.
"""
from repro.configs.base import ArchConfig, VLMConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    source="hf:microsoft/Phi-3-vision-128k-instruct",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    attn_type="gqa",
    rope_theta=10000.0,
    activation="swiglu",
    vlm=VLMConfig(n_image_tokens=576),
)

"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b.

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352. StableLM-2 details:
LayerNorm (not RMSNorm), partial rotary embedding on 25% of head dims.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    attn_type="gqa",
    rope_theta=10000.0,
    rope_pct=0.25,
    norm_type="layernorm",
    activation="swiglu",
)

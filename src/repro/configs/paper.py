"""Configs for the paper's own collaborator models (§4.1) and their AEs.

The original paper uses Keras MNIST/CIFAR classifiers. We reproduce the exact
parameter counts where the paper states them:

* MNIST classifier — 15,910 params. A 784→20→10 MLP gives exactly
  784*20 + 20 + 20*10 + 10 = 15,910. The AE bottleneck is 32 features →
  15,910/32 ≈ 497x ("about 500x", §5.1).
* CIFAR classifier — 550,570 params (conv net; we match the count with the
  conv stack below to within <0.1% and record the exact count in
  EXPERIMENTS.md). The paper's FC AE for it has 352,915,690 params and
  achieves ~1720x: a single-bottleneck 550,570→320→550,570 AE has
  2*550,570*320 + 320 + 550,570 = 352,915,690 params exactly — so the paper's
  CIFAR AE is the one-hidden-layer funnel, which we use verbatim.

Offline substitution: the container has no dataset downloads, so training uses
deterministic synthetic datasets with the same tensor shapes (MNIST-like:
784-dim 10-class gaussian clusters; CIFAR-like: 32x32x3 10-class). The claim
under test — that an AE can learn/compress/recreate *weight update* vectors
well enough to preserve task accuracy — is dataset-agnostic; DESIGN.md §3
records the substitution.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ClassifierConfig:
    name: str
    kind: str                      # mlp | cnn
    input_shape: Tuple[int, ...]
    n_classes: int
    hidden: Tuple[int, ...] = ()
    # cnn-only
    conv_channels: Tuple[int, ...] = ()
    conv_kernel: int = 3
    dense_hidden: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class AEConfig:
    """Fully-connected funnel autoencoder over flat weight vectors (Fig. 1)."""

    input_dim: int
    encoder_hidden: Tuple[int, ...]    # widths after the input layer
    latent_dim: int                    # bottleneck ("reduced feature space")
    activation: str = "relu"
    final_activation: str = "linear"

    @property
    def compression_ratio(self) -> float:
        return self.input_dim / self.latent_dim

    @property
    def n_params(self) -> int:
        dims = ([self.input_dim] + list(self.encoder_hidden)
                + [self.latent_dim] + list(reversed(self.encoder_hidden))
                + [self.input_dim])
        return sum(a * b + b for a, b in zip(dims[:-1], dims[1:]))


# --- paper §5.1: MNIST classifier, 15,910 params exactly -------------------
MNIST_CLASSIFIER = ClassifierConfig(
    name="mnist-mlp",
    kind="mlp",
    input_shape=(784,),
    n_classes=10,
    hidden=(20,),
)

# AE: 15,910 → 64 → 32 → 64 → 15,910; latent 32 → ~497x ("about 500x").
MNIST_AE = AEConfig(input_dim=15_910, encoder_hidden=(64,), latent_dim=32)

# --- paper §5.1: CIFAR classifier, ~550,570 params --------------------------
# conv(3->32,k3) 896 + conv(32->32,k3) 9,248 + conv(32->64,k3) 18,496
# + conv(64->64,k3) 36,928 + dense(1600->288) 461,088 + dense(288->80) 23,120
# + dense(80->10) 810  = 550,586 params (paper: 550,570; Δ=16, <0.003%).
CIFAR_CLASSIFIER = ClassifierConfig(
    name="cifar-cnn",
    kind="cnn",
    input_shape=(32, 32, 3),
    n_classes=10,
    conv_channels=(32, 32, 64, 64),
    conv_kernel=3,
    dense_hidden=(288, 80),
)

# Paper's CIFAR AE: single 320-wide bottleneck over 550,570 inputs →
# 2*550570*320 + 320 + 550570 = 352,915,690 params, 1720x compression.
CIFAR_AE = AEConfig(input_dim=550_570, encoder_hidden=(), latent_dim=320)


def cifar_ae_for(n_params: int) -> AEConfig:
    """Paper-shaped CIFAR AE resized to the actual classifier param count."""
    return AEConfig(input_dim=n_params, encoder_hidden=(), latent_dim=320)


# --- scalable-runtime scenarios (DESIGN.md §6) ------------------------------
@dataclasses.dataclass(frozen=True)
class FLRuntimeScenario:
    """Knobs for one scalable-runtime experiment: N clients, a C-of-N
    sampled cohort, a K-deep async buffer, and the latency distribution the
    straggler scenario runs under. Consumed by examples/fl_async_sampling.py
    and the ``fl_schedulers`` benchmark table; the numbers themselves plug
    into ``SampledSync``/``AsyncBuffered``/``LatencyModel``
    (repro.core.scheduler)."""

    n_clients: int
    cohort: int                       # SampledSync: C of N per round
    buffer_k: int                     # AsyncBuffered: aggregate first K
    rounds: int
    local_epochs: int = 1
    base_latency: float = 1.0
    latency_jitter: float = 0.5       # multiplicative U[1±j]
    straggler_frac: float = 0.0       # tail of straggler_mult-slower clients
    straggler_mult: float = 8.0


# Paper Fig. 10 works at ~1000 collaborators / ~40 rounds; this is that
# regime for byte-accounting analytics (not meant to be trained on CPU).
PAPER_SCALE_SCENARIO = FLRuntimeScenario(
    n_clients=1000, cohort=100, buffer_k=50, rounds=40, local_epochs=5,
    straggler_frac=0.1)

# CPU-trainable smoke version of the same shape: 16 clients, quarter
# cohorts, a 25% straggler tail — runs in ~a minute in the example.
SMOKE_SCALE_SCENARIO = FLRuntimeScenario(
    n_clients=16, cohort=4, buffer_k=4, rounds=3,
    straggler_frac=0.25)

"""mamba2-2.7b [ssm] — arXiv:2405.21060 (SSD, state-space duality).

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128. d_inner =
2*d_model = 5120, head_dim 64 → 80 SSD heads, depthwise conv width 4,
chunked-dual scan with chunk 256. Decode state is O(1) in sequence length, so
long_500k runs natively.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    n_layers=64,
    d_model=2560,
    n_heads=1,                         # unused by the SSD mixer
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,                            # no separate MLP block in mamba2
    vocab_size=50280,
    attn_type="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    activation="swiglu",
    long_context_window=None,          # native sub-quadratic
    tie_embeddings=True,
)

"""llama3-8b [dense] — arXiv:2407.21783.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope theta 500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-8b",
    family="dense",
    source="arXiv:2407.21783",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    attn_type="gqa",
    rope_theta=500000.0,
    activation="swiglu",
)

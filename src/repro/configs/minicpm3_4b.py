"""minicpm3-4b [dense, MLA] — hf:openbmb/MiniCPM3-4B.

62L d_model=2560 40H (kv=40 in the GQA sense, but attention is MLA: all heads
share a 256-dim compressed KV latent) d_ff=6400 vocab=73448. MLA dims follow
the MiniCPM3 model card: q_lora=768, kv_lora=256, nope=64, rope=32, v=64.
"""
from repro.configs.base import ArchConfig, MLAConfig

CONFIG = ArchConfig(
    name="minicpm3-4b",
    family="dense",
    source="hf:openbmb/MiniCPM3-4B",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=10000.0,
    activation="swiglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    # MLA's per-head K/V expansion makes the sequence-sharded residual
    # stream a net win in training too (dominant term 61s -> 35s, §Perf)
    train_seq_shard=True,
)

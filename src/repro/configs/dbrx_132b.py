"""dbrx-132b [moe] — hf:databricks/dbrx-base.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16 experts top-4
(fine-grained). LayerNorm, rope theta 500k.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    source="hf:databricks/dbrx-base",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    attn_type="gqa",
    moe=MoEConfig(n_experts=16, top_k=4, d_ff_expert=10752,
                  capacity_factor=1.25, shared_expert=False),
    rope_theta=500000.0,
    norm_type="layernorm",
    activation="swiglu",
)

"""Configuration system for the FedAE framework.

Every assigned architecture gets a module in ``repro/configs/<id>.py`` that
exports ``CONFIG: ArchConfig`` built from the exact assignment table. The
registry in this module resolves ``--arch <id>`` strings, provides the four
assigned input shapes, and the reduced smoke-test variants.

Design notes
------------
* ``ArchConfig`` is a frozen dataclass → hashable → usable as a static arg to
  ``jax.jit`` and safe to close over in scanned layer stacks.
* ``vocab_size`` is the *paper/model-card* vocabulary; ``padded_vocab`` rounds
  up to a multiple of 256 for MXU alignment + 16-way model sharding. Logits
  for padding ids are masked downstream.
* ``reduced()`` produces the CPU smoke-test variant (≤2 layers, d_model ≤ 512,
  ≤4 experts) of the *same family* — same code paths, tiny shapes.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 8
    top_k: int = 1
    d_ff_expert: int = 0           # expert hidden width
    capacity_factor: float = 1.25  # tokens-per-expert capacity multiplier
    shared_expert: bool = False    # Llama-4 style always-on shared expert
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD (state-space duality, arXiv:2405.21060)."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin RG-LRU hybrid (arXiv:2402.19427)."""

    lru_width: int = 4096
    conv_width: int = 4
    window: int = 2048            # local-attention window
    pattern: Tuple[str, ...] = ("rglru", "rglru", "attn")  # repeating block


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder; the conv/mel frontend is a stub that
    supplies precomputed frame embeddings of shape (B, n_frames, d_model)."""

    n_encoder_layers: int = 24
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """Phi-3-vision style: a stub vision tower supplies patch embeddings of
    shape (B, n_image_tokens, d_model) merged at reserved positions."""

    n_image_tokens: int = 576


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str = "unnamed"
    family: str = "dense"          # dense | moe | ssm | hybrid | audio | vlm
    source: str = ""               # citation from the assignment table

    # trunk
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 64
    d_ff: int = 1024
    vocab_size: int = 32000

    # attention flavour
    attn_type: str = "gqa"         # gqa | mla | none (ssm)
    rope_theta: float = 10000.0
    rope_pct: float = 1.0          # stablelm partial rotary
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm
    norm_eps: float = 1e-5
    activation: str = "swiglu"     # swiglu | gelu | geglu
    parallel_block: bool = False   # attn+mlp in parallel (not used by defaults)
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0

    # sub-configs (None when family doesn't use them)
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None

    # long-context decode fallback: sliding-window width used for the
    # long_500k shape on otherwise-quadratic architectures. None → native
    # sub-quadratic path (ssm/hybrid) or window from rglru config.
    long_context_window: Optional[int] = 8192

    # training policy
    # sequence-shard the residual stream during TRAINING too (always on for
    # prefill). Measured win only for MLA (minicpm3: bound 61s→35s); dense
    # GQA archs pay more in weight-grad reductions than they save
    # (llama3 collective 6.6s→34.7s) — see EXPERIMENTS.md §Perf.
    train_seq_shard: bool = False
    grad_reduce_dtype: str = "float32"   # bfloat16 halves grad all-reduces
    optimizer: str = "adamw"       # adamw | adam | sgdm | sgdm_bf16
    zero1: bool = True             # shard optimizer state over the data axis
    param_dtype: str = "float32"   # float32 | bfloat16 (giant archs)
    compute_dtype: str = "bfloat16"
    remat: bool = True             # activation checkpointing across layers
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    # ----------------------------------------------------------------- utils
    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab_size, 256)

    @property
    def q_dim(self) -> int:
        if self.attn_type == "mla":
            m = self.mla
            return self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_decoder_only(self) -> bool:
        return self.encdec is None

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/code paths, tiny shapes."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        n_heads = max(2, min(self.n_heads, d_model // head_dim))
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        changes = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            rope_theta=10000.0,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
            zero1=False,
        )
        if self.mla is not None:
            changes["mla"] = MLAConfig(
                q_lora_rank=64, kv_lora_rank=32,
                qk_nope_head_dim=16, qk_rope_head_dim=16, v_head_dim=32)
            changes["head_dim"] = 32
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=min(self.moe.d_ff_expert, 256))
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=32, chunk_size=32)
        if self.rglru is not None:
            changes["rglru"] = dataclasses.replace(
                self.rglru, lru_width=d_model, window=64)
            changes["n_layers"] = 3      # one full (R,R,A) pattern block
        if self.encdec is not None:
            changes["encdec"] = dataclasses.replace(
                self.encdec, n_encoder_layers=2, n_frames=16)
        if self.vlm is not None:
            changes["vlm"] = dataclasses.replace(self.vlm, n_image_tokens=8)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                      # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS = (
    "minicpm3_4b",
    "llama4_maverick_400b_a17b",
    "stablelm_1_6b",
    "deepseek_coder_33b",
    "whisper_medium",
    "phi3_vision_4_2b",
    "recurrentgemma_9b",
    "dbrx_132b",
    "mamba2_2_7b",
    "llama3_8b",
)

# CLI aliases: assignment-table ids (with dashes/dots) → module names.
_ALIASES = {
    "minicpm3-4b": "minicpm3_4b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "stablelm-1.6b": "stablelm_1_6b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "whisper-medium": "whisper_medium",
    "phi-3-vision-4.2b": "phi3_vision_4_2b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "dbrx-132b": "dbrx_132b",
    "mamba2-2.7b": "mamba2_2_7b",
    "llama3-8b": "llama3_8b",
    # paper collaborator models
    "mnist-mlp": "mnist_mlp",
    "cifar-cnn": "cifar_cnn",
}


def canonical_arch_id(arch: str) -> str:
    key = arch.strip()
    return _ALIASES.get(key, key.replace("-", "_").replace(".", "_"))


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canonical_arch_id(arch)}")
    return mod.CONFIG


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]

"""Pure-JAX optimizers (no external deps): SGD(+momentum), Adam, AdamW.

API: ``opt = make_optimizer(name, lr, ...)``; ``state = opt.init(params)``;
``params, state = opt.update(params, grads, state)``. All ops are pytree maps
so they jit/shard transparently; the ``sgdm_bf16`` variant keeps its momentum
in bfloat16 for the giant-MoE memory budget (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Pytree:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Pytree], Pytree]
    update: Callable[[Pytree, Pytree, Pytree], Tuple[Pytree, Pytree]]


def make_optimizer(name: str, lr: float, *, weight_decay: float = 0.0,
                   grad_clip: float = 0.0, b1: float = 0.9,
                   b2: float = 0.999, eps: float = 1e-8,
                   momentum: float = 0.9) -> Optimizer:
    def maybe_clip(grads):
        return clip_by_global_norm(grads, grad_clip) if grad_clip > 0 \
            else grads

    if name == "sgd":
        def init(params):
            return {"count": jnp.zeros((), jnp.int32)}

        def update(params, grads, state):
            grads = maybe_clip(grads)
            new = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new, {"count": state["count"] + 1}
        return Optimizer(name, init, update)

    if name in ("sgdm", "sgdm_bf16"):
        mdtype = jnp.bfloat16 if name == "sgdm_bf16" else jnp.float32

        def init(params):
            return {"count": jnp.zeros((), jnp.int32),
                    "mu": jax.tree_util.tree_map(
                        lambda p: jnp.zeros(p.shape, mdtype), params)}

        def update(params, grads, state):
            grads = maybe_clip(grads)
            mu = jax.tree_util.tree_map(
                lambda m, g: (momentum * m.astype(jnp.float32)
                              + g.astype(jnp.float32)).astype(mdtype),
                state["mu"], grads)
            new = jax.tree_util.tree_map(
                lambda p, m: (p.astype(jnp.float32)
                              - lr * m.astype(jnp.float32)).astype(p.dtype),
                params, mu)
            return new, {"count": state["count"] + 1, "mu": mu}
        return Optimizer(name, init, update)

    if name in ("adam", "adamw"):
        wd = weight_decay if name == "adamw" else 0.0

        def init(params):
            zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
            return {"count": jnp.zeros((), jnp.int32),
                    "m": jax.tree_util.tree_map(zeros, params),
                    "v": jax.tree_util.tree_map(zeros, params)}

        def update(params, grads, state):
            grads = maybe_clip(grads)
            t = state["count"] + 1
            m = jax.tree_util.tree_map(
                lambda a, g: b1 * a + (1 - b1) * g.astype(jnp.float32),
                state["m"], grads)
            v = jax.tree_util.tree_map(
                lambda a, g: b2 * a
                + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                state["v"], grads)
            tf = t.astype(jnp.float32)

            def upd(p, ml, vl):
                mh = ml / (1 - b1 ** tf)
                vh = vl / (1 - b2 ** tf)
                step = mh / (jnp.sqrt(vh) + eps)
                if wd > 0.0 and p.ndim >= 2:
                    step = step + wd * p.astype(jnp.float32)
                return (p.astype(jnp.float32) - lr * step).astype(p.dtype)
            new = jax.tree_util.tree_map(upd, params, m, v)
            return new, {"count": t, "m": m, "v": v}
        return Optimizer(name, init, update)

    raise ValueError(f"unknown optimizer {name}")

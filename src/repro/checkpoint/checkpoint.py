"""Pytree checkpointing: flat-key npz with dtype/shape round-trip, plus a
round-resumable federated-state wrapper.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "/"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return f"[{entry.idx}]"
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def save_pytree(path: str, tree: Pytree, metadata: Optional[dict] = None):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    # bf16 has no numpy dtype — store as uint16 view + dtype tag
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16) if hasattr(v, "view") else \
                np.asarray(jnp.asarray(v).view(jnp.uint16))
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    arrays["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8)
    if metadata is not None:
        arrays["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **arrays)


def load_pytree(path: str, like: Pytree) -> Tuple[Pytree, Optional[dict]]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        dtypes = json.loads(bytes(data["__dtypes__"]).decode())
        meta = json.loads(bytes(data["__meta__"]).decode()) \
            if "__meta__" in data else None
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        restored = []
        for pth, leaf in leaves:
            key = _SEP.join(_path_str(p) for p in pth)
            arr = data[key]
            if dtypes[key] == "bfloat16":
                arr = jnp.asarray(arr).view(jnp.bfloat16)
            restored.append(jnp.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, restored), meta


def save_federated_state(path: str, round_idx: int, global_params: Pytree,
                         extra: Optional[dict] = None):
    save_pytree(path, {"global": global_params},
                metadata={"round": round_idx, **(extra or {})})


def load_federated_state(path: str, like_params: Pytree
                         ) -> Tuple[int, Pytree, dict]:
    tree, meta = load_pytree(path, {"global": like_params})
    return int(meta["round"]), tree["global"], meta

"""Pytree checkpointing: flat-key npz with dtype/shape round-trip, plus a
round-resumable federated-state wrapper.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any
_SEP = "/"


def _flatten(tree: Pytree) -> Dict[str, np.ndarray]:
    flat = {}
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in leaves:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return f"[{entry.idx}]"
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def save_pytree(path: str, tree: Pytree, metadata: Optional[dict] = None):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    flat = _flatten(tree)
    # bf16 has no numpy dtype — store as uint16 view + dtype tag
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16) if hasattr(v, "view") else \
                np.asarray(jnp.asarray(v).view(jnp.uint16))
            dtypes[k] = "bfloat16"
        else:
            arrays[k] = v
            dtypes[k] = str(v.dtype)
    arrays["__dtypes__"] = np.frombuffer(
        json.dumps(dtypes).encode(), dtype=np.uint8)
    if metadata is not None:
        arrays["__meta__"] = np.frombuffer(
            json.dumps(metadata).encode(), dtype=np.uint8)
    np.savez(path, **arrays)


def load_pytree(path: str, like: Pytree) -> Tuple[Pytree, Optional[dict]]:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        dtypes = json.loads(bytes(data["__dtypes__"]).decode())
        meta = json.loads(bytes(data["__meta__"]).decode()) \
            if "__meta__" in data else None
        leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
        restored = []
        for pth, leaf in leaves:
            key = _SEP.join(_path_str(p) for p in pth)
            arr = data[key]
            if dtypes[key] == "bfloat16":
                arr = jnp.asarray(arr).view(jnp.bfloat16)
            restored.append(jnp.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, restored), meta


def save_federated_state(path: str, round_idx: int, global_params: Pytree,
                         clients: Optional[list] = None,
                         codec_params: Optional[list] = None,
                         ratecontrol: Optional[tuple] = None,
                         scheduler_state: Optional[dict] = None,
                         clients_soa: Optional[tuple] = None,
                         extra: Optional[dict] = None):
    """Checkpoint a federated run: global params plus (optionally) every
    per-client ``ClientState`` — error-feedback residuals and AE snapshot
    buffers are *run* state (DESIGN.md §6.3/§8.2); a resume that dropped
    them would silently reset error feedback and the refit datasets.
    ``codec_params`` (one AE param pytree or None per client, from
    ``Compressor.codec_params()``) persists the codecs themselves — under
    an :class:`AELifecycle` a refit *moves* them, and a resume that rebuilt
    compressors from the pre-pass would silently revert every decoder
    while ``last_refresh``/``ae_baseline`` still described the refit one.

    ``ratecontrol`` is the rate controller's ``(state_meta(),
    state_tree())`` pair (DESIGN.md §9.3): rung occupancy and cooldowns in
    JSON, every ladder rung's AE params as arrays — the active rung alone
    is not enough, a refit on a rung the client later stepped off must
    survive too. ``scheduler_state`` is ``RoundScheduler.state_dict()``
    (JSON-able): for ``AsyncBuffered`` the event heap, clock, version and
    the dispatched-but-unrecorded downlink bytes, paired with the
    per-client ``dispatched`` model snapshots saved here — dropping those
    (the pre-§9.3 behavior) silently mis-counted ``bytes_down`` across a
    save/load cycle.

    ``clients_soa`` is the struct-of-arrays alternative to ``clients``
    (DESIGN.md §12.4): ``ClientPool.state()``'s ``(tree, meta)`` pair —
    ring contents, cursors, counts and the residual block round-trip as
    whole stacked arrays instead of per-client entries, so checkpoint size
    and save/load time stay O(arrays), not O(population) npz keys. Pass
    exactly one of ``clients`` / ``clients_soa``.

    Array-valued state goes into the npz tree; the structural facts needed
    to rebuild it on load (which clients carry a residual, snapshot buffer
    shapes, scalar fields) ride in the JSON metadata."""
    assert clients is None or clients_soa is None, (
        "pass either the eager client list or the SoA pool state, not both")
    tree: dict = {"global": global_params}
    cmeta = None
    codec_meta = None
    rc_meta = None
    soa_meta = None
    if clients_soa is not None:
        soa_tree, soa_meta = clients_soa
        if soa_tree:
            tree["clients_soa"] = soa_tree
    if codec_params is not None:
        tree["codecs"] = [{"params": p} if p is not None else {}
                          for p in codec_params]
        codec_meta = [p is not None for p in codec_params]
    if ratecontrol is not None:
        rc_meta, rc_tree = ratecontrol
        tree["ratecontrol"] = rc_tree
    if clients is not None:
        ctree, cmeta = [], []
        for st in clients:
            entry = {}
            if st.residual is not None:
                entry["residual"] = st.residual
            if st.snapshots:
                entry["snapshots"] = jnp.stack(st.snapshots)
            if st.dispatched is not None:
                entry["dispatched"] = st.dispatched
            # per-partition lifecycle state (DESIGN.md §10.4): one snapshot
            # ring / refresh round / drift baseline per partition group
            part_snaps = {name: snaps for name, snaps
                          in getattr(st, "part_snapshots", {}).items()
                          if snaps}
            if part_snaps:
                entry["part_snapshots"] = {
                    name: jnp.stack(snaps)
                    for name, snaps in part_snaps.items()}
            ctree.append(entry)
            cmeta.append({
                "has_residual": st.residual is not None,
                "has_dispatched": st.dispatched is not None,
                "snap_shape": [len(st.snapshots),
                               *(np.asarray(st.snapshots[0]).shape
                                 if st.snapshots else [])],
                "snap_dtype": (str(np.asarray(st.snapshots[0]).dtype)
                               if st.snapshots else None),
                "version": st.version,
                "last_refresh": st.last_refresh,
                "ae_baseline": st.ae_baseline,
                "part_snap_shapes": {
                    name: [len(snaps),
                           *np.asarray(snaps[0]).shape]
                    for name, snaps in part_snaps.items()},
                "part_snap_dtypes": {
                    name: str(np.asarray(snaps[0]).dtype)
                    for name, snaps in part_snaps.items()},
                "part_last_refresh":
                    dict(getattr(st, "part_last_refresh", {})),
                "part_baseline": dict(getattr(st, "part_baseline", {})),
            })
        tree["clients"] = ctree
    save_pytree(path, tree,
                metadata={"round": round_idx, "clients": cmeta,
                          "clients_soa": soa_meta,
                          "codecs": codec_meta, "ratecontrol": rc_meta,
                          "scheduler": scheduler_state, **(extra or {})})


def _peek_meta(path: str) -> dict:
    with np.load(path) as data:
        if "__meta__" not in data:
            return {}
        return json.loads(bytes(data["__meta__"]).decode())


def load_federated_state(path: str, like_params: Pytree,
                         like_codec_params: Optional[list] = None,
                         like_ratecontrol: Optional[Pytree] = None
                         ) -> Tuple[int, Pytree, dict]:
    """Restore ``save_federated_state``. Returns (round, global params,
    meta); when client state was saved, ``meta["client_states"]`` holds the
    rebuilt ``ClientState`` list (residual and async ``dispatched``
    structures restored against ``like_params`` — both are model-shaped).
    When codec params were saved AND ``like_codec_params`` provides the
    matching structures (the current compressors' ``codec_params()``),
    ``meta["codec_params"]`` holds the restored per-client AE param list
    (None entries for pointwise codecs). When rate-controller state was
    saved AND ``like_ratecontrol`` provides the matching ladder tree
    (``RateController.state_tree()`` of a freshly bound controller),
    ``meta["ratecontrol_tree"]`` holds the restored ladder params, with
    the JSON side already in ``meta["ratecontrol"]``. The scheduler's
    ``state_dict()`` rides through as ``meta["scheduler"]``.

    SoA checkpoints (saved via ``clients_soa``) surface the restored array
    tree as ``meta["clients_soa_tree"]`` next to the JSON side in
    ``meta["clients_soa"]``; the caller rebuilds the pool with
    ``ClientPool.from_state`` (it holds the model template the residual
    views unravel against — this module stays template-agnostic)."""
    meta = _peek_meta(path)
    like: dict = {"global": like_params}
    soa_meta = meta.get("clients_soa")
    if soa_meta is not None:
        from repro.core.soa import ClientPool
        soa_like = ClientPool.like_from_meta(soa_meta)
        if soa_like:
            like["clients_soa"] = soa_like
    codec_meta = meta.get("codecs")
    if codec_meta is not None and like_codec_params is not None:
        assert len(codec_meta) == len(like_codec_params)
        like["codecs"] = [
            {"params": lp} if has else {}
            for has, lp in zip(codec_meta, like_codec_params)]
    if meta.get("ratecontrol") is not None and like_ratecontrol is not None:
        like["ratecontrol"] = like_ratecontrol
    cmeta = meta.get("clients")
    if cmeta is not None:
        clike = []
        for cm in cmeta:
            entry = {}
            if cm["has_residual"]:
                entry["residual"] = like_params
            if cm.get("has_dispatched"):
                entry["dispatched"] = like_params
            if cm["snap_shape"][0]:
                entry["snapshots"] = jnp.zeros(
                    tuple(cm["snap_shape"]), dtype=cm["snap_dtype"])
            if cm.get("part_snap_shapes"):
                entry["part_snapshots"] = {
                    name: jnp.zeros(tuple(shape),
                                    dtype=cm["part_snap_dtypes"][name])
                    for name, shape in cm["part_snap_shapes"].items()}
            clike.append(entry)
        like["clients"] = clike
    tree, meta = load_pytree(path, like)
    meta = dict(meta or {})
    if soa_meta is not None:
        meta["clients_soa_tree"] = tree.get("clients_soa") or {}
    if "codecs" in like:
        meta["codec_params"] = [entry.get("params")
                                for entry in tree["codecs"]]
    if "ratecontrol" in like:
        meta["ratecontrol_tree"] = tree["ratecontrol"]
    if cmeta is not None:
        from repro.core.scheduler import ClientState
        states = []
        for cm, entry in zip(cmeta, tree["clients"]):
            snaps = entry.get("snapshots")
            psnaps = entry.get("part_snapshots") or {}
            states.append(ClientState(
                residual=entry.get("residual"),
                version=int(cm["version"]),
                dispatched=entry.get("dispatched"),
                snapshots=([s for s in snaps] if snaps is not None else []),
                last_refresh=int(cm["last_refresh"]),
                ae_baseline=cm["ae_baseline"],
                part_snapshots={name: [s for s in stackd]
                                for name, stackd in psnaps.items()},
                part_last_refresh={
                    name: int(v) for name, v
                    in (cm.get("part_last_refresh") or {}).items()},
                part_baseline=dict(cm.get("part_baseline") or {})))
        meta["client_states"] = states
    return int(meta["round"]), tree["global"], meta

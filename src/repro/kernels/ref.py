"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_dense_ref(x: jax.Array, w: jax.Array, b: jax.Array,
                    act: str = "relu") -> jax.Array:
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "tanh":
        y = jnp.tanh(y)
    elif act == "sigmoid":
        y = jax.nn.sigmoid(y)
    elif act != "linear":
        raise ValueError(act)
    return y.astype(x.dtype)


def quantize_blocks_ref(x: jax.Array, bits: int = 8):
    """x: (n_blocks, block) → (q int8, scales f32 (n_blocks,))."""
    qmax = float(2 ** (bits - 1) - 1)
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale[:, 0]


def dequantize_blocks_ref(q: jax.Array, scales: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scales[:, None]


def ae_encode_ref(params, cfg, flat: jax.Array) -> jax.Array:
    from repro.core import autoencoder as ae
    return ae.chunked_encode(params, cfg, flat)


def ae_decode_ref(params, cfg, z: jax.Array, orig_len: int) -> jax.Array:
    from repro.core import autoencoder as ae
    return ae.chunked_decode(params, cfg, z, orig_len)


def fused_decode_agg_ref(h: jax.Array, weights: jax.Array,
                         w_last: jax.Array, b_last: jax.Array) -> jax.Array:
    """Oracle for kernels/fused_decode_agg.py: materializes the per-client
    decoded tensors the kernel exists to avoid, then reduces."""
    per_client = h.astype(jnp.float32) @ w_last.astype(jnp.float32)
    return (jnp.einsum("c,cmn->mn", weights.astype(jnp.float32), per_client)
            + b_last.astype(jnp.float32))


def grouped_fused_decode_agg_ref(hs, weights, w_stack, b_stack, dec_idx):
    """Oracle for the grouped ragged launch: one materialize-then-reduce
    pass per bucket, in bucket order. Empty buckets (zero clients) return
    exact zeros — their weight mass is zero, matching the kernel."""
    N = w_stack.shape[2]
    out = []
    for h, w, d in zip(hs, weights, dec_idx):
        if h.shape[0] == 0:
            out.append(jnp.zeros((h.shape[1], N), jnp.float32))
        else:
            out.append(fused_decode_agg_ref(h, w, w_stack[d], b_stack[d]))
    return out

"""Pallas TPU kernel: blockwise absmax quantization (int8 / int4-range).

The traditional-compression baseline the paper compares against (FedPAQ-style
quantization) and the latent post-quantizer of the composed AE+quant codec.
Each block of ``block`` consecutive values gets one f32 scale; values are
rounded to the signed integer range of the requested bit width.

Tiling: the flat vector is reshaped to (n_blocks, block); the grid walks row
tiles of 256 blocks. Per-step VMEM: 256*block f32 in + out + 256 scales —
≈ 0.5 MB at block=256, trivially resident; the kernel is bandwidth-bound,
which is the point (quantization must not cost more than it saves).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)             # (rows, block)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.maximum(absmax / qmax, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0][:, None]              # (rows, 1)


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)             # (rows, 1)
    x_ref[...] = (q * s).astype(x_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block", "rows",
                                             "interpret"))
def quantize_blocks_2d(x: jax.Array, *, bits: int = 8, block: int = 256,
                       rows: int = 256, interpret: bool = False):
    """x: (n_blocks, block) f32 → (q int8 (n_blocks, block), scales f32
    (n_blocks,))."""
    nb, blk = x.shape
    assert blk == block
    qmax = float(2 ** (bits - 1) - 1)
    rows = min(rows, nb)
    nbp = -(-nb // rows) * rows
    xp = jnp.pad(x, ((0, nbp - nb), (0, 0))) if nbp != nb else x
    q, s = pl.pallas_call(
        functools.partial(_quant_kernel, qmax=qmax),
        grid=(nbp // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((nbp, block), jnp.int8),
                   jax.ShapeDtypeStruct((nbp, 1), jnp.float32)],
        interpret=interpret,
    )(xp)
    return q[:nb], s[:nb, 0]


@functools.partial(jax.jit, static_argnames=("block", "rows", "interpret"))
def dequantize_blocks_2d(q: jax.Array, scales: jax.Array, *,
                         block: int = 256, rows: int = 256,
                         interpret: bool = False) -> jax.Array:
    nb, blk = q.shape
    assert blk == block
    rows = min(rows, nb)
    nbp = -(-nb // rows) * rows
    qp = jnp.pad(q, ((0, nbp - nb), (0, 0))) if nbp != nb else q
    sp = (jnp.pad(scales, (0, nbp - nb)) if nbp != nb else scales)[:, None]
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(nbp // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nbp, block), jnp.float32),
        interpret=interpret,
    )(qp, sp)
    return x[:nb]

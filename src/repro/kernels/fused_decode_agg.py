"""Pallas TPU kernel: fused batched decode→aggregate epilogue.

The aggregator's hot path at cohort scale (DESIGN.md §7): after the cohort's
AE latents are pushed through the decoder's hidden stack, the *final*
decoder layer is a linear matmul that expands each client's per-chunk
hidden activations ``h_c`` (small, latent-side) into full-model-sized chunk
reconstructions — and FedAvg immediately reduces those reconstructions
across clients. Materializing the per-client decoded tensors costs
``O(cohort × model)`` HBM; this kernel folds the per-client FedAvg weight
into the decoder-matmul accumulation instead. Because the final layer is
linear and shared, the weighted client reduction commutes with the matmul,
so each grid step reduces its client block *before* the chunk-wide
expansion:

    out = Σ_blocks ( Σ_{c∈block} w_c · h_c ) @ W_dec  + b_dec   (Σ_c w_c = 1)

Grid: ``(M/bm, C/bc)`` with the client-block axis innermost. Each output
tile ``(bm, N)`` stays resident in VMEM while the kernel walks the cohort
blocks: per step, a VPU reduction collapses ``bc`` clients' hidden tiles
into one weighted tile (latent-sided — ``bc·bm·K`` floats), a single MXU
matmul expands it to chunk width, and the result accumulates into the
output; the bias is added on the first block. Full-model-sized data exists
exactly once (the accumulator) — peak memory ``O(model)``, not
``O(cohort × model)``; per-client tensors never reach chunk width even in
VMEM (memory math in DESIGN.md §7.1).

VMEM per step: ``bc·bm·K + K·N + bm·N`` floats; at the defaults
(bc=16, bm=128, K≤512, N≤4096) ≈ 14.5 MB f32, inside the ~16 MB/core v5e
budget (K=512 is the production hidden width; the codec defaults use
K=32-64 where this is ≪1 MB).
Validated against the pure-jnp oracle ``ref.fused_decode_agg_ref`` (which
materializes the per-client decoded tensors this kernel avoids) in
interpret mode (DESIGN.md §7.3, tests/test_kernels.py).

Under per-layer codec partitions (DESIGN.md §10.2) the grouped server path
launches this kernel once per kernel-path chunked-AE (partition, spec)
bucket per round — ``M`` is then the *group's* chunk count, not the whole
model's, so the VMEM budget above holds per launch and shrinks with the
partition; the weighted client reduction still commutes because each
bucket's weights are renormalized to Σ=1 before dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_decode_agg_kernel(w_ref, h_ref, wl_ref, b_ref, o_ref):
    cb = pl.program_id(1)
    w = w_ref[...].astype(jnp.float32)       # (bc, 1) client-block weights
    h = h_ref[...].astype(jnp.float32)       # (bc, bm, K)
    # weighted client reduction BEFORE the chunk-wide expansion (VPU,
    # latent-sided): Σ_{c∈block} w_c · h_c → (bm, K)
    hbar = jnp.sum(h * w[:, :, None], axis=0)
    y = jnp.dot(hbar, wl_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)

    @pl.when(cb == 0)
    def _init():
        o_ref[...] = (y + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)

    @pl.when(cb > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "interpret"))
def fused_decode_agg(h: jax.Array, weights: jax.Array, w_last: jax.Array,
                     b_last: jax.Array, *, bm: int = 128, bc: int = 16,
                     interpret: bool = False) -> jax.Array:
    """``Σ_c weights[c] · (h[c] @ w_last) + b_last`` without materializing
    any per-client ``(M, N)`` tensor.

    h: (C, M, K) per-client penultimate decoder activations;
    weights: (C,) pre-normalized FedAvg weights (must sum to 1 — the bias
    is added once, which equals the weighted mean of per-client biases only
    under that normalization);
    w_last: (K, N), b_last: (N,) final decoder layer → (M, N).
    ``bc`` is the client-block size per grid step (zero-weight padded).
    """
    C, M, K = h.shape
    K2, N = w_last.shape
    assert K == K2 and b_last.shape == (N,) and weights.shape == (C,)
    bm = min(bm, max(8, M))
    bc = min(bc, C)
    Mp = -(-M // bm) * bm
    Cp = -(-C // bc) * bc
    if (Mp, Cp) != (M, C):
        h = jnp.pad(h, ((0, Cp - C), (0, Mp - M), (0, 0)))
    w2 = weights.astype(jnp.float32)
    if Cp != C:
        w2 = jnp.pad(w2, (0, Cp - C))      # zero weight ⇒ zero contribution
    w2 = w2.reshape(Cp, 1)
    bp = b_last.reshape(1, N)

    out = pl.pallas_call(
        _fused_decode_agg_kernel,
        grid=(Mp // bm, Cp // bc),
        in_specs=[
            pl.BlockSpec((bc, 1), lambda i, c: (c, 0)),
            pl.BlockSpec((bc, bm, K), lambda i, c: (c, i, 0)),
            pl.BlockSpec((K, N), lambda i, c: (0, 0)),
            pl.BlockSpec((1, N), lambda i, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(w2, h, w_last, bp)
    return out[:M]

"""Pallas TPU kernel: fused batched decode→aggregate epilogue.

The aggregator's hot path at cohort scale (DESIGN.md §7): after the cohort's
AE latents are pushed through the decoder's hidden stack, the *final*
decoder layer is a linear matmul that expands each client's per-chunk
hidden activations ``h_c`` (small, latent-side) into full-model-sized chunk
reconstructions — and FedAvg immediately reduces those reconstructions
across clients. Materializing the per-client decoded tensors costs
``O(cohort × model)`` HBM; this kernel folds the per-client FedAvg weight
into the decoder-matmul accumulation instead. Because the final layer is
linear and shared, the weighted client reduction commutes with the matmul,
so each grid step reduces its client block *before* the chunk-wide
expansion:

    out = Σ_blocks ( Σ_{c∈block} w_c · h_c ) @ W_dec  + b_dec   (Σ_c w_c = 1)

Grid: ``(M/bm, C/bc)`` with the client-block axis innermost. Each output
tile ``(bm, N)`` stays resident in VMEM while the kernel walks the cohort
blocks: per step, a VPU reduction collapses ``bc`` clients' hidden tiles
into one weighted tile (latent-sided — ``bc·bm·K`` floats), a single MXU
matmul expands it to chunk width, and the result accumulates into the
output; the bias is added on the first block. Full-model-sized data exists
exactly once (the accumulator) — peak memory ``O(model)``, not
``O(cohort × model)``; per-client tensors never reach chunk width even in
VMEM (memory math in DESIGN.md §7.1).

VMEM per step: ``bc·bm·K + K·N + bm·N`` floats; at the defaults
(bc=16, bm=128, K≤512, N≤4096) ≈ 14.5 MB f32, inside the ~16 MB/core v5e
budget (K=512 is the production hidden width; the codec defaults use
K=32-64 where this is ≪1 MB).
Validated against the pure-jnp oracle ``ref.fused_decode_agg_ref`` (which
materializes the per-client decoded tensors this kernel avoids) in
interpret mode (DESIGN.md §7.3, tests/test_kernels.py).

Under per-layer codec partitions (DESIGN.md §10.2) the grouped server path
launches this kernel once per kernel-path chunked-AE (partition, spec)
bucket per round — ``M`` is then the *group's* chunk count, not the whole
model's, so the VMEM budget above holds per launch and shrinks with the
partition; the weighted client reduction still commutes because each
bucket's weights are renormalized to Σ=1 before dispatch.
"""
from __future__ import annotations

import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_decode_agg_kernel(w_ref, h_ref, wl_ref, b_ref, o_ref):
    cb = pl.program_id(1)
    w = w_ref[...].astype(jnp.float32)       # (bc, 1) client-block weights
    h = h_ref[...].astype(jnp.float32)       # (bc, bm, K)
    # weighted client reduction BEFORE the chunk-wide expansion (VPU,
    # latent-sided): Σ_{c∈block} w_c · h_c → (bm, K)
    hbar = jnp.sum(h * w[:, :, None], axis=0)
    y = jnp.dot(hbar, wl_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32)

    @pl.when(cb == 0)
    def _init():
        o_ref[...] = (y + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)

    @pl.when(cb > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bc", "interpret"))
def fused_decode_agg(h: jax.Array, weights: jax.Array, w_last: jax.Array,
                     b_last: jax.Array, *, bm: int = 128, bc: int = 16,
                     interpret: bool = False) -> jax.Array:
    """``Σ_c weights[c] · (h[c] @ w_last) + b_last`` without materializing
    any per-client ``(M, N)`` tensor.

    h: (C, M, K) per-client penultimate decoder activations;
    weights: (C,) pre-normalized FedAvg weights (must sum to 1 — the bias
    is added once, which equals the weighted mean of per-client biases only
    under that normalization);
    w_last: (K, N), b_last: (N,) final decoder layer → (M, N).
    ``bc`` is the client-block size per grid step (zero-weight padded).
    """
    C, M, K = h.shape
    K2, N = w_last.shape
    assert K == K2 and b_last.shape == (N,) and weights.shape == (C,)
    bm = min(bm, max(8, M))
    bc = min(bc, C)
    Mp = -(-M // bm) * bm
    Cp = -(-C // bc) * bc
    if (Mp, Cp) != (M, C):
        h = jnp.pad(h, ((0, Cp - C), (0, Mp - M), (0, 0)))
    w2 = weights.astype(jnp.float32)
    if Cp != C:
        w2 = jnp.pad(w2, (0, Cp - C))      # zero weight ⇒ zero contribution
    w2 = w2.reshape(Cp, 1)
    bp = b_last.reshape(1, N)

    out = pl.pallas_call(
        _fused_decode_agg_kernel,
        grid=(Mp // bm, Cp // bc),
        in_specs=[
            pl.BlockSpec((bc, 1), lambda i, c: (c, 0)),
            pl.BlockSpec((bc, bm, K), lambda i, c: (c, i, 0)),
            pl.BlockSpec((K, N), lambda i, c: (0, 0)),
            pl.BlockSpec((1, N), lambda i, c: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda i, c: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(w2, h, w_last, bp)
    return out[:M]


# =====================================================================
# grouped ragged launch: one kernel sweep over every bucket of a round
# =====================================================================
def _grouped_decode_agg_kernel(desc_ref, w_ref, h_ref, wl_ref, b_ref,
                               o_ref):
    """Per grid step ``(t, cb)``: tile ``t`` is one (bucket, m-tile) pair
    resolved through the prefetched descriptor table; ``cb`` walks the
    bucket's client blocks (zero-weight padded up to the cohort-wide
    maximum). Same reduce-before-expand body as the per-bucket kernel."""
    del desc_ref                             # consumed by the index maps
    cb = pl.program_id(1)
    w = w_ref[...].astype(jnp.float32)       # (1, bc) this bucket's weights
    h = h_ref[...].astype(jnp.float32)       # (bc, bm, K)
    hbar = jnp.sum(h * w[0, :, None, None], axis=0)
    y = jnp.dot(hbar, wl_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)

    @pl.when(cb == 0)
    def _init():
        o_ref[...] = (y + b_ref[0].astype(jnp.float32)).astype(o_ref.dtype)

    @pl.when(cb > 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + y).astype(o_ref.dtype)


def grouped_fused_decode_agg(hs: Sequence[jax.Array],
                             weights: Sequence[jax.Array],
                             w_stack: jax.Array, b_stack: jax.Array,
                             dec_idx: Sequence[int], *, bm: int = 128,
                             bc: int = 16,
                             interpret: bool = False) -> List[jax.Array]:
    """One Pallas launch over every (partition, spec) bucket of a round:
    per bucket ``b``, ``Σ_c weights[b][c] · (hs[b][c] @ w_stack[dec_idx[b]])
    + b_stack[dec_idx[b]]`` — the ragged cohort packed into a single grid.

    hs[b]: (C_b, M_b, K) per-client penultimate decoder activations — the
    client count C_b AND the chunk-row count M_b are ragged across buckets;
    every bucket must share the hidden width ``K`` and the chunk width ``N``
    (the grouped server path groups launches by that (K, N) signature).
    weights[b]: (C_b,) this bucket's FedAvg weights (the caller owns the
    Σ-normalization contract, exactly as for :func:`fused_decode_agg` — the
    bias is added once per output tile). w_stack: (D, K, N) distinct final
    decoder layers, b_stack: (D, N); ``dec_idx[b]`` picks bucket ``b``'s
    decoder, so buckets sharing a decoder share one stacked copy.

    Descriptor layout (DESIGN.md §11.1): a ``(3, T)`` int32 table with one
    column per (bucket, m-tile) grid tile — row 0 the bucket id (selects
    the weight row), row 1 the packed output row-block (selects the h
    column band and the output tile), row 2 the decoder index. The table
    rides the scalar-prefetch operand of a ``PrefetchScalarGridSpec``, so
    the index maps resolve every block address from SMEM before the DMA
    fires — raggedness costs descriptor lookups, not extra launches.

    Packing: client axis padded to the cohort-wide max block count (zero
    weight ⇒ exact zero contribution), each bucket's rows padded to a
    ``bm`` multiple and laid end-to-end. A bucket with zero clients
    contributes nothing to the grid and returns exact zeros (its weight
    mass is zero, so the caller's scale-back drops it anyway).

    Returns the per-bucket ``(M_b, N)`` reconstructions (unpacked views of
    the one packed output). Not jit-wrapped: callers trace it inside the
    round's single jitted dispatch (core/partition.py, DESIGN.md §11.2).
    """
    assert len(hs) == len(weights) == len(dec_idx)
    D, K, N = w_stack.shape
    assert b_stack.shape == (D, N)
    live = [b for b, h in enumerate(hs) if h.shape[0] > 0]
    if not live:
        return [jnp.zeros((h.shape[1], N), jnp.float32) for h in hs]
    for b in live:
        C_b, M_b, K_b = hs[b].shape
        assert K_b == K, (
            f"bucket {b}: hidden width {K_b} != {K} — grouped launches "
            f"require one (K, N) signature; split the launch")
        assert weights[b].shape == (C_b,) and M_b > 0
        assert 0 <= dec_idx[b] < D
    bm = min(bm, max(8, max(hs[b].shape[1] for b in live)))
    bc = min(bc, max(hs[b].shape[0] for b in live))
    Cp = max(-(-hs[b].shape[0] // bc) * bc for b in live)

    # pack: clients → shared padded axis, rows → bm-padded bands, and the
    # (bucket, row-block, decoder) descriptor column per grid tile
    h_bands, w_rows, offsets = [], [], {}
    bucket_of, row_of, dec_of = [], [], []
    pos = 0
    for b in live:
        C_b, M_b, _ = hs[b].shape
        Mp_b = -(-M_b // bm) * bm
        h_bands.append(jnp.pad(hs[b], ((0, Cp - C_b), (0, Mp_b - M_b),
                                       (0, 0))))
        w_rows.append(jnp.pad(weights[b].astype(jnp.float32),
                              (0, Cp - C_b)))
        offsets[b] = pos
        for i in range(Mp_b // bm):
            bucket_of.append(len(w_rows) - 1)   # row in the packed weights
            row_of.append(pos // bm + i)
            dec_of.append(dec_idx[b])
        pos += Mp_b
    h_packed = jnp.concatenate(h_bands, axis=1)        # (Cp, Mtot, K)
    w_packed = jnp.stack(w_rows)                       # (B_live, Cp)
    desc = jnp.asarray([bucket_of, row_of, dec_of], jnp.int32)
    T = len(bucket_of)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(T, Cp // bc),
        in_specs=[
            pl.BlockSpec((1, bc), lambda t, cb, d: (d[0, t], cb)),
            pl.BlockSpec((bc, bm, K), lambda t, cb, d: (cb, d[1, t], 0)),
            pl.BlockSpec((1, K, N), lambda t, cb, d: (d[2, t], 0, 0)),
            pl.BlockSpec((1, 1, N), lambda t, cb, d: (d[2, t], 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, N), lambda t, cb, d: (d[1, t], 0)),
    )
    out = pl.pallas_call(
        _grouped_decode_agg_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((pos, N), jnp.float32),
        interpret=interpret,
    )(desc, w_packed, h_packed, w_stack, b_stack.reshape(D, 1, N))

    results: List[jax.Array] = []
    for b, h in enumerate(hs):
        if h.shape[0] == 0:
            results.append(jnp.zeros((h.shape[1], N), jnp.float32))
        else:
            off = offsets[b]
            results.append(out[off:off + h.shape[1]])
    return results

"""Pallas TPU kernel: fused dense layer ``act(x @ w + b)``.

This is the compute hot-spot of the paper's technique at datacenter scale:
the chunked-AE encode/decode is a batch-of-chunks matmul chain
(``(n_chunks, chunk) @ (chunk, hidden) @ (hidden, latent)``), executed every
communication round over the full flattened update. Fusing bias+activation
into the matmul epilogue keeps each output tile in VMEM for exactly one
HBM round-trip.

Tiling: grid over (M/bm, N/bn) output tiles; each step streams an
(bm, K) row-band of x and a (K, bn) column-band of w into VMEM and drives the
MXU with a single ``jnp.dot``. bm/bn default to 128 — the MXU systolic array
edge — and K (chunk size ≤ 4096) stays resident, so VMEM use is
bm*K + K*bn + bm*bn floats ≈ 4.2 MB at f32 defaults, within the ~16 MB/core
budget for v5e.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_act(y: jax.Array, act: str) -> jax.Array:
    if act == "relu":
        return jnp.maximum(y, 0.0)
    if act == "tanh":
        return jnp.tanh(y)
    if act == "sigmoid":
        return jax.nn.sigmoid(y)
    if act == "linear":
        return y
    raise ValueError(f"unsupported activation {act}")


def _fused_dense_kernel(x_ref, w_ref, b_ref, o_ref, *, act: str):
    x = x_ref[...]                      # (bm, K)
    w = w_ref[...]                      # (K, bn)
    b = b_ref[...]                      # (1, bn)
    y = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                preferred_element_type=jnp.float32)
    y = y + b.astype(jnp.float32)
    o_ref[...] = _apply_act(y, act).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("act", "bm", "bn", "interpret"))
def fused_dense(x: jax.Array, w: jax.Array, b: jax.Array, *,
                act: str = "relu", bm: int = 128, bn: int = 128,
                interpret: bool = False) -> jax.Array:
    """act(x @ w + b). x: (M, K), w: (K, N), b: (N,) → (M, N).

    Shapes are padded up to (bm, bn) multiples; K is used whole (the chunked
    AE keeps K ≤ 4096 so a full row-band fits VMEM).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and b.shape == (N,)
    bm = min(bm, max(8, M))
    bn = min(bn, max(128, 128))
    Mp, Np = -(-M // bm) * bm, -(-N // bn) * bn
    xp = jnp.pad(x, ((0, Mp - M), (0, 0))) if Mp != M else x
    wp = jnp.pad(w, ((0, 0), (0, Np - N))) if Np != N else w
    bp = (jnp.pad(b, (0, Np - N)) if Np != N else b).reshape(1, Np)

    out = pl.pallas_call(
        functools.partial(_fused_dense_kernel, act=act),
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
    )(xp, wp, bp)
    return out[:M, :N]

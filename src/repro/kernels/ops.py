"""Dispatch layer over the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container, unit tests) they execute with ``interpret=True`` so the *same
kernel bodies* are validated against the ``ref.py`` oracles. ``bits=4``
payloads are packed two-nibbles-per-byte here (packing is a reshape+or — not
worth a kernel).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.autoencoder import ChunkedAEConfig, chunk_vector
from repro.kernels.fused_dense import fused_dense
from repro.kernels.quantize import dequantize_blocks_2d, quantize_blocks_2d


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- quantize
def quantize_blocks(flat: jax.Array, *, bits: int = 8,
                    block: int = 256) -> Tuple[jax.Array, jax.Array, int]:
    """flat f32 vector → (payload int8, scales f32, orig_len). bits=4 packs
    two values per byte."""
    orig_len = int(flat.size)
    blocks, _ = chunk_vector(flat.astype(jnp.float32), block)
    q, scales = quantize_blocks_2d(blocks, bits=bits, block=block,
                                   interpret=_interpret())
    if bits == 4:
        qf = q.reshape(-1)
        lo = (qf[0::2] + 8).astype(jnp.uint8)       # [-7,7] → [1,15]
        hi = (qf[1::2] + 8).astype(jnp.uint8)
        q = (lo | (hi << 4)).astype(jnp.uint8)
    return q, scales, orig_len


def dequantize_blocks(q: jax.Array, scales: jax.Array, *, bits: int = 8,
                      block: int = 256, orig_len: int = 0) -> jax.Array:
    if bits == 4:
        lo = (q & 0xF).astype(jnp.int8) - 8
        hi = ((q >> 4) & 0xF).astype(jnp.int8) - 8
        flatq = jnp.stack([lo, hi], axis=-1).reshape(-1)
        q = flatq.reshape(-1, block)
    x = dequantize_blocks_2d(q, scales, block=block, interpret=_interpret())
    flat = x.reshape(-1)
    return flat[:orig_len] if orig_len else flat


# ---------------------------------------------------------------- chunked AE
def _stack_forward(stack, x: jax.Array, act: str, final_act: str) -> jax.Array:
    interp = _interpret()
    for i, layer in enumerate(stack):
        a = act if i < len(stack) - 1 else final_act
        x = fused_dense(x, layer["w"], layer["b"], act=a, interpret=interp)
    return x


def ae_encode(params, cfg: ChunkedAEConfig, flat: jax.Array) -> jax.Array:
    """Kernel-backed chunked encode: (n_chunks, chunk) → (n_chunks, latent)."""
    chunks, _ = chunk_vector(flat, cfg.chunk_size)
    norm = params["norm"]
    xn = (chunks - norm["mean"]) / norm["std"]
    return _stack_forward(params["enc"], xn, cfg.activation, cfg.activation)


def ae_decode(params, cfg: ChunkedAEConfig, z: jax.Array,
              orig_len: int) -> jax.Array:
    xn = _stack_forward(params["dec"], z, cfg.activation, "linear")
    norm = params["norm"]
    chunks = xn * norm["std"] + norm["mean"]
    return chunks.reshape(-1)[:orig_len]

"""Dispatch layer over the Pallas kernels.

On TPU the compiled kernels run natively; everywhere else (this CPU
container, unit tests) they execute with ``interpret=True`` so the *same
kernel bodies* are validated against the ``ref.py`` oracles. ``bits=4``
payloads are packed two-nibbles-per-byte here (packing is a reshape+or — not
worth a kernel).

Kernel-vs-jnp path selection for the codec layer is centralized in
:func:`use_kernel_default`: TPU backends take the Pallas path automatically,
everything else the pure-jnp path, with ``REPRO_USE_KERNEL=0|1`` as the
explicit override (DESIGN.md §7).
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.autoencoder import ChunkedAEConfig, chunk_vector
from repro.kernels.fused_dense import fused_dense
from repro.kernels.quantize import dequantize_blocks_2d, quantize_blocks_2d


def interpret_default() -> bool:
    """Whether Pallas kernels should run in interpret mode: everywhere but
    TPU. The single definition of the predicate — the codec layer and the
    dispatch wrappers below all route through here."""
    return jax.default_backend() != "tpu"


_interpret = interpret_default


def use_kernel_default(override: Optional[bool] = None) -> bool:
    """Resolve the kernel-vs-jnp dispatch for the AE codec hot path.

    Priority: explicit ``override`` argument (a hand-set compressor field) >
    ``REPRO_USE_KERNEL`` env var (``"0"``/``"1"``) > backend auto-detection
    (TPU ⇒ kernels compiled natively; CPU/GPU ⇒ pure-jnp, since interpret
    mode is a validation tool, not a fast path). This replaces the old
    hand-set ``use_kernel=False`` default that made TPU runs silently take
    the pure-jnp path."""
    if override is not None:
        return bool(override)
    env = os.environ.get("REPRO_USE_KERNEL")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return jax.default_backend() == "tpu"


def use_grouped_default(override: Optional[bool] = None) -> bool:
    """Resolve the grouped one-dispatch server path (DESIGN.md §11.2):
    explicit ``override`` (``FLConfig.use_grouped_kernel`` or a direct
    ``server_decode_aggregate`` argument) > ``REPRO_GROUPED_KERNEL`` env
    var > off. Off by default on purpose — the per-bucket sequential path
    is the differential oracle the grouped launch is validated against
    (tests/test_grouped_kernel.py), so it stays the default until a run
    opts in."""
    if override is not None:
        return bool(override)
    env = os.environ.get("REPRO_GROUPED_KERNEL")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return False


# ---------------------------------------------------------------- quantize
def quantize_blocks(flat: jax.Array, *, bits: int = 8,
                    block: int = 256) -> Tuple[jax.Array, jax.Array, int]:
    """flat f32 vector → (payload int8, scales f32, orig_len). bits=4 packs
    two values per byte."""
    orig_len = int(flat.size)
    blocks, _ = chunk_vector(flat.astype(jnp.float32), block)
    q, scales = quantize_blocks_2d(blocks, bits=bits, block=block,
                                   interpret=_interpret())
    if bits == 4:
        q = pack_nibbles(q)
    return q, scales, orig_len


def pack_nibbles(q: jax.Array) -> jax.Array:
    """int8 values in [-7, 7] → two-per-byte uint8 (bits=4 wire format)."""
    qf = q.reshape(-1)
    lo = (qf[0::2] + 8).astype(jnp.uint8)           # [-7,7] → [1,15]
    hi = (qf[1::2] + 8).astype(jnp.uint8)
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_nibbles(q: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_nibbles`: uint8 bytes → int8 pairs, flat."""
    lo = (q.reshape(-1) & 0xF).astype(jnp.int8) - 8
    hi = ((q.reshape(-1) >> 4) & 0xF).astype(jnp.int8) - 8
    return jnp.stack([lo, hi], axis=-1).reshape(-1)


def dequantize_blocks(q: jax.Array, scales: jax.Array, *, orig_len: int,
                      bits: int = 8, block: int = 256) -> jax.Array:
    """Inverse of :func:`quantize_blocks`. ``orig_len`` is mandatory: the
    padded tail introduced by block alignment is never valid payload, and
    the old ``orig_len=0 → return the padded vector`` default silently
    corrupted any caller that forgot to slice."""
    if orig_len <= 0:
        raise ValueError(f"orig_len must be positive, got {orig_len}")
    if bits == 4:
        q = unpack_nibbles(q).reshape(-1, block)
    x = dequantize_blocks_2d(q, scales, block=block, interpret=_interpret())
    return x.reshape(-1)[:orig_len]


# ---------------------------------------------------------------- chunked AE
def _stack_forward(stack, x: jax.Array, act: str, final_act: str) -> jax.Array:
    interp = _interpret()
    for i, layer in enumerate(stack):
        a = act if i < len(stack) - 1 else final_act
        x = fused_dense(x, layer["w"], layer["b"], act=a, interpret=interp)
    return x


def ae_encode(params, cfg: ChunkedAEConfig, flat: jax.Array) -> jax.Array:
    """Kernel-backed chunked encode: (n_chunks, chunk) → (n_chunks, latent)."""
    chunks, _ = chunk_vector(flat, cfg.chunk_size)
    norm = params["norm"]
    xn = (chunks - norm["mean"]) / norm["std"]
    return _stack_forward(params["enc"], xn, cfg.activation, cfg.activation)


def ae_decode(params, cfg: ChunkedAEConfig, z: jax.Array,
              orig_len: int) -> jax.Array:
    xn = _stack_forward(params["dec"], z, cfg.activation, "linear")
    norm = params["norm"]
    chunks = xn * norm["std"] + norm["mean"]
    return chunks.reshape(-1)[:orig_len]

"""Pallas TPU kernel: fused flash attention (forward).

This is the TPU-native counterpart of ``models/attention.flash_attention``
(the pure-JAX scan the dry-run lowers): the online-softmax (m, l, acc)
recurrence runs entirely in VMEM scratch, so the (q_block × kv_block) score
and probability tiles NEVER touch HBM — the basis of the roofline's
``memory_fused`` term (roofline/analysis.py).

Grid: (batch, q_heads, Sq/q_block, Skv/kv_block), kv innermost ("reduction"
axis). Per-step VMEM: q tile (qb, D) + k/v tiles (kb, D) + f32 scratch
acc (qb, D) / m (qb,) / l (qb,) ≈ 0.4 MB at 128-square tiles — far under the
~16 MB/core budget, and all matmul dims are multiples of the 128-wide MXU.
GQA is handled in the index maps: kv tiles are indexed by h // group_size,
so no K/V head replication is materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, mode: str, window: Optional[int],
                  q_block: int, kv_block: int, kv_len: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, :, 0, :].astype(jnp.float32)      # (qb, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)      # (kb, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)      # (kb, D)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_ids = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 0)
    k_ids = kj * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                     (q_block, kv_block), 1)
    mask = k_ids < kv_len                          # kv padding
    if mode in ("causal", "window"):
        mask &= k_ids <= q_ids
    if mode == "window":
        mask &= k_ids > q_ids - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (qb,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0, :, 0, :] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "window", "q_block",
                                             "kv_block", "interpret"))
def flash_attention_pallas(
    q: jax.Array,                  # (B, Sq, H, D)
    k: jax.Array,                  # (B, Skv, KV, D)
    v: jax.Array,                  # (B, Skv, KV, D)
    *,
    mode: str = "causal",
    window: Optional[int] = None,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, H, D = q.shape
    _, Skv, KV, Dv = v.shape
    G = H // KV
    scale = D ** -0.5

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Skv)
    sq_p = -(-Sq // q_block) * q_block
    sk_p = -(-Skv // kv_block) * kv_block
    if sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - Sq), (0, 0), (0, 0)))
    if sk_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, sk_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_p - Skv), (0, 0), (0, 0)))

    grid = (B, H, sq_p // q_block, sk_p // kv_block)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, mode=mode,
                          window=window, q_block=q_block,
                          kv_block=kv_block, kv_len=Skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block, 1, D),
                         lambda b, h, qi, kj: (b, qi, h, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, qi, kj, G=G: (b, kj, h // G, 0)),
            pl.BlockSpec((1, kv_block, 1, D),
                         lambda b, h, qi, kj, G=G: (b, kj, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, 1, D),
                               lambda b, h, qi, kj: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, sq_p, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]

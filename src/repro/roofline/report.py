"""Render dry-run JSONL reports into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List


def load(path: str) -> List[dict]:
    with open(path) as f:
        return [json.loads(line) for line in f]


def fmt_table(rows: List[dict], caption: str) -> str:
    hdr = ("| arch | shape | mesh | compute ms | memory ms | memF ms | "
           "coll ms | dominant | peak GB/dev | useful FLOPs | "
           "coll GB/dev |\n"
           "|---|---|---|---:|---:|---:|---:|---|---:|---:|---:|\n")
    out = [f"**{caption}**\n\n", hdr]
    for r in rows:
        memf = r.get("memory_fused_ms", r["memory_ms"])
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']}"
            f"{'+fl' if r.get('fl') else ''} | {r['compute_ms']:.1f} | "
            f"{r['memory_ms']:.1f} | {memf:.1f} | "
            f"{r['collective_ms']:.1f} | "
            f"{r['dominant']} | {r['hbm_gb_per_dev']:.1f} | "
            f"{r['model_flops_frac']:.3f} | "
            f"{r['collective_gb_per_dev']:.2f} |\n")
    return "".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="+")
    ap.add_argument("--caption", default=None)
    args = ap.parse_args()
    for path in args.files:
        rows = load(path)
        caption = args.caption or os.path.basename(path)
        print(fmt_table(rows, caption))


if __name__ == "__main__":
    main()

"""Static HLO-text analyzer with while-loop trip-count accounting.

XLA's built-in ``compiled.cost_analysis()`` visits each ``while`` body ONCE,
so scanned-layer models (every trunk in this framework) under-report FLOPs,
bytes and collectives by ~n_layers. This module re-derives the roofline
inputs from the post-SPMD-partitioning HLO text:

* **flops** — every ``dot`` (2·|result|·K from ``lhs_contracting_dims``) and
  ``convolution``, including dots inside fusion bodies, multiplied through
  the call graph (``while`` bodies × ``known_trip_count`` from
  backend_config).
* **memory bytes** — the standard one-kernel-per-top-level-instruction
  traffic model: result + operand bytes for every non-bookkeeping
  instruction in control computations (fusion internals excluded — their
  traffic is the fusion's operands/result at the call site).
* **collective bytes** — operand bytes of all-reduce / all-gather /
  reduce-scatter / all-to-all / collective-permute, trip-multiplied.

Shapes in SPMD HLO are per-device shards, so every total is per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_INST_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*?)\s+"
                      r"([a-z][\w\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_BOOKKEEPING = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((dt, shape))
    return out


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> float:
    total = 0.0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operands: List[str]
    rest: str                         # args + attrs text after '('


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction] = dataclasses.field(default_factory=list)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            current = Computation(mc.group(1))
            comps[current.name] = current
            continue
        if current is None:
            continue
        mi = _INST_RE.match(line)
        if mi:
            name, result, op, rest = mi.groups()
            operands = _OPERAND_RE.findall(rest.split(")")[0]) \
                if ")" in rest else _OPERAND_RE.findall(rest)
            current.instructions.append(Instruction(
                name=name, op=op, result_shapes=_shape_list(result),
                operands=operands, rest=rest))
    return comps


def _shape_map(comps: Dict[str, Computation]
               ) -> Dict[str, List[Tuple[str, Tuple[int, ...]]]]:
    m = {}
    for comp in comps.values():
        for inst in comp.instructions:
            m[inst.name] = inst.result_shapes
    return m


def _dot_flops(inst: Instruction, shapes) -> float:
    result_elems = 1.0
    for _, dims in inst.result_shapes:
        for d in dims:
            result_elems *= d
    k = 1.0
    mk = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.rest)
    if mk and inst.operands:
        lhs = shapes.get(inst.operands[0])
        if lhs:
            _, ldims = lhs[0]
            for idx in mk.group(1).split(","):
                if idx and int(idx) < len(ldims):
                    k *= ldims[int(idx)]
    return 2.0 * result_elems * k


def _conv_flops(inst: Instruction, shapes) -> float:
    result_elems = 1.0
    for _, dims in inst.result_shapes:
        for d in dims:
            result_elems *= d
    if len(inst.operands) >= 2:
        rhs = shapes.get(inst.operands[1])
        if rhs:
            _, kdims = rhs[0]
            k = 1.0
            for d in kdims[:-1]:          # all but output-feature dim
                k *= d
            return 2.0 * result_elems * k
    return 2.0 * result_elems


def _called(inst: Instruction) -> List[Tuple[str, float, str]]:
    """(callee, multiplier, kind) edges of the call graph."""
    out = []
    if inst.op == "while":
        trip = 1.0
        mt = _TRIP_RE.search(inst.rest)
        if mt:
            trip = float(mt.group(1))
        mb = re.search(r"body=(%[\w.\-]+)", inst.rest)
        mcond = re.search(r"condition=(%[\w.\-]+)", inst.rest)
        if mb:
            out.append((mb.group(1), trip, "body"))
        if mcond:
            out.append((mcond.group(1), trip, "cond"))
    elif inst.op == "fusion":
        mf = re.search(r"calls=(%[\w.\-]+)", inst.rest)
        if mf:
            out.append((mf.group(1), 1.0, "fusion"))
    elif inst.op in ("call", "custom-call", "async-start"):
        mf = re.search(r"to_apply=(%[\w.\-]+)", inst.rest)
        if mf:
            out.append((mf.group(1), 1.0, "call"))
    elif inst.op == "conditional":
        for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                             r"(?:true|false)_computation=(%[\w.\-]+))",
                             inst.rest):
            names = (m.group(1) or m.group(2) or "")
            for nm in _OPERAND_RE.findall(names):
                out.append((nm, 1.0, "call"))
    return out


def _is_dus(inst: Instruction, comps: Dict[str, "Computation"]) -> bool:
    if inst.op == "dynamic-update-slice":
        return True
    if inst.op == "fusion":
        mf = re.search(r"calls=(%[\w.\-]+)", inst.rest)
        body = comps.get(mf.group(1)) if mf else None
        if body and body.instructions:
            return body.instructions[-1].op == "dynamic-update-slice"
    return False


# flash-attention inner-loop dot labels: computations containing these are
# "attention-tile" regions whose intermediates live in VMEM on a fused TPU
# (Pallas) kernel — tracked separately so the roofline can report both the
# un-fused upper bound and the fused-attention estimate.
_FLASH_MARKERS = ("bqkgd,bskd", "bkgqs,bskd")


_IOTA_GROUPS_RE = re.compile(
    r"replica_groups=\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{(\{[\d,{}\s]*\})\}")


def crosses_boundary(rest: str, boundary: int) -> bool:
    """True if any replica group spans device ids on both sides of
    ``boundary`` (e.g. 256 = the pod edge on the 2x16x16 mesh) — i.e. the
    collective moves bytes across the pod axis."""
    m = _IOTA_GROUPS_RE.search(rest)
    if m:
        shape = [int(x) for x in m.group(1).split(",")]
        src = [int(x) for x in m.group(2).split(",")]
        n = 1
        for d in src:
            n *= d
        ids = list(range(n))
        if m.group(3):
            perm = [int(x) for x in m.group(3).split(",")]
            # reshape ids to src dims, transpose, flatten
            import numpy as _np
            ids = _np.arange(n).reshape(src).transpose(perm).reshape(-1)
        group_size = shape[-1] if len(shape) > 1 else shape[0]
        for g in range(0, n, group_size):
            grp = ids[g:g + group_size]
            lo = min(grp)
            hi = max(grp)
            if lo < boundary <= hi:
                return True
        return False
    m = _LIST_GROUPS_RE.search(rest)
    if m:
        for grp_txt in re.findall(r"\{([\d,\s]+)\}", m.group(1)):
            ids = [int(x) for x in grp_txt.replace(" ", "").split(",") if x]
            if ids and min(ids) < boundary <= max(ids):
                return True
    return False


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    memory_bytes: float = 0.0
    attn_loop_bytes: float = 0.0        # subset of memory_bytes
    collective_bytes: float = 0.0
    cross_pod_bytes: float = 0.0        # subset crossing the pod boundary
    collective_breakdown: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    collective_count: float = 0.0

    def add(self, other: "HloCost", mult: float = 1.0,
            memory: bool = True, flops: bool = True,
            as_attn: bool = False):
        if flops:
            self.flops += other.flops * mult
        if memory:
            self.memory_bytes += other.memory_bytes * mult
            if as_attn:
                self.attn_loop_bytes += other.memory_bytes * mult
            else:
                self.attn_loop_bytes += other.attn_loop_bytes * mult
            self.collective_bytes += other.collective_bytes * mult
            self.cross_pod_bytes += other.cross_pod_bytes * mult
            self.collective_count += other.collective_count * mult
            for k, v in other.collective_breakdown.items():
                self.collective_breakdown[k] = \
                    self.collective_breakdown.get(k, 0.0) + v * mult


def analyze_hlo(text: str, pod_boundary: int = 0) -> HloCost:
    """``pod_boundary``: device-id edge between pods (256 on the 2x16x16
    mesh); collectives whose replica groups cross it are tallied in
    ``cross_pod_bytes``."""
    comps = parse_module(text)
    shapes = _shape_map(comps)
    memo: Dict[Tuple[str, bool], HloCost] = {}

    def is_flash(name: str) -> bool:
        """A computation is a flash-attention tile region if any of its OWN
        instructions carries a flash einsum label. The labels only occur on
        ops created inside models/attention.flash_attention's q/kv loops
        (CSE may strip them from the dots themselves, but the surrounding
        copies/bitcasts keep the op_name), and those loops' bodies are
        separate computations from the layer body — so direct membership is
        the right granularity."""
        comp = comps.get(name)
        if comp is None:
            return False
        for inst in comp.instructions:
            if any(m in inst.rest for m in _FLASH_MARKERS):
                return True
        return False
    flash_flags = {n: is_flash(n) for n in comps}

    # find entry: computation named like %main or the one never called
    called_names = set()
    for comp in comps.values():
        for inst in comp.instructions:
            for callee, _, _ in _called(inst):
                called_names.add(callee)
    entries = [n for n in comps if n not in called_names]
    entry = None
    for n in entries:
        if "main" in n:
            entry = n
    if entry is None and entries:
        entry = entries[0]
    if entry is None:
        return HloCost()

    def eval_comp(name: str, in_fusion: bool) -> HloCost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        cost = HloCost()
        memo[key] = cost                     # recursion guard
        comp = comps.get(name)
        if comp is None:
            return cost
        for inst in comp.instructions:
            if inst.op == "dot":
                cost.flops += _dot_flops(inst, shapes)
            elif inst.op == "convolution":
                cost.flops += _conv_flops(inst, shapes)
            base = inst.op
            for suffix in ("-start", "-done"):
                if base.endswith(suffix):
                    base = base[:-len(suffix)]
            if not in_fusion:
                if base in COLLECTIVES and not inst.op.endswith("-done"):
                    op_bytes = 0.0
                    for o in inst.operands:
                        op_bytes += _nbytes(shapes.get(o, []))
                    if op_bytes == 0.0:
                        op_bytes = _nbytes(inst.result_shapes)
                    cost.collective_bytes += op_bytes
                    cost.collective_count += 1
                    cost.collective_breakdown[base] = \
                        cost.collective_breakdown.get(base, 0.0) + op_bytes
                    if pod_boundary and crosses_boundary(inst.rest,
                                                         pod_boundary):
                        cost.cross_pod_bytes += op_bytes
                if inst.op not in _BOOKKEEPING and inst.op != "while":
                    result_b = _nbytes(inst.result_shapes)
                    op_bytes = [_nbytes(shapes.get(o, []))
                                for o in inst.operands]
                    mem = result_b + sum(op_bytes)
                    # in-place dynamic-update-slice (bare or as fusion
                    # root): the big aliased buffer is not fully touched —
                    # count only the update slice + small operands.
                    if op_bytes and _is_dus(inst, comps):
                        big = max(op_bytes)
                        mem = max(result_b - big, 0.0) \
                            + sum(op_bytes) - big
                    cost.memory_bytes += mem
            for callee, mult, kind in _called(inst):
                sub = eval_comp(callee, in_fusion or kind == "fusion")
                cost.add(sub, mult, memory=not in_fusion,
                         as_attn=flash_flags.get(callee, False))
        memo[key] = cost
        return cost

    return eval_comp(entry, False)

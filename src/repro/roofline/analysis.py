"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch × shape × mesh), in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

``cost_analysis()`` is already per-device (the SPMD-partitioned module), so
no extra division by chip count. Collective bytes are NOT in cost_analysis —
we parse the post-partitioning HLO text and sum the operand sizes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute /
ragged-all-to-all instruction (shapes in SPMD HLO are per-device shards).
ICI assumption: one effective 50 GB/s link per chip (conservative; v5e has
multiple links — we report the term, not a latency promise).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# shape token e.g. f32[16,128] or bf16[2,4,8]{2,1,0} or f32[] (scalar)
_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z]*\d*(?:e\d+m\d+(?:fn)?)?)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum operand bytes per collective kind from (SPMD-partitioned) HLO."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    out["total"] = 0.0
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.search(
            r"=\s*(?:\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES)
            + r")(?:-start|-done)?\(", stripped)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in stripped:
            continue                        # avoid double count start/done
        # operand shapes: everything inside the call parens
        call = stripped[m.end():]
        depth = 1
        end = 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operands = call[:end]
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(operands))
        if nbytes == 0.0:
            # fall back to the result shape (left of '=')
            lhs = stripped.split("=")[0]
            prefix = stripped[:m.start()]
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(prefix))
            del lhs
        out[kind] += nbytes
        out["total"] += nbytes
        out["count"] += 1
    return out


@dataclasses.dataclass
class RooflineReport:
    name: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, float]
    peak_memory_per_device: float
    model_flops: float                  # 6*N*D (or mode-appropriate)
    attn_loop_bytes_per_device: float = 0.0
    cross_pod_bytes_per_device: float = 0.0
    compute_s: float = 0.0
    memory_s: float = 0.0
    memory_fused_s: float = 0.0
    collective_s: float = 0.0

    def __post_init__(self):
        self.compute_s = self.flops_per_device / PEAK_FLOPS_BF16
        self.memory_s = self.hbm_bytes_per_device / HBM_BW
        # fused-attention estimate: on TPU the flash score/prob tiles stay
        # in VMEM inside the Pallas kernel (kernels/flash_attention.py) —
        # remove their modeled HBM traffic from the memory term.
        self.memory_fused_s = (self.hbm_bytes_per_device
                               - self.attn_loop_bytes_per_device) / HBM_BW
        self.collective_s = self.collective_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_fused_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        total = self.flops_per_device * self.n_devices
        return self.model_flops / total if total else 0.0

    def row(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "devices": self.n_devices,
            "compute_ms": round(self.compute_s * 1e3, 3),
            "memory_ms": round(self.memory_s * 1e3, 3),
            "memory_fused_ms": round(self.memory_fused_s * 1e3, 3),
            "collective_ms": round(self.collective_s * 1e3, 3),
            "dominant": self.dominant,
            "hbm_gb_per_dev": round(self.peak_memory_per_device / 2**30, 2),
            "model_flops_frac": round(self.useful_flops_fraction, 3),
            "collective_gb_per_dev": round(
                self.collective_bytes_per_device / 2**30, 4),
            "cross_pod_gb_per_dev": round(
                self.cross_pod_bytes_per_device / 2**30, 6),
        }


def analyze_compiled(name: str, compiled, n_devices: int,
                     model_flops: float,
                     pod_boundary: int = 0) -> RooflineReport:
    """Roofline terms from the compiled SPMD executable.

    Uses the trip-count-aware static HLO analyzer (hlo_parse) — XLA's own
    ``cost_analysis()`` visits while bodies once, undercounting scanned-layer
    models by ~n_layers. The memory term is the un-fused upper bound
    (every top-level HLO op reads operands / writes results through HBM);
    compute and collective terms are exact up to elementwise FLOPs.
    """
    from repro.roofline.hlo_parse import analyze_hlo
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo, pod_boundary=pod_boundary)
    mem = compiled.memory_analysis()
    peak = 0.0
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes"):
        peak += float(getattr(mem, attr, 0.0) or 0.0)
    alias = float(getattr(mem, "alias_size_in_bytes", 0.0) or 0.0)
    peak -= alias
    return RooflineReport(
        name=name, n_devices=n_devices, flops_per_device=cost.flops,
        hbm_bytes_per_device=cost.memory_bytes,
        attn_loop_bytes_per_device=cost.attn_loop_bytes,
        cross_pod_bytes_per_device=cost.cross_pod_bytes,
        collective_bytes_per_device=cost.collective_bytes,
        collective_breakdown=dict(cost.collective_breakdown),
        peak_memory_per_device=peak, model_flops=model_flops)


# =====================================================================
# MODEL_FLOPS estimates (6·N·D dense / 6·N_active·D MoE)
# =====================================================================
def active_params(cfg) -> float:
    """Approximate active parameter count per token."""
    D, L, V = cfg.d_model, cfg.n_layers, cfg.padded_vocab
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        s = cfg.ssm
        d_in = s.expand * D
        nh = d_in // s.head_dim
        per_layer = D * (2 * d_in + 2 * s.n_groups * s.d_state + nh) \
            + d_in * D
        return emb + L * per_layer
    if cfg.attn_type == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        attn = (D * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
                + D * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.n_heads
                * (m.qk_nope_head_dim + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * D)
    else:
        attn = D * (cfg.n_heads * cfg.head_dim) * 2 \
            + D * (cfg.n_kv_heads * cfg.head_dim) * 2
    if cfg.family == "moe":
        moe = cfg.moe
        ff = 3 * D * moe.d_ff_expert * moe.top_k
        if moe.shared_expert:
            ff += 3 * D * moe.d_ff_expert
        ff += D * moe.n_experts                      # router
    else:
        n_mats = 3 if cfg.activation in ("swiglu", "geglu") else 2
        ff = n_mats * D * cfg.d_ff if cfg.d_ff else 0
    per_layer = attn + ff
    if cfg.family == "hybrid":
        rg = cfg.rglru
        W = rg.lru_width
        rec = D * W * 2 + 2 * W * W + W * D          # rglru block
        n_rec = sum(1 for k in cfg.rglru.pattern if k == "rglru")
        plen = len(cfg.rglru.pattern)
        frac_attn = (plen - n_rec) / plen
        per_layer = frac_attn * (attn + ff) + (1 - frac_attn) * (rec + ff)
    total_layers = L
    if cfg.family == "audio":
        total_layers = L + cfg.encdec.n_encoder_layers
        per_layer = per_layer + attn / 2             # cross-attn on dec half
    return emb + total_layers * per_layer


def attention_flops(cfg, shape) -> float:
    """Exact-ish attention MODEL_FLOPS (scores + PV, causal-halved)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        return 0.0
    if cfg.attn_type == "mla":
        m = cfg.mla
        width = cfg.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim
                               + m.v_head_dim)
    else:
        width = cfg.n_heads * cfg.head_dim * 2          # scores + pv
    L_attn = cfg.n_layers
    ctx = S
    if cfg.family == "hybrid":
        rg = cfg.rglru
        plen = len(rg.pattern)
        n_attn = sum(1 for k in rg.pattern if k == "attn")
        L_attn = (cfg.n_layers // plen) * n_attn
        ctx = min(S, rg.window)
    if shape.mode == "decode":
        # one query token against the cached context
        window = cfg.long_context_window
        if shape.name == "long_500k" and window:
            ctx = min(ctx, window)
        fwd = 2.0 * B * ctx * width * L_attn
        return fwd
    causal = 0.5
    fwd = 2.0 * B * S * ctx * causal * width * L_attn
    if cfg.family == "audio":
        F = cfg.encdec.n_frames
        enc = 2.0 * B * F * F * width * cfg.encdec.n_encoder_layers
        cross = 2.0 * B * S * F * width * cfg.n_layers
        fwd += enc + cross
    return fwd * (3.0 if shape.mode == "train" else 1.0)


def model_flops(cfg, shape) -> float:
    n_act = active_params(cfg)
    attn = attention_flops(cfg, shape)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens + attn
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens + attn
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch + attn


# =====================================================================
# analytic decode→aggregate roofline (DESIGN.md §11.3): where the four
# server-aggregation variants sit against the HBM roof, from shapes alone
# =====================================================================
def decode_agg_roofline(cohort: int, n_chunks: int, latent: int,
                        hidden: Tuple[int, ...], chunk: int, *,
                        n_buckets: int = 1,
                        dtype_bytes: int = 4) -> Dict[str, Dict]:
    """Place the chunked-AE decode→aggregate variants on the memory roofline.

    Every variant runs the same decoder math — ``cohort`` clients ×
    ``n_chunks`` chunks through ``latent → hidden... → chunk`` per bucket,
    ``n_buckets`` buckets per round — so FLOPs are identical; what differs
    is HBM traffic and launch count:

    * ``loop``    — per-client sequential decode + host reduce: every client
      materializes its full reconstruction to HBM and it is read back for
      the reduction; decoder params are re-read per client. C·B launches.
    * ``vmap``    — batched decode per bucket: params read once per bucket,
      but the (C, model) reconstruction block still round-trips HBM before
      the einsum. B launches.
    * ``fused``   — the per-bucket Pallas kernel (DESIGN.md §7.1): hidden
      activations round-trip at latent width, the chunk-wide expansion is
      reduced in-kernel, only the (model)-sized mean is written. B launches.
    * ``grouped`` — the ragged grouped launch (DESIGN.md §11.1): same
      traffic as ``fused`` minus repeated decoder-stack reads (each distinct
      decoder ships once into the stacked operand), in ONE launch.

    Returns per-variant dicts with ``flops``, ``hbm_bytes``,
    ``arith_intensity`` (FLOPs/byte), ``pct_of_roof`` (attainable FLOP/s at
    that intensity over peak), ``bound`` and ``launches``, plus the machine
    constants used — all finite for any positive shapes
    (tests/test_roofline_decode_agg.py)."""
    assert cohort > 0 and n_chunks > 0 and latent > 0 and chunk > 0
    assert n_buckets > 0 and dtype_bytes > 0
    widths = (latent,) + tuple(hidden) + (chunk,)
    K = widths[-2]                                  # penultimate width
    # identical compute for every variant: 2mnk per layer matmul, per
    # (client, chunk) row, per bucket
    flops_per_row = sum(2.0 * a * b for a, b in zip(widths[:-1], widths[1:]))
    flops = n_buckets * cohort * n_chunks * flops_per_row
    param_bytes = sum((a * b + b) * dtype_bytes
                      for a, b in zip(widths[:-1], widths[1:]))
    z_bytes = n_buckets * cohort * n_chunks * latent * dtype_bytes
    model_bytes = n_buckets * n_chunks * chunk * dtype_bytes   # one mean
    recon_bytes = cohort * model_bytes          # C materialized decodes
    hidden_rt = n_buckets * cohort * n_chunks * K * dtype_bytes
    ridge = PEAK_FLOPS_BF16 / HBM_BW

    def variant(hbm_bytes: float, launches: int) -> Dict[str, float]:
        ai = flops / hbm_bytes
        attainable = min(PEAK_FLOPS_BF16, ai * HBM_BW)
        return {
            "flops": flops,
            "hbm_bytes": hbm_bytes,
            "arith_intensity": ai,
            "pct_of_roof": 100.0 * attainable / PEAK_FLOPS_BF16,
            "bound": "memory" if ai < ridge else "compute",
            "launches": launches,
        }

    return {
        "shape": {"cohort": cohort, "n_chunks": n_chunks, "latent": latent,
                  "hidden": list(hidden), "chunk": chunk,
                  "n_buckets": n_buckets},
        "machine": {"hbm_bw": HBM_BW, "peak_flops": PEAK_FLOPS_BF16,
                    "ridge_intensity": ridge},
        "loop": variant(
            z_bytes + n_buckets * cohort * param_bytes    # params per client
            + 2.0 * recon_bytes                           # write + read back
            + model_bytes,                                # mean write
            launches=cohort * n_buckets),
        "vmap": variant(
            z_bytes + n_buckets * param_bytes
            + 2.0 * recon_bytes + model_bytes,
            launches=n_buckets),
        "fused": variant(
            z_bytes + n_buckets * param_bytes
            + 2.0 * hidden_rt                             # latent-sided only
            + model_bytes,
            launches=n_buckets),
        "grouped": variant(
            z_bytes + param_bytes                         # deduped decoders
            + 2.0 * hidden_rt + model_bytes,
            launches=1),
    }

"""Deterministic synthetic data pipeline.

The container is offline, so the paper's MNIST/CIFAR datasets are replaced by
synthetic classification tasks with identical tensor shapes and class counts
(DESIGN.md §3, "assumption changes"). Class structure: each class is a random
gaussian cluster in input space plus per-sample noise — learnable to high
accuracy by the paper's tiny models, which is what the repro needs (the claim
under test concerns the *weights* dataset, not the image dataset).

Also provides:
* the paper's 2-collaborator **color/grayscale imbalance** split (§5.2),
* **Dirichlet non-IID label partitioning** for larger federations,
* a token-stream sampler for the LLM training driver.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def synthetic_classification(
    seed: int, n: int, input_shape: Tuple[int, ...], n_classes: int,
    *, sep: float = 3.0, noise: float = 1.0,
) -> Dict[str, jnp.ndarray]:
    """Gaussian-cluster classification with deterministic structure."""
    rng = np.random.RandomState(seed)
    dim = int(np.prod(input_shape))
    centers = rng.randn(n_classes, dim).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    y = rng.randint(0, n_classes, size=n).astype(np.int32)
    x = centers[y] * sep + rng.randn(n, dim).astype(np.float32) * noise
    x = x.reshape(n, *input_shape)
    return {"x": jnp.asarray(x), "y": jnp.asarray(y)}


def mnist_like(seed: int, n: int = 2048) -> Dict[str, jnp.ndarray]:
    # sep chosen so the task generalizes from a few hundred samples (the
    # per-dim noise norm is sqrt(784)≈28; class structure must dominate it)
    return synthetic_classification(seed, n, (784,), 10, sep=8.0, noise=0.7)


def cifar_like(seed: int, n: int = 2048) -> Dict[str, jnp.ndarray]:
    return synthetic_classification(seed, n, (32, 32, 3), 10,
                                    sep=8.0, noise=0.7)


def to_grayscale(data: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Paper §5.2: the second collaborator sees grayscale images (channel
    mean replicated) — the color-imbalance non-IID condition."""
    x = data["x"]
    assert x.ndim == 4, "grayscale imbalance needs HWC images"
    g = jnp.mean(x, axis=-1, keepdims=True)
    return {"x": jnp.broadcast_to(g, x.shape), "y": data["y"]}


def color_imbalance_split(seed: int, n_per_collab: int = 2048,
                          n_eval: int = 256
                          ) -> Tuple[List[Dict[str, jnp.ndarray]],
                                     Dict[str, jnp.ndarray]]:
    """Two CIFAR-like collaborators over ONE underlying task (same class
    centers): collaborator 0 sees color images, collaborator 1 the grayscale
    version of a disjoint slice (paper §5.2). Returns ([c0, c1], eval)."""
    data = cifar_like(seed, 2 * n_per_collab + n_eval)
    c0 = {k: v[:n_per_collab] for k, v in data.items()}
    c1 = to_grayscale({k: v[n_per_collab:2 * n_per_collab]
                       for k, v in data.items()})
    evald = {k: v[2 * n_per_collab:] for k, v in data.items()}
    return [c0, c1], evald


def train_eval_split(data: Dict[str, jnp.ndarray], n_eval: int
                     ) -> Tuple[Dict[str, jnp.ndarray],
                                Dict[str, jnp.ndarray]]:
    """Split one dataset into train/eval — eval MUST share the generating
    seed (class centers) with train; a different-seed dataset is a different
    task."""
    n = data["x"].shape[0]
    assert n_eval < n
    train = {k: v[:n - n_eval] for k, v in data.items()}
    evald = {k: v[n - n_eval:] for k, v in data.items()}
    return train, evald


def dirichlet_partition(seed: int, data: Dict[str, jnp.ndarray],
                        n_clients: int, alpha: float = 0.5,
                        min_per_client: int = 1
                        ) -> List[Dict[str, jnp.ndarray]]:
    """Label-skew non-IID partition (standard FL benchmark protocol).

    At small ``alpha`` a draw can leave a client with almost no samples,
    which degenerates anything trained on the shard (a one-sample client
    still gets a FedAvg weight and a rate-control drift signal);
    ``min_per_client`` tops such shards up deterministically — index
    ``(ci + k) % n`` for the k-th filler, so the default (1) reproduces the
    previous give-empty-clients-one-sample behavior bit-for-bit. Fillers
    may duplicate samples already owned by other clients (documented
    overlap, negligible at benchmark sizes)."""
    rng = np.random.RandomState(seed)
    y = np.asarray(data["y"])
    n_classes = int(y.max()) + 1
    client_idx: List[List[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.where(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for ci, part in enumerate(np.split(idx, cuts)):
            client_idx[ci].extend(part.tolist())
    out = []
    for ci in range(n_clients):
        sel = np.array(sorted(client_idx[ci]), dtype=np.int64)
        if len(sel) < min_per_client:
            extra = [(ci + k) % len(y)
                     for k in range(min_per_client - len(sel))]
            sel = np.concatenate([sel, np.array(extra, dtype=np.int64)])
        out.append({"x": data["x"][sel], "y": data["y"][sel]})
    return out


def uniform_partition(seed: int, data: Dict[str, jnp.ndarray],
                      n_clients: int) -> List[Dict[str, jnp.ndarray]]:
    """Equal-sized IID shards (shuffle, then split evenly; the remainder is
    dropped). This is the homogeneous-cohort layout the vmap scheduler hot
    path requires — every shard has identical shapes, so ``SampledSync``
    batches the whole cohort in one jitted call (DESIGN.md §6.4). Use
    ``dirichlet_partition`` instead when label skew matters more than
    throughput."""
    rng = np.random.RandomState(seed)
    n = data["x"].shape[0]
    order = rng.permutation(n)
    per = n // n_clients
    assert per > 0, "fewer samples than clients"
    return [{k: v[order[i * per:(i + 1) * per]] for k, v in data.items()}
            for i in range(n_clients)]


def batch_indices(seed: int, n: int, batch_size: int
                  ) -> Iterator[np.ndarray]:
    """One epoch of shuffled batch index arrays (partial tail batch
    dropped). Single source of truth for batch order: both the sequential
    ``local_train`` (via :func:`batches`) and the vmapped
    ``local_train_batched`` consume this, which is what makes the two
    training paths equivalent for a shared seed (DESIGN.md §6.4)."""
    order = np.random.RandomState(seed).permutation(n)
    for i in range(0, n - batch_size + 1, batch_size):
        yield order[i:i + batch_size]


def batches(seed: int, data: Dict[str, jnp.ndarray], batch_size: int
            ) -> Iterator[Dict[str, jnp.ndarray]]:
    """One epoch of shuffled minibatches."""
    for sel in batch_indices(seed, data["x"].shape[0], batch_size):
        yield {"x": data["x"][sel], "y": data["y"][sel]}


# ----------------------------------------------------------------- LM stream
def synthetic_lm_batch(seed: int, vocab_size: int, batch: int,
                       seq_len: int) -> Dict[str, jnp.ndarray]:
    """Zipf-distributed token stream with next-token labels — a deterministic
    stand-in corpus for the LLM training driver."""
    rng = np.random.RandomState(seed)
    ranks = rng.zipf(1.3, size=(batch, seq_len + 1))
    tokens = (ranks % vocab_size).astype(np.int32)
    return {"tokens": jnp.asarray(tokens[:, :-1]),
            "labels": jnp.asarray(tokens[:, 1:])}

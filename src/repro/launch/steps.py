"""Step builders + ShapeDtypeStruct input specs for every (arch × shape).

``build_step(cfg, shape, mesh)`` returns a ``StepBundle``: the jit-able step
function, its example-input ShapeDtypeStructs (no device allocation — the
shannon/kernels dry-run pattern), and in/out shardings. The dry-run lowers
and compiles exactly these bundles; the real launchers execute them.

Step kinds by shape.mode:
* train   — fused loss/grad/optimizer update (donated params+opt state)
* prefill — full-sequence forward returning (last logits, decode cache)
* decode  — one-token serve step against a pre-filled KV/state cache
* fl      — federated round: local update + chunked-AE compressed exchange
            across the ``pod`` axis (the paper's technique; multi-pod mesh)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import model as model_lib
from repro.models import sharding as shard_lib
from repro.optim.optimizers import make_optimizer

Pytree = Any


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: Tuple[Pytree, ...]            # ShapeDtypeStruct pytrees
    in_shardings: Tuple[Pytree, ...]
    out_shardings: Pytree
    donate_argnums: Tuple[int, ...] = ()
    static_broadcasted: Dict[str, Any] = dataclasses.field(
        default_factory=dict)


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def param_shapes(cfg: ArchConfig) -> Pytree:
    return jax.eval_shape(
        functools.partial(model_lib.init_params, cfg=cfg),
        jax.random.PRNGKey(0))


def batch_shapes(cfg: ArchConfig, shape: ShapeConfig,
                 with_labels: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((B, S), jnp.int32)}
    if with_labels:
        out["labels"] = _sds((B, S), jnp.int32)
    if cfg.family == "audio":
        out["frames"] = _sds((B, cfg.encdec.n_frames, cfg.d_model),
                             jnp.dtype(cfg.compute_dtype))
    if cfg.family == "vlm":
        out["image_embeds"] = _sds((B, cfg.vlm.n_image_tokens, cfg.d_model),
                                   jnp.dtype(cfg.compute_dtype))
    return out


def decode_window(cfg: ArchConfig, shape: ShapeConfig) -> Optional[int]:
    """Sliding-window fallback for quadratic archs on very long contexts."""
    if shape.name == "long_500k" and cfg.long_context_window:
        return cfg.long_context_window
    return None


def cache_shapes(cfg: ArchConfig, shape: ShapeConfig) -> Pytree:
    window = decode_window(cfg, shape)
    return jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg,
                          shape.global_batch, shape.seq_len, window))


# =====================================================================
# sharding assembly
# =====================================================================
def _opt_specs(cfg: ArchConfig, mesh: Mesh, p_specs: Pytree,
               p_shapes: Pytree, opt_state_shape: Pytree) -> Pytree:
    """Optimizer state specs: moments follow params (+ZeRO-1 data sharding)."""
    def moment_spec(spec, shp):
        return shard_lib.zero1_spec(spec, shp.shape, mesh) if cfg.zero1 \
            else spec
    moment = jax.tree_util.tree_map(moment_spec, p_specs, p_shapes)
    out = {}
    for k, v in opt_state_shape.items():
        if k == "count":
            out[k] = P()
        else:
            out[k] = moment
    return out


def _activation_axes(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """(batch_axes, seq_axis) for residual-stream sharding constraints.

    batch over (pod, data) when divisible; sequence over `model` for
    full-sequence modes on attention-bearing archs (§Perf iteration 2:
    fractional-head sharding otherwise makes GSPMD split the attention
    contraction dim, inserting per-tile all-reduces inside the flash loop).
    SSM/hybrid keep 1D sharding — their scans run along the sequence.
    """
    axes = shard_lib.batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if shape.global_batch % total != 0:
        return None, None
    seq_axis = None
    seq_ok = (cfg.family in ("dense", "moe", "vlm", "audio")
              and shape.seq_len % mesh.shape.get("model", 1) == 0)
    if seq_ok and (shape.mode == "prefill"
                   or (shape.mode == "train" and cfg.train_seq_shard)):
        seq_axis = "model"
    return axes, seq_axis


def _with_activation_ctx(fn, axes, seq_axis=None):
    if axes is None:
        return fn
    from repro.models.partition_ctx import activation_sharding

    def wrapped(*a):
        with activation_sharding(axes, seq_axis):
            return fn(*a)
    return wrapped


def build_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
               fl: bool = False, constrain: bool = True) -> StepBundle:
    if shape.mode == "train":
        if fl:
            from repro.core.distributed import build_fl_round_step
            bundle = build_fl_round_step(cfg, shape, mesh)
        else:
            bundle = build_train_step(cfg, shape, mesh)
    elif shape.mode == "prefill":
        bundle = build_prefill_step(cfg, shape, mesh)
    elif shape.mode == "decode":
        bundle = build_decode_step(cfg, shape, mesh)
    else:
        raise ValueError(shape.mode)
    if constrain:
        axes, seq_axis = _activation_axes(cfg, shape, mesh)
        if fl and axes is not None:
            # inside the FL step the pod axis is Manual (shard_map) — the
            # residual-stream constraint may only name the auto axes
            axes = tuple(a for a in axes if a != "pod") or None
        bundle.fn = _with_activation_ctx(bundle.fn, axes, seq_axis)
    return bundle


def build_train_step(cfg: ArchConfig, shape: ShapeConfig,
                     mesh: Mesh) -> StepBundle:
    opt = make_optimizer(cfg.optimizer, cfg.learning_rate,
                         weight_decay=cfg.weight_decay,
                         grad_clip=cfg.grad_clip)

    def step(params, opt_state, batch):
        if cfg.grad_reduce_dtype == "bfloat16":
            # differentiate w.r.t. a bf16 view so the weight-gradient
            # all-reduces (data axis + sequence-parallel groups) move half
            # the bytes; the optimizer still applies f32 master updates
            cast_p = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
            (_, metrics), grads = jax.value_and_grad(
                model_lib.train_loss, has_aux=True)(cast_p, cfg, batch)
            grads = jax.tree_util.tree_map(
                lambda g, p: g.astype(p.dtype), grads, params)
        else:
            (_, metrics), grads = jax.value_and_grad(
                model_lib.train_loss, has_aux=True)(params, cfg, batch)
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": metrics["loss"],
                                   "accuracy": metrics["accuracy"]}

    p_shapes = param_shapes(cfg)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    b_shapes = batch_shapes(cfg, shape)

    p_specs = shard_lib.param_specs(p_shapes, mesh)
    o_specs = _opt_specs(cfg, mesh, p_specs, p_shapes, o_shapes)
    b_specs = shard_lib.batch_specs(b_shapes, mesh)
    metric_specs = {"loss": P(), "accuracy": P()}

    return StepBundle(
        name=f"train:{cfg.name}:{shape.name}",
        fn=step,
        args=(p_shapes, o_shapes, b_shapes),
        in_shardings=(p_specs, o_specs, b_specs),
        out_shardings=(p_specs, o_specs, metric_specs),
        donate_argnums=(0, 1),
    )


def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig,
                       mesh: Mesh, two_d_weights: bool = True) -> StepBundle:
    window = decode_window(cfg, shape)

    def step(params, batch):
        return model_lib.prefill(params, cfg, batch,
                                 cache_len=shape.seq_len, window=window)

    p_shapes = param_shapes(cfg)
    b_shapes = batch_shapes(cfg, shape, with_labels=False)
    c_shapes = jax.eval_shape(
        functools.partial(model_lib.init_cache, cfg, shape.global_batch,
                          shape.seq_len, window))
    # prefill cache has index set — same structure as init_cache
    p_specs = shard_lib.param_specs(p_shapes, mesh)
    if two_d_weights:
        p_specs = shard_lib.fully_shard(p_specs, p_shapes, mesh)
    b_specs = shard_lib.batch_specs(b_shapes, mesh)
    c_specs = shard_lib.cache_specs(c_shapes, mesh)
    logits_spec = shard_lib.data_spec(mesh, shape.global_batch, 2)

    return StepBundle(
        name=f"prefill:{cfg.name}:{shape.name}",
        fn=step,
        args=(p_shapes, b_shapes),
        in_shardings=(p_specs, b_specs),
        out_shardings=(logits_spec, c_specs),
    )


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig,
                      mesh: Mesh, two_d_weights: bool = True) -> StepBundle:
    window = decode_window(cfg, shape)

    def step(params, cache, token):
        return model_lib.decode_step(params, cfg, token, cache,
                                     window=window)

    p_shapes = param_shapes(cfg)
    c_shapes = cache_shapes(cfg, shape)
    t_shape = _sds((shape.global_batch, 1), jnp.int32)

    p_specs = shard_lib.param_specs(p_shapes, mesh)
    if two_d_weights:
        p_specs = shard_lib.fully_shard(p_specs, p_shapes, mesh)
    c_specs = shard_lib.cache_specs(c_shapes, mesh)
    t_spec = shard_lib.data_spec(mesh, shape.global_batch, 2)
    logits_spec = shard_lib.data_spec(mesh, shape.global_batch, 2)

    return StepBundle(
        name=f"decode:{cfg.name}:{shape.name}",
        fn=step,
        args=(p_shapes, c_shapes, t_shape),
        in_shardings=(p_specs, c_specs, t_spec),
        out_shardings=(logits_spec, c_specs),
        donate_argnums=(1,),
    )

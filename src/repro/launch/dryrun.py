"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, and emit roofline terms from the compiled artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh both --fl

The FIRST two lines below MUST run before any other import: jax locks the
device count at first init, and the dry-run needs 512 placeholder host
devices to build the 2x16x16 production mesh. (Smoke tests and benchmarks do
NOT set this — they see the real single CPU device.)
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse                                    # noqa: E402
import json                                        # noqa: E402
import time                                        # noqa: E402
import traceback                                   # noqa: E402

import dataclasses                                 # noqa: E402

import jax                                         # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, get_config, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step          # noqa: E402
from repro.models import sharding as shard_lib     # noqa: E402
from repro.roofline.analysis import analyze_compiled, model_flops  # noqa: E402

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports", "dryrun")


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            fl: bool = False, verbose: bool = True,
            constrain: bool = True, bf16_grads: bool = False) -> dict:
    cfg = get_config(arch)
    if bf16_grads:
        cfg = dataclasses.replace(cfg, grad_reduce_dtype="bfloat16")
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    tag = f"{cfg.name}|{shape.name}|{mesh_name}" + ("|fl" if fl else "")

    t0 = time.time()
    bundle = build_step(cfg, shape, mesh, fl=fl, constrain=constrain)
    with mesh:
        jitted = jax.jit(
            bundle.fn,
            in_shardings=shard_lib.named(mesh, bundle.in_shardings),
            out_shardings=shard_lib.named(mesh, bundle.out_shardings),
            donate_argnums=bundle.donate_argnums)
        lowered = jitted.lower(*bundle.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    report = analyze_compiled(tag, compiled, mesh.size,
                              model_flops(cfg, shape),
                              pod_boundary=256 if multi_pod else 0)
    row = report.row()
    row.update({
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "fl": fl, "mode": shape.mode,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "collective_breakdown_gb": {
            k: round(v / 2**30, 4)
            for k, v in report.collective_breakdown.items()},
    })
    if verbose:
        print(f"[ok] {tag:55s} compute={row['compute_ms']:9.3f}ms "
              f"memory={row['memory_ms']:9.3f}ms "
              f"memF={row['memory_fused_ms']:9.3f}ms "
              f"coll={row['collective_ms']:9.3f}ms "
              f"dom={row['dominant']:10s} hbm={row['hbm_gb_per_dev']:7.2f}GB "
              f"useful={row['model_flops_frac']:.3f}", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--fl", action="store_true",
                    help="lower the federated (AE-compressed) round instead "
                         "of the baseline train step (train shapes only)")
    ap.add_argument("--out", default=None, help="write JSONL results here")
    ap.add_argument("--bf16-grads", action="store_true",
                    help="bf16 gradient reductions (§Perf iteration 3)")
    ap.add_argument("--no-constrain", action="store_true",
                    help="disable activation-sharding constraints "
                         "(the pre-optimization §Perf baseline)")
    ap.add_argument("--keep-going", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    rows, failures = [], []
    for arch in archs:
        for shape in shapes:
            if args.fl and SHAPES[shape].mode != "train":
                continue
            for multi_pod in meshes:
                try:
                    rows.append(run_one(
                        arch, shape, multi_pod=multi_pod, fl=args.fl,
                        constrain=not args.no_constrain,
                        bf16_grads=args.bf16_grads))
                except Exception as e:           # noqa: BLE001
                    failures.append((arch, shape, multi_pod, repr(e)))
                    print(f"[FAIL] {arch}|{shape}|"
                          f"{'multi' if multi_pod else 'single'}: {e}",
                          flush=True)
                    if not args.keep_going:
                        traceback.print_exc()
                        raise

    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            for r in rows:
                f.write(json.dumps(r) + "\n")
    print(f"\n{len(rows)} configurations lowered+compiled, "
          f"{len(failures)} failures")
    for f_ in failures:
        print("  FAIL:", f_)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

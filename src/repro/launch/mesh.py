"""Production mesh definitions.

Single pod: 16x16 = 256 chips (v5e pod), axes (data, model).
Multi-pod:  2x16x16 = 512 chips, axes (pod, data, model) — the ``pod`` axis
is the federated-collaborator axis: AE-compressed updates are the only
traffic that crosses it (DESIGN.md §3.1).

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets XLA_FLAGS for 512 host devices before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Degenerate mesh over whatever devices exist (CPU smoke/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants for the roofline model (per chip / per link)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link

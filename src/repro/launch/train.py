"""End-to-end training driver.

Two modes:
* ``--mode train`` — conventional data+model-parallel training of any
  ``--arch`` (reduced or full) on synthetic LM data.
* ``--mode fl`` — federated rounds with chunked-AE-compressed update
  exchange (the paper's technique): on real hardware the pod axis carries
  only latents; on CPU the same step runs on a degenerate (1,1,1) mesh.

Examples (CPU):
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --steps 50 --batch 4 --seq 64
  PYTHONPATH=src python -m repro.launch.train --preset lm100m --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --reduced \
      --mode fl --steps 20
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import save_pytree
from repro.configs import get_config
from repro.configs.base import ArchConfig, ShapeConfig
from repro.data.pipeline import synthetic_lm_batch
from repro.models import init_params, param_count
from repro.models import sharding as shard_lib
from repro.optim.optimizers import make_optimizer

# ~100M-parameter preset for the end-to-end example driver
LM100M = ArchConfig(
    name="lm100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=16384,
    tie_embeddings=True, rope_theta=10000.0, activation="swiglu",
    remat=False, zero1=False, param_dtype="float32",
    compute_dtype="float32")

LM25M = dataclasses.replace(LM100M, name="lm25m", n_layers=8, d_model=384,
                            n_heads=6, n_kv_heads=2, d_ff=1536,
                            vocab_size=8192)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--preset", default=None, choices=["lm100m", "lm25m"])
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="train", choices=["train", "fl"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    if args.preset:
        cfg = LM100M if args.preset == "lm100m" else LM25M
    else:
        cfg = get_config(args.arch or "llama3-8b")
        if args.reduced:
            cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, learning_rate=args.lr)

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"arch={cfg.name} params={param_count(params):,} "
          f"mode={args.mode}", flush=True)

    if args.mode == "fl":
        from repro.core.autoencoder import ChunkedAEConfig, init_chunked_ae
        from repro.core.distributed import build_fl_round_step
        mesh = jax.make_mesh((1, 1, len(jax.devices())),
                             ("pod", "data", "model"))
        shape = ShapeConfig("cli", args.seq, args.batch, "train")
        ae_cfg = ChunkedAEConfig(chunk_size=512, hidden=(128,),
                                 latent_chunk=16)
        bundle = build_fl_round_step(cfg, shape, mesh, ae_cfg)
        ae_params = init_chunked_ae(jax.random.PRNGKey(1), ae_cfg)
        opt = make_optimizer(cfg.optimizer, cfg.learning_rate,
                             weight_decay=cfg.weight_decay,
                             grad_clip=cfg.grad_clip)
        opt_state = opt.init(params)
        with mesh:
            step_fn = jax.jit(
                bundle.fn,
                in_shardings=shard_lib.named(mesh, bundle.in_shardings),
                out_shardings=shard_lib.named(mesh, bundle.out_shardings))
            t0 = time.time()
            for i in range(args.steps):
                batch = synthetic_lm_batch(i, cfg.vocab_size, args.batch,
                                           args.seq)
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     ae_params, batch)
                if i % args.log_every == 0 or i == args.steps - 1:
                    print(f"fl round {i:4d} loss={float(metrics['loss']):.4f} "
                          f"acc={float(metrics['accuracy']):.3f} "
                          f"({(time.time() - t0) / (i + 1):.2f}s/round)",
                          flush=True)
    else:
        from repro.models import train_loss
        opt = make_optimizer(cfg.optimizer, cfg.learning_rate,
                             weight_decay=cfg.weight_decay,
                             grad_clip=cfg.grad_clip)
        opt_state = opt.init(params)

        @jax.jit
        def step_fn(p, s, b):
            (_, metrics), grads = jax.value_and_grad(
                train_loss, has_aux=True)(p, cfg, b)
            p, s = opt.update(p, grads, s)
            return p, s, metrics

        t0 = time.time()
        for i in range(args.steps):
            batch = synthetic_lm_batch(i, cfg.vocab_size, args.batch,
                                       args.seq)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss={float(metrics['loss']):.4f} "
                      f"acc={float(metrics['accuracy']):.3f} "
                      f"({(time.time() - t0) / (i + 1):.2f}s/step)",
                      flush=True)

    if args.checkpoint:
        save_pytree(args.checkpoint, params,
                    metadata={"arch": cfg.name, "steps": args.steps})
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()

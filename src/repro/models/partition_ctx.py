"""Activation-sharding context: step builders install the batch-axes spec
here; model code constrains the residual stream at layer boundaries.

Without explicit constraints GSPMD sometimes replicates the (B, S, D)
residual stream when head counts don't divide the model axis (56 or 40 heads
on 16 shards), turning per-layer partial-sum all-reduces into full-batch
f32 all-reduces — the dominant collective term of the §Perf baselines.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_ACTIVATION_AXES: contextvars.ContextVar[Optional[Tuple]] = \
    contextvars.ContextVar("activation_axes", default=None)
_SEQ_AXIS: contextvars.ContextVar[Optional[str]] = \
    contextvars.ContextVar("seq_axis", default=None)


@contextlib.contextmanager
def activation_sharding(batch_axes: Optional[Tuple],
                        seq_axis: Optional[str] = None):
    """batch_axes: mesh axis names for the batch dim, e.g. ('pod', 'data').
    seq_axis: optional mesh axis for the sequence dim (2D activation
    sharding — sequence parallelism for long-context prefill/train)."""
    t1 = _ACTIVATION_AXES.set(batch_axes)
    t2 = _SEQ_AXIS.set(seq_axis)
    try:
        yield
    finally:
        _ACTIVATION_AXES.reset(t1)
        _SEQ_AXIS.reset(t2)


def constrain_activations(x: jax.Array) -> jax.Array:
    """Constrain a (B, S, D) activation: batch over data axes, optionally
    sequence over the model axis, feature replicated."""
    axes = _ACTIVATION_AXES.get()
    if axes is None:
        return x
    seq = _SEQ_AXIS.get()
    if x.ndim >= 3 and seq is not None and x.shape[1] % 16 == 0:
        spec = P(axes, seq, *([None] * (x.ndim - 2)))
    else:
        spec = P(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)

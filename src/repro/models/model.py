"""Unified model zoo: one functional LM covering all six assigned families.

Public API
----------
``init_params(rng, cfg)``                          → param pytree
``train_loss(params, cfg, batch)``                 → (loss, metrics)
``prefill(params, cfg, batch, cache_len, window)`` → (last_logits, cache)
``decode_step(params, cfg, token, cache, extras)`` → (logits, cache)
``init_cache(cfg, batch, cache_len, window)``      → zeroed cache pytree

Families and their block stacks (every homogeneous stack is a
``jax.lax.scan`` over stacked params, so HLO size is depth-independent):

* dense / vlm : [GQA|MLA attn + MLP] x L         (vlm: patch embeds merged)
* moe         : [GQA attn + MoE]    x L
* ssm         : [Mamba-2 mixer]     x L
* hybrid      : [(RG-LRU, RG-LRU, local-attn) + MLP each] x L/3 (+tail)
* audio       : encoder [bidir attn + MLP] x Le, decoder [self + cross + MLP] x Ld
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (apply_mlp, apply_norm, cast,
                                 cross_entropy_loss, embed_init, init_mlp,
                                 init_norm, pdt)
from repro.models.partition_ctx import constrain_activations

Params = Dict[str, Any]
Cache = Dict[str, Any]


# =====================================================================
# helpers
# =====================================================================
def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal position encoding; positions (B, S) -> (B, S, d)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _group_size(n_tokens: int) -> int:
    """MoE group size: divides n_tokens, ≤1024, prefers ≥16 groups."""
    for gs in range(min(1024, n_tokens), 0, -1):
        if n_tokens % gs == 0 and (n_tokens // gs >= 16 or gs == n_tokens):
            if n_tokens // gs >= 16:
                return gs
    for gs in range(min(1024, n_tokens), 0, -1):
        if n_tokens % gs == 0:
            return gs
    return n_tokens


def _logits(params: Params, h: jax.Array, cfg: ArchConfig) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ cast(head, cfg)


def _maybe_remat(fn, cfg: ArchConfig, train: bool):
    if cfg.remat and train:
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


# =====================================================================
# per-family block init
# =====================================================================
def _init_attn(key, cfg: ArchConfig) -> Params:
    if cfg.attn_type == "mla":
        return attn.init_mla(key, cfg)
    return attn.init_gqa(key, cfg)


def _init_dense_block(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    p = {"ln1": init_norm(cfg), "attn": _init_attn(k1, cfg),
         "ln2": init_norm(cfg)}
    if cfg.family == "moe":
        p["ffn"] = moe_lib.init_moe(k2, cfg)
    else:
        p["ffn"] = init_mlp(k2, cfg)
    return p


def _init_ssm_block(key, cfg: ArchConfig) -> Params:
    return {"ln": init_norm(cfg), "mixer": ssm_lib.init_mamba2(key, cfg)}


def _init_hybrid_sub(key, cfg: ArchConfig, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    mixer = (rglru_lib.init_rglru_block(k1, cfg) if kind == "rglru"
             else attn.init_gqa(k1, cfg))
    return {"ln1": init_norm(cfg), "mixer": mixer,
            "ln2": init_norm(cfg), "mlp": init_mlp(k2, cfg)}


def _init_hybrid_group(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, len(cfg.rglru.pattern))
    return {f"sub{i}": _init_hybrid_sub(ks[i], cfg, kind)
            for i, kind in enumerate(cfg.rglru.pattern)}


def _init_enc_block(key, cfg: ArchConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": init_norm(cfg), "attn": attn.init_gqa(k1, cfg),
            "ln2": init_norm(cfg), "ffn": init_mlp(k2, cfg)}


def _init_dec_block(key, cfg: ArchConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": init_norm(cfg), "self_attn": attn.init_gqa(k1, cfg),
            "ln_x": init_norm(cfg), "cross_attn": attn.init_gqa(k2, cfg),
            "ln2": init_norm(cfg), "ffn": init_mlp(k3, cfg)}


def _stack_init(fn, key, n: int) -> Params:
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(rng: jax.Array, cfg: ArchConfig) -> Params:
    k_embed, k_layers, k_head, k_enc, k_tail = jax.random.split(rng, 5)
    dtype = pdt(cfg)
    params: Params = {
        "embed": embed_init(k_embed, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        # stored (d_model, vocab) so ``h @ lm_head`` needs no transpose
        params["lm_head"] = embed_init(k_head, cfg.padded_vocab,
                                       cfg.d_model, dtype).T
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["layers"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), k_layers, cfg.n_layers)
    elif fam == "ssm":
        params["layers"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), k_layers, cfg.n_layers)
    elif fam == "hybrid":
        plen = len(cfg.rglru.pattern)
        n_groups, n_tail = divmod(cfg.n_layers, plen)
        params["layers"] = _stack_init(
            lambda k: _init_hybrid_group(k, cfg), k_layers, n_groups)
        if n_tail:
            params["tail"] = _stack_init(
                lambda k: _init_hybrid_sub(k, cfg, "rglru"), k_tail, n_tail)
    elif fam == "audio":
        params["enc_layers"] = _stack_init(
            lambda k: _init_enc_block(k, cfg), k_enc,
            cfg.encdec.n_encoder_layers)
        params["enc_norm"] = init_norm(cfg)
        params["layers"] = _stack_init(
            lambda k: _init_dec_block(k, cfg), k_layers, cfg.n_layers)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# =====================================================================
# full-sequence block application (train / prefill)
# =====================================================================
def _attn_full(p, x, cfg, positions, mode="causal", window=None):
    if cfg.attn_type == "mla":
        return attn.mla_forward(p, x, cfg, positions=positions, mode=mode,
                                window=window)
    return attn.gqa_forward(p, x, cfg, positions=positions, mode=mode,
                            window=window)


def _dense_block_full(p, x, cfg, positions, window=None):
    """Returns (x, kv_for_cache, aux)."""
    x = constrain_activations(x)
    a, kv = _attn_full(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                       positions, window=window)
    x = constrain_activations(x + a)
    h = apply_norm(p["ln2"], x, cfg)
    if cfg.family == "moe":
        f, aux = moe_lib.apply_moe(p["ffn"], h, cfg,
                                   _group_size(h.shape[0] * h.shape[1]))
    else:
        f, aux = apply_mlp(p["ffn"], h, cfg), {}
    return x + f, kv, aux


def _hybrid_sub_full(p, x, cfg, positions, kind):
    x = constrain_activations(x)
    h = apply_norm(p["ln1"], x, cfg)
    if kind == "rglru":
        m, state = rglru_lib.rglru_forward(p["mixer"], h, cfg)
    else:
        m, state = attn.gqa_forward(p["mixer"], h, cfg, positions=positions,
                                    mode="window", window=cfg.rglru.window)
    x = x + m
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
    return x, state


def _trunk_full(params: Params, h: jax.Array, cfg: ArchConfig,
                positions: jax.Array, *, train: bool,
                enc_out: Optional[jax.Array] = None,
                window: Optional[int] = None):
    """Run the main stack full-sequence. Returns (h, per-layer cache, aux)."""
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def body(carry, lp):
            x, aux_sum = carry
            x, kv, aux = _dense_block_full(lp, x, cfg, positions,
                                           window=window)
            aux_sum = aux_sum + aux.get("moe_aux", 0.0)
            return (x, aux_sum), kv
        body = _maybe_remat(body, cfg, train)
        (h, aux), kvs = jax.lax.scan(body, (h, jnp.float32(0.0)),
                                     params["layers"])
        return h, kvs, {"moe_aux": aux}

    if fam == "ssm":
        def body(carry, lp):
            x = constrain_activations(carry)
            m, state = ssm_lib.mamba2_forward(
                lp["mixer"], apply_norm(lp["ln"], x, cfg), cfg)
            return x + m, state
        body = _maybe_remat(body, cfg, train)
        h, states = jax.lax.scan(body, h, params["layers"])
        return h, states, {}

    if fam == "hybrid":
        pattern = cfg.rglru.pattern

        def body(carry, gp):
            x = carry
            states = {}
            for i, kind in enumerate(pattern):
                x, st = _hybrid_sub_full(gp[f"sub{i}"], x, cfg, positions,
                                         kind)
                states[f"sub{i}"] = st
            return x, states
        body = _maybe_remat(body, cfg, train)
        h, group_states = jax.lax.scan(body, h, params["layers"])
        tail_states = None
        if "tail" in params:
            def tail_body(carry, lp):
                x = carry
                x, st = _hybrid_sub_full(lp, x, cfg, positions, "rglru")
                return x, st
            tail_body = _maybe_remat(tail_body, cfg, train)
            h, tail_states = jax.lax.scan(tail_body, h, params["tail"])
        return h, {"groups": group_states, "tail": tail_states}, {}

    if fam == "audio":
        def body(carry, lp):
            x = constrain_activations(carry)
            a, kv = attn.gqa_forward(lp["self_attn"],
                                     apply_norm(lp["ln1"], x, cfg), cfg,
                                     positions=positions, mode="causal",
                                     window=window)
            x = x + a
            c, cross_kv = attn.gqa_forward(
                lp["cross_attn"], apply_norm(lp["ln_x"], x, cfg), cfg,
                positions=None, mode="full", kv_x=enc_out, kv_positions=None)
            x = x + c
            x = x + apply_mlp(lp["ffn"], apply_norm(lp["ln2"], x, cfg), cfg)
            return x, {"self": kv, "cross": cross_kv}
        body = _maybe_remat(body, cfg, train)
        h, kvs = jax.lax.scan(body, h, params["layers"])
        return h, kvs, {}

    raise ValueError(fam)


def _encode_audio(params: Params, frames: jax.Array, cfg: ArchConfig,
                  train: bool) -> jax.Array:
    """Whisper encoder over precomputed (stub-frontend) frame embeddings."""
    B, F, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(F), (B, F))
    h = frames.astype(jnp.dtype(cfg.compute_dtype))
    h = h + _sinusoid(pos, cfg.d_model).astype(h.dtype)

    def body(carry, lp):
        x = constrain_activations(carry)
        a, _ = attn.gqa_forward(lp["attn"], apply_norm(lp["ln1"], x, cfg),
                                cfg, positions=None, mode="full")
        x = x + a
        x = x + apply_mlp(lp["ffn"], apply_norm(lp["ln2"], x, cfg), cfg)
        return x, None
    body = _maybe_remat(body, cfg, train)
    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(params["enc_norm"], h, cfg)


def _embed_inputs(params: Params, cfg: ArchConfig, batch: Dict[str, Any],
                  positions: jax.Array, train: bool) -> jax.Array:
    if "h0" in batch:
        # precomputed input embeddings (FL step computes the token gather
        # outside the partial-manual shard_map region — see
        # core/distributed.py)
        return batch["h0"].astype(jnp.dtype(cfg.compute_dtype))
    h = cast(params["embed"], cfg)[batch["tokens"]]
    if cfg.family == "vlm" and "image_embeds" in batch:
        img = batch["image_embeds"].astype(h.dtype)
        h = jax.lax.dynamic_update_slice(h, img, (0, 0, 0))
    if cfg.family == "audio":
        h = h + _sinusoid(positions, cfg.d_model).astype(h.dtype)
    return h


# =====================================================================
# training
# =====================================================================
def train_loss(params: Params, cfg: ArchConfig,
               batch: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
    tokens = batch["tokens"]
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = _embed_inputs(params, cfg, batch, positions, train=True)
    enc_out = None
    if cfg.family == "audio":
        enc_out = _encode_audio(params, batch["frames"], cfg, train=True)
    h, _, aux = _trunk_full(params, h, cfg, positions, train=True,
                            enc_out=enc_out)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = _logits(params, h, cfg)
    loss, acc = cross_entropy_loss(logits, batch["labels"], cfg.vocab_size)
    metrics = {"ce_loss": loss, "accuracy": acc}
    total = loss
    if cfg.family == "moe":
        total = total + aux.get("moe_aux", 0.0)
        metrics["moe_aux"] = aux.get("moe_aux", 0.0)
    metrics["loss"] = total
    return total, metrics


# =====================================================================
# caches
# =====================================================================
def _attn_cache_zeros(cfg: ArchConfig, B: int, C: int, ring: bool) -> Cache:
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.attn_type == "mla":
        m = cfg.mla
        return {"c_kv": jnp.zeros((B, C, m.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((B, C, m.qk_rope_head_dim), dtype)}
    c = {"k": jnp.zeros((B, C, cfg.n_kv_heads, cfg.head_dim), dtype),
         "v": jnp.zeros((B, C, cfg.n_kv_heads, cfg.head_dim), dtype)}
    if ring:
        c["pos"] = jnp.full((B, C), -1, jnp.int32)
    return c


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               window: Optional[int] = None) -> Cache:
    """Zeroed decode cache. ``window`` < cache_len → ring (sliding) caches."""
    fam = cfg.family
    ring = window is not None and window < cache_len
    C = min(cache_len, window) if ring else cache_len

    def stack(fn, n):
        return jax.vmap(lambda _: fn())(jnp.arange(n))

    cache: Cache = {"index": jnp.zeros((), jnp.int32)}
    if fam in ("dense", "moe", "vlm"):
        cache["layers"] = stack(
            lambda: _attn_cache_zeros(cfg, batch, C, ring), cfg.n_layers)
    elif fam == "ssm":
        cache["layers"] = stack(
            lambda: ssm_lib.init_mamba2_state(cfg, batch), cfg.n_layers)
    elif fam == "hybrid":
        plen = len(cfg.rglru.pattern)
        n_groups, n_tail = divmod(cfg.n_layers, plen)
        W = cfg.rglru.window

        def group_zero():
            g = {}
            for i, kind in enumerate(cfg.rglru.pattern):
                if kind == "rglru":
                    g[f"sub{i}"] = rglru_lib.init_rglru_state(cfg, batch)
                else:
                    g[f"sub{i}"] = _attn_cache_zeros(
                        cfg, batch, min(W, cache_len), cache_len > W)
            return g
        cache["layers"] = stack(group_zero, n_groups)
        if n_tail:
            cache["tail"] = stack(
                lambda: rglru_lib.init_rglru_state(cfg, batch), n_tail)
    elif fam == "audio":
        F = cfg.encdec.n_frames
        dtype = jnp.dtype(cfg.compute_dtype)

        def dec_zero():
            return {"self": _attn_cache_zeros(cfg, batch, C, ring),
                    "cross": {"k": jnp.zeros((batch, F, cfg.n_kv_heads,
                                              cfg.head_dim), dtype),
                              "v": jnp.zeros((batch, F, cfg.n_kv_heads,
                                              cfg.head_dim), dtype)}}
        cache["layers"] = stack(dec_zero, cfg.n_layers)
    return cache


def _fill_attn_cache(zero: Cache, kv, cfg: ArchConfig, prefill_len: int):
    """Place prefill K/V (or MLA latents) into a zeroed cache entry."""
    if cfg.attn_type == "mla":
        c_kv, k_rope = kv
        C = zero["c_kv"].shape[1]
        take = min(prefill_len, C)
        out = dict(zero)
        out["c_kv"] = jax.lax.dynamic_update_slice_in_dim(
            zero["c_kv"], c_kv[:, -take:].astype(zero["c_kv"].dtype),
            0, axis=1)
        out["k_rope"] = jax.lax.dynamic_update_slice_in_dim(
            zero["k_rope"], k_rope[:, -take:].astype(zero["k_rope"].dtype),
            0, axis=1)
        return out
    k, v = kv
    C = zero["k"].shape[1]
    out = dict(zero)
    if "pos" in zero:                         # ring: keep the last C tokens
        take = min(prefill_len, C)
        start = (prefill_len - take) % C if prefill_len > C else 0
        ks, vs = k[:, -take:], v[:, -take:]
        # ring layout: slot = pos % C
        slots = (jnp.arange(prefill_len - take, prefill_len)) % C
        order = jnp.argsort(slots)
        out["k"] = jnp.zeros_like(zero["k"]).at[:, slots[order]].set(
            ks[:, order].astype(zero["k"].dtype))
        out["v"] = jnp.zeros_like(zero["v"]).at[:, slots[order]].set(
            vs[:, order].astype(zero["v"].dtype))
        pos = jnp.full(zero["pos"].shape, -1, jnp.int32)
        pos = pos.at[:, slots[order]].set(
            jnp.arange(prefill_len - take, prefill_len, dtype=jnp.int32)
            [order][None, :])
        out["pos"] = pos
        del start
    else:
        take = min(prefill_len, C)
        out["k"] = jax.lax.dynamic_update_slice_in_dim(
            zero["k"], k[:, -take:].astype(zero["k"].dtype), 0, axis=1)
        out["v"] = jax.lax.dynamic_update_slice_in_dim(
            zero["v"], v[:, -take:].astype(zero["v"].dtype), 0, axis=1)
    return out


# =====================================================================
# prefill
# =====================================================================
def prefill(params: Params, cfg: ArchConfig, batch: Dict[str, Any],
            cache_len: Optional[int] = None,
            window: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    """Full-sequence forward that also builds the decode cache.

    Returns (last-position logits (B, V), cache). ``cache_len`` defaults to
    the prompt length (cache exactly full after prefill).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache_len = cache_len or S
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = _embed_inputs(params, cfg, batch, positions, train=False)
    enc_out = None
    if cfg.family == "audio":
        enc_out = _encode_audio(params, batch["frames"], cfg, train=False)
    h, layer_out, _ = _trunk_full(params, h, cfg, positions, train=False,
                                  enc_out=enc_out, window=window)
    h = apply_norm(params["final_norm"], h[:, -1:], cfg)
    logits = _logits(params, h, cfg)[:, 0]

    zero = init_cache(cfg, B, cache_len, window)
    cache: Cache = {"index": jnp.full((), S, jnp.int32)}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        cache["layers"] = jax.vmap(
            lambda z, kv: _fill_attn_cache(z, kv, cfg, S))(
                zero["layers"], layer_out)
    elif fam == "ssm":
        cache["layers"] = layer_out
    elif fam == "hybrid":
        groups = layer_out["groups"]

        def fill_group(z, st):
            out = {}
            for i, kind in enumerate(cfg.rglru.pattern):
                if kind == "rglru":
                    out[f"sub{i}"] = st[f"sub{i}"]
                else:
                    out[f"sub{i}"] = _fill_attn_cache(
                        z[f"sub{i}"], st[f"sub{i}"], cfg, S)
            return out
        cache["layers"] = jax.vmap(fill_group)(zero["layers"], groups)
        if layer_out["tail"] is not None:
            cache["tail"] = layer_out["tail"]
    elif fam == "audio":
        cache["layers"] = jax.vmap(
            lambda z, kv: {"self": _fill_attn_cache(z["self"], kv["self"],
                                                    cfg, S),
                           "cross": {"k": kv["cross"][0].astype(
                               z["cross"]["k"].dtype),
                               "v": kv["cross"][1].astype(
                               z["cross"]["v"].dtype)}})(
            zero["layers"], layer_out)
    return logits, cache


# =====================================================================
# decode
# =====================================================================
def _attn_decode(p, x, cfg, entry, index, window):
    if cfg.attn_type == "mla":
        return attn.mla_decode(p, x, cfg, entry, index)
    return attn.gqa_decode(p, x, cfg, entry, index, window=window)


def decode_step(params: Params, cfg: ArchConfig, token: jax.Array,
                cache: Cache,
                window: Optional[int] = None) -> Tuple[jax.Array, Cache]:
    """One-token decode. token: (B, 1) int32. Returns (logits (B, V), cache)."""
    index = cache["index"]
    B = token.shape[0]
    h = cast(params["embed"], cfg)[token]               # (B, 1, D)
    if cfg.family == "audio":
        pos = jnp.broadcast_to(index, (B, 1))
        h = h + _sinusoid(pos, cfg.d_model).astype(h.dtype)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def body(x, inp):
            lp, entry = inp
            a, new_entry = _attn_decode(lp["attn"],
                                        apply_norm(lp["ln1"], x, cfg), cfg,
                                        entry, index, window)
            x = x + a
            hh = apply_norm(lp["ln2"], x, cfg)
            if cfg.family == "moe":
                f, _ = moe_lib.apply_moe(lp["ffn"], hh, cfg, _group_size(B))
            else:
                f = apply_mlp(lp["ffn"], hh, cfg)
            return x + f, new_entry
        h, new_layers = jax.lax.scan(body, h,
                                     (params["layers"], cache["layers"]))
        new_cache = dict(cache, layers=new_layers)

    elif fam == "ssm":
        def body(x, inp):
            lp, st = inp
            m, new_st = ssm_lib.mamba2_decode(
                lp["mixer"], apply_norm(lp["ln"], x, cfg), cfg, st)
            return x + m, new_st
        h, new_layers = jax.lax.scan(body, h,
                                     (params["layers"], cache["layers"]))
        new_cache = dict(cache, layers=new_layers)

    elif fam == "hybrid":
        def sub_decode(sp, x, st, kind):
            hh = apply_norm(sp["ln1"], x, cfg)
            if kind == "rglru":
                m, new_st = rglru_lib.rglru_decode(sp["mixer"], hh, cfg, st)
            else:
                m, new_st = attn.gqa_decode(sp["mixer"], hh, cfg, st, index,
                                            window=cfg.rglru.window)
            x = x + m
            x = x + apply_mlp(sp["mlp"], apply_norm(sp["ln2"], x, cfg), cfg)
            return x, new_st

        def body(x, inp):
            gp, gst = inp
            new = {}
            for i, kind in enumerate(cfg.rglru.pattern):
                x, new[f"sub{i}"] = sub_decode(gp[f"sub{i}"], x,
                                               gst[f"sub{i}"], kind)
            return x, new
        h, new_groups = jax.lax.scan(body, h,
                                     (params["layers"], cache["layers"]))
        new_cache = dict(cache, layers=new_groups)
        if "tail" in cache:
            def tail_body(x, inp):
                lp, st = inp
                return sub_decode(lp, x, st, "rglru")
            h, new_tail = jax.lax.scan(tail_body, h,
                                       (params["tail"], cache["tail"]))
            new_cache["tail"] = new_tail

    elif fam == "audio":
        def body(x, inp):
            lp, entry = inp
            a, new_self = attn.gqa_decode(lp["self_attn"],
                                          apply_norm(lp["ln1"], x, cfg), cfg,
                                          entry["self"], index, window=window)
            x = x + a
            hh = apply_norm(lp["ln_x"], x, cfg)
            q = (hh @ cast(lp["cross_attn"]["wq"], cfg)).reshape(
                B, 1, cfg.n_heads, cfg.head_dim)
            c = attn.decode_attention(
                q, entry["cross"]["k"], entry["cross"]["v"],
                index=jnp.int32(10 ** 9))          # all frames visible
            c = c.reshape(B, 1, cfg.n_heads * cfg.head_dim)
            x = x + c @ cast(lp["cross_attn"]["wo"], cfg)
            x = x + apply_mlp(lp["ffn"], apply_norm(lp["ln2"], x, cfg), cfg)
            return x, {"self": new_self, "cross": entry["cross"]}
        h, new_layers = jax.lax.scan(body, h,
                                     (params["layers"], cache["layers"]))
        new_cache = dict(cache, layers=new_layers)
    else:
        raise ValueError(fam)

    h = apply_norm(params["final_norm"], h, cfg)
    logits = _logits(params, h, cfg)[:, 0]
    new_cache["index"] = index + 1
    return logits, new_cache


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))

"""Attention family: GQA (full / sliding-window / bidirectional / cross) and
MLA (multi-head latent attention), with flash-style chunked computation for
long sequences and KV-cache support for serving.

Memory design: full-sequence attention never materializes the (Sq, Skv)
matrix. ``flash_attention`` scans over query chunks and, inside, over KV
chunks with an online-softmax (m, l, acc) carry — the standard
FlashAttention-2 recurrence expressed in pure JAX (the TPU-kernel version of
this loop is what a fused Pallas attention kernel would implement; on this
framework the XLA scan already bounds live memory to one (q_chunk × kv_chunk)
tile per step, which is what the dry-run memory analysis needs).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import apply_norm, apply_rope, cast, dense_init, \
    init_norm, masked_softmax, pdt


def _cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


# =====================================================================
# Flash-style chunked attention (training / prefill)
# =====================================================================
def flash_attention(
    q: jax.Array,                 # (B, Sq, H, D)
    k: jax.Array,                 # (B, Skv, KV, D)
    v: jax.Array,                 # (B, Skv, KV, Dv)
    *,
    mode: str = "causal",         # causal | window | full
    q_offset: int = 0,            # absolute position of q[0] among kv
    window: Optional[int] = None,
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    extra_qk: Optional[Tuple[jax.Array, jax.Array]] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """``extra_qk=(q2 (B,Sq,H,P2), k2 (B,Skv,P2))`` adds a second,
    head-shared score term — the decomposed MLA formulation: rope scores are
    computed against the single shared rope key instead of broadcasting it
    into every head's K (saves (B,S,H,rope) bytes of K materialization)."""
    B, Sq, H, D = q.shape
    _, Skv, KV, Dv = v.shape
    G = H // KV
    if scale is None:
        scale = D ** -0.5

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq, nk = _cdiv(Sq, q_chunk), _cdiv(Skv, kv_chunk)
    q_pad, kv_pad = nq * q_chunk - Sq, nk * kv_chunk - Skv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))

    # (nq, B, qc, KV, G, D) query blocks / (nk, B, kc, KV, D) kv blocks
    qb = q.reshape(B, nq, q_chunk, KV, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, kv_chunk, KV, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, kv_chunk, KV, Dv).transpose(1, 0, 2, 3, 4)
    if extra_qk is not None:
        q2, k2 = extra_qk
        P2 = q2.shape[-1]
        if q_pad:
            q2 = jnp.pad(q2, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        if kv_pad:
            k2 = jnp.pad(k2, ((0, 0), (0, kv_pad), (0, 0)))
        q2b = q2.reshape(B, nq, q_chunk, KV, G, P2).transpose(
            1, 0, 2, 3, 4, 5)
        k2b = k2.reshape(B, nk, kv_chunk, P2).transpose(1, 0, 2, 3)
    else:
        q2b = jnp.zeros((nq,), q.dtype)          # placeholder leaves
        k2b = jnp.zeros((nk,), q.dtype)

    def mask_block(qi: jax.Array, kj: jax.Array) -> jax.Array:
        """(qc, kc) bool mask for query block qi vs kv block kj."""
        q_ids = qi * q_chunk + jnp.arange(q_chunk)[:, None] + q_offset
        k_ids = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
        valid = k_ids < Skv                      # kv padding
        if mode == "full":
            return valid
        m = k_ids <= q_ids
        if mode == "window":
            m &= k_ids > q_ids - window
        return m & valid

    def q_block_attend(args):
        qi_idx, q_blk, q2_blk = args              # q_blk: (B, qc, KV, G, D)

        def kv_step(carry, args2):
            m_run, l_run, acc = carry
            kj_idx, k_blk, v_blk, k2_blk = args2
            # scores: (B, KV, G, qc, kc)
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32))
            if extra_qk is not None:
                s = s + jnp.einsum("bqkgp,bsp->bkgqs",
                                   q2_blk.astype(jnp.float32),
                                   k2_blk.astype(jnp.float32))
            s = s * scale
            if softcap > 0.0:
                s = jnp.tanh(s / softcap) * softcap
            msk = mask_block(qi_idx, kj_idx)      # (qc, kc)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p,
                            v_blk.astype(jnp.float32))
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, Dv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), kb, vb, k2b))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        # (B, KV, G, qc, Dv) -> (B, qc, KV, G, Dv)
        return out.transpose(0, 3, 1, 2, 4)

    out = jax.lax.map(q_block_attend, (jnp.arange(nq), qb, q2b))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, Dv)
    return out[:, :Sq].astype(q.dtype)


# =====================================================================
# Single-token decode attention against a (possibly ring) cache
# =====================================================================
def decode_attention(
    q: jax.Array,                  # (B, 1, H, D)
    k_cache: jax.Array,            # (B, S, KV, D)
    v_cache: jax.Array,            # (B, S, KV, Dv)
    *,
    index: jax.Array,              # scalar int32: current absolute position
    positions: Optional[jax.Array] = None,   # (B, S) for ring caches
    window: Optional[int] = None,
    softcap: float = 0.0,
) -> jax.Array:
    B, S, KV, D = k_cache.shape
    H = q.shape[2]
    G = H // KV
    scale = q.shape[-1] ** -0.5
    qg = q.reshape(B, KV, G, q.shape[-1])

    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if positions is None:
        pos = jnp.arange(S)[None, :]                       # (1, S)
    else:
        pos = positions                                    # (B, S)
    mask = (pos <= index) & (pos >= 0)
    if window is not None:
        mask &= pos > index - window
    p = masked_softmax(s, mask[:, None, None, :], softcap)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# =====================================================================
# GQA module
# =====================================================================
def init_gqa(key: jax.Array, cfg: ArchConfig, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    dtype = pdt(cfg)
    q_dim = cfg.n_heads * cfg.head_dim
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    return {
        "wq": dense_init(ks[0], cfg.d_model, q_dim, dtype),
        "wk": dense_init(ks[1], cfg.d_model, kv_dim, dtype),
        "wv": dense_init(ks[2], cfg.d_model, kv_dim, dtype),
        "wo": dense_init(ks[3], q_dim, cfg.d_model, dtype,
                         scale=q_dim ** -0.5),
    }


def gqa_project_kv(p: dict, x: jax.Array, cfg: ArchConfig,
                   positions: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """K/V projection (+rope on K). Used by forward, prefill and cross-attn."""
    B, S, _ = x.shape
    k = (x @ cast(p["wk"], cfg)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = (x @ cast(p["wv"], cfg)).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.rope_theta > 0 and positions is not None:
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_pct)
    return k, v


def gqa_forward(
    p: dict,
    x: jax.Array,                          # (B, S, D)
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,  # (B, S) absolute positions
    mode: str = "causal",
    window: Optional[int] = None,
    kv_x: Optional[jax.Array] = None,       # cross-attention source
    kv_positions: Optional[jax.Array] = None,
    cached_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns (out, (k, v)) so prefill can build the cache and cross-attention
    can reuse projected encoder KV.
    """
    B, S, _ = x.shape
    q = (x @ cast(p["wq"], cfg)).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if cfg.rope_theta > 0 and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_pct)
    if cached_kv is not None:
        k, v = cached_kv
    else:
        src = x if kv_x is None else kv_x
        pos = positions if kv_x is None else kv_positions
        k, v = gqa_project_kv(p, src, cfg, pos)
    out = flash_attention(q, k, v, mode=mode, window=window,
                          softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ cast(p["wo"], cfg), (k, v)


def gqa_decode(
    p: dict,
    x: jax.Array,                          # (B, 1, D)
    cfg: ArchConfig,
    cache: dict,                           # {"k","v"[, "pos"]}
    index: jax.Array,                      # scalar int32 absolute position
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, dict]:
    """One-token decode: write the new KV into the cache (ring buffer when the
    cache is window-sized) and attend over it."""
    B = x.shape[0]
    pos_b = jnp.broadcast_to(index, (B,))
    q = (x @ cast(p["wq"], cfg)).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k_new, v_new = gqa_project_kv(p, x, cfg, pos_b[:, None])
    if cfg.rope_theta > 0:
        q = apply_rope(q, pos_b[:, None], cfg.rope_theta, cfg.rope_pct)

    S = cache["k"].shape[1]
    slot = index % S                                   # ring when S < index
    k_c = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v_c = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    new_cache = dict(cache, k=k_c, v=v_c)
    positions = None
    if "pos" in cache:
        pos_c = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.broadcast_to(index, (B, 1)).astype(jnp.int32),
            slot, axis=1)
        new_cache["pos"] = pos_c
        positions = pos_c
    out = decode_attention(q, k_c, v_c, index=index, positions=positions,
                           window=window, softcap=cfg.attn_logit_softcap)
    out = out.reshape(B, 1, cfg.n_heads * cfg.head_dim)
    return out @ cast(p["wo"], cfg), new_cache


# =====================================================================
# MLA (Multi-head Latent Attention) — MiniCPM3 / DeepSeek-V2 style
# =====================================================================
def init_mla(key: jax.Array, cfg: ArchConfig) -> dict:
    m = cfg.mla
    ks = jax.random.split(key, 6)
    dtype = pdt(cfg)
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": dense_init(ks[0], cfg.d_model, m.q_lora_rank, dtype),
        "q_norm": init_norm(cfg, m.q_lora_rank),
        "w_uq": dense_init(ks[1], m.q_lora_rank, H * qk_dim, dtype),
        # joint down-projection: [c_kv | k_rope]
        "w_dkv": dense_init(ks[2], cfg.d_model,
                            m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": init_norm(cfg, m.kv_lora_rank),
        # up-projections stored (rank, H, dim) for the absorbed decode path
        "w_uk": (dense_init(ks[3], m.kv_lora_rank, H * m.qk_nope_head_dim,
                            dtype).reshape(m.kv_lora_rank, H,
                                           m.qk_nope_head_dim)),
        "w_uv": (dense_init(ks[4], m.kv_lora_rank, H * m.v_head_dim,
                            dtype).reshape(m.kv_lora_rank, H, m.v_head_dim)),
        "wo": dense_init(ks[5], H * m.v_head_dim, cfg.d_model,
                         scale=(H * m.v_head_dim) ** -0.5, dtype=dtype),
    }


def _mla_q(p: dict, x: jax.Array, cfg: ArchConfig,
           positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = apply_norm(p["q_norm"], x @ cast(p["w_dq"], cfg), cfg)
    q = (q_lat @ cast(p["w_uq"], cfg)).reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p: dict, x: jax.Array, cfg: ArchConfig,
                   positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    m = cfg.mla
    dkv = x @ cast(p["w_dkv"], cfg)
    c_kv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm(p["kv_norm"], c_kv, cfg)         # (B, S, r)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)                # (B, S, 1, rope_d)
    return c_kv, k_rope[:, :, 0, :]


def mla_forward(p: dict, x: jax.Array, cfg: ArchConfig, *,
                positions: jax.Array, mode: str = "causal",
                window: Optional[int] = None
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence MLA (train / prefill): expand the latent to per-head K/V
    and run chunked attention. Returns (out, (c_kv, k_rope)) for caching."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, cast(p["w_uk"], cfg))
    v = jnp.einsum("bsr,rhv->bshv", c_kv, cast(p["w_uv"], cfg))
    # NOTE(§Perf, refuted): the decomposed-score formulation
    # (extra_qk=(q_rope, k_rope), no K broadcast) measured 2.3x MORE
    # collective bytes under sequence-sharded GSPMD — the head-shared rope
    # key forces per-q-block regathers. The concat form keeps rope inside
    # the per-head K stream, which shards cleanly. See EXPERIMENTS.md §Perf.
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    out = flash_attention(q, k, v, mode=mode, window=window)
    out = out.reshape(B, S, H * m.v_head_dim)
    return out @ cast(p["wo"], cfg), (c_kv, k_rope)


def mla_decode(p: dict, x: jax.Array, cfg: ArchConfig, cache: dict,
               index: jax.Array) -> Tuple[jax.Array, dict]:
    """Absorbed-matrix MLA decode: attention runs directly in the latent
    space, so the cache is only (B, S, r) + (B, S, rope_d) — the MLA memory
    win — and no per-step K/V expansion of the full history is needed."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    pos_b = jnp.broadcast_to(index, (B,))[:, None]
    q_nope, q_rope = _mla_q(p, x, cfg, pos_b)          # (B,1,H,*)
    c_new, kr_new = _mla_kv_latent(p, x, cfg, pos_b)   # (B,1,r), (B,1,rope)

    S = cache["c_kv"].shape[1]
    slot = index % S
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), slot, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), slot, axis=1)
    new_cache = dict(cache, c_kv=c_kv, k_rope=k_rope)

    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    # absorb W_uk into q: (B,1,H,r)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, cast(p["w_uk"], cfg))
    s = (jnp.einsum("bqhr,bsr->bhqs", q_abs.astype(jnp.float32),
                    c_kv.astype(jnp.float32))
         + jnp.einsum("bqhp,bsp->bhqs", q_rope.astype(jnp.float32),
                      k_rope.astype(jnp.float32))) * scale
    mask = (jnp.arange(S) <= index)[None, None, None, :]
    probs = masked_softmax(s, mask)
    ctx = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv.astype(jnp.float32))
    out = jnp.einsum("bqhr,rhv->bqhv", ctx.astype(x.dtype),
                     cast(p["w_uv"], cfg))
    out = out.reshape(B, 1, H * m.v_head_dim)
    return out @ cast(p["wo"], cfg), new_cache

"""Mamba-2 SSD mixer (state-space duality, arXiv:2405.21060).

Training/prefill use the chunked dual form: within-chunk computation is the
quadratic "attention-like" branch (MXU-friendly (chunk x chunk) matmuls) and
across chunks a linear recurrence over per-chunk states — i.e. the SSD
algorithm of the paper, expressed with einsums + ``lax.scan`` so XLA sees a
short recurrence over S/chunk steps instead of S sequential steps.

Decode keeps O(1) state per layer: a depthwise-conv tail of the last
(conv_width-1) inputs and the (H, P, N) SSM state — this is why mamba2 runs
the ``long_500k`` shape natively.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import cast, dense_init, init_norm, apply_norm, pdt


def _segsum(x: jax.Array) -> jax.Array:
    """x: (..., T) -> (..., T, T) with S[i, j] = sum_{k=j+1..i} x_k for
    i >= j and -inf elsewhere (log-space decay between positions)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_scan(
    x: jax.Array,        # (B, S, H, P) — pre-scaled by dt
    dA: jax.Array,       # (B, S, H)    — dt * A (negative)
    Bm: jax.Array,       # (B, S, G, N)
    Cm: jax.Array,       # (B, S, G, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,   # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    hg = h // g
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S = s + pad
    nc = S // chunk

    f32 = jnp.float32
    # reshape heads into (group, heads-per-group) and sequence into chunks
    xc = x.reshape(b, nc, chunk, g, hg, p).astype(f32)
    dAc = dA.reshape(b, nc, chunk, g, hg).transpose(0, 3, 4, 1, 2)  # b,g,hg,c,i
    Bc = Bm.reshape(b, nc, chunk, g, n).astype(f32)
    Cc = Cm.reshape(b, nc, chunk, g, n).astype(f32)

    dA_cumsum = jnp.cumsum(dAc, axis=-1)                   # (b,g,hg,c,i)

    # --- intra-chunk (quadratic, "attention-like") branch
    L = jnp.exp(_segsum(dAc))                              # (b,g,hg,c,i,j)
    CB = jnp.einsum("bcign,bcjgn->bcgij", Cc, Bc)          # (b,c,g,i,j)
    y_diag = jnp.einsum("bcgij,bghcij,bcjghp->bcighp", CB, L, xc)

    # --- per-chunk input states
    decay_states = jnp.exp(dA_cumsum[..., -1:] - dA_cumsum)   # (b,g,hg,c,j)
    states = jnp.einsum("bcjgn,bghcj,bcjghp->bcghpn", Bc, decay_states, xc)

    # --- inter-chunk linear recurrence over chunk states
    chunk_decay = jnp.exp(dA_cumsum[..., -1])              # (b,g,hg,c)
    if init_state is None:
        s0 = jnp.zeros((b, g, hg, p, n), f32)
    else:
        s0 = init_state.reshape(b, g, hg, p, n).astype(f32)

    def step(carry, inp):
        st_c, dec_c = inp                                  # (b,g,hg,p,n), (b,g,hg)
        prev = carry
        new = prev * dec_c[..., None, None] + st_c
        return new, prev

    states_t = states.transpose(1, 0, 2, 3, 4, 5)          # (c,b,g,hg,p,n)
    decay_t = chunk_decay.transpose(3, 0, 1, 2)            # (c,b,g,hg)
    final_state, prev_states = jax.lax.scan(step, s0, (states_t, decay_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)  # (b,c,g,hg,p,n)

    # --- inter-chunk output contribution
    state_decay_out = jnp.exp(dA_cumsum)                   # (b,g,hg,c,i)
    y_off = jnp.einsum("bcign,bcghpn,bghci->bcighp",
                       Cc, prev_states, state_decay_out)

    y = (y_diag + y_off).reshape(b, S, h, p)[:, :s]
    return y.astype(x.dtype), final_state.reshape(b, h, p, n)


# =====================================================================
# Mamba-2 block
# =====================================================================
def _dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    conv_channels = d_inner + 2 * ssm.n_groups * ssm.d_state
    return ssm, d_inner, n_heads, conv_channels


def init_mamba2(key: jax.Array, cfg: ArchConfig) -> dict:
    ssm, d_inner, n_heads, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    dtype = pdt(cfg)
    return {
        # joint projection to [z | xBC | dt]
        "w_in": dense_init(ks[0], cfg.d_model,
                           d_inner + conv_ch + n_heads, dtype),
        "conv_w": (jax.random.normal(ks[1], (ssm.conv_width, conv_ch),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads,
                                      dtype=jnp.float32)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "gate_norm": init_norm(cfg, d_inner),
        "w_out": dense_init(ks[2], d_inner, cfg.d_model, dtype,
                            scale=d_inner ** -0.5),
    }


def _split_in(p: dict, x: jax.Array, cfg: ArchConfig):
    ssm, d_inner, n_heads, conv_ch = _dims(cfg)
    h = x @ cast(p["w_in"], cfg)
    z, xbc, dt = jnp.split(h, [d_inner, d_inner + conv_ch], axis=-1)
    return z, xbc, dt


def _conv_full(p: dict, xbc: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Causal depthwise conv over the sequence (train / prefill)."""
    w = cast(p["conv_w"], cfg)                      # (W, C)
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(W))
    return jax.nn.silu(out + cast(p["conv_b"], cfg))


def mamba2_forward(p: dict, x: jax.Array, cfg: ArchConfig,
                   init_state: Optional[dict] = None
                   ) -> Tuple[jax.Array, dict]:
    """Full-sequence mixer. Returns (out, final_state dict)."""
    ssm, d_inner, n_heads, conv_ch = _dims(cfg)
    B, S, _ = x.shape
    z, xbc, dt = _split_in(p, x, cfg)
    xbc = _conv_full(p, xbc, cfg)
    xs, Bm, Cm = jnp.split(
        xbc, [d_inner, d_inner + ssm.n_groups * ssm.d_state], axis=-1)
    xs = xs.reshape(B, S, n_heads, ssm.head_dim)
    Bm = Bm.reshape(B, S, ssm.n_groups, ssm.d_state)
    Cm = Cm.reshape(B, S, ssm.n_groups, ssm.d_state)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,)
    y, final = ssd_scan(xs * dt[..., None], dt * A, Bm, Cm,
                        ssm.chunk_size,
                        None if init_state is None else init_state["ssm"])
    y = y + xs * p["D"][None, None, :, None].astype(y.dtype)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = apply_norm(p["gate_norm"], y * jax.nn.silu(z), cfg)
    out = y @ cast(p["w_out"], cfg)

    # decode-ready state: last (conv_width-1) pre-activation conv inputs
    z2, xbc_raw, _ = _split_in(p, x[:, -(ssm.conv_width - 1):], cfg)
    state = {"conv": xbc_raw.astype(jnp.float32), "ssm": final}
    return out, state


def mamba2_decode(p: dict, x: jax.Array, cfg: ArchConfig,
                  state: dict) -> Tuple[jax.Array, dict]:
    """One-token step. state: {"conv": (B, W-1, C), "ssm": (B, H, P, N)}."""
    ssm, d_inner, n_heads, conv_ch = _dims(cfg)
    B = x.shape[0]
    z, xbc_new, dt = _split_in(p, x, cfg)                # (B,1,*)
    window = jnp.concatenate(
        [state["conv"], xbc_new.astype(jnp.float32)], axis=1)  # (B, W, C)
    w = p["conv_w"].astype(jnp.float32)                  # (W, C)
    conv = jnp.einsum("bwc,wc->bc", window, w) + p["conv_b"].astype(jnp.float32)
    xbc = jax.nn.silu(conv)[:, None, :].astype(x.dtype)  # (B,1,C)

    xs, Bm, Cm = jnp.split(
        xbc[:, 0], [d_inner, d_inner + ssm.n_groups * ssm.d_state], axis=-1)
    xs = xs.reshape(B, n_heads, ssm.head_dim)            # (B,H,P)
    Bm = Bm.reshape(B, ssm.n_groups, ssm.d_state)
    Cm = Cm.reshape(B, ssm.n_groups, ssm.d_state)
    hg = n_heads // ssm.n_groups

    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt1 * A)                             # (B,H)
    h_prev = state["ssm"].astype(jnp.float32)            # (B,H,P,N)
    xbar = (xs.astype(jnp.float32) * dt1[..., None])     # (B,H,P)
    Bh = jnp.repeat(Bm, hg, axis=1)                      # (B,H,N)
    Ch = jnp.repeat(Cm, hg, axis=1)
    h_new = h_prev * decay[..., None, None] + xbar[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch)
    y = y + xs.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    y = apply_norm(p["gate_norm"], y * jax.nn.silu(z), cfg)
    out = y @ cast(p["w_out"], cfg)
    new_state = {"conv": window[:, 1:], "ssm": h_new}
    return out, new_state


def init_mamba2_state(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    ssm, d_inner, n_heads, conv_ch = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, ssm.conv_width - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros((batch, n_heads, ssm.head_dim, ssm.d_state),
                         jnp.float32),
    }

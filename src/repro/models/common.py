"""Shared building blocks for the model zoo.

Everything is functional: params are nested dicts of jnp arrays, modules are
(init, apply) pairs of pure functions parameterized by ``ArchConfig``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


# --------------------------------------------------------------------- dtype
def dt(cfg: ArchConfig) -> jnp.dtype:
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg: ArchConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def cast(x: jax.Array, cfg: ArchConfig) -> jax.Array:
    return x.astype(dt(cfg))


# ---------------------------------------------------------------------- init
def dense_init(key: jax.Array, d_in: int, d_out: int, dtype,
               scale: Optional[float] = None) -> jax.Array:
    """Truncated-normal fan-in init (matches common LLM practice)."""
    if scale is None:
        scale = d_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out),
                                        jnp.float32) * scale).astype(dtype)


def embed_init(key: jax.Array, vocab: int, d_model: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d_model), jnp.float32)
            * 0.02).astype(dtype)


# --------------------------------------------------------------------- norms
def init_norm(cfg: ArchConfig, width: Optional[int] = None) -> dict:
    width = width or cfg.d_model
    p = {"scale": jnp.ones((width,), pdt(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((width,), pdt(cfg))
    return p


def apply_norm(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    orig_dtype = x.dtype
    x = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        x = x - jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + cfg.norm_eps)
    x = x * p["scale"].astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        x = x + p["bias"].astype(jnp.float32)
    return x.astype(orig_dtype)


# --------------------------------------------------------------- activations
def activation_fn(name: str):
    return {
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "silu": jax.nn.silu,
        "tanh": jnp.tanh,
        "linear": lambda x: x,
        "sigmoid": jax.nn.sigmoid,
    }[name]


# ---------------------------------------------------------------------- rope
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embedding over ``head_dim`` dims."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)                       # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               rope_pct: float = 1.0) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, D); positions: broadcastable to (..., S). ``rope_pct``
    rotates only the first ``pct`` of dims (StableLM-2 partial rotary).
    """
    d = x.shape[-1]
    rot_d = int(d * rope_pct)
    rot_d -= rot_d % 2
    if rot_d == 0:
        return x
    x_rot, x_pass = x[..., :rot_d], x[..., rot_d:]
    inv_freq = rope_frequencies(rot_d, theta)              # (rot_d//2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * inv_freq
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                              axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# ----------------------------------------------------------------------- mlp
def init_mlp(key: jax.Array, cfg: ArchConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    dtype = pdt(cfg)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, cfg.d_model, d_ff, dtype),
            "w_up": dense_init(k2, cfg.d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, cfg.d_model, dtype,
                                 scale=d_ff ** -0.5),
        }
    return {
        "w_up": dense_init(k1, cfg.d_model, d_ff, dtype),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": dense_init(k2, d_ff, cfg.d_model, dtype,
                             scale=d_ff ** -0.5),
        "b_down": jnp.zeros((cfg.d_model,), dtype),
    }


def apply_mlp(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.activation in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.activation == "swiglu" else jax.nn.gelu
        gate = act(x @ cast(p["w_gate"], cfg))
        return (gate * (x @ cast(p["w_up"], cfg))) @ cast(p["w_down"], cfg)
    act = activation_fn("gelu" if cfg.activation == "gelu" else "relu")
    h = act(x @ cast(p["w_up"], cfg) + cast(p["b_up"], cfg))
    return h @ cast(p["w_down"], cfg) + cast(p["b_down"], cfg)


# ------------------------------------------------------------------- softmax
def masked_softmax(scores: jax.Array, mask: Optional[jax.Array],
                   softcap: float = 0.0) -> jax.Array:
    """Softmax in f32 with an additive bool mask (True = attend)."""
    scores = scores.astype(jnp.float32)
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask, scores, -1e30)
    return jax.nn.softmax(scores, axis=-1)


def causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """(q_len, kv_len) bool mask; query i attends kv j iff j <= i + offset."""
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return kj <= qi


def window_mask(q_len: int, kv_len: int, q_offset, window: int) -> jax.Array:
    qi = jnp.arange(q_len)[:, None] + q_offset
    kj = jnp.arange(kv_len)[None, :]
    return (kj <= qi) & (kj > qi - window)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       vocab_size: int) -> Tuple[jax.Array, jax.Array]:
    """Token-level CE with padded-vocab masking. Returns (loss, accuracy)."""
    logits = logits.astype(jnp.float32)
    padded = logits.shape[-1]
    if padded > vocab_size:
        pad_mask = jnp.arange(padded) >= vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    logp = jax.nn.log_softmax(logits, axis=-1)
    # one-hot reduction instead of take_along_axis: fuses to
    # iota/compare/select on TPU and avoids a gather along the
    # vocab-sharded dim (which the SPMD partitioner handles poorly inside
    # partial-manual shard_map regions).
    onehot = (jnp.arange(padded)[None, None, :] == labels[..., None])
    ll = jnp.sum(jnp.where(onehot, logp, 0.0), axis=-1)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return -jnp.mean(ll), acc

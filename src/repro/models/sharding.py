"""GSPMD sharding rules: param / batch / cache PartitionSpec pytrees.

Rules are name-based over the functional param tree and divisibility-guarded:
a dim is sharded over the ``model`` axis only when its size divides evenly;
otherwise it stays replicated and XLA's SPMD propagation decides activation
layouts. Optimizer state can additionally be sharded over the ``data`` axis
(ZeRO-1) via :func:`zero1_spec`.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# weights sharded on their output (last) dim over `model`
_OUT_SHARDED = {
    "wq", "wk", "wv", "w_uq", "w_dkv", "w_gate", "w_up", "w_in",
    "w_x", "w_a", "w_i", "w_dq",
}
# weights sharded on their input (second-to-last) dim over `model`
_IN_SHARDED = {"wo", "w_down", "w_out"}
# MLA up-projections (rank, H, head_dim): shard the latent rank
_RANK_SHARDED = {"w_uk", "w_uv"}
_REPLICATED = {"router", "b_a", "b_i", "lambda", "A_log", "dt_bias", "D",
               "scale", "bias", "conv_b", "dt_bias", "b_up", "b_down"}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            names.append(str(e.key))
        else:
            names.append(str(e))
    return tuple(names)


def _divides(n: int, mesh: Mesh, axis: str) -> bool:
    return n % mesh.shape[axis] == 0


def _spec_for(names: Tuple[str, ...], shape: Tuple[int, ...],
              mesh: Mesh) -> P:
    name = names[-1]
    nd = len(shape)
    spec: list = [None] * nd

    def shard(dim: int):
        if _divides(shape[dim], mesh, "model"):
            spec[dim] = "model"

    if name in _REPLICATED or nd == 0 or nd == 1:
        pass
    elif name == "embed":
        shard(0)                                   # (V, D) vocab-sharded
    elif name == "lm_head":
        shard(nd - 1)                              # (D, V)
    elif name in _RANK_SHARDED:
        shard(nd - 3) if nd >= 3 else None
    elif name == "conv_w":
        shard(nd - 1)                              # (W, C) channel-sharded
    elif name in ("w_gate", "w_up", "w_down") and nd >= 4:
        # stacked MoE experts (L, E, D, F) → expert-parallel
        shard(nd - 3)
    elif name in _OUT_SHARDED:
        shard(nd - 1)
    elif name in _IN_SHARDED:
        shard(nd - 2)
    return P(*spec)


def param_specs(param_shapes: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching a param (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_names(path), leaf.shape, mesh),
        param_shapes)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_spec(mesh: Mesh, global_batch: int, ndim: int) -> P:
    """Shard the leading batch dim over (pod, data) when divisible."""
    axes = batch_axes(mesh)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    if global_batch % total != 0:
        return P(*([None] * ndim))
    return P(axes, *([None] * (ndim - 1)))


def batch_specs(batch_shapes: Any, mesh: Mesh) -> Any:
    def spec(leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        return data_spec(mesh, b, leaf.ndim)
    return jax.tree_util.tree_map(spec, batch_shapes)


def cache_specs(cache_shapes: Any, mesh: Mesh) -> Any:
    """Decode caches: leaves are (L, B, ...) stacked per layer (batch dim 1)
    or scalars ('index')."""
    def spec(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0 or names[-1] == "index":
            return P()
        batch_dim = 1 if names[0] in ("layers", "tail") else 0
        if leaf.ndim <= batch_dim:
            return P(*([None] * leaf.ndim))
        b = leaf.shape[batch_dim]
        inner = data_spec(mesh, b, leaf.ndim - batch_dim)
        return P(*([None] * batch_dim), *inner)
    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def fully_shard(spec_tree: Any, shape_tree: Any, mesh: Mesh,
                min_size: int = 1 << 20) -> Any:
    """Inference-mode 2D weight sharding: additionally shard one unsharded
    dim of every large leaf over the ``data`` axis (serving has no gradient
    sync, so the data axis is free capacity — this is how a 773B-param MoE
    fits a 16GB/chip pod at decode time)."""
    def upd(spec, shp):
        if any(d for d in spec if d is not None):
            size = 1
            for d in shp.shape:
                size *= d
            if size >= min_size:
                return zero1_spec(spec, shp.shape, mesh)
        return spec
    return jax.tree_util.tree_map(
        upd, spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def zero1_spec(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Add `data`-axis sharding to one unsharded dim (optimizer moments)."""
    if "data" not in mesh.shape:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (p, n) in enumerate(zip(parts, shape)):
        if p is None and n % mesh.shape["data"] == 0 and n > 1:
            parts[i] = "data"
            return P(*parts)
    return spec


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))

"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence: h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t), with
a_t = exp(-c * softplus(Lambda) * r_t), r/i input-dependent sigmoid gates.
Training/prefill evaluate it with ``jax.lax.associative_scan`` (log-depth on
TPU); decode is a single fused elementwise step over O(width) state.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import cast, dense_init, pdt

_C = 8.0   # Griffin's fixed recurrence-sharpness constant


def init_rglru_block(key: jax.Array, cfg: ArchConfig) -> dict:
    rg = cfg.rglru
    W = rg.lru_width
    ks = jax.random.split(key, 7)
    dtype = pdt(cfg)
    # Lambda init so that a^c spans ~(0.9, 0.999) as in the paper
    u = jax.random.uniform(ks[5], (W,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))   # softplus^-1(-log u / c)
    return {
        "w_x": dense_init(ks[0], cfg.d_model, W, dtype),
        "w_gate": dense_init(ks[1], cfg.d_model, W, dtype),
        "conv_w": (jax.random.normal(ks[2], (rg.conv_width, W), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((W,), dtype),
        "w_a": dense_init(ks[3], W, W, dtype),
        "b_a": jnp.zeros((W,), jnp.float32),
        "w_i": dense_init(ks[4], W, W, dtype),
        "b_i": jnp.zeros((W,), jnp.float32),
        "lambda": lam,
        "w_out": dense_init(ks[6], W, cfg.d_model, dtype, scale=W ** -0.5),
    }


def _gates(p: dict, xs: jax.Array, cfg: ArchConfig):
    """a_t (decay) and scaled input gate, in float32."""
    r = jax.nn.sigmoid(xs @ cast(p["w_a"], cfg) + p["b_a"].astype(xs.dtype))
    i = jax.nn.sigmoid(xs @ cast(p["w_i"], cfg) + p["b_i"].astype(xs.dtype))
    log_a = (-_C * jax.nn.softplus(p["lambda"])
             * r.astype(jnp.float32))                     # (B,S,W)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * i.astype(jnp.float32) * xs.astype(jnp.float32)
    return a, b, log_a


def _conv_full(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    w = cast(p["conv_w"], cfg)
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(W)) \
        + cast(p["conv_b"], cfg)


def rglru_forward(p: dict, x: jax.Array, cfg: ArchConfig,
                  init_state: Optional[dict] = None
                  ) -> Tuple[jax.Array, dict]:
    """Full-sequence recurrent block. Returns (out, decode-ready state)."""
    B, S, _ = x.shape
    xs_raw = x @ cast(p["w_x"], cfg)                      # (B,S,W)
    gate = x @ cast(p["w_gate"], cfg)
    xs = _conv_full(p, xs_raw, cfg)
    a, b, log_a = _gates(p, xs, cfg)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if init_state is not None and "h" in init_state:
        # fold a prior hidden state in: h_t += (prod_{<=t} a) * h0
        h = h + a_sc * init_state["h"].astype(jnp.float32)[:, None, :]
    y = h.astype(x.dtype) * jax.nn.gelu(gate)
    out = y @ cast(p["w_out"], cfg)
    state = {"conv": xs_raw[:, -(cfg.rglru.conv_width - 1):].astype(jnp.float32),
             "h": h[:, -1].astype(jnp.float32)}
    return out, state


def rglru_decode(p: dict, x: jax.Array, cfg: ArchConfig,
                 state: dict) -> Tuple[jax.Array, dict]:
    """One-token step. state: {"conv": (B, W-1, width), "h": (B, width)}."""
    xs_raw = x @ cast(p["w_x"], cfg)                      # (B,1,W)
    gate = x @ cast(p["w_gate"], cfg)
    window = jnp.concatenate([state["conv"],
                              xs_raw.astype(jnp.float32)], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    xs = (jnp.einsum("bwc,wc->bc", window, w)
          + p["conv_b"].astype(jnp.float32))[:, None, :]  # (B,1,W)
    a, b, _ = _gates(p, xs.astype(x.dtype), cfg)
    h = a[:, 0] * state["h"].astype(jnp.float32) + b[:, 0]
    y = h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate)
    out = y @ cast(p["w_out"], cfg)
    return out, {"conv": window[:, 1:], "h": h}


def init_rglru_state(cfg: ArchConfig, batch: int) -> dict:
    rg = cfg.rglru
    return {"conv": jnp.zeros((batch, rg.conv_width - 1, rg.lru_width),
                              jnp.float32),
            "h": jnp.zeros((batch, rg.lru_width), jnp.float32)}

"""Mixture-of-Experts layer: top-k router with group-wise capacity dispatch.

Follows the production einsum-dispatch formulation (Switch/GShard/MaxText):
tokens are reshaped into groups, each group routes its tokens into per-expert
capacity slots via cumulative-sum position assignment, and expert computation
is a single batched einsum over (expert, capacity) blocks. Under GSPMD with
the expert axis sharded over the ``model`` mesh axis this lowers to
expert-parallel all-to-alls — exactly the communication pattern the roofline
§collective term tracks for the MoE architectures.

Capacity math: slots-per-expert C = group_size * capacity_factor * top_k /
n_experts; tokens overflowing an expert's capacity within a group are dropped
(their combine weight is zero) — the standard lossy-dispatch trade-off.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import cast, dense_init, init_mlp, apply_mlp, pdt


def _capacity(group_size: int, cfg: ArchConfig) -> int:
    moe = cfg.moe
    c = int(group_size * moe.capacity_factor * moe.top_k / moe.n_experts)
    c = max(c, moe.top_k)
    return min(c, group_size)


def init_moe(key: jax.Array, cfg: ArchConfig) -> dict:
    moe = cfg.moe
    ks = jax.random.split(key, 5)
    dtype = pdt(cfg)
    E, D, F = moe.n_experts, cfg.d_model, moe.d_ff_expert

    def expert_stack(k, d_in, d_out, scale=None):
        kk = jax.random.split(k, E)
        return jax.vmap(
            lambda ki: dense_init(ki, d_in, d_out, dtype, scale))(kk)

    p = {
        "router": dense_init(ks[0], D, E, jnp.float32, scale=D ** -0.5),
        "w_gate": expert_stack(ks[1], D, F),                   # (E, D, F)
        "w_up": expert_stack(ks[2], D, F),                     # (E, D, F)
        "w_down": expert_stack(ks[3], F, D, scale=F ** -0.5),  # (E, F, D)
    }
    if moe.shared_expert:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=moe.d_ff_expert)
    return p


def route(router_logits: jax.Array, cfg: ArchConfig, capacity: int
          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Group-wise top-k routing with capacity assignment.

    router_logits: (G, S, E). Returns (dispatch (G,S,E,C) bool-ish f32,
    combine (G,S,E,C) f32, aux_losses (load_balance, router_z)).
    """
    moe = cfg.moe
    G, S, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)

    # aux losses (Switch-style load balance + z-loss)
    density = jnp.mean(probs, axis=1)                         # (G, E)
    top1 = jax.nn.one_hot(jnp.argmax(probs, -1), E)
    frac = jnp.mean(top1, axis=1)                             # (G, E)
    lb_loss = E * jnp.mean(jnp.sum(frac * density, axis=-1))
    z_loss = jnp.mean(jax.scipy.special.logsumexp(
        router_logits.astype(jnp.float32), axis=-1) ** 2)

    # iterative top-k: mask out chosen experts each round
    dispatch = jnp.zeros((G, S, E, capacity), jnp.float32)
    combine = jnp.zeros((G, S, E, capacity), jnp.float32)
    masked = probs
    # running per-expert slot counter across the k rounds
    fill = jnp.zeros((G, E), jnp.int32)
    for _ in range(moe.top_k):
        idx = jnp.argmax(masked, axis=-1)                     # (G, S)
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.int32)      # (G, S, E)
        # one-hot reduction instead of take_along_axis (gather-free: the
        # SPMD partitioner mishandles gathers in manual subgroups)
        gate = jnp.sum(masked * onehot.astype(masked.dtype), axis=-1)
        # position of each token within its expert's slots for this round
        pos_in_expert = (jnp.cumsum(onehot, axis=1) - onehot) + fill[:, None]
        pos = jnp.sum(onehot * pos_in_expert, axis=-1)        # (G, S)
        keep = pos < capacity
        slot = jax.nn.one_hot(jnp.where(keep, pos, capacity), capacity,
                              dtype=jnp.float32)              # (G, S, C)
        d = onehot.astype(jnp.float32)[..., None] * slot[:, :, None, :]
        dispatch = dispatch + d
        combine = combine + d * gate[..., None, None]
        fill = fill + jnp.sum(onehot * keep[..., None].astype(jnp.int32),
                              axis=1)
        masked = masked * (1.0 - onehot.astype(masked.dtype))
    return dispatch, combine, (lb_loss, z_loss)


def apply_moe(p: dict, x: jax.Array, cfg: ArchConfig,
              group_size: int = 1024) -> Tuple[jax.Array, dict]:
    """x: (B, S, D) -> (out (B, S, D), aux-loss metrics)."""
    moe = cfg.moe
    B, S, D = x.shape
    tokens = x.reshape(B * S, D)
    n = tokens.shape[0]
    gs = min(group_size, n)
    G = n // gs
    assert G * gs == n, f"tokens {n} not divisible by group {gs}"
    xg = tokens.reshape(G, gs, D)
    capacity = _capacity(gs, cfg)

    logits = xg @ cast(p["router"], cfg).astype(xg.dtype)     # (G, S, E)
    dispatch, combine, (lb, zl) = route(
        logits.astype(jnp.float32), cfg, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    # dispatch tokens into (G, E, C, D) expert blocks
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    # expert FFN (swiglu), expert dim contracted against stacked weights
    gate = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, cast(p["w_gate"], cfg)))
    up = jnp.einsum("gecd,edf->gecf", xe, cast(p["w_up"], cfg))
    ye = jnp.einsum("gecf,efd->gecd", gate * up, cast(p["w_down"], cfg))
    # combine back to token order
    y = jnp.einsum("gsec,gecd->gsd", combine, ye)
    y = y.reshape(B, S, D)

    if moe.shared_expert:
        y = y + apply_mlp(p["shared"], x, cfg)

    metrics = {"moe_lb_loss": lb, "moe_z_loss": zl,
               "moe_aux": moe.load_balance_loss * lb + moe.router_z_loss * zl}
    return y, metrics

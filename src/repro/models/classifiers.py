"""The paper's collaborator models (§4.1) in pure JAX.

MNIST-MLP: 784→20→10, exactly 15,910 parameters (paper §5.1).
CIFAR-CNN: 4 conv layers + 3 dense, ≈550,586 parameters (paper: 550,570).

These are the models whose *weight updates* the autoencoder compresses; they
are deliberately small and Keras-like to match the paper's experimental setup.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper import ClassifierConfig

Params = Dict[str, Any]


def _dense(key, d_in, d_out):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (d_in ** -0.5)
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def _conv(key, c_in, c_out, k):
    fan_in = c_in * k * k
    w = jax.random.normal(key, (k, k, c_in, c_out),
                          jnp.float32) * (fan_in ** -0.5)
    return {"w": w, "b": jnp.zeros((c_out,), jnp.float32)}


def init_classifier(rng: jax.Array, cfg: ClassifierConfig) -> Params:
    if cfg.kind == "mlp":
        dims = [cfg.input_shape[0], *cfg.hidden, cfg.n_classes]
        keys = jax.random.split(rng, len(dims) - 1)
        return {f"dense{i}": _dense(k, dims[i], dims[i + 1])
                for i, k in enumerate(keys)}
    # cnn: conv stack (maxpool every 2 convs) then dense head
    keys = jax.random.split(rng, len(cfg.conv_channels)
                            + len(cfg.dense_hidden) + 1)
    params: Params = {}
    c_in = cfg.input_shape[-1]
    for i, c_out in enumerate(cfg.conv_channels):
        params[f"conv{i}"] = _conv(keys[i], c_in, c_out, cfg.conv_kernel)
        c_in = c_out
    flat_dim = _cnn_flat_dim(cfg)
    dims = [flat_dim, *cfg.dense_hidden, cfg.n_classes]
    for i in range(len(dims) - 1):
        params[f"dense{i}"] = _dense(keys[len(cfg.conv_channels) + i],
                                     dims[i], dims[i + 1])
    return params


def _cnn_flat_dim(cfg: ClassifierConfig) -> int:
    h = w = cfg.input_shape[0]
    for i in range(len(cfg.conv_channels)):
        h, w = h - cfg.conv_kernel + 1, w - cfg.conv_kernel + 1   # VALID conv
        if i % 2 == 1:                                            # pool 2x2
            h, w = h // 2, w // 2
    return h * w * cfg.conv_channels[-1]


def apply_classifier(params: Params, cfg: ClassifierConfig,
                     x: jax.Array) -> jax.Array:
    """x: (B, *input_shape) → logits (B, n_classes)."""
    if cfg.kind == "mlp":
        h = x.reshape(x.shape[0], -1)
        n = len([k for k in params if k.startswith("dense")])
        for i in range(n):
            p = params[f"dense{i}"]
            h = h @ p["w"] + p["b"]
            if i < n - 1:
                h = jax.nn.relu(h)
        return h
    h = x
    for i in range(len(cfg.conv_channels)):
        p = params[f"conv{i}"]
        h = jax.lax.conv_general_dilated(
            h, p["w"], window_strides=(1, 1), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
        h = jax.nn.relu(h)
        if i % 2 == 1:
            h = jax.lax.reduce_window(h, -jnp.inf, jax.lax.max,
                                      (1, 2, 2, 1), (1, 2, 2, 1), "VALID")
    h = h.reshape(h.shape[0], -1)
    n = len([k for k in params if k.startswith("dense")])
    for i in range(n):
        p = params[f"dense{i}"]
        h = h @ p["w"] + p["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def classifier_loss(params: Params, cfg: ClassifierConfig,
                    batch: Dict[str, jax.Array]
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits = apply_classifier(params, cfg, batch["x"])
    logp = jax.nn.log_softmax(logits)
    ll = jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    loss = -jnp.mean(ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}


def n_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))

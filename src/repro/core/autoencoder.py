"""Autoencoders over weight-update vectors — the paper's core mechanism.

Three AE families, all trained with the paper's reconstruction loss (Eq. 3):

* **Fully-connected funnel AE** (paper §3/§4): input/output width equals the
  flattened parameter count of the collaborator model; hidden widths shrink
  to a ``latent_dim`` bottleneck (Fig. 1). ``z = act(Wx+b)`` stacks (Eq. 1/2).
  This is the paper-faithful variant used for the MNIST/CIFAR collaborators.
* **Chunked (shared) AE** — the TPU-native scaling of the paper's
  convolutional-AE insight (§4.3): the flat update is reshaped into
  ``(num_chunks, chunk_size)`` and one small funnel AE is shared across
  chunks. Compression ratio = chunk_size / latent_chunk; the encode is a
  single MXU-shaped matmul over the chunk batch (see kernels/ae_encode.py).
* **Conv1d AE** (paper appendix): strided depthwise+pointwise conv encoder /
  transposed decoder over the flat vector — included for the paper's
  "probe further research" variant and ablations.

All trainers normalize inputs with dataset-level (mean, std) kept inside the
AE state, so compression is scale-free across rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper import AEConfig
from repro.models.common import activation_fn

Params = Dict[str, Any]


# =====================================================================
# fully-connected funnel AE (paper-faithful)
# =====================================================================
def _fc_dims(cfg: AEConfig) -> Tuple[List[int], List[int]]:
    enc = [cfg.input_dim, *cfg.encoder_hidden, cfg.latent_dim]
    dec = [cfg.latent_dim, *reversed(cfg.encoder_hidden), cfg.input_dim]
    return enc, dec


def init_fc_ae(rng: jax.Array, cfg: AEConfig) -> Params:
    enc_dims, dec_dims = _fc_dims(cfg)
    n = len(enc_dims) + len(dec_dims) - 2
    keys = jax.random.split(rng, n)

    def dense(k, a, b):
        return {"w": jax.random.normal(k, (a, b), jnp.float32) * (a ** -0.5),
                "b": jnp.zeros((b,), jnp.float32)}

    ki = iter(keys)
    return {
        "enc": [dense(next(ki), a, b)
                for a, b in zip(enc_dims[:-1], enc_dims[1:])],
        "dec": [dense(next(ki), a, b)
                for a, b in zip(dec_dims[:-1], dec_dims[1:])],
        "norm": {"mean": jnp.zeros((), jnp.float32),
                 "std": jnp.ones((), jnp.float32)},
    }


def _run_stack(stack: Sequence[Params], x: jax.Array, act, final_act) -> jax.Array:
    for i, layer in enumerate(stack):
        x = x @ layer["w"] + layer["b"]
        x = act(x) if i < len(stack) - 1 else final_act(x)
    return x


def fc_encode(params: Params, cfg: AEConfig, x: jax.Array) -> jax.Array:
    """x: (..., input_dim) → latent (..., latent_dim). Eq. 1."""
    act = activation_fn(cfg.activation)
    xn = (x - params["norm"]["mean"]) / params["norm"]["std"]
    return _run_stack(params["enc"], xn, act, act)


def fc_decode(params: Params, cfg: AEConfig, z: jax.Array) -> jax.Array:
    """latent → reconstructed update (Eq. 2)."""
    act = activation_fn(cfg.activation)
    final = activation_fn(cfg.final_activation)
    xn = _run_stack(params["dec"], z, act, final)
    return xn * params["norm"]["std"] + params["norm"]["mean"]


def fc_reconstruct(params: Params, cfg: AEConfig, x: jax.Array) -> jax.Array:
    return fc_decode(params, cfg, fc_encode(params, cfg, x))


# =====================================================================
# chunked shared AE (TPU-scale variant)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class ChunkedAEConfig:
    chunk_size: int = 4096
    hidden: Tuple[int, ...] = (512,)
    latent_chunk: int = 8            # → 512x per-chunk compression
    activation: str = "relu"

    @property
    def compression_ratio(self) -> float:
        return self.chunk_size / self.latent_chunk

    def as_fc(self) -> AEConfig:
        return AEConfig(input_dim=self.chunk_size,
                        encoder_hidden=self.hidden,
                        latent_dim=self.latent_chunk,
                        activation=self.activation)


def init_chunked_ae(rng: jax.Array, cfg: ChunkedAEConfig) -> Params:
    return init_fc_ae(rng, cfg.as_fc())


def chunk_vector(flat: jax.Array, chunk_size: int) -> Tuple[jax.Array, int]:
    """Pad a flat vector to a chunk multiple and reshape (n_chunks, chunk)."""
    n = flat.shape[0]
    pad = (-n) % chunk_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, chunk_size), n


def unchunk_vector(chunks: jax.Array, orig_len: int) -> jax.Array:
    return chunks.reshape(-1)[:orig_len]


def chunked_encode(params: Params, cfg: ChunkedAEConfig,
                   flat: jax.Array) -> jax.Array:
    chunks, _ = chunk_vector(flat, cfg.chunk_size)
    return fc_encode(params, cfg.as_fc(), chunks)     # (n_chunks, latent)


def chunked_decode(params: Params, cfg: ChunkedAEConfig,
                   latents: jax.Array, orig_len: int) -> jax.Array:
    chunks = fc_decode(params, cfg.as_fc(), latents)
    return unchunk_vector(chunks, orig_len)


# =====================================================================
# conv1d AE (paper appendix variant)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class ConvAEConfig:
    channels: Tuple[int, ...] = (16, 32)
    kernel: int = 9
    stride: int = 8                    # per stage → total ratio stride**n/ch
    latent_channels: int = 1

    def total_stride(self) -> int:
        return self.stride ** len(self.channels)


def init_conv_ae(rng: jax.Array, cfg: ConvAEConfig) -> Params:
    keys = jax.random.split(rng, 2 * len(cfg.channels) + 2)
    enc, dec = [], []
    c_in = 1
    ki = iter(keys)
    for c_out in cfg.channels:
        k = next(ki)
        enc.append({"w": jax.random.normal(
            k, (cfg.kernel, c_in, c_out), jnp.float32)
            * (cfg.kernel * c_in) ** -0.5,
            "b": jnp.zeros((c_out,), jnp.float32)})
        c_in = c_out
    k = next(ki)
    enc.append({"w": jax.random.normal(
        k, (1, c_in, cfg.latent_channels), jnp.float32) * c_in ** -0.5,
        "b": jnp.zeros((cfg.latent_channels,), jnp.float32)})
    c_in = cfg.latent_channels
    for c_out in reversed(cfg.channels):
        k = next(ki)
        dec.append({"w": jax.random.normal(
            k, (cfg.kernel, c_in, c_out), jnp.float32)
            * (cfg.kernel * c_in) ** -0.5,
            "b": jnp.zeros((c_out,), jnp.float32)})
        c_in = c_out
    k = next(ki)
    dec.append({"w": jax.random.normal(k, (1, c_in, 1), jnp.float32)
                * c_in ** -0.5, "b": jnp.zeros((1,), jnp.float32)})
    return {"enc": enc, "dec": dec,
            "norm": {"mean": jnp.zeros((), jnp.float32),
                     "std": jnp.ones((), jnp.float32)}}


def conv_encode(params: Params, cfg: ConvAEConfig, x: jax.Array) -> jax.Array:
    """x: (B, length) → (B, length/total_stride, latent_channels)."""
    h = ((x - params["norm"]["mean"]) / params["norm"]["std"])[..., None]
    for i, layer in enumerate(params["enc"][:-1]):
        h = jax.lax.conv_general_dilated(
            h, layer["w"], (cfg.stride,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC")) + layer["b"]
        h = jax.nn.relu(h)
    last = params["enc"][-1]
    return jax.lax.conv_general_dilated(
        h, last["w"], (1,), "SAME",
        dimension_numbers=("NWC", "WIO", "NWC")) + last["b"]


def conv_decode(params: Params, cfg: ConvAEConfig, z: jax.Array) -> jax.Array:
    h = z
    for layer in params["dec"][:-1]:
        h = jax.lax.conv_transpose(
            h, layer["w"], (cfg.stride,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC")) + layer["b"]
        h = jax.nn.relu(h)
    last = params["dec"][-1]
    h = jax.lax.conv_general_dilated(
        h, last["w"], (1,), "SAME",
        dimension_numbers=("NWC", "WIO", "NWC")) + last["b"]
    out = h[..., 0]
    return out * params["norm"]["std"] + params["norm"]["mean"]


# =====================================================================
# AE training (paper Eq. 3: L = ||x - x'||^2) with Adam
# =====================================================================
def ae_loss(params: Params, cfg, x: jax.Array, kind: str) -> jax.Array:
    if kind == "fc":
        x_hat = fc_reconstruct(params, cfg, x)
    elif kind == "conv":
        x_hat = conv_decode(params, cfg, conv_encode(params, cfg, x))
    else:
        raise ValueError(kind)
    return jnp.mean(jnp.square(x - x_hat))


def ae_accuracy(params: Params, cfg, x: jax.Array, kind: str = "fc",
                tol: float = 0.05) -> jax.Array:
    """The paper's "accuracy" metric for AE training (Figs. 4/6): fraction of
    reconstructed weights within a tolerance band of the originals, measured
    in units of the dataset std."""
    if kind == "fc":
        x_hat = fc_reconstruct(params, cfg, x)
    else:
        x_hat = conv_decode(params, cfg, conv_encode(params, cfg, x))
    scale = params["norm"]["std"]
    return jnp.mean((jnp.abs(x - x_hat) <= tol * scale).astype(jnp.float32))


def fit_normalizer(params: Params, dataset: jax.Array) -> Params:
    mean = jnp.mean(dataset)
    std = jnp.maximum(jnp.std(dataset), 1e-8)
    return dict(params, norm={"mean": mean, "std": std})


def train_autoencoder(
    rng: jax.Array,
    cfg,
    dataset: jax.Array,              # (n_samples, input_dim) weight vectors
    *,
    kind: str = "fc",
    epochs: int = 200,
    batch_size: int = 8,
    lr: float = 3e-3,    # weight-vector AEs train on tiny datasets (tens of
                         # snapshots); 1e-3 underfits within the CI epoch
                         # budget — see §Perf iteration log in DESIGN.md
    val_fraction: float = 0.2,
    init: Optional[Params] = None,
) -> Tuple[Params, Dict[str, list]]:
    """Train an AE on a weights dataset; returns (params, history)."""
    n = dataset.shape[0]
    n_val = max(1, int(n * val_fraction)) if n > 2 else 0
    k_init, k_shuf, k_split = jax.random.split(rng, 3)
    # random (not tail) val split: the tail snapshots are the converged
    # weights the codec most needs to reconstruct — don't hold them all out
    order = jax.random.permutation(k_split, n)
    shuffled_all = dataset[order]
    train_set, val_set = shuffled_all[:n - n_val], shuffled_all[n - n_val:]
    if init is None:
        if kind == "fc":
            params = init_fc_ae(k_init, cfg)
        else:
            params = init_conv_ae(k_init, cfg)
    else:
        params = init
    params = fit_normalizer(params, train_set)

    # Adam state
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, x: ae_loss(p, cfg, x, kind)))
    acc_fn = jax.jit(lambda p, x: ae_accuracy(p, cfg, x, kind))

    @jax.jit
    def adam_update(p, g, m, v, t):
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree_util.tree_map(
            lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        def upd(pl, ml, vl):
            mh = ml / (1 - b1 ** t)
            vh = vl / (1 - b2 ** t)
            return pl - lr * mh / (jnp.sqrt(vh) + eps)
        return jax.tree_util.tree_map(upd, p, m, v), m, v

    history = {"loss": [], "accuracy": [], "val_loss": [], "val_accuracy": []}
    bs = min(batch_size, max(1, train_set.shape[0]))
    step = 0
    for epoch in range(epochs):
        k_shuf, k = jax.random.split(k_shuf)
        order = jax.random.permutation(k, train_set.shape[0])
        shuffled = train_set[order]
        ep_loss = 0.0
        nb = 0
        for i in range(0, shuffled.shape[0] - bs + 1, bs):
            xb = shuffled[i:i + bs]
            loss, g = loss_grad(params, xb)
            # norm stats are data statistics, not trainable
            g = dict(g, norm=jax.tree_util.tree_map(jnp.zeros_like,
                                                    g["norm"]))
            step += 1
            params, m, v = adam_update(params, g, m, v, step)
            ep_loss += float(loss)
            nb += 1
        history["loss"].append(ep_loss / max(nb, 1))
        history["accuracy"].append(float(acc_fn(params, train_set)))
        if n_val:
            vl, _ = loss_grad(params, val_set)
            history["val_loss"].append(float(vl))
            history["val_accuracy"].append(float(acc_fn(params, val_set)))
    return params, history


def ae_param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(
        {"enc": params["enc"], "dec": params["dec"]}))


def decoder_param_count(params: Params) -> int:
    """Size of the decoder half — the pre-pass shipping cost (Eq. 5/6)."""
    return sum(x.size for x in jax.tree_util.tree_leaves(params["dec"]))

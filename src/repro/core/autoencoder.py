"""Autoencoders over weight-update vectors — the paper's core mechanism.

Three AE families, all trained with the paper's reconstruction loss (Eq. 3):

* **Fully-connected funnel AE** (paper §3/§4): input/output width equals the
  flattened parameter count of the collaborator model; hidden widths shrink
  to a ``latent_dim`` bottleneck (Fig. 1). ``z = act(Wx+b)`` stacks (Eq. 1/2).
  This is the paper-faithful variant used for the MNIST/CIFAR collaborators.
* **Chunked (shared) AE** — the TPU-native scaling of the paper's
  convolutional-AE insight (§4.3): the flat update is reshaped into
  ``(num_chunks, chunk_size)`` and one small funnel AE is shared across
  chunks. Compression ratio = chunk_size / latent_chunk; the encode is a
  single MXU-shaped matmul over the chunk batch (see kernels/ae_encode.py).
* **Conv1d AE** (paper appendix): strided depthwise+pointwise conv encoder /
  transposed decoder over the flat vector — included for the paper's
  "probe further research" variant and ablations.

All trainers normalize inputs with dataset-level (mean, std) kept inside the
AE state, so compression is scale-free across rounds.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.paper import AEConfig
from repro.models.common import activation_fn

Params = Dict[str, Any]


# =====================================================================
# fully-connected funnel AE (paper-faithful)
# =====================================================================
def _fc_dims(cfg: AEConfig) -> Tuple[List[int], List[int]]:
    enc = [cfg.input_dim, *cfg.encoder_hidden, cfg.latent_dim]
    dec = [cfg.latent_dim, *reversed(cfg.encoder_hidden), cfg.input_dim]
    return enc, dec


def init_fc_ae(rng: jax.Array, cfg: AEConfig) -> Params:
    enc_dims, dec_dims = _fc_dims(cfg)
    n = len(enc_dims) + len(dec_dims) - 2
    keys = jax.random.split(rng, n)

    def dense(k, a, b):
        return {"w": jax.random.normal(k, (a, b), jnp.float32) * (a ** -0.5),
                "b": jnp.zeros((b,), jnp.float32)}

    ki = iter(keys)
    return {
        "enc": [dense(next(ki), a, b)
                for a, b in zip(enc_dims[:-1], enc_dims[1:])],
        "dec": [dense(next(ki), a, b)
                for a, b in zip(dec_dims[:-1], dec_dims[1:])],
        "norm": {"mean": jnp.zeros((), jnp.float32),
                 "std": jnp.ones((), jnp.float32)},
    }


def _run_stack(stack: Sequence[Params], x: jax.Array, act, final_act) -> jax.Array:
    for i, layer in enumerate(stack):
        x = x @ layer["w"] + layer["b"]
        x = act(x) if i < len(stack) - 1 else final_act(x)
    return x


def fc_encode(params: Params, cfg: AEConfig, x: jax.Array) -> jax.Array:
    """x: (..., input_dim) → latent (..., latent_dim). Eq. 1."""
    act = activation_fn(cfg.activation)
    xn = (x - params["norm"]["mean"]) / params["norm"]["std"]
    return _run_stack(params["enc"], xn, act, act)


def fc_decode(params: Params, cfg: AEConfig, z: jax.Array) -> jax.Array:
    """latent → reconstructed update (Eq. 2)."""
    act = activation_fn(cfg.activation)
    final = activation_fn(cfg.final_activation)
    xn = _run_stack(params["dec"], z, act, final)
    return xn * params["norm"]["std"] + params["norm"]["mean"]


def fc_reconstruct(params: Params, cfg: AEConfig, x: jax.Array) -> jax.Array:
    return fc_decode(params, cfg, fc_encode(params, cfg, x))


# =====================================================================
# chunked shared AE (TPU-scale variant)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class ChunkedAEConfig:
    chunk_size: int = 4096
    hidden: Tuple[int, ...] = (512,)
    latent_chunk: int = 8            # → 512x per-chunk compression
    activation: str = "relu"

    @property
    def compression_ratio(self) -> float:
        return self.chunk_size / self.latent_chunk

    def as_fc(self) -> AEConfig:
        return AEConfig(input_dim=self.chunk_size,
                        encoder_hidden=self.hidden,
                        latent_dim=self.latent_chunk,
                        activation=self.activation)


def init_chunked_ae(rng: jax.Array, cfg: ChunkedAEConfig) -> Params:
    return init_fc_ae(rng, cfg.as_fc())


def chunk_vector(flat: jax.Array, chunk_size: int) -> Tuple[jax.Array, int]:
    """Pad a flat vector to a chunk multiple and reshape (n_chunks, chunk)."""
    n = flat.shape[0]
    pad = (-n) % chunk_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, chunk_size), n


def unchunk_vector(chunks: jax.Array, orig_len: int) -> jax.Array:
    return chunks.reshape(-1)[:orig_len]


def chunked_encode(params: Params, cfg: ChunkedAEConfig,
                   flat: jax.Array) -> jax.Array:
    chunks, _ = chunk_vector(flat, cfg.chunk_size)
    return fc_encode(params, cfg.as_fc(), chunks)     # (n_chunks, latent)


def chunked_decode(params: Params, cfg: ChunkedAEConfig,
                   latents: jax.Array, orig_len: int) -> jax.Array:
    chunks = fc_decode(params, cfg.as_fc(), latents)
    return unchunk_vector(chunks, orig_len)


# =====================================================================
# conv1d AE (paper appendix variant)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class ConvAEConfig:
    channels: Tuple[int, ...] = (16, 32)
    kernel: int = 9
    stride: int = 8                    # per stage → total ratio stride**n/ch
    latent_channels: int = 1

    def total_stride(self) -> int:
        return self.stride ** len(self.channels)


def init_conv_ae(rng: jax.Array, cfg: ConvAEConfig) -> Params:
    keys = jax.random.split(rng, 2 * len(cfg.channels) + 2)
    enc, dec = [], []
    c_in = 1
    ki = iter(keys)
    for c_out in cfg.channels:
        k = next(ki)
        enc.append({"w": jax.random.normal(
            k, (cfg.kernel, c_in, c_out), jnp.float32)
            * (cfg.kernel * c_in) ** -0.5,
            "b": jnp.zeros((c_out,), jnp.float32)})
        c_in = c_out
    k = next(ki)
    enc.append({"w": jax.random.normal(
        k, (1, c_in, cfg.latent_channels), jnp.float32) * c_in ** -0.5,
        "b": jnp.zeros((cfg.latent_channels,), jnp.float32)})
    c_in = cfg.latent_channels
    for c_out in reversed(cfg.channels):
        k = next(ki)
        dec.append({"w": jax.random.normal(
            k, (cfg.kernel, c_in, c_out), jnp.float32)
            * (cfg.kernel * c_in) ** -0.5,
            "b": jnp.zeros((c_out,), jnp.float32)})
        c_in = c_out
    k = next(ki)
    dec.append({"w": jax.random.normal(k, (1, c_in, 1), jnp.float32)
                * c_in ** -0.5, "b": jnp.zeros((1,), jnp.float32)})
    return {"enc": enc, "dec": dec,
            "norm": {"mean": jnp.zeros((), jnp.float32),
                     "std": jnp.ones((), jnp.float32)}}


def conv_encode(params: Params, cfg: ConvAEConfig, x: jax.Array) -> jax.Array:
    """x: (B, length) → (B, length/total_stride, latent_channels)."""
    h = ((x - params["norm"]["mean"]) / params["norm"]["std"])[..., None]
    for i, layer in enumerate(params["enc"][:-1]):
        h = jax.lax.conv_general_dilated(
            h, layer["w"], (cfg.stride,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC")) + layer["b"]
        h = jax.nn.relu(h)
    last = params["enc"][-1]
    return jax.lax.conv_general_dilated(
        h, last["w"], (1,), "SAME",
        dimension_numbers=("NWC", "WIO", "NWC")) + last["b"]


def conv_decode(params: Params, cfg: ConvAEConfig, z: jax.Array) -> jax.Array:
    h = z
    for layer in params["dec"][:-1]:
        h = jax.lax.conv_transpose(
            h, layer["w"], (cfg.stride,), "SAME",
            dimension_numbers=("NWC", "WIO", "NWC")) + layer["b"]
        h = jax.nn.relu(h)
    last = params["dec"][-1]
    h = jax.lax.conv_general_dilated(
        h, last["w"], (1,), "SAME",
        dimension_numbers=("NWC", "WIO", "NWC")) + last["b"]
    out = h[..., 0]
    return out * params["norm"]["std"] + params["norm"]["mean"]


# =====================================================================
# AE training (paper Eq. 3: L = ||x - x'||^2) with Adam
# =====================================================================
def ae_loss(params: Params, cfg, x: jax.Array, kind: str) -> jax.Array:
    if kind == "fc":
        x_hat = fc_reconstruct(params, cfg, x)
    elif kind == "conv":
        x_hat = conv_decode(params, cfg, conv_encode(params, cfg, x))
    else:
        raise ValueError(kind)
    return jnp.mean(jnp.square(x - x_hat))


def ae_accuracy(params: Params, cfg, x: jax.Array, kind: str = "fc",
                tol: float = 0.05) -> jax.Array:
    """The paper's "accuracy" metric for AE training (Figs. 4/6): fraction of
    reconstructed weights within a tolerance band of the originals, measured
    in units of the dataset std."""
    if kind == "fc":
        x_hat = fc_reconstruct(params, cfg, x)
    else:
        x_hat = conv_decode(params, cfg, conv_encode(params, cfg, x))
    scale = params["norm"]["std"]
    return jnp.mean((jnp.abs(x - x_hat) <= tol * scale).astype(jnp.float32))


def fit_normalizer(params: Params, dataset: jax.Array) -> Params:
    mean = jnp.mean(dataset)
    std = jnp.maximum(jnp.std(dataset), 1e-8)
    return dict(params, norm={"mean": mean, "std": std})


def _train_setup(rng: jax.Array, cfg, dataset: jax.Array, *, kind: str,
                 batch_size: int, val_fraction: float,
                 init: Optional[Params],
                 refit_normalizer: Optional[bool]
                 ) -> Tuple[Params, jax.Array, jax.Array, jax.Array, int]:
    """Shared trainer prologue (split/init/normalizer) for the eager oracle
    and the scan trainer — one definition so the two paths see identical
    train/val splits, initial params, and normalizer state.

    Warm-start semantics (explicit, DESIGN.md §8.2): passing ``init`` warms
    the *weights only* — Adam moments and the bias-correction step always
    restart fresh, and the normalizer is kept as-is unless
    ``refit_normalizer=True`` (a refit rescales what the latents mean, so a
    warm start keeps the old statistics by default; a fresh init always
    fits them, since mean=0/std=1 is a placeholder)."""
    n = dataset.shape[0]
    n_val = max(1, int(n * val_fraction)) if n > 2 else 0
    k_init, k_shuf, k_split = jax.random.split(rng, 3)
    # random (not tail) val split: the tail snapshots are the converged
    # weights the codec most needs to reconstruct — don't hold them all out
    order = jax.random.permutation(k_split, n)
    shuffled_all = dataset[order]
    train_set, val_set = shuffled_all[:n - n_val], shuffled_all[n - n_val:]
    if init is None:
        params = init_fc_ae(k_init, cfg) if kind == "fc" \
            else init_conv_ae(k_init, cfg)
        refit = True if refit_normalizer is None else refit_normalizer
    else:
        params = init
        refit = False if refit_normalizer is None else refit_normalizer
    if refit:
        params = fit_normalizer(params, train_set)
    bs = min(batch_size, max(1, train_set.shape[0]))
    return params, train_set, val_set, k_shuf, bs


def _masked_ae_loss(params: Params, cfg, xb: jax.Array, wb: jax.Array,
                    kind: str) -> jax.Array:
    """Eq.-3 MSE over a batch with a 0/1 row mask ``wb`` — equals
    ``ae_loss`` on the unmasked rows (tail batches ride in a full-width
    batch with padding rows masked to exactly zero contribution)."""
    if kind == "fc":
        x_hat = fc_reconstruct(params, cfg, xb)
    elif kind == "conv":
        x_hat = conv_decode(params, cfg, conv_encode(params, cfg, xb))
    else:
        raise ValueError(kind)
    sq = jnp.square(xb - x_hat)
    per_row = sq.reshape(sq.shape[0], -1)
    denom = jnp.sum(wb) * per_row.shape[1]
    return jnp.sum(per_row * wb[:, None]) / denom


def _adam_update(p: Params, g: Params, m: Params, v: Params, t, lr):
    """One Adam step (shared by the eager oracle and the scan trainer so
    their op chains are identical; ``t`` is the 1-based bias-correction
    step)."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
    v = jax.tree_util.tree_map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)

    def upd(pl, ml, vl):
        mh = ml / (1 - b1 ** t)
        vh = vl / (1 - b2 ** t)
        return pl - lr * mh / (jnp.sqrt(vh) + eps)
    return jax.tree_util.tree_map(upd, p, m, v), m, v


def train_autoencoder_eager(
    rng: jax.Array,
    cfg,
    dataset: jax.Array,              # (n_samples, input_dim) weight vectors
    *,
    kind: str = "fc",
    epochs: int = 200,
    batch_size: int = 8,
    lr: float = 3e-3,    # weight-vector AEs train on tiny datasets (tens of
                         # snapshots); 1e-3 underfits within the CI epoch
                         # budget — see §Perf iteration log in DESIGN.md
    val_fraction: float = 0.2,
    init: Optional[Params] = None,
    refit_normalizer: Optional[bool] = None,
) -> Tuple[Params, Dict[str, list]]:
    """The eager epoch/batch-loop trainer — kept as the oracle the scan
    trainer is asserted against (DESIGN.md §8.1). One Python dispatch plus a
    host sync per batch; use :func:`train_autoencoder` (scan) on hot paths.

    Every sample trains every epoch: the trailing partial batch is included
    (a ``bs``-aligned loop would silently drop up to ``bs-1`` of the paper's
    tens-of-snapshots datasets per epoch)."""
    params, train_set, val_set, k_shuf, bs = _train_setup(
        rng, cfg, dataset, kind=kind, batch_size=batch_size,
        val_fraction=val_fraction, init=init,
        refit_normalizer=refit_normalizer)
    n_val = val_set.shape[0]

    # Adam state: always fresh, also under warm starts (see _train_setup)
    m = jax.tree_util.tree_map(jnp.zeros_like, params)
    v = jax.tree_util.tree_map(jnp.zeros_like, params)

    loss_grad = jax.jit(jax.value_and_grad(
        lambda p, x: ae_loss(p, cfg, x, kind)))
    acc_fn = jax.jit(lambda p, x: ae_accuracy(p, cfg, x, kind))
    adam = jax.jit(_adam_update)

    history = {"loss": [], "accuracy": [], "val_loss": [], "val_accuracy": []}
    step = 0
    for epoch in range(epochs):
        k_shuf, k = jax.random.split(k_shuf)
        order = jax.random.permutation(k, train_set.shape[0])
        shuffled = train_set[order]
        ep_loss = 0.0
        nb = 0
        for i in range(0, shuffled.shape[0], bs):
            xb = shuffled[i:i + bs]          # tail batch may be < bs
            loss, g = loss_grad(params, xb)
            # norm stats are data statistics, not trainable
            g = dict(g, norm=jax.tree_util.tree_map(jnp.zeros_like,
                                                    g["norm"]))
            step += 1
            params, m, v = adam(params, g, m, v, step, lr)
            ep_loss += float(loss)
            nb += 1
        history["loss"].append(ep_loss / max(nb, 1))
        history["accuracy"].append(float(acc_fn(params, train_set)))
        if n_val:
            vl, _ = loss_grad(params, val_set)
            history["val_loss"].append(float(vl))
            history["val_accuracy"].append(float(acc_fn(params, val_set)))
    return params, history


@functools.partial(jax.jit, static_argnames=("cfg", "kind", "epochs", "bs"))
def _scan_fit(params: Params, train_set: jax.Array, val_set: jax.Array,
              key: jax.Array, lr, *, cfg, kind: str, epochs: int, bs: int
              ) -> Tuple[Params, Dict[str, jax.Array]]:
    """The jit-native trainer core: ``scan(epochs) ∘ scan(batches)`` with
    (params, Adam moments, step, shuffle key) as the carry and the per-epoch
    metric row as the scan output — zero host syncs anywhere inside
    (DESIGN.md §8.1). Batches are a static ``(nb, bs)`` index grid over the
    epoch permutation; the tail batch is padded to ``bs`` and masked, which
    reproduces the eager oracle's partial-batch loss exactly."""
    n_train = train_set.shape[0]
    nb = -(-n_train // bs)
    flat_idx = jnp.arange(nb * bs)
    idx = jnp.minimum(flat_idx, n_train - 1).reshape(nb, bs)
    mask = (flat_idx < n_train).astype(train_set.dtype).reshape(nb, bs)

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)

    def epoch_body(carry, _):
        p, m, v, step, k_shuf = carry
        ks = jax.random.split(k_shuf)
        k_shuf, k = ks[0], ks[1]
        order = jax.random.permutation(k, n_train)
        shuffled = train_set[order]

        def batch_body(c, batch_i):
            p, m, v, step = c
            xb = shuffled[idx[batch_i]]
            wb = mask[batch_i]
            loss, g = jax.value_and_grad(_masked_ae_loss)(
                p, cfg, xb, wb, kind)
            # norm stats are data statistics, not trainable
            g = dict(g, norm=jax.tree_util.tree_map(jnp.zeros_like,
                                                    g["norm"]))
            step = step + 1
            p, m, v = _adam_update(p, g, m, v, step, lr)
            return (p, m, v, step), loss

        (p, m, v, step), losses = jax.lax.scan(
            batch_body, (p, m, v, step), jnp.arange(nb))
        row = {"loss": jnp.sum(losses) / nb,
               "accuracy": ae_accuracy(p, cfg, train_set, kind)}
        if val_set.shape[0]:
            row["val_loss"] = ae_loss(p, cfg, val_set, kind)
            row["val_accuracy"] = ae_accuracy(p, cfg, val_set, kind)
        return (p, m, v, step, k_shuf), row

    init_carry = (params, zeros, zeros, jnp.int32(0), key)
    (params, _, _, _, _), hist = jax.lax.scan(
        epoch_body, init_carry, None, length=epochs)
    return params, hist


def train_autoencoder_scan(
    rng: jax.Array,
    cfg,
    dataset: jax.Array,
    *,
    kind: str = "fc",
    epochs: int = 200,
    batch_size: int = 8,
    lr: float = 3e-3,
    val_fraction: float = 0.2,
    init: Optional[Params] = None,
    refit_normalizer: Optional[bool] = None,
) -> Tuple[Params, Dict[str, list]]:
    """Jit-native AE trainer: identical math to the eager oracle (same
    split, same Adam op chain, same masked tail batch), staged as one XLA
    computation — the only host sync is materializing the final history.
    Equivalence is float-tolerance, not bit-for-bit (XLA reassociates the
    fused epoch reductions; tested in tests/test_ae_lifecycle.py)."""
    params, train_set, val_set, k_shuf, bs = _train_setup(
        rng, cfg, dataset, kind=kind, batch_size=batch_size,
        val_fraction=val_fraction, init=init,
        refit_normalizer=refit_normalizer)
    params, hist = _scan_fit(params, train_set, val_set, k_shuf,
                             jnp.float32(lr), cfg=cfg, kind=kind,
                             epochs=epochs, bs=bs)
    history = {k: np_list(v) for k, v in hist.items()}
    # oracle contract: the eager history always carries the val keys (empty
    # lists when there is no val split, i.e. n <= 2)
    history.setdefault("val_loss", [])
    history.setdefault("val_accuracy", [])
    return params, history


def np_list(x: jax.Array) -> list:
    """Stacked per-epoch metrics → plain floats (the one host sync)."""
    return [float(e) for e in x]


def train_autoencoder(
    rng: jax.Array,
    cfg,
    dataset: jax.Array,
    *,
    kind: str = "fc",
    epochs: int = 200,
    batch_size: int = 8,
    lr: float = 3e-3,
    val_fraction: float = 0.2,
    init: Optional[Params] = None,
    refit_normalizer: Optional[bool] = None,
    method: str = "scan",
) -> Tuple[Params, Dict[str, list]]:
    """Train an AE on a weights dataset; returns (params, history).

    ``method="scan"`` (default) runs the jit-native ``lax.scan`` trainer;
    ``method="eager"`` runs the per-batch Python loop kept as its oracle
    (DESIGN.md §8.1)."""
    fit = {"scan": train_autoencoder_scan,
           "eager": train_autoencoder_eager}[method]
    return fit(rng, cfg, dataset, kind=kind, epochs=epochs,
               batch_size=batch_size, lr=lr, val_fraction=val_fraction,
               init=init, refit_normalizer=refit_normalizer)


def train_autoencoder_cohort(
    rngs: jax.Array,                 # (C, key) — one PRNG key per client
    cfg,
    datasets: jax.Array,             # (C, n_samples, input_dim)
    *,
    kind: str = "fc",
    epochs: int = 200,
    batch_size: int = 8,
    lr: float = 3e-3,
    val_fraction: float = 0.2,
    init: Optional[Params] = None,   # stacked params, leading client axis
    refit_normalizer: Optional[bool] = None,
) -> Tuple[Params, Dict[str, jax.Array]]:
    """Fit C autoencoders in ONE jitted dispatch: the whole trainer —
    split, init, normalizer, and the scan loops — is ``vmap``ed over a
    leading client axis, mirroring ``local_train_batched`` for classifier
    training (DESIGN.md §8.1). Per-client shuffles/inits come from the
    per-client keys, so each lane equals a sequential
    :func:`train_autoencoder_scan` fit with the same key (float tolerance).

    Returns (stacked params with leading client axis, history dict of
    ``(C, epochs)`` arrays)."""
    def one(rng, dataset, init_p):
        params, train_set, val_set, k_shuf, bs = _train_setup(
            rng, cfg, dataset, kind=kind, batch_size=batch_size,
            val_fraction=val_fraction, init=init_p,
            refit_normalizer=refit_normalizer)
        return _scan_fit(params, train_set, val_set, k_shuf,
                         jnp.float32(lr), cfg=cfg, kind=kind,
                         epochs=epochs, bs=bs)

    if init is None:
        return jax.vmap(lambda r, d: one(r, d, None))(rngs, datasets)
    return jax.vmap(one)(rngs, datasets, init)


def ae_param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(
        {"enc": params["enc"], "dec": params["dec"]}))


def decoder_param_count(params: Params) -> int:
    """Size of the decoder half — the pre-pass shipping cost (Eq. 5/6)."""
    return sum(x.size for x in jax.tree_util.tree_leaves(params["dec"]))


def decoder_tree(params: Params) -> Params:
    """Exactly what a collaborator ships for one decoder sync: the decoder
    stack plus the (mean, std) normalizer the server-side decode denorms
    with (DESIGN.md §8.3). The encoder never crosses the wire."""
    return {"dec": params["dec"], "norm": params["norm"]}


def decoder_sync_bytes(params: Params) -> float:
    """Wire bytes of one decoder sync — what the schedulers charge to
    ``RoundRecord.bytes_down`` per shipped decoder (DESIGN.md §8.3)."""
    return float(sum(x.size * x.dtype.itemsize
                     for x in jax.tree_util.tree_leaves(decoder_tree(params))))

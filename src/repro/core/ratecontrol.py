"""Adaptive rate control: per-client dynamic codec selection (DESIGN.md §9).

The paper sells the AE scheme as *dynamic* — "the compression ratio ... can
be modified based on the accuracy requirements, computational capacity, and
other requirements of the given FL setup" (§4.2) — but a static compressor
assignment never exercises that knob. Mitchell et al. (2022) show the right
rate-distortion operating point moves over training, and FedZip (Malekijoo
et al., 2021) adapts the compression stack per layer; this module makes the
operating point a first-class *policy*:

* a **ladder** is a per-client list of pre-built compressors ordered
  cheapest-uplink-first. Every rung's spec is a frozen hashable
  ``CodecSpec``, so whatever rung a client sits on, the server's fused
  ``decode_and_aggregate`` call keeps hitting the jit cache (heterogeneous
  cohorts group by spec before dispatch — DESIGN.md §9.2);
* a :class:`RateController` decides, at the *end* of each round (mirroring
  the AE lifecycle: a new codec takes effect the round after the server
  learns its decoder), which rung each participant occupies next:

  - :class:`FixedRate` — today's behavior, the default: never switches.
    Trajectory-preserving by construction (params/metrics/bytes_up are
    untouched; with AE rungs it additionally charges the honest initial
    decoder ships when no ``AELifecycle`` is attached).
  - :class:`DistortionTarget` — walk the ladder toward the cheapest rung
    whose observed post-EF reconstruction error stays under ``target``
    (step up when over target, step down with hysteresis), measured on the
    per-client snapshot buffers the AE lifecycle already maintains.
  - :class:`ByteBudget` — greedy per-round allocation of a global uplink
    budget across the observed cohort: everyone starts on the cheapest
    rung and marginal bytes go to the clients whose current-rung
    reconstruction drift is largest.
  - :class:`RDBudget` — Lagrangian rate-distortion water-filling of the
    same budget (DESIGN.md §15): every movable lane's distortion-vs-bytes
    curve is probed across ALL rungs in one batched dispatch, pruned to
    its lower convex hull, and the multiplier λ swept until marginal
    distortion per byte is equalized across lanes — with switch-time
    decoder re-ships amortized into each rung's price. Greedy
    :class:`ByteBudget` stays as the comparison baseline / differential
    oracle.

* a switch onto an AE rung triggers a refit of that rung's AE on the
  client's snapshot buffer through the existing ``AELifecycle`` cohort path
  (same-round same-shape fits share ONE ``train_autoencoder_cohort``
  dispatch, DESIGN.md §8.1) and **ships the new decoder** — charged to
  ``RoundRecord.bytes_down``/``bytes_decoder`` exactly like a lifecycle
  refresh, so ``savings.reconcile`` stays honest under rung churn
  (DESIGN.md §9.3). Controller decisions ride the record's
  ``spec_switches``/``controller`` fields, and the whole controller state
  (rung occupancy, cooldowns, every rung's AE params) survives
  ``save_federated_state`` for bit-exact resume.
"""
from __future__ import annotations

import dataclasses
import functools
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig
from repro.core import autoencoder as ae
from repro.core import codec
from repro.core.compressor import (ComposedCompressor, Compressor,
                                   FCAECompressor, PartitionedCompressor,
                                   partitioned)
from repro.core.lifecycle import (AELifecycle, _rel_recon_err,
                                  buffer_snapshot)

Pytree = Any
# [client][rung] (flat), or [client]{group: [rung]} (per-partition ladders,
# DESIGN.md §10.3) — cheapest-uplink-first within every rung list
Ladder = List[Any]


def fc_ae_ladder(n_clients: int, input_dim: int,
                 latent_dims: Sequence[int] = (8, 32, 128),
                 hidden: Tuple[int, ...] = (64,),
                 bits: Optional[int] = None,
                 seed: int = 0,
                 params: Optional[Sequence[Sequence[Pytree]]] = None,
                 ) -> Ladder:
    """Build the paper-faithful ladder: per-client FC autoencoders at
    increasing latent widths (cheapest uplink first — ``latent_dims`` must
    be ascending), optionally composed with ``bits``-wide latent
    quantization (the §4.2 "orthogonal add-on"). ``params[ci][k]`` supplies
    pre-trained AE params (e.g. from a pre-pass); omitted rungs start at a
    fresh per-(client, rung) init and rely on the switch-time refit
    (DESIGN.md §9.1). Seeded rungs are marked ``prefit`` so the policies'
    distortion probes trust them immediately; fresh-init rungs stay unfit
    until a refit lands — their probes measure garbage and are gated out
    of scoring (DESIGN.md §15.2)."""
    assert list(latent_dims) == sorted(latent_dims), (
        "ladder rungs must be ordered cheapest-uplink-first "
        f"(ascending latent dims), got {latent_dims}")
    out: Ladder = []
    for ci in range(n_clients):
        row: List[Compressor] = []
        for k, latent in enumerate(latent_dims):
            cfg = AEConfig(input_dim=input_dim, encoder_hidden=hidden,
                           latent_dim=latent)
            seeded = params is not None and params[ci][k] is not None
            if seeded:
                p = params[ci][k]
            else:
                p = ae.init_fc_ae(
                    jax.random.PRNGKey(
                        (seed * 1_000_003 + ci * 1009 + k) % 2 ** 31), cfg)
            inner = FCAECompressor(p, cfg)
            inner.prefit = seeded
            comp: Compressor = inner
            if bits is not None:
                comp = ComposedCompressor(comp, bits=bits)
            row.append(comp)
        out.append(row)
    return out


def partition_ladder(n_clients: int, pmap,
                     rung_factories: Dict[str, Sequence],
                     ) -> Ladder:
    """Build a per-(client, partition) ladder (DESIGN.md §10.3):
    ``rung_factories[group] = [factory, ...]`` cheapest-uplink-first, where
    each ``factory(ci, group_size) -> Compressor`` builds one client's rung
    for that group (AE rungs want per-client params; pointwise factories
    can ignore ``ci``). Every partition group of ``pmap`` needs an entry —
    single-entry groups are pinned (the controller never moves that lane).
    The returned ladder rows are ``{group: [Compressor, ...]}`` dicts;
    binding a :class:`RateController` to one installs a
    ``PartitionedCompressor`` per client and walks each (client, group)
    lane independently under the shared policy."""
    assert set(rung_factories) == set(pmap.names), (
        f"rung factories {sorted(rung_factories)} != partition groups "
        f"{sorted(pmap.names)}")
    out: Ladder = []
    for ci in range(n_clients):
        out.append({
            name: [factory(ci, pmap.group_size(name))
                   for factory in rung_factories[name]]
            for name in pmap.names})
    return out


@functools.partial(jax.jit, static_argnames=("specs",))
def _batched_rel_errs(specs: Tuple[Any, ...], params_cols, flats
                      ) -> jax.Array:
    """The whole (rung × lane) distortion matrix in ONE device dispatch
    (DESIGN.md §15.1): ``flats`` stacks the probed lanes' newest snapshots
    ``(L, n)``, ``params_cols[k]`` stacks every lane's rung-``k`` codec
    params along a leading axis (or is None for parameterless codecs), and
    each rung's ``_rel_recon_err`` is vmapped over lanes under one jit.
    Specs are static (they key the jit cache exactly like the fused server
    decode), so the per-round cost is one dispatch + one host transfer
    instead of the L·R blocking ``float()`` syncs the per-lane probes paid
    — retraced only when the cohort size changes."""
    rows = []
    for spec, prm in zip(specs, params_cols):
        if prm is None:
            rows.append(jax.vmap(
                lambda f, spec=spec: _rel_recon_err(spec, None, f))(flats))
        else:
            rows.append(jax.vmap(
                lambda p, f, spec=spec: _rel_recon_err(spec, p, f))(
                    prm, flats))
    return jnp.stack(rows)


def _rung_prefit(comp: Compressor) -> bool:
    """Whether a rung's distortion probe is honest from round 0: pointwise
    codecs are deterministic (always), AE-backed rungs only when their
    params came from a real fit (``prefit`` set by :func:`fc_ae_ladder`
    when pre-pass params are supplied). Fresh-init AE rungs measure
    garbage until a refit lands (DESIGN.md §15.2)."""
    sub = comp.ae_compressor()
    return sub is None or bool(getattr(sub, "prefit", False))


def _hull_prune(points: List[Tuple[int, float, float, float]]
                ) -> List[Tuple[int, float, float, float]]:
    """Lower-convex-hull filter for one lane's rate-distortion curve
    (DESIGN.md §15.3). ``points`` are ``(rung, cost, price, dist)``
    operating points — ``cost`` the uplink wire bytes, ``price`` the
    allocation axis (cost plus any amortized decoder-ship charge),
    ``dist`` the probed relative distortion. Dominated points (pricier,
    no less distorted) fall away; interior points beaten by skipping
    straight past them are pruned so the surviving step gains are
    non-increasing along the curve — the premise of the λ sweep.
    Collinear points are KEPT: equal-slope curves keep single-rung steps,
    which is what makes the allocator coincide with greedy
    :class:`ByteBudget` on affine equal-slope ladders (the differential
    contract, tests/test_rd_allocator.py). "Above the chord" carries a
    relative tolerance — probed distortions arrive through float math, and
    a point sitting 1 ulp above an exactly-collinear chord must not lose
    its single-rung step to rounding noise."""
    pts = sorted(points, key=lambda p: (p[2], p[3], p[0]))
    mono: List[Tuple[int, float, float, float]] = []
    for p in pts:
        if mono and p[3] >= mono[-1][3]:
            continue                      # dominated: pricier, not better
        mono.append(p)
    hull: List[Tuple[int, float, float, float]] = []
    for p in mono:
        while len(hull) >= 2:
            a, b = hull[-2], hull[-1]
            direct = (a[3] - p[3]) / (p[2] - a[2])
            through = (a[3] - b[3]) / (b[2] - a[2])
            if direct > through * (1.0 + 1e-9):  # b above the chord a→p
                hull.pop()
            else:
                break
        hull.append(p)
    return hull


def _quantized_gain(gain: float) -> float:
    """Collapse float-noise gain differences (7 significant digits) so
    near-tied hull steps fall through to the deterministic greedy
    tie-break ``(step, -drift, lane)`` instead of being ordered by
    rounding error — affine equal-slope ladders must replay
    :class:`ByteBudget`'s pass order exactly."""
    return float(f"{gain:.6e}")


def _lane_sort_key(ln) -> Tuple:
    """Heap-comparable lane id: flat lanes are ints, partitioned lanes
    ``(client, group)`` tuples — normalize both to tuples."""
    return ln if isinstance(ln, tuple) else (ln,)


def _rd_waterfill(curves: Dict[Any, Tuple[List[Tuple[int, float, float,
                                                     float]], float]],
                  budget: float, fixed_spend: float
                  ) -> Tuple[Optional[Dict[Any, int]], Optional[float]]:
    """Sweep the Lagrangian multiplier over every lane's hull steps
    (DESIGN.md §15.3). ``curves[lane] = (hull, tiebreak)`` where ``hull``
    is that lane's pruned curve and ``tiebreak`` its current-rung drift
    (mirrors greedy's ordering when gains tie). Every lane starts at its
    cheapest hull point; a heap merges the lanes' next steps and takes
    them in descending marginal-distortion-per-price-byte order until the
    uplink budget is exhausted — because hull gains are non-increasing
    per lane, this greedy merge IS the λ sweep: the gain of the last
    accepted step is the equalized multiplier λ*. A lane's step ``i+1``
    only enters the heap once step ``i`` is accepted (in-lane order holds
    structurally, independent of rounding), and gains are quantized for
    ordering (:func:`_quantized_gain`) so noise-tied steps resolve by
    greedy's ``(step, -drift, lane)`` tie-break. A lane whose next step
    no longer fits is done (its later steps start from a point never
    reached). Budget feasibility is checked in true uplink ``cost``;
    ordering uses the ship-amortized ``price``. Returns
    ``(hull index per lane, λ*)``, or ``(None, None)`` when even the
    all-cheapest floor overflows."""
    take = {ln: 0 for ln in curves}
    spent = fixed_spend + sum(h[0][1] for h, _ in curves.values())
    if spent > budget:
        return None, None

    def step(ln, i):
        hull, score = curves[ln]
        if i >= len(hull):
            return None
        gain = ((hull[i - 1][3] - hull[i][3])
                / (hull[i][2] - hull[i - 1][2]))
        key = (-_quantized_gain(gain), i, -score, _lane_sort_key(ln))
        return (key, gain, i, ln, hull[i][1] - hull[i - 1][1])

    heap = [s for ln in curves if (s := step(ln, 1)) is not None]
    heapq.heapify(heap)
    lam = None
    while heap:
        _key, gain, i, ln, dcost = heapq.heappop(heap)
        if spent + dcost > budget:
            continue                      # lane done: later steps unreachable
        take[ln] = i
        spent += dcost
        lam = gain
        nxt = step(ln, i + 1)
        if nxt is not None:
            heapq.heappush(heap, nxt)
    return take, lam


def _rd_topup(raw: Dict[Any, List[Tuple[int, float, float, float]]],
              chosen: Dict[Any, Tuple[int, float, float, float]],
              budget: float, spent: float) -> Optional[float]:
    """Integer-allocation top-up after the hull sweep (DESIGN.md §15.3).
    Hull steps are whole rungs, and decoder-ship pricing can bend a
    lane's curve concave at its middle rungs — the hull then keeps only
    a multi-rung jump, and when that jump no longer fits the budget the
    lane strands its share unspent even though a pruned INTERIOR rung
    would fit and still cut distortion (greedy's one-rung walk reaches
    it; the λ sweep alone cannot). Greedily spend the remainder on the
    best affordable raw-point upgrade — marginal distortion per priced
    byte, feasibility in true cost bytes, deterministic lane tie-break
    so the allocation stays invariant to cohort enumeration order.
    Mutates ``chosen`` in place; returns the gain of the last accepted
    upgrade (the effective shadow price once off-hull points are in
    play), or None when nothing affordable improved."""
    lam = None
    while True:
        best = None
        for ln in sorted(raw, key=_lane_sort_key):
            cpt = chosen[ln]
            for p in raw[ln]:
                if p[3] >= cpt[3]:
                    continue              # not a distortion improvement
                if spent + (p[1] - cpt[1]) > budget:
                    continue              # true uplink cost infeasible
                dprice = p[2] - cpt[2]
                gain = ((cpt[3] - p[3]) / dprice if dprice > 0
                        else float("inf"))
                key = (-_quantized_gain(gain), _lane_sort_key(ln), p[0])
                if best is None or key < best[0]:
                    best = (key, ln, p, gain)
        if best is None:
            return lam
        _, ln, p, gain = best
        spent += p[1] - chosen[ln][1]
        chosen[ln] = p
        lam = gain


@dataclasses.dataclass
class RateController:
    """Base policy: owns the ladder, the per-client rung occupancy, and the
    switch→refit→decoder-ship mechanics shared by every policy. Subclasses
    implement :meth:`plan` only. With ``ladder=None`` the run's existing
    compressors become a one-rung ladder (nothing can switch — the
    :class:`FixedRate` degenerate case).

    ``min_snapshots`` gates switching (a refit needs data); ``buffer_size``
    bounds the snapshot ring this controller maintains for clients the AE
    lifecycle does not cover (pointwise rungs, or no lifecycle attached).
    The ``refit_*`` knobs configure the internal :class:`AELifecycle` used
    for switch-time refits when the run has no lifecycle of its own — with
    one attached, its hyperparameters win (one refit configuration per
    run)."""

    ladder: Optional[Ladder] = None
    initial_rung: int = 0
    min_snapshots: int = 2
    buffer_size: int = 8
    refit_epochs: int = 30
    refit_batch: int = 8
    refit_lr: float = 3e-3
    seed: int = 0
    # the partition.PartitionMap behind a per-partition ladder (rows are
    # {group: [rungs]} dicts, see partition_ladder) — None for flat ladders
    partition: Optional[Any] = None
    name: str = "fixed"

    # ------------------------------------------------------------------
    def bind(self, run) -> None:
        """Attach to a ``FederatedRun`` and install the ladder's initial
        rung as each client's compressor. Called once from the run ctor —
        a controller carries per-run state, one instance per run."""
        assert getattr(self, "run", None) is None, (
            "controller is already bound to a FederatedRun; create a fresh "
            "controller instance per run")
        self.run = run
        n = len(run.datasets)
        self._partitioned = (self.ladder is not None and len(self.ladder)
                             and isinstance(self.ladder[0], dict))
        if self._partitioned:
            self._bind_partitioned(run, n)
            return
        if self.ladder is not None:
            assert len(self.ladder) == n, (
                f"ladder has {len(self.ladder)} clients, run has {n}")
            widths = {len(row) for row in self.ladder}
            assert len(widths) == 1, "every client needs the same rung count"
            self._comps = [list(row) for row in self.ladder]
            assert 0 <= self.initial_rung < len(self._comps[0])
            for ci in range(n):
                run.compressors[ci] = self._comps[ci][self.initial_rung]
        else:
            self._comps = [[c] for c in run.compressors]
        self.n_rungs = len(self._comps[0])
        start = self.initial_rung if self.ladder is not None else 0
        # rung occupancy as packed arrays (DESIGN.md §12.1): O(1) numpy
        # rows instead of O(population) Python list cells — and the layout
        # jit-native rate control (ROADMAP item 4) will gather from
        self._rung = np.full(n, start, dtype=np.int64)
        self._last_switch = np.full(n, -(10 ** 9), dtype=np.int64)
        self._any_ae = any(c.ae_compressor() is not None
                           for row in self._comps for c in row)
        self._refitter = AELifecycle(
            buffer_size=self.buffer_size, min_snapshots=self.min_snapshots,
            refresh_epochs=self.refit_epochs, batch_size=self.refit_batch,
            lr=self.refit_lr, seed=self.seed)
        flat, _ = ravel_pytree(run.global_params)
        self._n = int(flat.size)
        # one price list serves every client, so rung k must mean the SAME
        # spec for all of them (params may differ — specs are the static
        # shapes/bits the wire cost and the jit cache key on)
        for ci, row in enumerate(self._comps[1:], start=1):
            for k, c in enumerate(row):
                assert c.spec(self._n) == self._comps[0][k].spec(self._n), (
                    f"client {ci} rung {k} spec differs from client 0's — "
                    "per-rung specs must agree across the ladder")
        self._costs = [codec.wire_bytes(self._comps[0][k].spec(self._n),
                                        self._comps[0][k].codec_params())
                       for k in range(self.n_rungs)]
        assert all(a <= b for a, b in zip(self._costs, self._costs[1:])), (
            "ladder rungs must be ordered cheapest-uplink-first, got wire "
            f"costs {self._costs}")
        # per-(client, rung) fitted flags (DESIGN.md §15.2): pointwise
        # rungs are always honest, AE rungs only once pre-pass seeded or
        # refit — unfit rungs measure garbage and are gated out of scoring
        self._fitted = np.array(
            [[_rung_prefit(c) for c in row] for row in self._comps],
            dtype=bool)
        self._last_err: Dict[int, float] = {}
        self.probe_dispatches = 0

    def _bind_partitioned(self, run, n: int) -> None:
        """Per-partition ladders (DESIGN.md §10.3): the unit of control is
        the *lane* ``(client, group)`` — each walks its own rung list, all
        lanes share one policy (and, for :class:`ByteBudget`, one budget).
        Installs a ``PartitionedCompressor`` per client assembled from each
        group's initial rung; a later switch swaps just that group's
        sub-compressor in place."""
        assert self.partition is not None, (
            "a per-partition ladder (dict rows) needs the controller's "
            "``partition=`` PartitionMap")
        assert len(self.ladder) == n, (
            f"ladder has {len(self.ladder)} clients, run has {n}")
        names = list(self.partition.names)
        for ci, row in enumerate(self.ladder):
            assert set(row) == set(names), (
                f"client {ci} ladder groups {sorted(row)} != partition "
                f"groups {sorted(names)}")
        self._pcomps = [
            {name: list(self.ladder[ci][name]) for name in names}
            for ci in range(n)]
        self._pnrungs = {name: len(self._pcomps[0][name]) for name in names}
        for ci in range(1, n):
            for name in names:
                assert len(self._pcomps[ci][name]) == self._pnrungs[name], (
                    f"client {ci} group {name!r}: rung count differs")
        # lane occupancy as one packed (n,) array per group — same SoA
        # layout as the flat ladder's _rung (DESIGN.md §12.1)
        self._prung = {
            name: np.full(n, min(self.initial_rung,
                                 self._pnrungs[name] - 1), dtype=np.int64)
            for name in names}
        self._plast = {name: np.full(n, -(10 ** 9), dtype=np.int64)
                       for name in names}
        for ci in range(n):
            run.compressors[ci] = PartitionedCompressor(
                self.partition,
                {name: self._pcomps[ci][name][self._prung[name][ci]]
                 for name in names})
        self._any_ae = any(c.ae_compressor() is not None
                           for row in self._pcomps
                           for rungs in row.values() for c in rungs)
        self._refitter = AELifecycle(
            buffer_size=self.buffer_size, min_snapshots=self.min_snapshots,
            refresh_epochs=self.refit_epochs, batch_size=self.refit_batch,
            lr=self.refit_lr, seed=self.seed)
        flat, _ = ravel_pytree(run.global_params)
        self._n = int(flat.size)
        assert self._n == self.partition.size, (
            f"partition map covers {self.partition.size} params but the "
            f"model has {self._n}")
        # one price list per group: rung k of group g must mean the same
        # spec for every client (params may differ)
        for name in names:
            gsize = self.partition.group_size(name)
            for ci in range(1, n):
                for k, c in enumerate(self._pcomps[ci][name]):
                    assert c.spec(gsize) == \
                        self._pcomps[0][name][k].spec(gsize), (
                            f"client {ci} group {name!r} rung {k} spec "
                            "differs from client 0's — per-rung specs must "
                            "agree across the ladder")
        self._pcosts = {
            name: [codec.wire_bytes(
                self._pcomps[0][name][k].spec(
                    self.partition.group_size(name)),
                self._pcomps[0][name][k].codec_params())
                for k in range(self._pnrungs[name])]
            for name in names}
        for name, costs in self._pcosts.items():
            assert all(a <= b for a, b in zip(costs, costs[1:])), (
                f"group {name!r} rungs must be ordered "
                f"cheapest-uplink-first, got wire costs {costs}")
        # per-(lane, rung) fitted flags, one packed (n, rungs) bool array
        # per group — same gating as the flat ladder (DESIGN.md §15.2)
        self._pfitted = {
            name: np.array([[_rung_prefit(c)
                             for c in self._pcomps[ci][name]]
                            for ci in range(n)], dtype=bool)
            for name in names}
        self._last_err: Dict[int, float] = {}
        self.probe_dispatches = 0

    # ------------------------------------------------------------------
    def rung_of(self, ci: int) -> int:
        return int(self._rung[ci])

    def rung_of_group(self, ci: int, name: str) -> int:
        """Current rung of the ``(ci, name)`` lane (per-partition ladders)."""
        return int(self._prung[name][ci])

    def wire_cost(self, rung: int) -> float:
        """Planned uplink bytes of one payload at ``rung`` (static — from
        ``codec.wire_bytes``, asserted equal to observed encodes)."""
        return float(self._costs[rung])

    def wire_cost_group(self, name: str, rung: int) -> float:
        return float(self._pcosts[name][rung])

    # ------------------------------------------------------------------
    def observe(self, run, state, comp, flat: jax.Array) -> None:
        """Buffer the post-EF flat vector a client just encoded, for the
        clients the AE lifecycle does not already buffer (pointwise rungs,
        or no lifecycle attached) — distortion decisions need the codec's
        true input distribution whatever the current rung is. A ladder
        that cannot move (one rung) buffers nothing: the vectors would be
        model-sized dead weight in memory and in every checkpoint."""
        if self._partitioned:
            from repro.core import partition
            pc = partitioned(comp)
            ae_groups = pc.ae_groups()
            for name in self.partition.names:
                if self._pnrungs[name] <= 1:
                    continue             # pinned lane: nothing to decide
                if run.lifecycle is not None and name in ae_groups:
                    continue             # lifecycle buffered this group
                seg = partition.gather(pc.pmap.slices_of(name), flat)
                ring = state.part_snapshots.setdefault(name, [])
                ring.append(jnp.asarray(seg))
                del ring[:-self.buffer_size]
            return
        if self.n_rungs <= 1:
            return
        if run.lifecycle is not None and comp.ae_compressor() is not None:
            return                   # lifecycle buffered this one already
        buffer_snapshot(state, flat, self.buffer_size)

    # ------------------------------------------------------------------
    def plan(self, run, r: int, participants: List[int]) -> Dict[int, int]:
        """Policy hook: proposed rung per client (omit = stay). The base
        controller is FixedRate — it never proposes a move."""
        return {}

    # ------------------------------------------------------------------
    def end_of_round(self, run, r: int, participants: Sequence[int]
                     ) -> Tuple[float, List[int], List[Tuple[int, int, int]]]:
        """Advance the controller after round ``r``'s aggregation: apply
        the policy's planned moves, refit switched-to AE rungs on the
        snapshot buffers (grouped cohort dispatch), and ship their decoders.
        Returns ``(decoder_bytes, synced_client_ids, switches)`` where each
        switch is ``(client, from_rung, to_rung)``. Runs *after* the AE
        lifecycle's own ``end_of_round`` so this round's decoder traffic
        (initial ships, cadence/drift refreshes) is charged against the
        rung that actually served the round (DESIGN.md §9.3)."""
        bytes_dec, synced = 0.0, []
        if run.lifecycle is None and self._any_ae:
            # no user lifecycle: the internal refitter still owes the honest
            # initial decoder ships of Eq. 5/6 (DESIGN.md §8.3)
            bytes_dec, synced = self._refitter.end_of_round(
                run, r, participants)
        moves = self.plan(run, r, sorted(set(participants)))
        if self._partitioned:
            b, s, switches = self._apply_lane_moves(run, r, moves)
            return bytes_dec + b, sorted(synced + s), switches
        switches: List[Tuple[int, int, int]] = []
        refit_todo: List[int] = []
        for ci in sorted(moves):
            new = int(moves[ci])
            old = int(self._rung[ci])
            if new == old:
                continue
            self._rung[ci] = new
            run.compressors[ci] = self._comps[ci][new]
            self._last_switch[ci] = r
            switches.append((ci, old, new))
            if run.compressors[ci].ae_compressor() is not None:
                refit_todo.append(ci)
            else:
                run.clients[ci].ae_baseline = None   # stale vs old AE rung
        lc = run.lifecycle if run.lifecycle is not None else self._refitter
        fit_now = [ci for ci in refit_todo
                   if len(run.clients[ci].snapshots) >= self.min_snapshots]
        refit = dict(lc._refit(run, r, fit_now))
        for ci in refit_todo:
            comp = run.compressors[ci].ae_compressor()
            if ci in refit:
                comp.params = refit[ci]
                self._fitted[ci, int(self._rung[ci])] = True
            st = run.clients[ci]
            st.last_refresh = r
            st.ae_baseline = lc._lane_baseline(run, ci)
            # the server cannot decode the new rung without its decoder:
            # every switch onto an AE rung ships one, refit or not
            bytes_dec += ae.decoder_sync_bytes(comp.params)
            synced.append(ci)
        # multiset: initial ship + switch re-ship in one round = 2 syncs
        return bytes_dec, sorted(synced), switches

    def _apply_lane_moves(self, run, r: int, moves: Dict
                          ) -> Tuple[float, List, List]:
        """Per-partition half of :meth:`end_of_round`: apply ``moves``
        keyed by ``(client, group)`` lane, swapping just that group's
        sub-compressor inside the client's ``PartitionedCompressor``;
        switched-onto AE lanes refit on the group's own snapshot ring and
        ship that group's decoder (DESIGN.md §10.3). Switch records carry
        the lane as the client field: ``((ci, group), from, to)``."""
        bytes_dec, synced = 0.0, []
        switches: List[Tuple[Any, int, int]] = []
        refit_todo: List[Tuple[int, str]] = []
        for lane in sorted(moves):
            ci, name = lane
            new = int(moves[lane])
            old = int(self._prung[name][ci])
            if new == old:
                continue
            self._prung[name][ci] = new
            pc = partitioned(run.compressors[ci])
            pc.compressors[name] = self._pcomps[ci][name][new]
            self._plast[name][ci] = r
            switches.append((lane, old, new))
            if pc.compressors[name].ae_compressor() is not None:
                refit_todo.append(lane)
            else:
                run.clients[ci].part_baseline[name] = None
        lc = run.lifecycle if run.lifecycle is not None else self._refitter
        fit_now = [
            lane for lane in refit_todo
            if len(run.clients[lane[0]].part_snapshots.get(lane[1], []))
            >= self.min_snapshots]
        refit = dict(lc._refit(run, r, fit_now))
        for lane in refit_todo:
            ci, name = lane
            comp = partitioned(run.compressors[ci]).ae_groups()[name]
            if lane in refit:
                comp.params = refit[lane]
                self._pfitted[name][ci, int(self._prung[name][ci])] = True
            st = run.clients[ci]
            st.part_last_refresh[name] = r
            st.part_baseline[name] = lc._lane_baseline(run, lane)
            # the server cannot decode the new rung without its decoder:
            # every switch onto an AE rung ships that group's, refit or not
            bytes_dec += ae.decoder_sync_bytes(comp.params)
            synced.append(lane)
        return bytes_dec, synced, switches

    # ------------------------------------------------------------------
    def note_refit(self, lane) -> None:
        """Lifecycle hook: a refresh refit just landed on ``lane``'s
        active rung, so its distortion probe is trustworthy from here on
        (DESIGN.md §15.2). Called by ``AELifecycle.end_of_round`` for
        cadence/drift refreshes; switch-time refits mark themselves."""
        if isinstance(lane, tuple):
            ci, name = lane
            if getattr(self, "_partitioned", False) and name in self._pfitted:
                self._pfitted[name][ci, int(self._prung[name][ci])] = True
            return
        if not getattr(self, "_partitioned", False):
            self._fitted[lane, int(self._rung[lane])] = True

    def distortion_of(self, ci: int) -> Optional[float]:
        """Latest probed current-rung relative distortion of client ``ci``
        (group-size-weighted across lanes for partitioned ladders), or
        None until the policy has probed them — the ``d_i`` source for the
        async scheduler's distortion-weighted staleness discount
        (DESIGN.md §15.5)."""
        return self._last_err.get(int(ci))

    # ------------------------------------------------------------------
    def _probe_all(self, run, lanes: List[int]) -> np.ndarray:
        """Distortion of EVERY ladder rung for every probed client from
        one batched device dispatch + one host transfer (DESIGN.md §15.1),
        replacing the per-(client, rung) blocking ``float()`` probes: the
        cohort's newest snapshots stack lane-major, each rung's per-client
        codec params stack alongside, and :func:`_batched_rel_errs` vmaps
        the lifecycle probe over lanes inside a single jit. Returns the
        ``(n_rungs, len(lanes))`` numpy matrix and caches the current-rung
        row for :meth:`distortion_of`."""
        flats = jnp.stack([run.clients[ci].snapshots[-1] for ci in lanes])
        specs = tuple(self._comps[lanes[0]][k].spec(self._n)
                      for k in range(self.n_rungs))
        cols = []
        for k in range(self.n_rungs):
            ps = [self._comps[ci][k].codec_params() for ci in lanes]
            cols.append(None if ps[0] is None else jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *ps))
        self.probe_dispatches += 1
        errs = np.asarray(_batched_rel_errs(specs, tuple(cols), flats))
        for j, ci in enumerate(lanes):
            self._last_err[ci] = float(errs[int(self._rung[ci]), j])
        return errs

    def _probe_all_lanes(self, run, lanes: List[Tuple[int, str]]
                         ) -> Dict[Tuple[int, str], np.ndarray]:
        """Per-partition twin of :meth:`_probe_all`: lanes group by
        partition name (segment sizes differ across groups) and each
        group's (rung × lane) matrix comes from one batched dispatch —
        L·R blocking probes collapse to one dispatch/transfer per group.
        Returns each lane's per-rung error column and caches a
        group-size-weighted current-rung distortion per client."""
        out: Dict[Tuple[int, str], np.ndarray] = {}
        acc: Dict[int, List[Tuple[float, float]]] = {}
        by_name: Dict[str, List[int]] = {}
        for ci, name in lanes:
            by_name.setdefault(name, []).append(ci)
        for name, cis in sorted(by_name.items()):
            gsize = self.partition.group_size(name)
            flats = jnp.stack([run.clients[ci].part_snapshots[name][-1]
                               for ci in cis])
            specs = tuple(self._pcomps[cis[0]][name][k].spec(gsize)
                          for k in range(self._pnrungs[name]))
            cols = []
            for k in range(self._pnrungs[name]):
                ps = [self._pcomps[ci][name][k].codec_params()
                      for ci in cis]
                cols.append(None if ps[0] is None else
                            jax.tree_util.tree_map(
                                lambda *xs: jnp.stack(xs), *ps))
            self.probe_dispatches += 1
            errs = np.asarray(_batched_rel_errs(specs, tuple(cols), flats))
            for j, ci in enumerate(cis):
                out[(ci, name)] = errs[:, j]
                acc.setdefault(ci, []).append(
                    (float(errs[int(self._prung[name][ci]), j]),
                     float(gsize)))
        for ci, pairs in acc.items():
            tot = sum(w for _, w in pairs)
            self._last_err[ci] = sum(e * w for e, w in pairs) / max(tot,
                                                                    1.0)
        return out

    # ------------------------------------------------------------------
    def _rung_err(self, run, ci: int, rung: int, flat: jax.Array) -> float:
        """Observed relative reconstruction error of ``flat`` through the
        given rung's codec (the lifecycle's scale-free fidelity probe).
        One blocking host sync per call — kept as the differential oracle
        for :meth:`_probe_all` (tests); the policies plan off the batched
        matrix (DESIGN.md §15.1)."""
        comp = self._comps[ci][rung]
        spec = comp.spec(flat.shape[0])
        return float(_rel_recon_err(spec, comp.codec_params(), flat))

    def _lane_rung_err(self, ci: int, name: str, rung: int,
                       seg: jax.Array) -> float:
        """Per-partition variant of :meth:`_rung_err`: the group's own
        payload segment through that lane's rung codec."""
        comp = self._pcomps[ci][name][rung]
        spec = comp.spec(seg.shape[0])
        return float(_rel_recon_err(spec, comp.codec_params(), seg))

    def _eligible(self, run, r: int, participants: List[int], cooldown: int
                  ) -> List[int]:
        return [ci for ci in participants
                if len(run.clients[ci].snapshots) >= self.min_snapshots
                and r - self._last_switch[ci] >= cooldown]

    def _eligible_lanes(self, run, r: int, participants: List[int],
                        cooldown: int) -> List[Tuple[int, str]]:
        """Movable (client, group) lanes: >1 rung, enough of the group's
        own snapshots to judge (and refit onto), and off lane cooldown."""
        return [
            (ci, name)
            for ci in participants for name in self.partition.names
            if self._pnrungs[name] > 1
            and len(run.clients[ci].part_snapshots.get(name, []))
            >= self.min_snapshots
            and r - int(self._plast[name][ci]) >= cooldown]

    # ------------------------------------------------------------------
    # checkpointing (DESIGN.md §9.3): meta is JSON state, tree is the
    # array-valued state (every rung's AE params — a refit on a non-active
    # rung must not be lost when the client has since stepped away)
    # ------------------------------------------------------------------
    def state_meta(self) -> Dict[str, Any]:
        # JSON shape unchanged from the list-based layout (per-client dicts
        # for lanes, flat int lists otherwise) so old checkpoints restore;
        # the fitted flags + cached distortions (DESIGN.md §15.2/§15.5)
        # ride as extra keys so a resumed run gates and discounts exactly
        # like the uninterrupted one
        dist = {str(ci): float(e)
                for ci, e in sorted(self._last_err.items())}
        if self._partitioned:
            n = len(self._pcomps)
            return {"name": self.name, "partitioned": True,
                    "rung": [{name: int(arr[ci])
                              for name, arr in self._prung.items()}
                             for ci in range(n)],
                    "last_switch": [{name: int(arr[ci])
                                     for name, arr in self._plast.items()}
                                    for ci in range(n)],
                    "fitted": [{name: [bool(x) for x in arr[ci]]
                                for name, arr in self._pfitted.items()}
                               for ci in range(n)],
                    "distortion": dist}
        return {"name": self.name,
                "rung": [int(x) for x in self._rung],
                "last_switch": [int(x) for x in self._last_switch],
                "fitted": [[bool(x) for x in row] for row in self._fitted],
                "distortion": dist}

    def state_tree(self) -> Pytree:
        if self._partitioned:
            return {"codecs": [
                {name: [({"params": c.codec_params()}
                         if c.codec_params() is not None else {})
                        for c in rungs]
                 for name, rungs in row.items()}
                for row in self._pcomps]}
        return {"codecs": [
            [({"params": c.codec_params()}
              if c.codec_params() is not None else {}) for c in row]
            for row in self._comps]}

    def load_state(self, meta: Dict[str, Any], tree: Pytree) -> None:
        if self._partitioned:
            assert meta.get("partitioned"), (
                "checkpoint holds a flat controller state but this run's "
                "controller is per-partition — rebuild the run to match")
            assert len(meta["rung"]) == len(self._pcomps)
            self._prung = {
                name: np.asarray([int(d[name]) for d in meta["rung"]],
                                 dtype=np.int64)
                for name in self.partition.names}
            self._plast = {
                name: np.asarray([int(d[name])
                                  for d in meta["last_switch"]],
                                 dtype=np.int64)
                for name in self.partition.names}
            if "fitted" in meta:     # absent in pre-§15 checkpoints
                self._pfitted = {
                    name: np.asarray([[bool(x) for x in d[name]]
                                      for d in meta["fitted"]], dtype=bool)
                    for name in self.partition.names}
            self._last_err = {int(k): float(v)
                              for k, v in meta.get("distortion",
                                                   {}).items()}
            for ci, row in enumerate(tree["codecs"]):
                for name, rungs in row.items():
                    for k, entry in enumerate(rungs):
                        if entry.get("params") is not None:
                            self._pcomps[ci][name][k].set_codec_params(
                                entry["params"])
                pc = partitioned(self.run.compressors[ci])
                for name in self.partition.names:
                    pc.compressors[name] = \
                        self._pcomps[ci][name][self._prung[name][ci]]
            return
        assert not meta.get("partitioned"), (
            "checkpoint holds a per-partition controller state but this "
            "run's controller is flat — rebuild the run to match")
        assert len(meta["rung"]) == len(self._comps)
        self._rung = np.asarray([int(x) for x in meta["rung"]],
                                dtype=np.int64)
        self._last_switch = np.asarray(
            [int(x) for x in meta["last_switch"]], dtype=np.int64)
        if "fitted" in meta:         # absent in pre-§15 checkpoints
            self._fitted = np.asarray([[bool(x) for x in row]
                                       for row in meta["fitted"]],
                                      dtype=bool)
        self._last_err = {int(k): float(v)
                          for k, v in meta.get("distortion", {}).items()}
        for ci, row in enumerate(tree["codecs"]):
            for k, entry in enumerate(row):
                if entry.get("params") is not None:
                    self._comps[ci][k].set_codec_params(entry["params"])
            self.run.compressors[ci] = self._comps[ci][self._rung[ci]]


@dataclasses.dataclass
class FixedRate(RateController):
    """Pin every client to ``initial_rung`` forever — today's behavior as
    an explicit policy, so fixed-rate runs carry the same ``controller``/
    ``spec_switches`` record fields the adaptive policies do. Trajectory-
    preserving: params, metrics, and ``bytes_up`` equal a controller-less
    run exactly (tested); with AE rungs and no lifecycle it adds only the
    honest initial decoder charges to ``bytes_down``. Never buffers
    snapshots — a policy that cannot switch has no use for them."""

    def observe(self, run, state, comp, flat: jax.Array) -> None:
        return


@dataclasses.dataclass
class DistortionTarget(RateController):
    """Walk the ladder toward the cheapest rung whose observed post-EF
    reconstruction error stays under ``target``: step one rung up when the
    current rung's error (on the newest snapshot) exceeds the target, step
    one rung down when the *cheaper neighbor* already measures under
    ``margin * target`` (hysteresis, so the controller does not oscillate
    across the target boundary). Walking — rather than jumping straight to
    the argmin — matters because an unfit AE rung measures garbage error
    until its switch-time refit has run; stepping explores one refit at a
    time (DESIGN.md §9.1). ``cooldown`` is the minimum number of rounds a
    client stays on a rung between switches.

    All rung errors for the eligible cohort come from ONE batched probe
    dispatch per round (:meth:`RateController._probe_all`, DESIGN.md
    §15.1). A step DOWN additionally requires the cheaper neighbor to be
    *fitted* — an unfit AE rung's garbage reading can spuriously qualify
    and must never win a move (DESIGN.md §15.2); stepping UP keeps the
    exploration semantics above (the switch refit fits the target rung)."""

    target: float = 0.1
    margin: float = 0.7
    cooldown: int = 1
    name: str = "distortion_target"

    def plan(self, run, r: int, participants: List[int]) -> Dict:
        if self._partitioned:
            # same walk per (client, group) lane: each group's distortion
            # is judged on its OWN payload segment, so a drifting conv
            # stack steps up without dragging the head along
            # (DESIGN.md §10.3)
            moves: Dict[Tuple[int, str], int] = {}
            lanes = self._eligible_lanes(run, r, participants,
                                         self.cooldown)
            if not lanes:
                return moves
            errs = self._probe_all_lanes(run, lanes)
            for ci, name in lanes:
                cur = int(self._prung[name][ci])
                col = errs[(ci, name)]
                if col[cur] > self.target and cur + 1 < self._pnrungs[name]:
                    moves[(ci, name)] = cur + 1
                elif (cur > 0 and self._pfitted[name][ci, cur - 1]
                        and col[cur - 1] <= self.margin * self.target):
                    moves[(ci, name)] = cur - 1
            return moves
        moves: Dict[int, int] = {}
        parts = self._eligible(run, r, participants, self.cooldown)
        if not parts:
            return moves
        errs = self._probe_all(run, parts)
        for j, ci in enumerate(parts):
            cur = int(self._rung[ci])
            if errs[cur, j] > self.target and cur + 1 < self.n_rungs:
                moves[ci] = cur + 1
            elif (cur > 0 and self._fitted[ci, cur - 1]
                    and errs[cur - 1, j] <= self.margin * self.target):
                moves[ci] = cur - 1
        return moves


@dataclasses.dataclass
class ByteBudget(RateController):
    """Greedy per-round allocation of a global uplink ``budget`` (bytes per
    round) across the observed cohort, spending bits where drift is
    largest: every participant starts at the cheapest rung, then upgrade
    passes bump clients one rung at a time in descending order of their
    current-rung reconstruction error until the next upgrade would exceed
    the budget. High-drift clients therefore end up at most one rung above
    low-drift ones when the budget runs out mid-pass, and everyone rides
    the cheapest rung when ``budget`` is below the cohort floor. Planned
    costs come from ``codec.wire_bytes`` (DESIGN.md §9.1), so the planned
    round uplink is exactly what the next round's records observe when the
    cohort repeats; under partial participation it tracks to the extent
    cohorts overlap (documented in DESIGN.md §9.1).

    Drift scores for the whole cohort come from ONE batched probe dispatch
    per round (DESIGN.md §15.1); a lane whose *current* rung has never
    been fitted scores 0 — a fictional drift reading must not win marginal
    bytes (DESIGN.md §15.2). ``switch_hysteresis`` closes the decoder
    flapping hole: with ``cooldown=0`` a budget hovering at a rung
    boundary used to flip clients on/off an AE rung every round, shipping
    a full decoder (``bytes_down``) per upward flip while only uplink was
    budgeted. After ANY switch, a lane must now sit ``switch_hysteresis``
    rounds before the greedy may move it onto an AE rung ABOVE its current
    one; downgrades (never ship) are never blocked (DESIGN.md §15.4)."""

    budget: float = float("inf")
    cooldown: int = 0
    switch_hysteresis: int = 2
    name: str = "byte_budget"

    def plan(self, run, r: int, participants: List[int]) -> Dict:
        if self._partitioned:
            return self._plan_lanes(run, r, participants)
        parts = self._eligible(run, r, participants, self.cooldown)
        if not parts:
            return {}
        # participants this round cannot move (cooldown, thin snapshot
        # buffer) still encode next round at their current rung: price
        # them into the budget before allocating upgrades, or the greedy
        # would systematically over-spend the round
        fixed_spend = sum(self._costs[self._rung[ci]]
                          for ci in set(participants) - set(parts))
        errs = self._probe_all(run, parts)
        score = {ci: (float(errs[int(self._rung[ci]), j])
                      if self._fitted[ci, int(self._rung[ci])] else 0.0)
                 for j, ci in enumerate(parts)}
        order = sorted(parts, key=lambda ci: (-score[ci], ci))
        alloc = {ci: 0 for ci in parts}
        spent = fixed_spend + self._costs[0] * len(parts)
        if spent > self.budget:      # budget below the all-cheapest floor
            return {ci: 0 for ci in parts if self._rung[ci] != 0}
        changed = True
        while changed:
            changed = False
            for ci in order:
                nxt = alloc[ci] + 1
                if nxt >= self.n_rungs:
                    continue
                if (nxt > int(self._rung[ci])
                        and self._comps[ci][nxt].ae_compressor() is not None
                        and r - int(self._last_switch[ci])
                        < self.switch_hysteresis):
                    continue         # decoder re-ship hysteresis (§15.4)
                delta = self._costs[nxt] - self._costs[alloc[ci]]
                if spent + delta <= self.budget:
                    alloc[ci] = nxt
                    spent += delta
                    changed = True
        return {ci: k for ci, k in alloc.items() if k != self._rung[ci]}

    def _plan_lanes(self, run, r: int, participants: List[int]) -> Dict:
        """Per-partition greedy under the ONE shared budget: every
        (client, group) lane competes for the same marginal bytes, so a
        high-drift conv stack can out-bid every head lane in the cohort —
        spending bits per layer where distortion hurts most
        (DESIGN.md §10.3). Same shape as the flat plan: movable lanes
        start at their group's cheapest rung, frozen lanes are priced at
        their current rung, upgrade passes walk lanes in descending
        drift."""
        participants = sorted(set(participants))
        lanes = self._eligible_lanes(run, r, participants, self.cooldown)
        if not lanes:
            return {}
        all_lanes = [(ci, name) for ci in participants
                     for name in self.partition.names]
        lane_set = set(lanes)
        frozen = [ln for ln in all_lanes if ln not in lane_set]
        fixed_spend = sum(self._pcosts[name][self._prung[name][ci]]
                          for ci, name in frozen)
        errs = self._probe_all_lanes(run, lanes)
        score = {
            (ci, name): (float(errs[(ci, name)][int(self._prung[name][ci])])
                         if self._pfitted[name][ci,
                                               int(self._prung[name][ci])]
                         else 0.0)
            for ci, name in lanes}
        order = sorted(lanes, key=lambda ln: (-score[ln], ln))
        alloc = {ln: 0 for ln in lanes}
        spent = fixed_spend + sum(self._pcosts[name][0]
                                  for _, name in lanes)
        if spent > self.budget:      # budget below the all-cheapest floor
            return {(ci, name): 0 for ci, name in lanes
                    if self._prung[name][ci] != 0}
        changed = True
        while changed:
            changed = False
            for ln in order:
                ci, name = ln
                nxt = alloc[ln] + 1
                if nxt >= self._pnrungs[name]:
                    continue
                if (nxt > int(self._prung[name][ci])
                        and self._pcomps[ci][name][nxt].ae_compressor()
                        is not None
                        and r - int(self._plast[name][ci])
                        < self.switch_hysteresis):
                    continue         # decoder re-ship hysteresis (§15.4)
                delta = self._pcosts[name][nxt] - \
                    self._pcosts[name][alloc[ln]]
                if spent + delta <= self.budget:
                    alloc[ln] = nxt
                    spent += delta
                    changed = True
        return {(ci, name): k for (ci, name), k in alloc.items()
                if k != self._prung[name][ci]}


@dataclasses.dataclass
class RDBudget(RateController):
    """Lagrangian rate-distortion water-filling of the shared uplink
    budget (ROADMAP item 4; Mitchell et al. 2022 frame the FL
    communication-accuracy trade-off as exactly this problem). Where
    :class:`ByteBudget` spends marginal bytes by drift *rank*, this
    controller spends them by marginal distortion *per byte*:

    1. every movable lane's distortion is probed at ALL rungs against its
       snapshot ring in one batched dispatch (DESIGN.md §15.1);
    2. each lane's (bytes, distortion) curve is pruned to its lower convex
       hull (:func:`_hull_prune`) — unfit rungs are excluded, they can
       neither win nor block (§15.2), and a lane whose CURRENT rung is
       unfit is held frozen at its current price (no honest reference
       point; seed the ladder from a pre-pass, as ``fc_ae_ladder(params=)``
       does, to avoid the cold-start hold);
    3. a switch onto an AE rung would ship that rung's decoder, so the
       planner adds ``decoder_sync_bytes / ship_amortize_rounds`` to such
       rungs' PRICE — the move must earn back its downlink cost in
       marginal distortion before it can out-bid a stay (§15.3), which is
       what keeps a boundary-hovering budget from flapping decoders the
       way un-hysteresed greedy did;
    4. the multiplier λ is swept over the merged hull steps
       (:func:`_rd_waterfill`) until the budget is exhausted — marginal
       distortion per priced byte is equalized across lanes at the stop
       point, and ``last_lambda`` records λ* for the benchmark's frontier
       artifact (``lambda_trace`` keeps the per-round history);
    5. a final integer-allocation top-up (:func:`_rd_topup`) spends any
       stranded remainder on affordable pruned interior rungs — decoder
       pricing can bend curves concave so the hull keeps only a jump the
       budget can't buy, and without the top-up those lanes would sit at
       the floor while greedy's one-rung walk overtakes them.

    Frozen/ineligible participants are priced at their current rung like
    greedy; a budget below the all-cheapest floor drops every movable
    lane to rung 0, mirroring :class:`ByteBudget` exactly (the
    differential contract). State (rung occupancy, fitted flags, cached
    distortions, every rung's AE params) rides the shared
    ``state_meta``/``state_tree`` checkpoint path bit-exactly."""

    budget: float = float("inf")
    cooldown: int = 0
    # decoder-ship amortization horizon (rounds): the price of switching
    # onto an AE rung includes its decoder ship spread over this many
    # rounds (DESIGN.md §15.3)
    ship_amortize_rounds: float = 8.0
    name: str = "rd_budget"
    # per-plan λ* telemetry ``[(round, λ*)]`` for the benchmark's Pareto
    # artifact; diagnostic only — it feeds no planning decision and does
    # not ride the checkpoint
    lambda_trace: List[Tuple[int, Optional[float]]] = dataclasses.field(
        default_factory=list, repr=False)

    # λ* of the last plan (None when no step was taken / no plan yet)
    last_lambda = None

    def _lane_points(self, ci: int, cur: int, col: np.ndarray
                     ) -> Optional[List[Tuple[int, float, float, float]]]:
        """One client's candidate operating points ``(rung, cost, price,
        dist)`` from its probed error column; None when the current rung
        is unfit (hold the lane, §15.2)."""
        if not self._fitted[ci, cur]:
            return None
        pts = []
        for k in range(self.n_rungs):
            if not self._fitted[ci, k]:
                continue
            price = cost = float(self._costs[k])
            sub = self._comps[ci][k].ae_compressor()
            if k != cur and sub is not None:
                price += (ae.decoder_sync_bytes(sub.codec_params())
                          / max(self.ship_amortize_rounds, 1e-9))
            pts.append((k, cost, price, float(col[k])))
        return pts

    def _lane_points_group(self, ci: int, name: str, cur: int,
                           col: np.ndarray
                           ) -> Optional[List[Tuple[int, float, float,
                                                    float]]]:
        """Per-partition twin of :meth:`_lane_points`."""
        if not self._pfitted[name][ci, cur]:
            return None
        pts = []
        for k in range(self._pnrungs[name]):
            if not self._pfitted[name][ci, k]:
                continue
            price = cost = float(self._pcosts[name][k])
            sub = self._pcomps[ci][name][k].ae_compressor()
            if k != cur and sub is not None:
                price += (ae.decoder_sync_bytes(sub.codec_params())
                          / max(self.ship_amortize_rounds, 1e-9))
            pts.append((k, cost, price, float(col[k])))
        return pts

    def plan(self, run, r: int, participants: List[int]) -> Dict:
        moves = (self._plan_lanes(run, r, participants)
                 if self._partitioned
                 else self._plan_flat(run, r, participants))
        self.lambda_trace.append((r, self.last_lambda))
        return moves

    def _plan_flat(self, run, r: int, participants: List[int]) -> Dict:
        parts = self._eligible(run, r, participants, self.cooldown)
        if not parts:
            self.last_lambda = None
            return {}
        fixed_spend = sum(self._costs[self._rung[ci]]
                          for ci in set(participants) - set(parts))
        errs = self._probe_all(run, parts)
        curves: Dict[int, Tuple[List, float]] = {}
        raw: Dict[int, List] = {}
        for j, ci in enumerate(parts):
            cur = int(self._rung[ci])
            pts = self._lane_points(ci, cur, errs[:, j])
            if pts is None:          # unfit current rung: hold the lane
                fixed_spend += self._costs[cur]
                continue
            curves[ci] = (_hull_prune(pts), float(errs[cur, j]))
            raw[ci] = pts
        alloc, lam = (_rd_waterfill(curves, self.budget, fixed_spend)
                      if curves else ({}, None))
        if alloc is None:            # below the all-cheapest floor:
            self.last_lambda = None  # mirror ByteBudget exactly
            return {ci: 0 for ci in parts if self._rung[ci] != 0}
        chosen = {ci: curves[ci][0][idx] for ci, idx in alloc.items()}
        spent = fixed_spend + sum(p[1] for p in chosen.values())
        tlam = _rd_topup(raw, chosen, self.budget, spent)
        self.last_lambda = tlam if tlam is not None else lam
        moves: Dict[int, int] = {}
        for ci, p in chosen.items():
            if p[0] != int(self._rung[ci]):
                moves[ci] = p[0]
        return moves

    def _plan_lanes(self, run, r: int, participants: List[int]) -> Dict:
        """Per-partition water-fill under the ONE shared budget: every
        (client, group) lane's hull competes in the same λ sweep, so
        marginal distortion per priced byte equalizes across layers as
        well as clients (DESIGN.md §15.3 over §10.3)."""
        participants = sorted(set(participants))
        lanes = self._eligible_lanes(run, r, participants, self.cooldown)
        if not lanes:
            self.last_lambda = None
            return {}
        lane_set = set(lanes)
        fixed_spend = sum(
            self._pcosts[name][self._prung[name][ci]]
            for ci in participants for name in self.partition.names
            if (ci, name) not in lane_set)
        errs = self._probe_all_lanes(run, lanes)
        curves: Dict[Tuple[int, str], Tuple[List, float]] = {}
        raw: Dict[Tuple[int, str], List] = {}
        for ln in lanes:
            ci, name = ln
            cur = int(self._prung[name][ci])
            pts = self._lane_points_group(ci, name, cur, errs[ln])
            if pts is None:          # unfit current rung: hold the lane
                fixed_spend += self._pcosts[name][cur]
                continue
            curves[ln] = (_hull_prune(pts), float(errs[ln][cur]))
            raw[ln] = pts
        alloc, lam = (_rd_waterfill(curves, self.budget, fixed_spend)
                      if curves else ({}, None))
        if alloc is None:            # below the all-cheapest floor:
            self.last_lambda = None  # mirror ByteBudget exactly
            return {(ci, name): 0 for ci, name in lanes
                    if self._prung[name][ci] != 0}
        chosen = {ln: curves[ln][0][idx] for ln, idx in alloc.items()}
        spent = fixed_spend + sum(p[1] for p in chosen.values())
        tlam = _rd_topup(raw, chosen, self.budget, spent)
        self.last_lambda = tlam if tlam is not None else lam
        moves: Dict[Tuple[int, str], int] = {}
        for ln, p in chosen.items():
            ci, name = ln
            if p[0] != int(self._prung[name][ci]):
                moves[ln] = p[0]
        return moves

"""Adaptive rate control: per-client dynamic codec selection (DESIGN.md §9).

The paper sells the AE scheme as *dynamic* — "the compression ratio ... can
be modified based on the accuracy requirements, computational capacity, and
other requirements of the given FL setup" (§4.2) — but a static compressor
assignment never exercises that knob. Mitchell et al. (2022) show the right
rate-distortion operating point moves over training, and FedZip (Malekijoo
et al., 2021) adapts the compression stack per layer; this module makes the
operating point a first-class *policy*:

* a **ladder** is a per-client list of pre-built compressors ordered
  cheapest-uplink-first. Every rung's spec is a frozen hashable
  ``CodecSpec``, so whatever rung a client sits on, the server's fused
  ``decode_and_aggregate`` call keeps hitting the jit cache (heterogeneous
  cohorts group by spec before dispatch — DESIGN.md §9.2);
* a :class:`RateController` decides, at the *end* of each round (mirroring
  the AE lifecycle: a new codec takes effect the round after the server
  learns its decoder), which rung each participant occupies next:

  - :class:`FixedRate` — today's behavior, the default: never switches.
    Trajectory-preserving by construction (params/metrics/bytes_up are
    untouched; with AE rungs it additionally charges the honest initial
    decoder ships when no ``AELifecycle`` is attached).
  - :class:`DistortionTarget` — walk the ladder toward the cheapest rung
    whose observed post-EF reconstruction error stays under ``target``
    (step up when over target, step down with hysteresis), measured on the
    per-client snapshot buffers the AE lifecycle already maintains.
  - :class:`ByteBudget` — greedy per-round allocation of a global uplink
    budget across the observed cohort: everyone starts on the cheapest
    rung and marginal bytes go to the clients whose current-rung
    reconstruction drift is largest.

* a switch onto an AE rung triggers a refit of that rung's AE on the
  client's snapshot buffer through the existing ``AELifecycle`` cohort path
  (same-round same-shape fits share ONE ``train_autoencoder_cohort``
  dispatch, DESIGN.md §8.1) and **ships the new decoder** — charged to
  ``RoundRecord.bytes_down``/``bytes_decoder`` exactly like a lifecycle
  refresh, so ``savings.reconcile`` stays honest under rung churn
  (DESIGN.md §9.3). Controller decisions ride the record's
  ``spec_switches``/``controller`` fields, and the whole controller state
  (rung occupancy, cooldowns, every rung's AE params) survives
  ``save_federated_state`` for bit-exact resume.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig
from repro.core import autoencoder as ae
from repro.core import codec
from repro.core.compressor import (ComposedCompressor, Compressor,
                                   FCAECompressor, PartitionedCompressor,
                                   partitioned)
from repro.core.lifecycle import (AELifecycle, _rel_recon_err,
                                  buffer_snapshot)

Pytree = Any
# [client][rung] (flat), or [client]{group: [rung]} (per-partition ladders,
# DESIGN.md §10.3) — cheapest-uplink-first within every rung list
Ladder = List[Any]


def fc_ae_ladder(n_clients: int, input_dim: int,
                 latent_dims: Sequence[int] = (8, 32, 128),
                 hidden: Tuple[int, ...] = (64,),
                 bits: Optional[int] = None,
                 seed: int = 0,
                 params: Optional[Sequence[Sequence[Pytree]]] = None,
                 ) -> Ladder:
    """Build the paper-faithful ladder: per-client FC autoencoders at
    increasing latent widths (cheapest uplink first — ``latent_dims`` must
    be ascending), optionally composed with ``bits``-wide latent
    quantization (the §4.2 "orthogonal add-on"). ``params[ci][k]`` supplies
    pre-trained AE params (e.g. from a pre-pass); omitted rungs start at a
    fresh per-(client, rung) init and rely on the switch-time refit
    (DESIGN.md §9.1)."""
    assert list(latent_dims) == sorted(latent_dims), (
        "ladder rungs must be ordered cheapest-uplink-first "
        f"(ascending latent dims), got {latent_dims}")
    out: Ladder = []
    for ci in range(n_clients):
        row: List[Compressor] = []
        for k, latent in enumerate(latent_dims):
            cfg = AEConfig(input_dim=input_dim, encoder_hidden=hidden,
                           latent_dim=latent)
            if params is not None and params[ci][k] is not None:
                p = params[ci][k]
            else:
                p = ae.init_fc_ae(
                    jax.random.PRNGKey(
                        (seed * 1_000_003 + ci * 1009 + k) % 2 ** 31), cfg)
            comp: Compressor = FCAECompressor(p, cfg)
            if bits is not None:
                comp = ComposedCompressor(comp, bits=bits)
            row.append(comp)
        out.append(row)
    return out


def partition_ladder(n_clients: int, pmap,
                     rung_factories: Dict[str, Sequence],
                     ) -> Ladder:
    """Build a per-(client, partition) ladder (DESIGN.md §10.3):
    ``rung_factories[group] = [factory, ...]`` cheapest-uplink-first, where
    each ``factory(ci, group_size) -> Compressor`` builds one client's rung
    for that group (AE rungs want per-client params; pointwise factories
    can ignore ``ci``). Every partition group of ``pmap`` needs an entry —
    single-entry groups are pinned (the controller never moves that lane).
    The returned ladder rows are ``{group: [Compressor, ...]}`` dicts;
    binding a :class:`RateController` to one installs a
    ``PartitionedCompressor`` per client and walks each (client, group)
    lane independently under the shared policy."""
    assert set(rung_factories) == set(pmap.names), (
        f"rung factories {sorted(rung_factories)} != partition groups "
        f"{sorted(pmap.names)}")
    out: Ladder = []
    for ci in range(n_clients):
        out.append({
            name: [factory(ci, pmap.group_size(name))
                   for factory in rung_factories[name]]
            for name in pmap.names})
    return out


@dataclasses.dataclass
class RateController:
    """Base policy: owns the ladder, the per-client rung occupancy, and the
    switch→refit→decoder-ship mechanics shared by every policy. Subclasses
    implement :meth:`plan` only. With ``ladder=None`` the run's existing
    compressors become a one-rung ladder (nothing can switch — the
    :class:`FixedRate` degenerate case).

    ``min_snapshots`` gates switching (a refit needs data); ``buffer_size``
    bounds the snapshot ring this controller maintains for clients the AE
    lifecycle does not cover (pointwise rungs, or no lifecycle attached).
    The ``refit_*`` knobs configure the internal :class:`AELifecycle` used
    for switch-time refits when the run has no lifecycle of its own — with
    one attached, its hyperparameters win (one refit configuration per
    run)."""

    ladder: Optional[Ladder] = None
    initial_rung: int = 0
    min_snapshots: int = 2
    buffer_size: int = 8
    refit_epochs: int = 30
    refit_batch: int = 8
    refit_lr: float = 3e-3
    seed: int = 0
    # the partition.PartitionMap behind a per-partition ladder (rows are
    # {group: [rungs]} dicts, see partition_ladder) — None for flat ladders
    partition: Optional[Any] = None
    name: str = "fixed"

    # ------------------------------------------------------------------
    def bind(self, run) -> None:
        """Attach to a ``FederatedRun`` and install the ladder's initial
        rung as each client's compressor. Called once from the run ctor —
        a controller carries per-run state, one instance per run."""
        assert getattr(self, "run", None) is None, (
            "controller is already bound to a FederatedRun; create a fresh "
            "controller instance per run")
        self.run = run
        n = len(run.datasets)
        self._partitioned = (self.ladder is not None and len(self.ladder)
                             and isinstance(self.ladder[0], dict))
        if self._partitioned:
            self._bind_partitioned(run, n)
            return
        if self.ladder is not None:
            assert len(self.ladder) == n, (
                f"ladder has {len(self.ladder)} clients, run has {n}")
            widths = {len(row) for row in self.ladder}
            assert len(widths) == 1, "every client needs the same rung count"
            self._comps = [list(row) for row in self.ladder]
            assert 0 <= self.initial_rung < len(self._comps[0])
            for ci in range(n):
                run.compressors[ci] = self._comps[ci][self.initial_rung]
        else:
            self._comps = [[c] for c in run.compressors]
        self.n_rungs = len(self._comps[0])
        start = self.initial_rung if self.ladder is not None else 0
        # rung occupancy as packed arrays (DESIGN.md §12.1): O(1) numpy
        # rows instead of O(population) Python list cells — and the layout
        # jit-native rate control (ROADMAP item 4) will gather from
        self._rung = np.full(n, start, dtype=np.int64)
        self._last_switch = np.full(n, -(10 ** 9), dtype=np.int64)
        self._any_ae = any(c.ae_compressor() is not None
                           for row in self._comps for c in row)
        self._refitter = AELifecycle(
            buffer_size=self.buffer_size, min_snapshots=self.min_snapshots,
            refresh_epochs=self.refit_epochs, batch_size=self.refit_batch,
            lr=self.refit_lr, seed=self.seed)
        flat, _ = ravel_pytree(run.global_params)
        self._n = int(flat.size)
        # one price list serves every client, so rung k must mean the SAME
        # spec for all of them (params may differ — specs are the static
        # shapes/bits the wire cost and the jit cache key on)
        for ci, row in enumerate(self._comps[1:], start=1):
            for k, c in enumerate(row):
                assert c.spec(self._n) == self._comps[0][k].spec(self._n), (
                    f"client {ci} rung {k} spec differs from client 0's — "
                    "per-rung specs must agree across the ladder")
        self._costs = [codec.wire_bytes(self._comps[0][k].spec(self._n),
                                        self._comps[0][k].codec_params())
                       for k in range(self.n_rungs)]
        assert all(a <= b for a, b in zip(self._costs, self._costs[1:])), (
            "ladder rungs must be ordered cheapest-uplink-first, got wire "
            f"costs {self._costs}")

    def _bind_partitioned(self, run, n: int) -> None:
        """Per-partition ladders (DESIGN.md §10.3): the unit of control is
        the *lane* ``(client, group)`` — each walks its own rung list, all
        lanes share one policy (and, for :class:`ByteBudget`, one budget).
        Installs a ``PartitionedCompressor`` per client assembled from each
        group's initial rung; a later switch swaps just that group's
        sub-compressor in place."""
        assert self.partition is not None, (
            "a per-partition ladder (dict rows) needs the controller's "
            "``partition=`` PartitionMap")
        assert len(self.ladder) == n, (
            f"ladder has {len(self.ladder)} clients, run has {n}")
        names = list(self.partition.names)
        for ci, row in enumerate(self.ladder):
            assert set(row) == set(names), (
                f"client {ci} ladder groups {sorted(row)} != partition "
                f"groups {sorted(names)}")
        self._pcomps = [
            {name: list(self.ladder[ci][name]) for name in names}
            for ci in range(n)]
        self._pnrungs = {name: len(self._pcomps[0][name]) for name in names}
        for ci in range(1, n):
            for name in names:
                assert len(self._pcomps[ci][name]) == self._pnrungs[name], (
                    f"client {ci} group {name!r}: rung count differs")
        # lane occupancy as one packed (n,) array per group — same SoA
        # layout as the flat ladder's _rung (DESIGN.md §12.1)
        self._prung = {
            name: np.full(n, min(self.initial_rung,
                                 self._pnrungs[name] - 1), dtype=np.int64)
            for name in names}
        self._plast = {name: np.full(n, -(10 ** 9), dtype=np.int64)
                       for name in names}
        for ci in range(n):
            run.compressors[ci] = PartitionedCompressor(
                self.partition,
                {name: self._pcomps[ci][name][self._prung[name][ci]]
                 for name in names})
        self._any_ae = any(c.ae_compressor() is not None
                           for row in self._pcomps
                           for rungs in row.values() for c in rungs)
        self._refitter = AELifecycle(
            buffer_size=self.buffer_size, min_snapshots=self.min_snapshots,
            refresh_epochs=self.refit_epochs, batch_size=self.refit_batch,
            lr=self.refit_lr, seed=self.seed)
        flat, _ = ravel_pytree(run.global_params)
        self._n = int(flat.size)
        assert self._n == self.partition.size, (
            f"partition map covers {self.partition.size} params but the "
            f"model has {self._n}")
        # one price list per group: rung k of group g must mean the same
        # spec for every client (params may differ)
        for name in names:
            gsize = self.partition.group_size(name)
            for ci in range(1, n):
                for k, c in enumerate(self._pcomps[ci][name]):
                    assert c.spec(gsize) == \
                        self._pcomps[0][name][k].spec(gsize), (
                            f"client {ci} group {name!r} rung {k} spec "
                            "differs from client 0's — per-rung specs must "
                            "agree across the ladder")
        self._pcosts = {
            name: [codec.wire_bytes(
                self._pcomps[0][name][k].spec(
                    self.partition.group_size(name)),
                self._pcomps[0][name][k].codec_params())
                for k in range(self._pnrungs[name])]
            for name in names}
        for name, costs in self._pcosts.items():
            assert all(a <= b for a, b in zip(costs, costs[1:])), (
                f"group {name!r} rungs must be ordered "
                f"cheapest-uplink-first, got wire costs {costs}")

    # ------------------------------------------------------------------
    def rung_of(self, ci: int) -> int:
        return int(self._rung[ci])

    def rung_of_group(self, ci: int, name: str) -> int:
        """Current rung of the ``(ci, name)`` lane (per-partition ladders)."""
        return int(self._prung[name][ci])

    def wire_cost(self, rung: int) -> float:
        """Planned uplink bytes of one payload at ``rung`` (static — from
        ``codec.wire_bytes``, asserted equal to observed encodes)."""
        return float(self._costs[rung])

    def wire_cost_group(self, name: str, rung: int) -> float:
        return float(self._pcosts[name][rung])

    # ------------------------------------------------------------------
    def observe(self, run, state, comp, flat: jax.Array) -> None:
        """Buffer the post-EF flat vector a client just encoded, for the
        clients the AE lifecycle does not already buffer (pointwise rungs,
        or no lifecycle attached) — distortion decisions need the codec's
        true input distribution whatever the current rung is. A ladder
        that cannot move (one rung) buffers nothing: the vectors would be
        model-sized dead weight in memory and in every checkpoint."""
        if self._partitioned:
            from repro.core import partition
            pc = partitioned(comp)
            ae_groups = pc.ae_groups()
            for name in self.partition.names:
                if self._pnrungs[name] <= 1:
                    continue             # pinned lane: nothing to decide
                if run.lifecycle is not None and name in ae_groups:
                    continue             # lifecycle buffered this group
                seg = partition.gather(pc.pmap.slices_of(name), flat)
                ring = state.part_snapshots.setdefault(name, [])
                ring.append(jnp.asarray(seg))
                del ring[:-self.buffer_size]
            return
        if self.n_rungs <= 1:
            return
        if run.lifecycle is not None and comp.ae_compressor() is not None:
            return                   # lifecycle buffered this one already
        buffer_snapshot(state, flat, self.buffer_size)

    # ------------------------------------------------------------------
    def plan(self, run, r: int, participants: List[int]) -> Dict[int, int]:
        """Policy hook: proposed rung per client (omit = stay). The base
        controller is FixedRate — it never proposes a move."""
        return {}

    # ------------------------------------------------------------------
    def end_of_round(self, run, r: int, participants: Sequence[int]
                     ) -> Tuple[float, List[int], List[Tuple[int, int, int]]]:
        """Advance the controller after round ``r``'s aggregation: apply
        the policy's planned moves, refit switched-to AE rungs on the
        snapshot buffers (grouped cohort dispatch), and ship their decoders.
        Returns ``(decoder_bytes, synced_client_ids, switches)`` where each
        switch is ``(client, from_rung, to_rung)``. Runs *after* the AE
        lifecycle's own ``end_of_round`` so this round's decoder traffic
        (initial ships, cadence/drift refreshes) is charged against the
        rung that actually served the round (DESIGN.md §9.3)."""
        bytes_dec, synced = 0.0, []
        if run.lifecycle is None and self._any_ae:
            # no user lifecycle: the internal refitter still owes the honest
            # initial decoder ships of Eq. 5/6 (DESIGN.md §8.3)
            bytes_dec, synced = self._refitter.end_of_round(
                run, r, participants)
        moves = self.plan(run, r, sorted(set(participants)))
        if self._partitioned:
            b, s, switches = self._apply_lane_moves(run, r, moves)
            return bytes_dec + b, sorted(synced + s), switches
        switches: List[Tuple[int, int, int]] = []
        refit_todo: List[int] = []
        for ci in sorted(moves):
            new = int(moves[ci])
            old = int(self._rung[ci])
            if new == old:
                continue
            self._rung[ci] = new
            run.compressors[ci] = self._comps[ci][new]
            self._last_switch[ci] = r
            switches.append((ci, old, new))
            if run.compressors[ci].ae_compressor() is not None:
                refit_todo.append(ci)
            else:
                run.clients[ci].ae_baseline = None   # stale vs old AE rung
        lc = run.lifecycle if run.lifecycle is not None else self._refitter
        fit_now = [ci for ci in refit_todo
                   if len(run.clients[ci].snapshots) >= self.min_snapshots]
        refit = dict(lc._refit(run, r, fit_now))
        for ci in refit_todo:
            comp = run.compressors[ci].ae_compressor()
            if ci in refit:
                comp.params = refit[ci]
            st = run.clients[ci]
            st.last_refresh = r
            st.ae_baseline = lc._lane_baseline(run, ci)
            # the server cannot decode the new rung without its decoder:
            # every switch onto an AE rung ships one, refit or not
            bytes_dec += ae.decoder_sync_bytes(comp.params)
            synced.append(ci)
        # multiset: initial ship + switch re-ship in one round = 2 syncs
        return bytes_dec, sorted(synced), switches

    def _apply_lane_moves(self, run, r: int, moves: Dict
                          ) -> Tuple[float, List, List]:
        """Per-partition half of :meth:`end_of_round`: apply ``moves``
        keyed by ``(client, group)`` lane, swapping just that group's
        sub-compressor inside the client's ``PartitionedCompressor``;
        switched-onto AE lanes refit on the group's own snapshot ring and
        ship that group's decoder (DESIGN.md §10.3). Switch records carry
        the lane as the client field: ``((ci, group), from, to)``."""
        bytes_dec, synced = 0.0, []
        switches: List[Tuple[Any, int, int]] = []
        refit_todo: List[Tuple[int, str]] = []
        for lane in sorted(moves):
            ci, name = lane
            new = int(moves[lane])
            old = int(self._prung[name][ci])
            if new == old:
                continue
            self._prung[name][ci] = new
            pc = partitioned(run.compressors[ci])
            pc.compressors[name] = self._pcomps[ci][name][new]
            self._plast[name][ci] = r
            switches.append((lane, old, new))
            if pc.compressors[name].ae_compressor() is not None:
                refit_todo.append(lane)
            else:
                run.clients[ci].part_baseline[name] = None
        lc = run.lifecycle if run.lifecycle is not None else self._refitter
        fit_now = [
            lane for lane in refit_todo
            if len(run.clients[lane[0]].part_snapshots.get(lane[1], []))
            >= self.min_snapshots]
        refit = dict(lc._refit(run, r, fit_now))
        for lane in refit_todo:
            ci, name = lane
            comp = partitioned(run.compressors[ci]).ae_groups()[name]
            if lane in refit:
                comp.params = refit[lane]
            st = run.clients[ci]
            st.part_last_refresh[name] = r
            st.part_baseline[name] = lc._lane_baseline(run, lane)
            # the server cannot decode the new rung without its decoder:
            # every switch onto an AE rung ships that group's, refit or not
            bytes_dec += ae.decoder_sync_bytes(comp.params)
            synced.append(lane)
        return bytes_dec, synced, switches

    # ------------------------------------------------------------------
    def _rung_err(self, run, ci: int, rung: int, flat: jax.Array) -> float:
        """Observed relative reconstruction error of ``flat`` through the
        given rung's codec (the lifecycle's scale-free fidelity probe)."""
        comp = self._comps[ci][rung]
        spec = comp.spec(flat.shape[0])
        return float(_rel_recon_err(spec, comp.codec_params(), flat))

    def _lane_rung_err(self, ci: int, name: str, rung: int,
                       seg: jax.Array) -> float:
        """Per-partition variant of :meth:`_rung_err`: the group's own
        payload segment through that lane's rung codec."""
        comp = self._pcomps[ci][name][rung]
        spec = comp.spec(seg.shape[0])
        return float(_rel_recon_err(spec, comp.codec_params(), seg))

    def _eligible(self, run, r: int, participants: List[int], cooldown: int
                  ) -> List[int]:
        return [ci for ci in participants
                if len(run.clients[ci].snapshots) >= self.min_snapshots
                and r - self._last_switch[ci] >= cooldown]

    def _eligible_lanes(self, run, r: int, participants: List[int],
                        cooldown: int) -> List[Tuple[int, str]]:
        """Movable (client, group) lanes: >1 rung, enough of the group's
        own snapshots to judge (and refit onto), and off lane cooldown."""
        return [
            (ci, name)
            for ci in participants for name in self.partition.names
            if self._pnrungs[name] > 1
            and len(run.clients[ci].part_snapshots.get(name, []))
            >= self.min_snapshots
            and r - int(self._plast[name][ci]) >= cooldown]

    # ------------------------------------------------------------------
    # checkpointing (DESIGN.md §9.3): meta is JSON state, tree is the
    # array-valued state (every rung's AE params — a refit on a non-active
    # rung must not be lost when the client has since stepped away)
    # ------------------------------------------------------------------
    def state_meta(self) -> Dict[str, Any]:
        # JSON shape unchanged from the list-based layout (per-client dicts
        # for lanes, flat int lists otherwise) so old checkpoints restore
        if self._partitioned:
            n = len(self._pcomps)
            return {"name": self.name, "partitioned": True,
                    "rung": [{name: int(arr[ci])
                              for name, arr in self._prung.items()}
                             for ci in range(n)],
                    "last_switch": [{name: int(arr[ci])
                                     for name, arr in self._plast.items()}
                                    for ci in range(n)]}
        return {"name": self.name,
                "rung": [int(x) for x in self._rung],
                "last_switch": [int(x) for x in self._last_switch]}

    def state_tree(self) -> Pytree:
        if self._partitioned:
            return {"codecs": [
                {name: [({"params": c.codec_params()}
                         if c.codec_params() is not None else {})
                        for c in rungs]
                 for name, rungs in row.items()}
                for row in self._pcomps]}
        return {"codecs": [
            [({"params": c.codec_params()}
              if c.codec_params() is not None else {}) for c in row]
            for row in self._comps]}

    def load_state(self, meta: Dict[str, Any], tree: Pytree) -> None:
        if self._partitioned:
            assert meta.get("partitioned"), (
                "checkpoint holds a flat controller state but this run's "
                "controller is per-partition — rebuild the run to match")
            assert len(meta["rung"]) == len(self._pcomps)
            self._prung = {
                name: np.asarray([int(d[name]) for d in meta["rung"]],
                                 dtype=np.int64)
                for name in self.partition.names}
            self._plast = {
                name: np.asarray([int(d[name])
                                  for d in meta["last_switch"]],
                                 dtype=np.int64)
                for name in self.partition.names}
            for ci, row in enumerate(tree["codecs"]):
                for name, rungs in row.items():
                    for k, entry in enumerate(rungs):
                        if entry.get("params") is not None:
                            self._pcomps[ci][name][k].set_codec_params(
                                entry["params"])
                pc = partitioned(self.run.compressors[ci])
                for name in self.partition.names:
                    pc.compressors[name] = \
                        self._pcomps[ci][name][self._prung[name][ci]]
            return
        assert not meta.get("partitioned"), (
            "checkpoint holds a per-partition controller state but this "
            "run's controller is flat — rebuild the run to match")
        assert len(meta["rung"]) == len(self._comps)
        self._rung = np.asarray([int(x) for x in meta["rung"]],
                                dtype=np.int64)
        self._last_switch = np.asarray(
            [int(x) for x in meta["last_switch"]], dtype=np.int64)
        for ci, row in enumerate(tree["codecs"]):
            for k, entry in enumerate(row):
                if entry.get("params") is not None:
                    self._comps[ci][k].set_codec_params(entry["params"])
            self.run.compressors[ci] = self._comps[ci][self._rung[ci]]


@dataclasses.dataclass
class FixedRate(RateController):
    """Pin every client to ``initial_rung`` forever — today's behavior as
    an explicit policy, so fixed-rate runs carry the same ``controller``/
    ``spec_switches`` record fields the adaptive policies do. Trajectory-
    preserving: params, metrics, and ``bytes_up`` equal a controller-less
    run exactly (tested); with AE rungs and no lifecycle it adds only the
    honest initial decoder charges to ``bytes_down``. Never buffers
    snapshots — a policy that cannot switch has no use for them."""

    def observe(self, run, state, comp, flat: jax.Array) -> None:
        return


@dataclasses.dataclass
class DistortionTarget(RateController):
    """Walk the ladder toward the cheapest rung whose observed post-EF
    reconstruction error stays under ``target``: step one rung up when the
    current rung's error (on the newest snapshot) exceeds the target, step
    one rung down when the *cheaper neighbor* already measures under
    ``margin * target`` (hysteresis, so the controller does not oscillate
    across the target boundary). Walking — rather than jumping straight to
    the argmin — matters because an unfit AE rung measures garbage error
    until its switch-time refit has run; stepping explores one refit at a
    time (DESIGN.md §9.1). ``cooldown`` is the minimum number of rounds a
    client stays on a rung between switches."""

    target: float = 0.1
    margin: float = 0.7
    cooldown: int = 1
    name: str = "distortion_target"

    def plan(self, run, r: int, participants: List[int]) -> Dict:
        if self._partitioned:
            # same walk per (client, group) lane: each group's distortion
            # is judged on its OWN payload segment, so a drifting conv
            # stack steps up without dragging the head along
            # (DESIGN.md §10.3)
            moves: Dict[Tuple[int, str], int] = {}
            for ci, name in self._eligible_lanes(run, r, participants,
                                                 self.cooldown):
                seg = run.clients[ci].part_snapshots[name][-1]
                cur = int(self._prung[name][ci])
                err = self._lane_rung_err(ci, name, cur, seg)
                if err > self.target and cur + 1 < self._pnrungs[name]:
                    moves[(ci, name)] = cur + 1
                elif (cur > 0 and self._lane_rung_err(ci, name, cur - 1,
                                                      seg)
                        <= self.margin * self.target):
                    moves[(ci, name)] = cur - 1
            return moves
        moves: Dict[int, int] = {}
        for ci in self._eligible(run, r, participants, self.cooldown):
            flat = run.clients[ci].snapshots[-1]
            cur = int(self._rung[ci])
            err = self._rung_err(run, ci, cur, flat)
            if err > self.target and cur + 1 < self.n_rungs:
                moves[ci] = cur + 1
            elif (cur > 0 and self._rung_err(run, ci, cur - 1, flat)
                    <= self.margin * self.target):
                moves[ci] = cur - 1
        return moves


@dataclasses.dataclass
class ByteBudget(RateController):
    """Greedy per-round allocation of a global uplink ``budget`` (bytes per
    round) across the observed cohort, spending bits where drift is
    largest: every participant starts at the cheapest rung, then upgrade
    passes bump clients one rung at a time in descending order of their
    current-rung reconstruction error until the next upgrade would exceed
    the budget. High-drift clients therefore end up at most one rung above
    low-drift ones when the budget runs out mid-pass, and everyone rides
    the cheapest rung when ``budget`` is below the cohort floor. Planned
    costs come from ``codec.wire_bytes`` (DESIGN.md §9.1), so the planned
    round uplink is exactly what the next round's records observe when the
    cohort repeats; under partial participation it tracks to the extent
    cohorts overlap (documented in DESIGN.md §9.1)."""

    budget: float = float("inf")
    cooldown: int = 0
    name: str = "byte_budget"

    def plan(self, run, r: int, participants: List[int]) -> Dict:
        if self._partitioned:
            return self._plan_lanes(run, r, participants)
        parts = self._eligible(run, r, participants, self.cooldown)
        if not parts:
            return {}
        # participants this round cannot move (cooldown, thin snapshot
        # buffer) still encode next round at their current rung: price
        # them into the budget before allocating upgrades, or the greedy
        # would systematically over-spend the round
        fixed_spend = sum(self._costs[self._rung[ci]]
                          for ci in set(participants) - set(parts))
        score = {ci: self._rung_err(run, ci, self._rung[ci],
                                    run.clients[ci].snapshots[-1])
                 for ci in parts}
        order = sorted(parts, key=lambda ci: (-score[ci], ci))
        alloc = {ci: 0 for ci in parts}
        spent = fixed_spend + self._costs[0] * len(parts)
        if spent > self.budget:      # budget below the all-cheapest floor
            return {ci: 0 for ci in parts if self._rung[ci] != 0}
        changed = True
        while changed:
            changed = False
            for ci in order:
                nxt = alloc[ci] + 1
                if nxt >= self.n_rungs:
                    continue
                delta = self._costs[nxt] - self._costs[alloc[ci]]
                if spent + delta <= self.budget:
                    alloc[ci] = nxt
                    spent += delta
                    changed = True
        return {ci: k for ci, k in alloc.items() if k != self._rung[ci]}

    def _plan_lanes(self, run, r: int, participants: List[int]) -> Dict:
        """Per-partition greedy under the ONE shared budget: every
        (client, group) lane competes for the same marginal bytes, so a
        high-drift conv stack can out-bid every head lane in the cohort —
        spending bits per layer where distortion hurts most
        (DESIGN.md §10.3). Same shape as the flat plan: movable lanes
        start at their group's cheapest rung, frozen lanes are priced at
        their current rung, upgrade passes walk lanes in descending
        drift."""
        participants = sorted(set(participants))
        lanes = self._eligible_lanes(run, r, participants, self.cooldown)
        if not lanes:
            return {}
        all_lanes = [(ci, name) for ci in participants
                     for name in self.partition.names]
        lane_set = set(lanes)
        frozen = [ln for ln in all_lanes if ln not in lane_set]
        fixed_spend = sum(self._pcosts[name][self._prung[name][ci]]
                          for ci, name in frozen)
        score = {
            (ci, name): self._lane_rung_err(
                ci, name, int(self._prung[name][ci]),
                run.clients[ci].part_snapshots[name][-1])
            for ci, name in lanes}
        order = sorted(lanes, key=lambda ln: (-score[ln], ln))
        alloc = {ln: 0 for ln in lanes}
        spent = fixed_spend + sum(self._pcosts[name][0]
                                  for _, name in lanes)
        if spent > self.budget:      # budget below the all-cheapest floor
            return {(ci, name): 0 for ci, name in lanes
                    if self._prung[name][ci] != 0}
        changed = True
        while changed:
            changed = False
            for ln in order:
                _, name = ln
                nxt = alloc[ln] + 1
                if nxt >= self._pnrungs[name]:
                    continue
                delta = self._pcosts[name][nxt] - \
                    self._pcosts[name][alloc[ln]]
                if spent + delta <= self.budget:
                    alloc[ln] = nxt
                    spent += delta
                    changed = True
        return {(ci, name): k for (ci, name), k in alloc.items()
                if k != self._prung[name][ci]}

"""Federated-learning orchestration with compressed update communication.

Implements the paper's FL scheme (§1, §3, Fig. 3): a server (Aggregator)
ships a global model to Collaborators; each trains locally for E epochs; the
weight *update* (local − global) is encoded by the collaborator-side encoder,
"communicated" (byte-accounted), decoded server-side, and FedAvg'd into the
next global model. Error feedback (beyond paper, DGC-style) optionally keeps
the reconstruction residual local and folds it into the next round's update.

Round *orchestration* is delegated to a pluggable ``RoundScheduler``
(DESIGN.md §6): the default ``SyncFedAvg`` reproduces the original
all-clients-every-round loop (float tolerance, §7), while ``SampledSync`` (C-of-N
cohorts, vmap-batched local training) and ``AsyncBuffered`` (FedBuff-style
staleness-weighted buffering over a simulated latency model) open the
partial-participation and straggler scenario families the paper's
large-scale analysis (Fig. 10) assumes. See examples/fl_async_sampling.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.paper import ClassifierConfig
from repro.core.compressor import Compressor, IdentityCompressor
from repro.core.prepass import evaluate
from repro.core.scheduler import ClientState, RoundScheduler, SyncFedAvg
from repro.core.task import ClassifierTask, ClientTask
from repro.models.classifiers import init_classifier

Pytree = Any


@dataclasses.dataclass
class FLConfig:
    n_rounds: int = 40
    local_epochs: int = 5              # paper §5.2: 40 rounds x 5 epochs
    lr: float = 1e-3
    batch_size: int = 64
    optimizer: str = "adam"
    aggregation: str = "fedavg"        # fedavg | fedprox
    prox_mu: float = 0.01              # fedprox only
    server_lr: float = 1.0
    error_feedback: bool = False
    # what crosses the wire: the paper's §5.2 protocol compresses the
    # collaborators' *converged weights* each round ("the converged weights
    # ... are passed through their respective AE"), so AEs trained on the
    # pre-pass weights dataset see in-distribution inputs. "update" ships
    # deltas instead (the right target for quantize/top-k codecs).
    payload: str = "weights"           # weights | update
    # server aggregation dispatch: None defers to ops.use_grouped_default
    # (REPRO_GROUPED_KERNEL env var, else the per-bucket sequential path);
    # True stages each heterogeneous round into ONE jitted dispatch whose
    # kernel-path AE buckets share a single grouped ragged Pallas launch
    # (DESIGN.md §11.2)
    use_grouped_kernel: Optional[bool] = None
    seed: int = 0


@dataclasses.dataclass
class RoundRecord:
    round: int
    collab_metrics: List[Dict[str, float]]
    global_metrics: Dict[str, float]
    bytes_up: float                    # collaborator→server this round
    bytes_up_raw: float                # uncompressed equivalent
    compression_ratio: float
    # measured-bytes channel (DESIGN.md §13.3): uplink priced from the
    # actual encoded payloads. Equal to ``bytes_up`` for shape-static
    # stacks; below it when an ``EntropySpec`` terminal prices integer
    # leaves at their Shannon bound instead of the dense eval-shape size.
    bytes_up_measured: float = 0.0
    # scheduler-layer accounting (DESIGN.md §6.1/§8.3). ``bytes_down`` is
    # the model-sync plane: the global-model broadcast to each participant
    # PLUS any decoder syncs the AE lifecycle shipped this round (both
    # uncompressed, so down == down_raw; the split keeps ``bytes_up`` the
    # pure per-round update traffic Eq. 4's numerator/denominator compare,
    # while the decoder Cost term of Eq. 5/6 lands in the records instead
    # of being silently dropped). ``bytes_decoder`` itemizes the decoder
    # share of ``bytes_down``; ``ae_syncs`` lists which clients shipped a
    # decoder (initial or refit) — ``savings.reconcile`` consumes both.
    bytes_down: float = 0.0            # server↔collaborator model syncs
    bytes_down_raw: float = 0.0
    bytes_decoder: float = 0.0         # decoder-sync share of bytes_down
    # clients that shipped a decoder this round (a multiset of ships). Flat
    # runs list client ids; partitioned runs (DESIGN.md §10) list
    # ``(client, group)`` pairs so savings.reconcile can sum per-partition
    # decoder ships against each partition's own Eq. 5/6 Cost term.
    ae_syncs: Optional[List] = None
    participants: Optional[List[int]] = None    # client ids in this round
    staleness: Optional[List[int]] = None       # async only, per participant
    sim_time: float = 0.0              # async only: simulated clock
    # rate-control plane (DESIGN.md §9): which policy drove this round and
    # the ladder moves it made — each switch is (client, from_rung,
    # to_rung), applied after this round's aggregation (effective next
    # round). None when no controller is attached.
    controller: Optional[str] = None
    spec_switches: Optional[List] = None


class FederatedRun:
    """One FL experiment over any :class:`~repro.core.task.ClientTask` —
    the paper's collaborator classifiers (``ClassifierTask``) or a real
    ``configs/`` zoo transformer (``LMDeltaTask``, DESIGN.md §14).

    ``task`` owns model init / local training / evaluation; this class and
    its ``scheduler`` own everything codec-, byte-, and schedule-shaped.
    Passing a ``ClassifierConfig`` as ``task`` still works (deprecation
    shim: it is wrapped in a ``ClassifierTask``, bit-identical to the
    pre-task runtime). ``scheduler`` selects the orchestration policy;
    ``SyncFedAvg`` (default) is the seed behavior. Per-client state
    (error-feedback residuals, model versions) lives in ``self.clients``
    and is shared across schedulers."""

    def __init__(
        self,
        task: "ClientTask | ClassifierConfig",
        datasets: Sequence[Dict[str, jnp.ndarray]],
        fl_cfg: FLConfig,
        compressors: Optional[Sequence[Compressor]] = None,
        eval_data: Optional[Dict[str, jnp.ndarray]] = None,
        scheduler: Optional[RoundScheduler] = None,
        lifecycle: Optional["AELifecycle"] = None,
        ratecontrol: Optional["RateController"] = None,
        soa_state: bool = False,
        ring_depth: Optional[int] = None,
    ):
        if isinstance(task, ClassifierConfig):
            # pre-task call sites passed the classifier config directly;
            # wrap it so they (and their checkpoints) keep working
            task = ClassifierTask(task)
        self.task = task
        # back-compat attribute: None for non-classifier tasks
        self.clf_cfg = getattr(task, "clf_cfg", None)
        task.check_config(fl_cfg)
        self.datasets = list(datasets)
        self.cfg = fl_cfg
        n = len(self.datasets)
        if compressors is None:
            compressors = [IdentityCompressor() for _ in range(n)]
        assert len(compressors) == n
        self.compressors = list(compressors)
        self.eval_data = eval_data
        self.global_params = task.init_params(
            jax.random.PRNGKey(fl_cfg.seed))
        if soa_state:
            # struct-of-arrays client state (DESIGN.md §12.1): same
            # ClientState attribute surface via views, stacked device
            # arrays underneath. Ring depth must cover every snapshot
            # consumer's buffer_size — sized from whatever is attached
            # (the eager lists are unbounded between truncations, but both
            # consumers truncate to buffer_size right after appending, so
            # depth == max buffer_size reproduces list semantics exactly)
            from repro.core.soa import ClientPool
            if ring_depth is None:
                ring_depth = max(
                    8,
                    int(getattr(lifecycle, "buffer_size", 0) or 0),
                    int(getattr(ratecontrol, "buffer_size", 0) or 0))
            self.clients = ClientPool(n, self.global_params,
                                      ring_depth=ring_depth)
        else:
            self.clients = [ClientState() for _ in range(n)]
        self.history: List[RoundRecord] = []
        self.round_offset = 0              # set by load_state on resume
        self.lifecycle = lifecycle
        # the rate controller binds BEFORE the scheduler: its ladder
        # installs each client's initial-rung compressor, which the
        # scheduler must see from its first dispatch (DESIGN.md §9.1)
        self.ratecontrol = ratecontrol
        if ratecontrol is not None:
            ratecontrol.bind(self)
        self.scheduler = scheduler if scheduler is not None else SyncFedAvg()
        self.scheduler.bind(self)

    @property
    def _residuals(self) -> List[Optional[Pytree]]:
        """Back-compat READ-ONLY snapshot of the per-client error-feedback
        residuals. Writing to this list mutates a throwaway copy; assign
        ``run.clients[i].residual`` to change a client's residual."""
        return [c.residual for c in self.clients]

    # ------------------------------------------------------------------
    def run(self, progress: Optional[Callable[[RoundRecord], None]] = None
            ) -> List[RoundRecord]:
        # run() is resumable: within a process via the history length, and
        # across processes via load_state()'s round offset
        start = self.round_offset + len(self.history)
        for r in range(start, start + self.cfg.n_rounds):
            rec = self.scheduler.run_round(r)
            self.history.append(rec)
            if progress:
                progress(rec)
        return self.history

    # ------------------------------------------------------------------
    def total_bytes(self) -> Dict[str, float]:
        up = sum(r.bytes_up for r in self.history)
        raw = sum(r.bytes_up_raw for r in self.history)
        down = sum(r.bytes_down for r in self.history)
        dec = sum(r.bytes_decoder for r in self.history)
        return {"bytes_up": up, "bytes_up_raw": raw,
                "bytes_down": down,
                "bytes_decoder": dec,
                "bytes_total": up + down,
                "effective_ratio": raw / max(up, 1.0)}

    # ------------------------------------------------------------------
    def savings_report(self, model) -> Dict[str, float]:
        """Reconcile this run's observed byte accounting against the
        paper's Eq. 4–6 analytics (``savings.reconcile``, DESIGN.md §8.3).
        ``model`` is one ``SavingsModel``, or — under per-layer codec
        partitions — a ``{group_name: SavingsModel}`` mapping so the Cost
        term sums each partition's own decoder ships (DESIGN.md §10.4)."""
        from repro.core.savings import reconcile
        return reconcile(model, self.history)

    # ------------------------------------------------------------------
    def save_state(self, path: str) -> None:
        """Checkpoint the resumable run state: round index, global params,
        every ``ClientState`` (error-feedback residuals, AE snapshot
        buffers, lifecycle scalars, async dispatch snapshots), the
        per-client AE codec params — an ``AELifecycle`` refit moves the
        compressors, so resuming must not silently revert any decoder to
        its pre-pass state — plus the scheduler's event-loop state and,
        under a rate controller, every ladder rung's params and the rung
        occupancy (DESIGN.md §9.3). With a controller attached the codec
        params ride its ladder tree instead of the flat ``codecs`` section
        (the active rung differs per client, so a flat section would have
        no stable structure to restore into)."""
        from repro.checkpoint.checkpoint import save_federated_state
        from repro.core.soa import ClientPool
        rc = self.ratecontrol
        is_pool = isinstance(self.clients, ClientPool)
        save_federated_state(
            path, self.round_offset + len(self.history), self.global_params,
            clients=(None if is_pool else self.clients),
            clients_soa=(self.clients.state() if is_pool else None),
            codec_params=(None if rc is not None else
                          [c.codec_params() for c in self.compressors]),
            ratecontrol=((rc.state_meta(), rc.state_tree())
                         if rc is not None else None),
            scheduler_state=self.scheduler.state_dict(),
            extra={"task": self.task.checkpoint_key()})

    def load_state(self, path: str) -> int:
        """Restore a checkpoint into this (freshly constructed) run;
        subsequent ``run()`` calls continue from the saved round. All
        schedulers resume exactly — ``AsyncBuffered`` restores its event
        loop (heap, clock, version, pending downlink bytes) from the
        checkpoint's scheduler state, falling back to a simulation restart
        only for legacy checkpoints without one. Returns the next round
        index."""
        from repro.checkpoint.checkpoint import (_peek_meta,
                                                 load_federated_state)
        rc = self.ratecontrol
        # checkpoints are keyed on the task (DESIGN.md §14.3): restoring a
        # different task/arch would try to unravel the saved trees into the
        # wrong pytree, so refuse BEFORE touching any state. Legacy
        # checkpoints carry no key and load as before (all pre-task
        # checkpoints are classifier).
        saved_task = _peek_meta(path).get("task")
        if saved_task is not None and saved_task != self.task.checkpoint_key():
            raise ValueError(
                f"task mismatch: checkpoint was saved by task "
                f"{saved_task!r} but this run's task is "
                f"{self.task.checkpoint_key()!r} — params cannot be "
                "restored; rebuild the run with the matching task")
        rnd, params, meta = load_federated_state(
            path, self.global_params,
            like_codec_params=(None if rc is not None else
                               [c.codec_params() for c in self.compressors]),
            like_ratecontrol=(rc.state_tree() if rc is not None else None))
        # codec params ride the controller's ladder tree when one is
        # attached and the flat ``codecs`` section otherwise — a presence
        # mismatch between save and load would silently leave every
        # compressor at its construction-time params (the exact
        # silent-decoder-revert save_state exists to prevent), so refuse
        if (rc is not None) != (meta.get("ratecontrol") is not None):
            raise ValueError(
                "rate-controller mismatch: checkpoint was saved "
                f"{'with' if meta.get('ratecontrol') is not None else 'without'}"
                " a RateController but this run was constructed "
                f"{'with' if rc is not None else 'without'} one — codec "
                "params cannot be restored; rebuild the run to match the "
                "checkpoint")
        self.global_params = params
        if meta.get("clients_soa") is not None:
            # SoA checkpoint: rebuild the pool against the restored params
            # template (DESIGN.md §12.4). The checkpoint's layout — not
            # this run's ctor flag — decides, so an SoA run restores an
            # SoA checkpoint regardless of how it was constructed.
            from repro.core.soa import ClientPool
            assert int(meta["clients_soa"]["n"]) == len(self.clients)
            self.clients = ClientPool.from_state(
                meta.get("clients_soa_tree") or {}, meta["clients_soa"],
                self.global_params)
        elif meta.get("client_states") is not None:
            assert len(meta["client_states"]) == len(self.clients)
            self.clients = meta["client_states"]
        for comp, restored in zip(self.compressors,
                                  meta.get("codec_params") or []):
            # PartitionedCompressor fans the per-group dict out to its
            # sub-compressors; AE adapters restore their params directly
            comp.set_codec_params(restored)
        if rc is not None and meta.get("ratecontrol") is not None:
            rc.load_state(meta["ratecontrol"], meta["ratecontrol_tree"])
        self.history = []
        self.round_offset = rnd
        # rebuild client-derived state / restore the event loop
        self.scheduler.on_restore(meta.get("scheduler"))
        return rnd


# =====================================================================
# paper §5.1 "validation model": set AE-reconstructed weights into a fresh
# model and check the loss/accuracy curve matches the original training
# =====================================================================
def validation_model_curve(
    clf_cfg: ClassifierConfig,
    weight_vectors: jnp.ndarray,          # (E, P) original snapshots
    reconstruct: Callable[[jnp.ndarray], jnp.ndarray],
    data: Dict[str, jnp.ndarray],
) -> Dict[str, List[float]]:
    """For each training snapshot: evaluate the model with (a) original and
    (b) AE-reconstructed weights — the paper's Figs. 5/7 overlay."""
    template = init_classifier(jax.random.PRNGKey(0), clf_cfg)
    flat0, unravel = ravel_pytree(template)
    P = flat0.size

    out = {"original_acc": [], "predicted_acc": [],
           "original_loss": [], "predicted_loss": []}
    for i in range(weight_vectors.shape[0]):
        w = weight_vectors[i][:P]
        w_hat = reconstruct(weight_vectors[i])[:P]
        m_orig = evaluate(unravel(w), clf_cfg, data)
        m_pred = evaluate(unravel(w_hat), clf_cfg, data)
        out["original_acc"].append(m_orig["accuracy"])
        out["predicted_acc"].append(m_pred["accuracy"])
        out["original_loss"].append(m_orig["loss"])
        out["predicted_loss"].append(m_pred["loss"])
    return out

"""Federated-learning orchestration with compressed update communication.

Implements the paper's FL scheme (§1, §3, Fig. 3): a server (Aggregator)
ships a global model to Collaborators; each trains locally for E epochs; the
weight *update* (local − global) is encoded by the collaborator-side encoder,
"communicated" (byte-accounted), decoded server-side, and FedAvg'd into the
next global model. Error feedback (beyond paper, DGC-style) optionally keeps
the reconstruction residual local and folds it into the next round's update.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.paper import ClassifierConfig
from repro.core.aggregate import fedavg, weighted_mean
from repro.core.compressor import Compressor, IdentityCompressor
from repro.core.prepass import evaluate, local_train
from repro.models.classifiers import init_classifier

Pytree = Any


@dataclasses.dataclass
class FLConfig:
    n_rounds: int = 40
    local_epochs: int = 5              # paper §5.2: 40 rounds x 5 epochs
    lr: float = 1e-3
    batch_size: int = 64
    optimizer: str = "adam"
    aggregation: str = "fedavg"        # fedavg | fedprox
    prox_mu: float = 0.01              # fedprox only
    server_lr: float = 1.0
    error_feedback: bool = False
    # what crosses the wire: the paper's §5.2 protocol compresses the
    # collaborators' *converged weights* each round ("the converged weights
    # ... are passed through their respective AE"), so AEs trained on the
    # pre-pass weights dataset see in-distribution inputs. "update" ships
    # deltas instead (the right target for quantize/top-k codecs).
    payload: str = "weights"           # weights | update
    seed: int = 0


@dataclasses.dataclass
class RoundRecord:
    round: int
    collab_metrics: List[Dict[str, float]]
    global_metrics: Dict[str, float]
    bytes_up: float                    # collaborator→server this round
    bytes_up_raw: float                # uncompressed equivalent
    compression_ratio: float


class FederatedRun:
    """One FL experiment over the paper's small collaborator models."""

    def __init__(
        self,
        clf_cfg: ClassifierConfig,
        datasets: Sequence[Dict[str, jnp.ndarray]],
        fl_cfg: FLConfig,
        compressors: Optional[Sequence[Compressor]] = None,
        eval_data: Optional[Dict[str, jnp.ndarray]] = None,
    ):
        self.clf_cfg = clf_cfg
        self.datasets = list(datasets)
        self.cfg = fl_cfg
        n = len(self.datasets)
        if compressors is None:
            compressors = [IdentityCompressor() for _ in range(n)]
        assert len(compressors) == n
        self.compressors = list(compressors)
        self.eval_data = eval_data
        self.global_params = init_classifier(
            jax.random.PRNGKey(fl_cfg.seed), clf_cfg)
        self._residuals: List[Optional[Pytree]] = [None] * n
        self.history: List[RoundRecord] = []

    # ------------------------------------------------------------------
    def run(self, progress: Optional[Callable[[RoundRecord], None]] = None
            ) -> List[RoundRecord]:
        cfg = self.cfg
        for r in range(cfg.n_rounds):
            updates, weights, metrics = [], [], []
            bytes_up = bytes_raw = 0.0
            ratios = []
            for ci, data in enumerate(self.datasets):
                local, _, hist = local_train(
                    self.global_params, self.clf_cfg, data,
                    epochs=cfg.local_epochs, lr=cfg.lr,
                    batch_size=cfg.batch_size, seed=cfg.seed * 997 + r,
                    optimizer=cfg.optimizer,
                    prox_mu=(cfg.prox_mu
                             if cfg.aggregation == "fedprox" else 0.0),
                    anchor=self.global_params)
                if cfg.payload == "weights":
                    payload = local               # paper §5.2 protocol
                else:
                    payload = jax.tree_util.tree_map(
                        lambda a, b: a - b, local, self.global_params)
                if cfg.error_feedback and self._residuals[ci] is not None:
                    payload = jax.tree_util.tree_map(
                        lambda u, res: u + res, payload,
                        self._residuals[ci])

                decoded, stats = self.compressors[ci].roundtrip(payload)
                if cfg.error_feedback:
                    self._residuals[ci] = jax.tree_util.tree_map(
                        lambda u, d: u - d, payload, decoded)
                if cfg.payload == "weights":
                    # aggregation averages weights: express as an update
                    decoded = jax.tree_util.tree_map(
                        lambda w, g: w - g, decoded, self.global_params)
                updates.append(decoded)
                weights.append(float(data["x"].shape[0]))
                bytes_up += stats["compressed_bytes"]
                bytes_raw += stats["original_bytes"]
                ratios.append(stats["compression_ratio"])
                metrics.append(hist[-1] if hist else {})

            self.global_params = fedavg(self.global_params, updates,
                                        weights, cfg.server_lr)
            gmetrics = {}
            if self.eval_data is not None:
                gmetrics = evaluate(self.global_params, self.clf_cfg,
                                    self.eval_data)
            rec = RoundRecord(
                round=r, collab_metrics=metrics, global_metrics=gmetrics,
                bytes_up=bytes_up, bytes_up_raw=bytes_raw,
                compression_ratio=float(jnp.mean(jnp.array(ratios))))
            self.history.append(rec)
            if progress:
                progress(rec)
        return self.history

    # ------------------------------------------------------------------
    def total_bytes(self) -> Dict[str, float]:
        up = sum(r.bytes_up for r in self.history)
        raw = sum(r.bytes_up_raw for r in self.history)
        return {"bytes_up": up, "bytes_up_raw": raw,
                "effective_ratio": raw / max(up, 1.0)}


# =====================================================================
# paper §5.1 "validation model": set AE-reconstructed weights into a fresh
# model and check the loss/accuracy curve matches the original training
# =====================================================================
def validation_model_curve(
    clf_cfg: ClassifierConfig,
    weight_vectors: jnp.ndarray,          # (E, P) original snapshots
    reconstruct: Callable[[jnp.ndarray], jnp.ndarray],
    data: Dict[str, jnp.ndarray],
) -> Dict[str, List[float]]:
    """For each training snapshot: evaluate the model with (a) original and
    (b) AE-reconstructed weights — the paper's Figs. 5/7 overlay."""
    template = init_classifier(jax.random.PRNGKey(0), clf_cfg)
    flat0, unravel = ravel_pytree(template)
    P = flat0.size

    out = {"original_acc": [], "predicted_acc": [],
           "original_loss": [], "predicted_loss": []}
    for i in range(weight_vectors.shape[0]):
        w = weight_vectors[i][:P]
        w_hat = reconstruct(weight_vectors[i])[:P]
        m_orig = evaluate(unravel(w), clf_cfg, data)
        m_pred = evaluate(unravel(w_hat), clf_cfg, data)
        out["original_acc"].append(m_orig["accuracy"])
        out["predicted_acc"].append(m_pred["accuracy"])
        out["original_loss"].append(m_orig["loss"])
        out["predicted_loss"].append(m_pred["loss"])
    return out

"""Client tasks: the model-side half of the federated runtime (DESIGN.md §14).

Until this refactor ``FederatedRun`` was hard-wired to the paper's toy
classifier — ``init_classifier`` in its ctor, ``local_train``/``evaluate``
calls inside every scheduler. :class:`ClientTask` extracts that coupling
into a strategy object so the runtime (schedulers, codecs, lifecycle, rate
control, checkpointing) is model-agnostic:

* :class:`ClassifierTask` — the paper's small collaborator models
  (MNIST MLP / CIFAR CNN). Delegates to the exact ``prepass`` functions the
  schedulers used to call directly, with identical argument plumbing and
  seed streams, so pre-refactor trajectories replay **bit-for-bit**
  (golden-trajectory + resume-matrix tests pass unmodified).
* :class:`LMDeltaTask` — federated delta fine-tuning of a real
  ``configs/`` transformer (dense/MoE/SSM/hybrid/audio zoo): each client
  runs a few steps of next-token training on its own token shard and ships
  the post-error-feedback weight *delta* through the existing codec stack
  (``FLConfig(payload="update")`` enforced — the refit distribution the AE
  lifecycle buffers is deltas, the right codec target at LM shapes).

The protocol is intentionally small — everything the schedulers touch:

* ``init_params(key)``         — the global model pytree
* ``local_update(...)``        — one client's local training round
* ``local_update_batched(...)``— optional vmapped cohort fast path
  (``None`` = scheduler falls back to the sequential loop)
* ``evaluate(params, data)``   — global-model metrics for RoundRecords
* ``make_batches(...)``        — the task's minibatch stream
* ``data_weight(data)``        — FedAvg sample weight of a client shard
* ``checkpoint_key()``         — task identity stored in checkpoints so a
  resume into a different task/arch is refused instead of silently
  unraveling params into the wrong tree
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any


class ClientTask:
    """Strategy interface binding a model family to the federated runtime.

    Subclasses own model init, local training, and evaluation; the runtime
    owns everything codec/byte/schedule-shaped. All methods take the run's
    ``FLConfig`` where training hyperparameters live (``local_epochs``,
    ``lr``, ``batch_size``, ``optimizer``, ``aggregation``/``prox_mu``)."""

    name = "base"

    # ------------------------------------------------------------- model
    def init_params(self, key: jax.Array) -> Pytree:
        """The global model pytree this federation trains."""
        raise NotImplementedError

    # ---------------------------------------------------------- training
    def local_update(self, params: Pytree, data: Dict[str, jnp.ndarray],
                     cfg, *, seed: int, anchor: Optional[Pytree] = None
                     ) -> Tuple[Pytree, Dict[str, float]]:
        """One client's local round: train ``params`` on ``data`` and
        return ``(trained params, final metrics)``. ``anchor`` is the
        round-start global model (the FedProx proximal target)."""
        raise NotImplementedError

    def local_update_batched(self, params: Pytree,
                             datasets: List[Dict[str, jnp.ndarray]],
                             cfg, *, seed: int,
                             anchor: Optional[Pytree] = None
                             ) -> Optional[List[Tuple[Pytree,
                                                      Dict[str, float]]]]:
        """Cohort fast path: train every client of a homogeneous cohort in
        one vmapped dispatch. Return ``None`` (the default) when the task
        has no batched path or the cohort is ragged — the scheduler falls
        back to per-client :meth:`local_update` calls."""
        return None

    # -------------------------------------------------------- evaluation
    def evaluate(self, params: Pytree, data: Dict[str, jnp.ndarray]
                 ) -> Dict[str, float]:
        """Global-model metrics on held-out ``data`` (RoundRecord's
        ``global_metrics``)."""
        raise NotImplementedError

    # --------------------------------------------------------------- data
    def make_batches(self, seed: int, data: Dict[str, jnp.ndarray],
                     batch_size: int) -> Iterator[Dict[str, jnp.ndarray]]:
        """One epoch of shuffled minibatches over a client shard."""
        raise NotImplementedError

    def num_examples(self, data: Dict[str, jnp.ndarray]) -> int:
        raise NotImplementedError

    def data_weight(self, data: Dict[str, jnp.ndarray]) -> float:
        """FedAvg weight of a client's shard (sample count by default)."""
        return float(self.num_examples(data))

    # ------------------------------------------------------------- hooks
    def check_config(self, cfg) -> None:
        """Validate an ``FLConfig`` against this task (ctor-time hook)."""

    def checkpoint_key(self) -> str:
        """Stable identity stored in checkpoint metadata; a load whose
        saved key differs from the resuming run's task is refused."""
        return self.name


# =====================================================================
# the paper's collaborator models, extracted verbatim from the schedulers
# =====================================================================
@dataclasses.dataclass
class ClassifierTask(ClientTask):
    """The paper's small collaborator models (``configs.paper``): thin
    delegation to ``prepass.local_train``/``local_train_batched``/
    ``evaluate`` with the exact argument plumbing the schedulers inlined
    before the task extraction — same seed streams, same jit caches, same
    FedProx gating — so trajectories are bit-identical to the pre-task
    runtime (asserted by the golden-trajectory fixture and the
    ClassifierTask differential test)."""

    clf_cfg: Any                        # configs.paper.ClassifierConfig
    name: str = "classifier"

    def init_params(self, key: jax.Array) -> Pytree:
        from repro.models.classifiers import init_classifier
        return init_classifier(key, self.clf_cfg)

    def local_update(self, params, data, cfg, *, seed, anchor=None):
        from repro.core.prepass import local_train
        local, _, hist = local_train(
            params, self.clf_cfg, data,
            epochs=cfg.local_epochs, lr=cfg.lr,
            batch_size=cfg.batch_size, seed=seed,
            optimizer=cfg.optimizer,
            prox_mu=(cfg.prox_mu if cfg.aggregation == "fedprox" else 0.0),
            anchor=anchor)
        return local, (hist[-1] if hist else {})

    def local_update_batched(self, params, datasets, cfg, *, seed,
                             anchor=None):
        from repro.core.prepass import local_train_batched
        shapes = [jax.tree_util.tree_map(lambda x: x.shape, d)
                  for d in datasets]
        if any(s != shapes[0] for s in shapes[1:]):
            return None
        stacked_data = {k: jnp.stack([d[k] for d in datasets])
                        for k in datasets[0]}
        stacked, metrics = local_train_batched(
            params, self.clf_cfg, stacked_data,
            epochs=cfg.local_epochs, lr=cfg.lr, batch_size=cfg.batch_size,
            seed=seed, optimizer=cfg.optimizer,
            prox_mu=(cfg.prox_mu if cfg.aggregation == "fedprox" else 0.0),
            anchor=anchor)
        locals_ = [jax.tree_util.tree_map(lambda x, i=i: x[i], stacked)
                   for i in range(len(datasets))]
        return list(zip(locals_, metrics))

    def evaluate(self, params, data):
        from repro.core.prepass import evaluate
        return evaluate(params, self.clf_cfg, data)

    def make_batches(self, seed, data, batch_size):
        from repro.data.pipeline import batches
        return batches(seed, data, batch_size)

    def num_examples(self, data) -> int:
        return int(data["x"].shape[0])

    def checkpoint_key(self) -> str:
        # hidden sizes pin the param-tree structure a checkpoint must
        # unravel into; activation etc. don't change shapes but a mismatch
        # there is still a different experiment — refuse those too
        return f"classifier:{getattr(self.clf_cfg, 'name', 'clf')}"


# =====================================================================
# federated delta fine-tuning of the real model zoo
# =====================================================================
# jitted LM-step cache, mirroring prepass._BATCHED_STEP_CACHE: keyed on
# everything baked into the trace so every client of every round is a
# cache HIT after the first trace (params/opt state/batch are arguments).
_LM_STEP_CACHE: Dict[Any, Any] = {}


def _lm_step(arch_cfg, optimizer: str, lr: float, prox_mu: float,
             frozen_roles: Tuple[str, ...]):
    key = (arch_cfg, optimizer, lr, prox_mu, frozen_roles)
    cached = _LM_STEP_CACHE.get(key)
    if cached is not None:
        return cached
    from repro.models import model as model_lib
    from repro.optim.optimizers import make_optimizer
    opt = make_optimizer(optimizer, lr)

    def loss_fn(p, batch, anchor):
        loss, metrics = model_lib.train_loss(p, arch_cfg, batch)
        if prox_mu > 0.0:
            sq = sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                jax.tree_util.tree_leaves(p),
                jax.tree_util.tree_leaves(anchor)))
            loss = loss + 0.5 * prox_mu * sq
        return loss, metrics

    @jax.jit
    def step(p, s, batch, anchor, mask):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, batch, anchor)
        grads = jax.tree_util.tree_map(lambda g, m: g * m, grads, mask)
        p, s = opt.update(p, grads, s)
        return p, s, metrics

    _LM_STEP_CACHE[key] = (opt, step)
    return opt, step


@dataclasses.dataclass
class LMDeltaTask(ClientTask):
    """Federated delta/LoRA-style fine-tuning of a ``configs/`` zoo model.

    Each client shard is a token corpus ``{"tokens": (n, S), "labels":
    (n, S)}`` (``data.pipeline.synthetic_lm_batch`` produces one); a local
    round runs ``cfg.local_epochs`` epochs of jitted next-token training
    drawn from the same ``batch_indices`` stream the classifier path uses.
    The task requires ``FLConfig(payload="update")`` — what crosses the
    wire is the post-EF weight *delta*, which is what the chunked-AE
    codecs refit on and the right target for quantize/top-k stages.

    ``freeze_roles`` masks gradients for whole parameter roles (as named
    by :func:`repro.core.partition.role_of_path` — e.g. ``("embedding",)``
    freezes the embedding/LM-head matrices), the LoRA-flavored knob:
    frozen roles ship exact-zero deltas, so their partition groups
    compress to nothing under any sparsifying stage while the payload
    keeps the full model structure."""

    arch_cfg: Any                       # configs.base.ArchConfig
    freeze_roles: Tuple[str, ...] = ()
    name: str = "lm_delta"

    def __post_init__(self):
        self._mask = None               # built lazily from the param tree
        self._eval_fn = None

    def init_params(self, key: jax.Array) -> Pytree:
        from repro.models import model as model_lib
        return model_lib.init_params(key, self.arch_cfg)

    def _grad_mask(self, params: Pytree) -> Pytree:
        if self._mask is None:
            from repro.core.partition import role_of_path
            from repro.core.partition import _key_str
            frozen = set(self.freeze_roles)

            def leaf_mask(path, leaf):
                name = "/".join(_key_str(p) for p in path)
                keep = role_of_path(name) not in frozen
                return jnp.asarray(1.0 if keep else 0.0, leaf.dtype)

            self._mask = jax.tree_util.tree_map_with_path(leaf_mask, params)
        return self._mask

    def local_update(self, params, data, cfg, *, seed, anchor=None):
        from repro.data.pipeline import batch_indices
        prox = (cfg.prox_mu if cfg.aggregation == "fedprox" else 0.0)
        opt, step = _lm_step(self.arch_cfg, cfg.optimizer, cfg.lr,
                             prox if anchor is not None else 0.0,
                             tuple(self.freeze_roles))
        mask = self._grad_mask(params)
        anchor_arg = anchor if anchor is not None else params
        state = opt.init(params)
        n = self.num_examples(data)
        last = None
        for epoch in range(cfg.local_epochs):
            # same seed stream as the classifier path: epoch-keyed shuffles
            for sel in batch_indices(seed * 1000 + epoch, n,
                                     cfg.batch_size):
                batch = {k: v[sel] for k, v in data.items()}
                params, state, last = step(params, state, batch,
                                           anchor_arg, mask)
        metrics = ({} if last is None
                   else {k: float(v) for k, v in last.items()})
        return params, metrics

    def evaluate(self, params, data):
        if self._eval_fn is None:
            from repro.models import model as model_lib
            arch_cfg = self.arch_cfg
            self._eval_fn = jax.jit(
                lambda p, b: model_lib.train_loss(p, arch_cfg, b))
        _, metrics = self._eval_fn(params, data)
        return {k: float(v) for k, v in metrics.items()}

    def make_batches(self, seed, data, batch_size):
        from repro.data.pipeline import batch_indices
        n = self.num_examples(data)
        for sel in batch_indices(seed, n, batch_size):
            yield {k: v[sel] for k, v in data.items()}

    def num_examples(self, data) -> int:
        return int(data["tokens"].shape[0])

    def check_config(self, cfg) -> None:
        if cfg.payload != "update":
            raise ValueError(
                "LMDeltaTask ships weight deltas — construct the run with "
                f"FLConfig(payload='update'), got payload={cfg.payload!r}")

    def checkpoint_key(self) -> str:
        return f"lm_delta:{self.arch_cfg.name}"

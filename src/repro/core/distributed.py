"""The paper's technique as a first-class distributed training feature.

``build_fl_round_step`` assembles a federated round over the production mesh:
the ``pod`` mesh axis is the collaborator axis (DESIGN.md §3.1). Each pod
computes its local gradients (conventional data/model parallelism *inside*
the pod — handled by GSPMD auto axes under a partial-manual ``shard_map``),
then — instead of all-reducing full gradients across pods — each pod:

  1. chunk-encodes every update leaf with the shared chunked AE
     (collaborator-side encoder, Eq. 1),
  2. ``pmean``s only the LATENTS across the ``pod`` axis — the sole
     cross-pod traffic, smaller by the compression ratio,
  3. decodes (aggregator-side decoder, Eq. 2) and applies the optimizer.

The roofline §collective term of this step vs. the baseline train step
quantifies the paper's bandwidth claim at datacenter scale.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.autoencoder import (ChunkedAEConfig, fc_decode, fc_encode,
                                    init_chunked_ae)
from repro.models import model as model_lib
from repro.optim.optimizers import make_optimizer

Pytree = Any

# default production codec: 4096-element chunks → 8 latents = 512x
DEFAULT_AE = ChunkedAEConfig(chunk_size=4096, hidden=(512,), latent_chunk=8)


def _shard_map_compat(f, *, axis_names, in_specs, out_specs, mesh,
                      nested=False):
    """Partial-manual shard_map across jax versions: newer jax exposes
    ``jax.shard_map(axis_names=..., check_vma=...)``; 0.4.x has
    ``jax.experimental.shard_map.shard_map`` with the complementary
    ``auto=`` set and ``check_rep=``. For a ``nested`` region (inside an
    already-Manual outer shard_map) new jax must infer the mesh from
    context — passing the concrete mesh there re-introduces the outer axis;
    the old API always takes it explicitly."""
    if hasattr(jax, "shard_map"):
        kwargs = dict(axis_names=axis_names, in_specs=in_specs,
                      out_specs=out_specs, check_vma=False)
        if not nested:
            kwargs["mesh"] = mesh
        return jax.shard_map(f, **kwargs)
    from jax.experimental.shard_map import shard_map
    auto = frozenset(mesh.axis_names) - set(axis_names)
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False, auto=auto)


def leaf_encode(ae_params: Pytree, ae_cfg: ChunkedAEConfig,
                leaf: jax.Array) -> jax.Array:
    """Flatten a param leaf into chunks and encode: (n_chunks, latent)."""
    flat = leaf.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % ae_cfg.chunk_size
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, ae_cfg.chunk_size)
    return fc_encode(ae_params, ae_cfg.as_fc(), chunks)


def leaf_decode(ae_params: Pytree, ae_cfg: ChunkedAEConfig,
                latents: jax.Array, like: jax.Array) -> jax.Array:
    chunks = fc_decode(ae_params, ae_cfg.as_fc(), latents)
    flat = chunks.reshape(-1)[:like.size]
    return flat.reshape(like.shape).astype(like.dtype)


def encode_tree(ae_params: Pytree, ae_cfg: ChunkedAEConfig,
                tree: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda leaf: leaf_encode(ae_params, ae_cfg, leaf), tree)


def decode_tree(ae_params: Pytree, ae_cfg: ChunkedAEConfig,
                latents: Pytree, like: Pytree) -> Pytree:
    return jax.tree_util.tree_map(
        lambda z, l: leaf_decode(ae_params, ae_cfg, z, l), latents, like)


def compressed_fraction(tree: Pytree, ae_cfg: ChunkedAEConfig) -> float:
    """Latent bytes / original bytes for a param tree (exactly what crosses
    the pod axis vs. what a full all-reduce would move)."""
    orig = comp = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        n = leaf.size
        chunks = -(-n // ae_cfg.chunk_size)
        orig += n * 4
        comp += chunks * ae_cfg.latent_chunk * 4
    return comp / max(orig, 1)


def _spec_shards(spec, mesh: Mesh) -> int:
    total = 1
    for axis in spec:
        if axis is None:
            continue
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            total *= mesh.shape[a]
    return total


def build_fl_round_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                        ae_cfg: ChunkedAEConfig = DEFAULT_AE,
                        aligned: bool = True):
    """StepBundle for one federated round on the (pod, data, model) mesh.

    ``aligned=True`` (§Perf iteration 1 for the FL step): the codec runs in a
    fully-manual nested shard_map over (data, model) — every device encodes
    its LOCAL gradient shard, so the chunking can never force GSPMD to
    all-gather model-sharded leaves (the naive flatten-then-chunk baseline
    measured 8–12 TB/device of resharding all-reduce). Only the latent
    ``pmean`` crosses the pod axis, exactly as DESIGN.md §3 specifies.
    """
    from jax.sharding import PartitionSpec
    from repro.launch.steps import (StepBundle, _opt_specs, batch_shapes,
                                    param_shapes)
    from repro.models import sharding as shard_lib

    assert "pod" in mesh.shape, "FL round step needs the multi-pod mesh"
    opt = make_optimizer(cfg.optimizer, cfg.learning_rate,
                         weight_decay=cfg.weight_decay,
                         grad_clip=cfg.grad_clip)

    p_shapes_early = param_shapes(cfg)
    grad_specs = shard_lib.param_specs(p_shapes_early, mesh)

    def _codec_local(grads_local, ae_p):
        """Runs per-device on raw local shards (inner manual region)."""
        latents = jax.tree_util.tree_map(
            lambda leaf: leaf_encode(ae_p, ae_cfg, leaf), grads_local)
        # the ONLY cross-pod communication: compressed latents
        latents = jax.lax.pmean(latents, "pod")
        return jax.tree_util.tree_map(
            lambda z, g: leaf_decode(ae_p, ae_cfg, z, g),
            latents, grads_local)

    def per_pod(params, opt_state, ae_params, batch):
        # local gradients: data/model parallelism inside the pod (auto axes)
        if cfg.grad_reduce_dtype == "bfloat16":
            cast_p = jax.tree_util.tree_map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 else p, params)
            (_, metrics), grads = jax.value_and_grad(
                model_lib.train_loss, has_aux=True)(cast_p, cfg, batch)
            grads = jax.tree_util.tree_map(
                lambda g, p: g.astype(jnp.float32), grads, params)
        else:
            (_, metrics), grads = jax.value_and_grad(
                model_lib.train_loss, has_aux=True)(params, cfg, batch)
        grads = dict(grads)
        if aligned:
            # pin gradient sharding to the param layout, then run the codec
            # on raw local shards (zero collectives by construction)
            grads = jax.lax.with_sharding_constraint(grads, grad_specs)
            ae_rep = jax.tree_util.tree_map(lambda _: PartitionSpec(),
                                            ae_params)
            # nested manual region: mesh inferred from context on new jax
            # (the outer pod-manual shard_map has already marked `pod`
            # Manual); the old-jax fallback takes it explicitly
            decoded = _shard_map_compat(
                _codec_local, axis_names={"data", "model"},
                in_specs=(grad_specs, ae_rep), out_specs=grad_specs,
                mesh=mesh, nested=True)(grads, ae_params)
        else:
            # naive baseline: flatten+chunk whole leaves (GSPMD reshards)
            latents = encode_tree(ae_params, ae_cfg, grads)
            latents = jax.lax.pmean(latents, "pod")
            decoded = decode_tree(ae_params, ae_cfg, latents, grads)
        params, opt_state = opt.update(params, decoded, opt_state)
        loss = jax.lax.pmean(metrics["loss"], "pod")
        acc = jax.lax.pmean(metrics["accuracy"], "pod")
        return params, opt_state, {"loss": loss, "accuracy": acc}

    p_shapes = param_shapes(cfg)
    o_shapes = jax.eval_shape(opt.init, p_shapes)
    b_shapes = batch_shapes(cfg, shape)
    ae_shapes = jax.eval_shape(
        functools.partial(init_chunked_ae, cfg=ae_cfg),
        jax.random.PRNGKey(0))

    p_specs = shard_lib.param_specs(p_shapes, mesh)
    # XLA workaround: a vocab-sharded embedding gather inside a
    # partial-manual shard_map trips a CHECK in the SPMD partitioner
    # (PartitionGather + manual pod subgroups). Shard the embedding on the
    # feature dim instead for the FL step — the gather dim stays whole and
    # partitions trivially; the tied/untied head keeps its own spec.
    if "embed" in p_specs and cfg.d_model % mesh.shape["model"] == 0:
        p_specs = dict(p_specs, embed=P(None, "model"))
    o_specs = _opt_specs(cfg, mesh, p_specs, p_shapes, o_shapes)
    b_specs = shard_lib.batch_specs(b_shapes, mesh)
    ae_specs = jax.tree_util.tree_map(lambda _: P(), ae_shapes)
    metric_specs = {"loss": P(), "accuracy": P()}

    # shard_map manual only over 'pod'; batch dim0 carries pod + data
    inner_batch_shapes = dict(
        b_shapes,
        h0=jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)))
    sm_batch_in = jax.tree_util.tree_map(
        lambda s: P("pod") if s.ndim >= 1 else P(), inner_batch_shapes)
    rep = jax.tree_util.tree_map(lambda _: P(), p_shapes)
    rep_o = jax.tree_util.tree_map(lambda _: P(), o_shapes)
    rep_ae = jax.tree_util.tree_map(lambda _: P(), ae_shapes)

    sm = _shard_map_compat(
        per_pod, mesh=mesh, axis_names={"pod"},
        in_specs=(rep, rep_o, rep_ae, sm_batch_in),
        out_specs=(rep, rep_o, {"loss": P(), "accuracy": P()}))

    def step(params, opt_state, ae_params, batch):
        # token-embedding gather OUTSIDE the manual region: the SPMD
        # partitioner CHECK-fails on gathers under manual pod subgroups
        # (input-embedding path is stop-gradiented — frozen in FL mode;
        # tied/untied head gradients still flow through the logits matmul)
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        frozen = dict(params,
                      embed=jax.lax.stop_gradient(params["embed"]))
        h0 = model_lib._embed_inputs(frozen, cfg, batch, positions,
                                     train=True)
        return sm(params, opt_state, ae_params, dict(batch, h0=h0))

    return StepBundle(
        name=f"fl_round:{cfg.name}:{shape.name}",
        fn=step,
        args=(p_shapes, o_shapes, ae_shapes, b_shapes),
        in_shardings=(p_specs, o_specs, ae_specs, b_specs),
        out_shardings=(p_specs, o_specs, metric_specs),
        donate_argnums=(0, 1),
    )

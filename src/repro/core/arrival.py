"""Vectorized event simulation for buffered-async FL (DESIGN.md §12.2).

``AsyncBuffered``'s original event loop is a host-side ``heapq`` advanced
one client at a time: every dispatch is a push, every buffer slot a pop,
so per-round host bookkeeping is O(population · log population) in Python
object churn. At the FedBuff regime the roadmap targets (10^5–10^6 clients
with continuous arrivals) that loop — not the decode math — is the
bottleneck.

:class:`ArrivalEngine` replaces the heap with struct-of-arrays state: one
``float64`` next-arrival-time per client plus one ``int64`` dispatch
sequence number (the FIFO tie-break the heap's ``(time, seq, ci)`` tuples
encode). Popping the first-K buffer becomes a single vectorized
selection — ``np.partition`` finds the K-th arrival time in O(N), a
lexsort over the (tiny) candidate set breaks ties — instead of K Python
heap pops. Per-round *Python* work is O(cohort): pushes touch only the
re-dispatched clients, and the one O(N) primitive left is a vectorized
C-level partition, not an interpreted loop.

The engine is **order-exact** against the heap: times stay ``float64``
(the same Python floats the heap compares), sequence numbers are assigned
identically, and ``pop_k`` returns exactly the K lexicographically
smallest ``(time, seq)`` entries in pop order. ``AsyncBuffered`` keeps the
heap as the differential oracle (``engine="heap"``) — the equivalence is
property-tested across random populations, latency models, and seeds in
tests/test_arrival.py.

:func:`pop_k_device` is the jit-native variant the streaming serve
pipeline (core/serve.py) stages on device: ``jax.lax.sort`` over the
``(time, seq)`` key pair — the same lexicographic contract, zero host
work — so the whole ingest round (pop → gather → decode→aggregate →
scatter re-dispatch) compiles into one donated XLA computation.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ArrivalEngine:
    """Struct-of-arrays event queue over a fixed client population.

    State per client: ``times[ci]`` — the simulated arrival time of the
    in-flight dispatch (``+inf`` = not in flight), ``seqs[ci]`` — the
    global dispatch sequence number (FIFO tie-break; ``-1`` = not in
    flight). A client has at most one in-flight update (the FedBuff
    dispatch discipline), which is what lets the heap collapse to one
    row per client."""

    def __init__(self, n_clients: int):
        self.n = int(n_clients)
        self.times = np.full(self.n, np.inf, dtype=np.float64)
        self.seqs = np.full(self.n, -1, dtype=np.int64)
        self.next_seq = 0

    # ------------------------------------------------------------------
    def in_flight(self) -> int:
        return int(np.count_nonzero(np.isfinite(self.times)))

    def push(self, ci: int, t: float) -> None:
        """Dispatch client ``ci`` with arrival time ``t``. O(1)."""
        assert not np.isfinite(self.times[ci]), (
            f"client {ci} already has an in-flight dispatch")
        self.times[ci] = float(t)
        self.seqs[ci] = self.next_seq
        self.next_seq += 1

    def push_many(self, cis: Sequence[int], ts: Sequence[float]) -> None:
        """Vectorized dispatch of a cohort: sequence numbers are assigned
        in ``cis`` order, matching one :meth:`push` per client."""
        cis = np.asarray(cis, dtype=np.int64)
        assert not np.isfinite(self.times[cis]).any(), (
            "push_many over clients with in-flight dispatches")
        self.times[cis] = np.asarray(ts, dtype=np.float64)
        self.seqs[cis] = self.next_seq + np.arange(len(cis), dtype=np.int64)
        self.next_seq += len(cis)

    # ------------------------------------------------------------------
    def pop_k(self, k: int) -> List[Tuple[float, int]]:
        """Drain the first-K buffer: the K in-flight entries with the
        lexicographically smallest ``(time, seq)``, in pop order — exactly
        what K ``heapq.heappop`` calls on ``(time, seq, ci)`` tuples
        return. One O(N) vectorized partition + an O(c log c) lexsort over
        the boundary-tie candidate set; no interpreted per-entry loop."""
        assert 0 < k <= self.in_flight(), (
            f"pop_k({k}) with only {self.in_flight()} in flight")
        # K-th smallest arrival time bounds the candidate set; ties AT the
        # boundary make it a superset, resolved by the (time, seq) lexsort
        kth = np.partition(self.times, k - 1)[k - 1]
        cand = np.flatnonzero(self.times <= kth)
        order = np.lexsort((self.seqs[cand], self.times[cand]))
        take = cand[order[:k]]
        out = [(float(self.times[ci]), int(ci)) for ci in take]
        self.times[take] = np.inf
        self.seqs[take] = -1
        return out

    # ------------------------------------------------------------------
    # checkpointing: the same JSON shape AsyncBuffered's heap persists
    # ([[time, seq, client], ...]), so heap- and vector-engine runs can
    # restore each other's checkpoints (DESIGN.md §12.2)
    # ------------------------------------------------------------------
    def entries(self) -> List[List[float]]:
        live = np.flatnonzero(np.isfinite(self.times))
        return [[float(self.times[ci]), int(self.seqs[ci]), int(ci)]
                for ci in live]

    @classmethod
    def from_entries(cls, n_clients: int, entries, next_seq: int
                     ) -> "ArrivalEngine":
        eng = cls(n_clients)
        for t, s, ci in entries:
            eng.times[int(ci)] = float(t)
            eng.seqs[int(ci)] = int(s)
        eng.next_seq = int(next_seq)
        return eng


# =====================================================================
# jit-native pop for the device-resident serve pipeline (DESIGN.md §12.3)
# =====================================================================
def pop_k_device(times: jax.Array, seqs: jax.Array, k: int
                 ) -> Tuple[jax.Array, jax.Array]:
    """First-K selection staged on device: ``lax.sort`` over the
    ``(time, seq)`` key pair (ascending, lexicographic — the heap's exact
    contract) returns the popped arrival times ``(k,)`` and client indices
    ``(k,)``. ``lax.top_k`` on negated times alone would leave equal-time
    tie order unspecified; carrying ``seq`` as the second sort key keeps
    the selection deterministic and oracle-equal. O(N log N) inside the
    kernel, O(1) host work."""
    idx = jnp.arange(times.shape[0], dtype=jnp.int32)
    s_times, _, s_idx = jax.lax.sort((times, seqs, idx), num_keys=2)
    return s_times[:k], s_idx[:k]

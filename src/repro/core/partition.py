"""Pytree-native per-layer codec partitions (DESIGN.md §10).

The paper trains one autoencoder **per layer** of the client model ("the
encoder is set up on each of the nodes", with per-tensor compression ratios
of 500–1720×), and FedZip (Malekijoo et al., 2021) shows layer-wise codec
selection is where the wins are — but until now the runtime raveled the
whole client pytree into one flat vector and compressed it with a single
spec, so a conv kernel and its bias shared one latent and one rate-control
rung. This module makes the mapping *leaf group → codec* first-class:

* :class:`PartitionMap` — the frozen **structural** half: named groups of
  model leaves, each group a tuple of ``(offset, size)`` slices into the
  ``ravel_pytree`` order of the model. Built once from a model template by
  :func:`identity_partition`, :func:`by_leaf_partition`, or
  :func:`by_layer_partition` and shared by every client (a federation
  shares one model, so it shares one partition structure).
* :class:`PartitionSpec` — the structural map **plus** one frozen
  ``CodecSpec`` per group. Hashable, so it is a valid jit-static spec and a
  drop-in member of the ``codec.CodecSpec`` union: ``codec.encode/decode/
  decode_batched/decode_and_aggregate/wire_bytes`` all dispatch on it, and
  ``Compressor``-level code (``_encode_local``, error feedback,
  ``codec_stats``) works unchanged.
* pure :func:`encode_tree`/:func:`decode_tree` — per-group gather →
  sub-codec encode; sub-codec decode → scatter. The identity partition
  (one group covering every leaf in ravel order) gathers and scatters with
  full-range slices, so its trajectories are **bit-identical** to the flat
  path (asserted at the repo's 1-ulp tolerance rule end-to-end).
* the grouped fused server path — :func:`server_decode_aggregate` reuses
  PR 4's group-by-spec machinery one level down: for each partition group
  it buckets the cohort by that group's codec spec and issues exactly ONE
  ``codec.decode_and_aggregate`` per (partition, spec) group per round
  (each a single jitted fused decode→aggregate; ``ChunkedAESpec``
  kernel-path groups launch one Pallas ``fused_decode_agg`` each), scaling
  sub-cohort means back by their weight mass exactly as the flat
  heterogeneous path does (DESIGN.md §9.2).

Params for a partitioned spec are a dict ``{group_name: ae_params_or_None}``
(the :class:`~repro.core.compressor.PartitionedCompressor` adapter builds
it), and payloads are ``{group_name: payload_dict}`` — still fixed-shape
array pytrees, so they stack along a client axis like any other payload.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import codec

Pytree = Any
Slices = Tuple[Tuple[int, int], ...]      # ((offset, size), ...) in ravel order


# =====================================================================
# structural half: named leaf groups as flat-vector slices
# =====================================================================
@dataclasses.dataclass(frozen=True)
class PartitionMap:
    """Frozen structural partition: ``groups[i] = (name, slices)`` where the
    slices index the ``ravel_pytree`` flat order of the model template. The
    map carries no codec choices — those live in :class:`PartitionSpec` (or
    per-group ``Compressor`` adapters) — so one map can serve every rung of
    a per-partition rate-control ladder (DESIGN.md §10.3)."""

    groups: Tuple[Tuple[str, Slices], ...]

    def __post_init__(self):
        names = [n for n, _ in self.groups]
        assert len(set(names)) == len(names), f"duplicate group names {names}"
        covered = sorted(
            (o, s) for _, sl in self.groups for o, s in sl)
        pos = 0
        for o, s in covered:
            assert s > 0, "empty slice in partition map"
            assert o == pos, (
                f"partition slices must tile the flat vector: gap/overlap "
                f"at offset {o} (expected {pos})")
            pos = o + s
        object.__setattr__(self, "_size", pos)

    @property
    def size(self) -> int:
        return self._size

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.groups)

    def group_size(self, name: str) -> int:
        return sum(s for _, s in self.slices_of(name))

    def slices_of(self, name: str) -> Slices:
        return dict(self.groups)[name]


def _leaf_segments(template: Pytree) -> List[Tuple[str, int, int]]:
    """(path-name, offset, size) per leaf of ``template`` in ravel order."""
    leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    out, pos = [], 0
    for path, leaf in leaves:
        name = "/".join(_key_str(p) for p in path)
        size = int(jnp.size(leaf))
        out.append((name, pos, size))
        pos += size
    return out


def _key_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def identity_partition(template: Pytree, name: str = "all") -> PartitionMap:
    """One group covering every leaf in ravel order — the compatibility
    partition whose trajectories must reproduce the flat path bit-for-bit
    (its gather/scatter are full-range slices)."""
    segs = _leaf_segments(template)
    total = sum(s for _, _, s in segs)
    return PartitionMap(groups=((name, ((0, total),)),))


def by_leaf_partition(template: Pytree) -> PartitionMap:
    """One group per model leaf (the paper's one-AE-per-weight-tensor
    reading): group names are the ``/``-joined pytree paths."""
    segs = _leaf_segments(template)
    return PartitionMap(groups=tuple(
        (name, ((off, size),)) for name, off, size in segs))


def by_layer_partition(template: Pytree,
                       key_fn: Optional[Callable[[str], str]] = None
                       ) -> PartitionMap:
    """Group leaves by ``key_fn`` of their path (default: the first path
    component, so ``dense0/w`` and ``dense0/b`` share the ``dense0`` group
    — one codec per *layer*, the FedZip granularity). Groups keep first-seen
    order; a group's slices may be non-contiguous in the flat vector (its
    codec sees the concatenation)."""
    key_fn = key_fn or (lambda path: path.split("/")[0])
    segs = _leaf_segments(template)
    grouped: Dict[str, List[Tuple[int, int]]] = {}
    for name, off, size in segs:
        grouped.setdefault(key_fn(name), []).append((off, size))
    return PartitionMap(groups=tuple(
        (k, tuple(v)) for k, v in grouped.items()))


# Transformer role taxonomy for the ``configs/`` model zoo (DESIGN.md §14):
# four coarse groups that want different codec rungs — the giant embedding
# matrices, the attention/mixer projections, the MLP/expert blocks, and
# the tiny norm vectors that should never pay AE distortion. Component
# checks run outermost-first so e.g. ``layers/mixer/conv_w`` lands in
# ``attention`` (the mixer is the sequence-mixing block in SSM/hybrid
# archs) before any inner key can match.
_ROLE_NORM_KEYS = ("ln", "ln1", "ln2", "ln_x", "final_norm", "enc_norm")


def role_of_path(path: str) -> str:
    """Map a ``/``-joined pytree path to its architectural role:
    ``embedding`` | ``attention`` | ``mlp`` | ``norm`` (``other`` only for
    trees outside the zoo's vocabulary). Covers every ``configs/`` family:
    dense/MoE/VLM blocks, SSM and hybrid mixers, audio encoder/decoder."""
    for comp in path.split("/"):
        if comp in ("embed", "lm_head", "pos_embed") or \
                comp.startswith("embed"):
            return "embedding"
        if comp in _ROLE_NORM_KEYS or "norm" in comp:
            return "norm"
        if "attn" in comp or comp == "mixer":
            return "attention"
        if comp in ("ffn", "mlp") or "expert" in comp or \
                "router" in comp or "moe" in comp:
            return "mlp"
    return "other"


def by_role_partition(template: Pytree,
                      key_fn: Callable[[str], str] = role_of_path
                      ) -> PartitionMap:
    """Partition a real model pytree by architectural role — embedding vs
    attention vs MLP vs norm — so each role can ride a different codec
    rung (chunked-AE on the bulk roles, cheap quantize on norms). Thin
    wrapper over :func:`by_layer_partition` with :func:`role_of_path` as
    the grouping key; property tests assert the groups tile every zoo
    config's param tree with no ``other`` leftovers."""
    return by_layer_partition(template, key_fn=key_fn)


# =====================================================================
# full spec: structure + one codec per group (a CodecSpec union member)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """A :class:`PartitionMap` with one frozen ``CodecSpec`` per group:
    ``groups[i] = (name, slices, codec_spec)``. Hashable → jit-static; the
    ``codec`` module's encode/decode/aggregate entry points dispatch on it
    (DESIGN.md §10.1)."""

    groups: Tuple[Tuple[str, Slices, codec.CodecSpec], ...]

    def __post_init__(self):
        PartitionMap(groups=tuple((n, sl) for n, sl, _ in self.groups))
        for name, sl, spec in self.groups:
            gsize = sum(s for _, s in sl)
            assert spec.size == gsize, (
                f"group {name!r}: codec spec sized {spec.size} but the "
                f"group's leaves total {gsize}")

    @property
    def size(self) -> int:
        return sum(s for _, sl, _ in self.groups for _, s in sl)

    @property
    def structure(self) -> Tuple[Tuple[str, Slices], ...]:
        """The codec-free structural half — what must agree across a cohort
        for the grouped server path to aggregate it."""
        return tuple((n, sl) for n, sl, _ in self.groups)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _, _ in self.groups)

    def spec_of(self, name: str) -> codec.CodecSpec:
        return {n: sp for n, _, sp in self.groups}[name]


def make_partition_spec(pmap: PartitionMap,
                        specs: Dict[str, codec.CodecSpec]) -> PartitionSpec:
    """Bind one codec spec per group of ``pmap`` (keys must match)."""
    assert set(specs) == set(pmap.names), (
        f"spec keys {sorted(specs)} != partition groups "
        f"{sorted(pmap.names)}")
    return PartitionSpec(groups=tuple(
        (name, sl, specs[name]) for name, sl in pmap.groups))


# =====================================================================
# pure gather/scatter between the model-flat vector and group vectors
# =====================================================================
def gather(slices: Slices, flat: jax.Array) -> jax.Array:
    """Concatenate a group's slices out of the model-flat vector. All
    offsets/sizes are static, so this stages into XLA slices under jit; a
    single full-range slice (the identity partition) is the vector itself,
    bit-for-bit."""
    if len(slices) == 1:
        o, s = slices[0]
        return jax.lax.slice_in_dim(flat, o, o + s, axis=-1)
    return jnp.concatenate(
        [jax.lax.slice_in_dim(flat, o, o + s, axis=-1) for o, s in slices],
        axis=-1)


def scatter_groups(spec_structure: Sequence[Tuple[str, Slices]],
                   group_vecs: Dict[str, jax.Array],
                   size: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of per-group :func:`gather`: place every group's (possibly
    batched ``(..., group_size)``) vector back into a ``(..., size)``
    model-flat vector. Groups tile the vector (PartitionMap invariant), so
    every element is written exactly once."""
    lead = next(iter(group_vecs.values())).shape[:-1]
    out = jnp.zeros(lead + (size,), dtype)
    for name, slices in spec_structure:
        vec = group_vecs[name]
        pos = 0
        for o, s in slices:
            seg = jax.lax.slice_in_dim(vec, pos, pos + s, axis=-1)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, seg.astype(dtype), o, axis=-1)
            pos += s
    return out


# =====================================================================
# pure per-partition encode/decode (the codec module dispatches here)
# =====================================================================
def encode_tree(spec: PartitionSpec, params: Optional[Dict[str, Pytree]],
                flat: jax.Array) -> Dict[str, codec.Payload]:
    """Collaborator-side: gather each group out of the model-flat vector
    and run its own codec → ``{group_name: payload}``. Pure and jit-able
    with ``spec`` static (DESIGN.md §10.1)."""
    out = {}
    for name, slices, cspec in spec.groups:
        p = None if params is None else params.get(name)
        out[name] = codec.encode(cspec, p, gather(slices, flat))
    return out


def decode_tree(spec: PartitionSpec, params: Optional[Dict[str, Pytree]],
                payloads: Dict[str, codec.Payload]) -> jax.Array:
    """Aggregator-side inverse: decode every group and scatter the results
    back into one ``(spec.size,)`` model-flat vector."""
    vecs = {}
    for name, slices, cspec in spec.groups:
        p = None if params is None else params.get(name)
        vecs[name] = codec.decode(cspec, p, payloads[name])
    return scatter_groups(spec.structure, vecs, spec.size)


def decode_tree_batched(spec: PartitionSpec,
                        params: Optional[Dict[str, Pytree]],
                        stacked: Dict[str, codec.Payload], *,
                        params_batched: bool = False) -> jax.Array:
    """Cohort-batched decode: per-group ``codec.decode_batched`` then a
    batched scatter → ``(C, spec.size)``."""
    vecs = {}
    for name, slices, cspec in spec.groups:
        p = None if params is None else params.get(name)
        vecs[name] = codec.decode_batched(
            cspec, p, stacked[name],
            # pointwise groups carry no params: keep their shared fast path
            params_batched=params_batched and p is not None)
    return scatter_groups(spec.structure, vecs, spec.size)


def wire_bytes_by_group(spec: PartitionSpec,
                        params: Optional[Dict[str, Pytree]] = None
                        ) -> Dict[str, int]:
    """Per-partition uplink price list: ``codec.wire_bytes`` of each
    group's codec (eval-shape, nothing runs). Sums to
    ``codec.wire_bytes(spec, params)`` — the same single pricing rule the
    rate controllers plan per-(client, partition) ladders with
    (DESIGN.md §10.3)."""
    out = {}
    for name, _, cspec in spec.groups:
        p = None if params is None else params.get(name)
        out[name] = codec.wire_bytes(cspec, p)
    return out


# =====================================================================
# the grouped fused server path: one fused call per (partition, spec) group
# =====================================================================
def server_decode_aggregate(encoded: Sequence, norm_weights: List[float],
                            base: Optional[jax.Array], *,
                            use_grouped_kernel: Optional[bool] = None
                            ) -> jax.Array:
    """Fused decode→aggregate for a partitioned cohort: for each partition
    group, bucket the cohort by that group's codec spec and issue exactly
    one ``codec.decode_and_aggregate`` per (partition, spec) bucket —
    heterogeneous cohorts × heterogeneous layers still hit the fused path
    (DESIGN.md §10.2). ``encoded`` entries are the scheduler's
    ``EncodedUpdate``s whose ``spec`` is a :class:`PartitionSpec`;
    ``norm_weights`` must sum to 1 (``aggregate.normalize_weights``).

    A single-bucket group reduces with the cohort weights directly — the
    bit-stable homogeneous path, so the identity partition reproduces the
    flat reduction exactly. A multi-bucket group renormalizes each bucket
    to Σ=1 (``decode_and_aggregate``'s contract; the kernel-path chunked AE
    denorms and subtracts ``base`` on that assumption) and scales its mean
    back by the bucket's weight mass, exactly as the flat heterogeneous
    path does (DESIGN.md §9.2).

    ``use_grouped_kernel`` (default: ``ops.use_grouped_default`` — env var
    ``REPRO_GROUPED_KERNEL``, else off) routes the whole round through ONE
    jitted dispatch instead of one per bucket: pointwise/batched-params
    buckets inline their fused reductions, and every kernel-path chunked-AE
    bucket joins a single grouped ragged Pallas launch
    (``kernels.fused_decode_agg.grouped_fused_decode_agg``, DESIGN.md
    §11.2). The per-bucket sequential loop below stays the differential
    oracle (tests/test_grouped_kernel.py)."""
    spec0: PartitionSpec = encoded[0].spec
    structure = spec0.structure
    for e in encoded:
        assert isinstance(e.spec, PartitionSpec) and \
            e.spec.structure == structure, (
                "partitioned cohorts must share one partition structure "
                "(groups/slices); per-group codec specs may differ")
    from repro.kernels.ops import use_grouped_default
    if use_grouped_default(use_grouped_kernel):
        groups_host = []
        for gi, (name, slices) in enumerate(structure):
            buckets: Dict[codec.CodecSpec, List[int]] = {}
            for i, e in enumerate(encoded):
                buckets.setdefault(e.spec.groups[gi][2], []).append(i)
            groups_host.append((name, slices, [
                (cspec, idx,
                 [encoded[i].payload[name] for i in idx],
                 [None if encoded[i].params is None
                  else encoded[i].params.get(name) for i in idx])
                for cspec, idx in buckets.items()]))
        return _grouped_server_round(groups_host, list(norm_weights), base,
                                     spec0.size)
    norm_w = jnp.asarray(norm_weights, jnp.float32)
    group_means: Dict[str, jax.Array] = {}
    for gi, (name, slices) in enumerate(structure):
        base_g = None if base is None else gather(slices, base)
        buckets: Dict[codec.CodecSpec, List[int]] = {}
        for i, e in enumerate(encoded):
            buckets.setdefault(e.spec.groups[gi][2], []).append(i)
        mean_g = None
        for cspec, idx in buckets.items():
            stacked = codec.stack_payloads(
                [encoded[i].payload[name] for i in idx])
            plist = [None if encoded[i].params is None
                     else encoded[i].params.get(name) for i in idx]
            if all(p is plist[0] for p in plist):
                params, pb = plist[0], False
            else:
                params = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *plist)
                pb = True
            if len(buckets) == 1:
                mean_g = codec.decode_and_aggregate(
                    cspec, params, stacked, norm_w, base_g,
                    params_batched=pb)
                break
            s_g = sum(norm_weights[i] for i in idx)    # host float: stable
            w_g = jnp.asarray([norm_weights[i] / s_g for i in idx],
                              jnp.float32)
            part = codec.decode_and_aggregate(cspec, params, stacked, w_g,
                                              base_g, params_batched=pb)
            contrib = jnp.float32(s_g) * part
            mean_g = contrib if mean_g is None else mean_g + contrib
        group_means[name] = mean_g
    return scatter_groups(structure, group_means, spec0.size)


# =====================================================================
# grouped one-dispatch round (DESIGN.md §11.2): the whole heterogeneous
# cohort — every (partition, spec) bucket — staged into ONE jitted call,
# with all kernel-path chunked-AE buckets sharing ONE grouped ragged
# Pallas launch per (hidden, chunk) signature.
# =====================================================================
def grouped_flat_server_aggregate(encoded: Sequence,
                                  norm_weights: List[float],
                                  base: Optional[jax.Array]) -> jax.Array:
    """Flat (non-partitioned) heterogeneous cohort — e.g. rate-control
    ladder rungs — as one pseudo-group covering the whole vector, routed
    through the same one-dispatch grouped round as the partitioned path.
    Numerically matches the scheduler's sequential group-by-spec loop:
    identical per-bucket renormalization (host floats) and identical
    per-bucket kernel math (the grouped launch's zero-weight padding adds
    exact zeros, DESIGN.md §11.1)."""
    size = encoded[0].spec.size
    buckets: Dict[codec.CodecSpec, List[int]] = {}
    for i, e in enumerate(encoded):
        buckets.setdefault(e.spec, []).append(i)
    groups_host = [("all", ((0, size),), [
        (cspec, idx,
         [encoded[i].payload for i in idx],
         [encoded[i].params for i in idx])
        for cspec, idx in buckets.items()])]
    return _grouped_server_round(groups_host, list(norm_weights), base, size)


def _grouped_server_round(groups_host, norm_weights: List[float],
                          base: Optional[jax.Array], size: int) -> jax.Array:
    """Host-side builder for :func:`_grouped_round`: split every bucket into
    its static half (spec, params-batched?, decoder slot, single-bucket?) —
    the jit cache key — and its dynamic half (stacked payloads, params,
    renormalized weights, weight masses). Client *index lists* stay dynamic
    (weights are materialized as arrays here), so round-to-round cohort
    reshuffles at fixed bucket shapes do NOT retrace.

    ``groups_host[g] = (name, slices, [(cspec, idx, payload_list,
    params_list), ...])``. Decoder slots are assigned in first-seen bucket
    order (not by object id), so a stable bucket ordering yields a stable
    plan even when AE params are refreshed between rounds."""
    norm_w = jnp.asarray(norm_weights, jnp.float32)
    plan, payloads, params_all, wlists, sgs = [], [], [], [], []
    dec_slots: Dict[int, int] = {}
    for name, slices, buckets in groups_host:
        single = len(buckets) == 1
        bplan, pays, prms, ws, sgl = [], [], [], [], []
        for cspec, idx, pay_list, prm_list in buckets:
            stacked = codec.stack_payloads(pay_list)
            if all(p is prm_list[0] for p in prm_list):
                prm, pb = prm_list[0], False
            else:
                prm = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *prm_list)
                pb = True
            if single:
                w_b, s_g = norm_w, 1.0       # bit-stable homogeneous path
            else:
                s_g = sum(norm_weights[i] for i in idx)   # host float
                w_b = jnp.asarray([norm_weights[i] / s_g for i in idx],
                                  jnp.float32)
            slot = None
            # terminal-stage routing (DESIGN.md §13.4): any spec whose final
            # decode transform is a kernel-path chunked-AE expansion — bare
            # or behind pointwise chain stages — joins the grouped ragged
            # launch; slots key on the AE *stage's* params so chains and
            # bare specs sharing one decoder share one slot
            if codec.kernel_terminal_ae(cspec) is not None and not pb:
                slot = dec_slots.setdefault(
                    id(codec.ae_stage_params(cspec, prm)), len(dec_slots))
            bplan.append((cspec, pb, slot, single))
            pays.append(stacked)
            prms.append(prm)
            ws.append(w_b)
            sgl.append(s_g)
        plan.append((name, slices, tuple(bplan)))
        payloads.append(tuple(pays))
        params_all.append(tuple(prms))
        wlists.append(tuple(ws))
        sgs.append(tuple(sgl))
    return _grouped_round(tuple(plan), size, tuple(payloads),
                          tuple(params_all), tuple(wlists), tuple(sgs), base)


@functools.partial(jax.jit, static_argnames=("plan", "size"))
def _grouped_round(plan, size, payloads, params, wlists, sgs,
                   base: Optional[jax.Array]) -> jax.Array:
    """ONE jitted dispatch for the whole round. Pointwise / batched-params
    buckets inline ``codec.decode_and_aggregate`` (nested jit inlines into
    this trace); kernel-path chunked-AE buckets compute their latent-sided
    hidden activations and then share one grouped ragged Pallas launch per
    ``(hidden_width, chunk_size)`` signature — the final expansion +
    weighted reduction for every AE bucket of every group in one kernel
    sweep (DESIGN.md §11.1–§11.2). Decoder stacks are deduped by slot, so
    buckets sharing one decoder ship its weights to the launch once."""
    from repro.kernels.fused_decode_agg import grouped_fused_decode_agg
    from repro.kernels.ops import interpret_default

    group_means: Dict[str, jax.Array] = {}

    def _add(name, contrib):
        prev = group_means.get(name)
        group_means[name] = contrib if prev is None else prev + contrib

    jobs: Dict[Tuple[int, int], List[dict]] = {}
    for (name, slices, bplan), pays, prms, ws, sgl in zip(
            plan, payloads, params, wlists, sgs):
        base_g = None if base is None else gather(slices, base)
        for (cspec, pb, slot, single), pay, prm, w_b, s_g in zip(
                bplan, pays, prms, ws, sgl):
            if slot is not None:
                kspec = codec.kernel_terminal_ae(cspec)
                z, ae_prm = codec.kernel_chain_latents(cspec, prm, pay)
                h = codec.chunked_hidden(kspec, ae_prm, z)
                jobs.setdefault((h.shape[-1], kspec.cfg.chunk_size),
                                []).append(dict(
                    h=h, w=w_b, slot=slot, dec=ae_prm["dec"][-1],
                    norm=ae_prm["norm"], spec=cspec, sg=s_g, single=single,
                    base_g=base_g, name=name))
                continue
            mean_b = codec.decode_and_aggregate(cspec, prm, pay, w_b,
                                                base_g, params_batched=pb)
            _add(name, mean_b if single
                 else jnp.asarray(s_g, jnp.float32) * mean_b)
    for (_K, _N), js in jobs.items():
        slots = sorted({j["slot"] for j in js})
        remap = {s: i for i, s in enumerate(slots)}
        by_slot = {}
        for j in js:
            by_slot.setdefault(j["slot"], j)
        w_stack = jnp.stack([by_slot[s]["dec"]["w"] for s in slots])
        b_stack = jnp.stack([by_slot[s]["dec"]["b"] for s in slots])
        outs = grouped_fused_decode_agg(
            [j["h"] for j in js], [j["w"] for j in js], w_stack, b_stack,
            [remap[j["slot"]] for j in js], interpret=interpret_default())
        for j, chunks in zip(js, outs):
            # Σw=1 per bucket ⇒ the weighted sum of normalized chunks
            # denorms like a single reconstruction (same math as the
            # per-bucket path in codec._fused_chunked_decode_agg)
            norm = j["norm"]
            flat_b = (chunks * norm["std"] + norm["mean"]
                      ).reshape(-1)[:j["spec"].size]
            if j["base_g"] is not None:
                flat_b = flat_b - j["base_g"]
            _add(j["name"], flat_b if j["single"]
                 else jnp.asarray(j["sg"], jnp.float32) * flat_b)
    structure = tuple((n, sl) for n, sl, _ in plan)
    return scatter_groups(structure, group_means, size)

"""Pytree-native per-layer codec partitions (DESIGN.md §10).

The paper trains one autoencoder **per layer** of the client model ("the
encoder is set up on each of the nodes", with per-tensor compression ratios
of 500–1720×), and FedZip (Malekijoo et al., 2021) shows layer-wise codec
selection is where the wins are — but until now the runtime raveled the
whole client pytree into one flat vector and compressed it with a single
spec, so a conv kernel and its bias shared one latent and one rate-control
rung. This module makes the mapping *leaf group → codec* first-class:

* :class:`PartitionMap` — the frozen **structural** half: named groups of
  model leaves, each group a tuple of ``(offset, size)`` slices into the
  ``ravel_pytree`` order of the model. Built once from a model template by
  :func:`identity_partition`, :func:`by_leaf_partition`, or
  :func:`by_layer_partition` and shared by every client (a federation
  shares one model, so it shares one partition structure).
* :class:`PartitionSpec` — the structural map **plus** one frozen
  ``CodecSpec`` per group. Hashable, so it is a valid jit-static spec and a
  drop-in member of the ``codec.CodecSpec`` union: ``codec.encode/decode/
  decode_batched/decode_and_aggregate/wire_bytes`` all dispatch on it, and
  ``Compressor``-level code (``_encode_local``, error feedback,
  ``codec_stats``) works unchanged.
* pure :func:`encode_tree`/:func:`decode_tree` — per-group gather →
  sub-codec encode; sub-codec decode → scatter. The identity partition
  (one group covering every leaf in ravel order) gathers and scatters with
  full-range slices, so its trajectories are **bit-identical** to the flat
  path (asserted at the repo's 1-ulp tolerance rule end-to-end).
* the grouped fused server path — :func:`server_decode_aggregate` reuses
  PR 4's group-by-spec machinery one level down: for each partition group
  it buckets the cohort by that group's codec spec and issues exactly ONE
  ``codec.decode_and_aggregate`` per (partition, spec) group per round
  (each a single jitted fused decode→aggregate; ``ChunkedAESpec``
  kernel-path groups launch one Pallas ``fused_decode_agg`` each), scaling
  sub-cohort means back by their weight mass exactly as the flat
  heterogeneous path does (DESIGN.md §9.2).

Params for a partitioned spec are a dict ``{group_name: ae_params_or_None}``
(the :class:`~repro.core.compressor.PartitionedCompressor` adapter builds
it), and payloads are ``{group_name: payload_dict}`` — still fixed-shape
array pytrees, so they stack along a client axis like any other payload.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import codec

Pytree = Any
Slices = Tuple[Tuple[int, int], ...]      # ((offset, size), ...) in ravel order


# =====================================================================
# structural half: named leaf groups as flat-vector slices
# =====================================================================
@dataclasses.dataclass(frozen=True)
class PartitionMap:
    """Frozen structural partition: ``groups[i] = (name, slices)`` where the
    slices index the ``ravel_pytree`` flat order of the model template. The
    map carries no codec choices — those live in :class:`PartitionSpec` (or
    per-group ``Compressor`` adapters) — so one map can serve every rung of
    a per-partition rate-control ladder (DESIGN.md §10.3)."""

    groups: Tuple[Tuple[str, Slices], ...]

    def __post_init__(self):
        names = [n for n, _ in self.groups]
        assert len(set(names)) == len(names), f"duplicate group names {names}"
        covered = sorted(
            (o, s) for _, sl in self.groups for o, s in sl)
        pos = 0
        for o, s in covered:
            assert s > 0, "empty slice in partition map"
            assert o == pos, (
                f"partition slices must tile the flat vector: gap/overlap "
                f"at offset {o} (expected {pos})")
            pos = o + s
        object.__setattr__(self, "_size", pos)

    @property
    def size(self) -> int:
        return self._size

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.groups)

    def group_size(self, name: str) -> int:
        return sum(s for _, s in self.slices_of(name))

    def slices_of(self, name: str) -> Slices:
        return dict(self.groups)[name]


def _leaf_segments(template: Pytree) -> List[Tuple[str, int, int]]:
    """(path-name, offset, size) per leaf of ``template`` in ravel order."""
    leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    out, pos = [], 0
    for path, leaf in leaves:
        name = "/".join(_key_str(p) for p in path)
        size = int(jnp.size(leaf))
        out.append((name, pos, size))
        pos += size
    return out


def _key_str(entry) -> str:
    if isinstance(entry, jax.tree_util.DictKey):
        return str(entry.key)
    if isinstance(entry, jax.tree_util.SequenceKey):
        return str(entry.idx)
    if isinstance(entry, jax.tree_util.GetAttrKey):
        return str(entry.name)
    return str(entry)


def identity_partition(template: Pytree, name: str = "all") -> PartitionMap:
    """One group covering every leaf in ravel order — the compatibility
    partition whose trajectories must reproduce the flat path bit-for-bit
    (its gather/scatter are full-range slices)."""
    segs = _leaf_segments(template)
    total = sum(s for _, _, s in segs)
    return PartitionMap(groups=((name, ((0, total),)),))


def by_leaf_partition(template: Pytree) -> PartitionMap:
    """One group per model leaf (the paper's one-AE-per-weight-tensor
    reading): group names are the ``/``-joined pytree paths."""
    segs = _leaf_segments(template)
    return PartitionMap(groups=tuple(
        (name, ((off, size),)) for name, off, size in segs))


def by_layer_partition(template: Pytree,
                       key_fn: Optional[Callable[[str], str]] = None
                       ) -> PartitionMap:
    """Group leaves by ``key_fn`` of their path (default: the first path
    component, so ``dense0/w`` and ``dense0/b`` share the ``dense0`` group
    — one codec per *layer*, the FedZip granularity). Groups keep first-seen
    order; a group's slices may be non-contiguous in the flat vector (its
    codec sees the concatenation)."""
    key_fn = key_fn or (lambda path: path.split("/")[0])
    segs = _leaf_segments(template)
    grouped: Dict[str, List[Tuple[int, int]]] = {}
    for name, off, size in segs:
        grouped.setdefault(key_fn(name), []).append((off, size))
    return PartitionMap(groups=tuple(
        (k, tuple(v)) for k, v in grouped.items()))


# =====================================================================
# full spec: structure + one codec per group (a CodecSpec union member)
# =====================================================================
@dataclasses.dataclass(frozen=True)
class PartitionSpec:
    """A :class:`PartitionMap` with one frozen ``CodecSpec`` per group:
    ``groups[i] = (name, slices, codec_spec)``. Hashable → jit-static; the
    ``codec`` module's encode/decode/aggregate entry points dispatch on it
    (DESIGN.md §10.1)."""

    groups: Tuple[Tuple[str, Slices, codec.CodecSpec], ...]

    def __post_init__(self):
        PartitionMap(groups=tuple((n, sl) for n, sl, _ in self.groups))
        for name, sl, spec in self.groups:
            gsize = sum(s for _, s in sl)
            assert spec.size == gsize, (
                f"group {name!r}: codec spec sized {spec.size} but the "
                f"group's leaves total {gsize}")

    @property
    def size(self) -> int:
        return sum(s for _, sl, _ in self.groups for _, s in sl)

    @property
    def structure(self) -> Tuple[Tuple[str, Slices], ...]:
        """The codec-free structural half — what must agree across a cohort
        for the grouped server path to aggregate it."""
        return tuple((n, sl) for n, sl, _ in self.groups)

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _, _ in self.groups)

    def spec_of(self, name: str) -> codec.CodecSpec:
        return {n: sp for n, _, sp in self.groups}[name]


def make_partition_spec(pmap: PartitionMap,
                        specs: Dict[str, codec.CodecSpec]) -> PartitionSpec:
    """Bind one codec spec per group of ``pmap`` (keys must match)."""
    assert set(specs) == set(pmap.names), (
        f"spec keys {sorted(specs)} != partition groups "
        f"{sorted(pmap.names)}")
    return PartitionSpec(groups=tuple(
        (name, sl, specs[name]) for name, sl in pmap.groups))


# =====================================================================
# pure gather/scatter between the model-flat vector and group vectors
# =====================================================================
def gather(slices: Slices, flat: jax.Array) -> jax.Array:
    """Concatenate a group's slices out of the model-flat vector. All
    offsets/sizes are static, so this stages into XLA slices under jit; a
    single full-range slice (the identity partition) is the vector itself,
    bit-for-bit."""
    if len(slices) == 1:
        o, s = slices[0]
        return jax.lax.slice_in_dim(flat, o, o + s, axis=-1)
    return jnp.concatenate(
        [jax.lax.slice_in_dim(flat, o, o + s, axis=-1) for o, s in slices],
        axis=-1)


def scatter_groups(spec_structure: Sequence[Tuple[str, Slices]],
                   group_vecs: Dict[str, jax.Array],
                   size: int, dtype=jnp.float32) -> jax.Array:
    """Inverse of per-group :func:`gather`: place every group's (possibly
    batched ``(..., group_size)``) vector back into a ``(..., size)``
    model-flat vector. Groups tile the vector (PartitionMap invariant), so
    every element is written exactly once."""
    lead = next(iter(group_vecs.values())).shape[:-1]
    out = jnp.zeros(lead + (size,), dtype)
    for name, slices in spec_structure:
        vec = group_vecs[name]
        pos = 0
        for o, s in slices:
            seg = jax.lax.slice_in_dim(vec, pos, pos + s, axis=-1)
            out = jax.lax.dynamic_update_slice_in_dim(
                out, seg.astype(dtype), o, axis=-1)
            pos += s
    return out


# =====================================================================
# pure per-partition encode/decode (the codec module dispatches here)
# =====================================================================
def encode_tree(spec: PartitionSpec, params: Optional[Dict[str, Pytree]],
                flat: jax.Array) -> Dict[str, codec.Payload]:
    """Collaborator-side: gather each group out of the model-flat vector
    and run its own codec → ``{group_name: payload}``. Pure and jit-able
    with ``spec`` static (DESIGN.md §10.1)."""
    out = {}
    for name, slices, cspec in spec.groups:
        p = None if params is None else params.get(name)
        out[name] = codec.encode(cspec, p, gather(slices, flat))
    return out


def decode_tree(spec: PartitionSpec, params: Optional[Dict[str, Pytree]],
                payloads: Dict[str, codec.Payload]) -> jax.Array:
    """Aggregator-side inverse: decode every group and scatter the results
    back into one ``(spec.size,)`` model-flat vector."""
    vecs = {}
    for name, slices, cspec in spec.groups:
        p = None if params is None else params.get(name)
        vecs[name] = codec.decode(cspec, p, payloads[name])
    return scatter_groups(spec.structure, vecs, spec.size)


def decode_tree_batched(spec: PartitionSpec,
                        params: Optional[Dict[str, Pytree]],
                        stacked: Dict[str, codec.Payload], *,
                        params_batched: bool = False) -> jax.Array:
    """Cohort-batched decode: per-group ``codec.decode_batched`` then a
    batched scatter → ``(C, spec.size)``."""
    vecs = {}
    for name, slices, cspec in spec.groups:
        p = None if params is None else params.get(name)
        vecs[name] = codec.decode_batched(
            cspec, p, stacked[name],
            # pointwise groups carry no params: keep their shared fast path
            params_batched=params_batched and p is not None)
    return scatter_groups(spec.structure, vecs, spec.size)


def wire_bytes_by_group(spec: PartitionSpec,
                        params: Optional[Dict[str, Pytree]] = None
                        ) -> Dict[str, int]:
    """Per-partition uplink price list: ``codec.wire_bytes`` of each
    group's codec (eval-shape, nothing runs). Sums to
    ``codec.wire_bytes(spec, params)`` — the same single pricing rule the
    rate controllers plan per-(client, partition) ladders with
    (DESIGN.md §10.3)."""
    out = {}
    for name, _, cspec in spec.groups:
        p = None if params is None else params.get(name)
        out[name] = codec.wire_bytes(cspec, p)
    return out


# =====================================================================
# the grouped fused server path: one fused call per (partition, spec) group
# =====================================================================
def server_decode_aggregate(encoded: Sequence, norm_weights: List[float],
                            base: Optional[jax.Array]) -> jax.Array:
    """Fused decode→aggregate for a partitioned cohort: for each partition
    group, bucket the cohort by that group's codec spec and issue exactly
    one ``codec.decode_and_aggregate`` per (partition, spec) bucket —
    heterogeneous cohorts × heterogeneous layers still hit the fused path
    (DESIGN.md §10.2). ``encoded`` entries are the scheduler's
    ``EncodedUpdate``s whose ``spec`` is a :class:`PartitionSpec`;
    ``norm_weights`` must sum to 1 (``aggregate.normalize_weights``).

    A single-bucket group reduces with the cohort weights directly — the
    bit-stable homogeneous path, so the identity partition reproduces the
    flat reduction exactly. A multi-bucket group renormalizes each bucket
    to Σ=1 (``decode_and_aggregate``'s contract; the kernel-path chunked AE
    denorms and subtracts ``base`` on that assumption) and scales its mean
    back by the bucket's weight mass, exactly as the flat heterogeneous
    path does (DESIGN.md §9.2)."""
    spec0: PartitionSpec = encoded[0].spec
    structure = spec0.structure
    for e in encoded:
        assert isinstance(e.spec, PartitionSpec) and \
            e.spec.structure == structure, (
                "partitioned cohorts must share one partition structure "
                "(groups/slices); per-group codec specs may differ")
    norm_w = jnp.asarray(norm_weights, jnp.float32)
    group_means: Dict[str, jax.Array] = {}
    for gi, (name, slices) in enumerate(structure):
        base_g = None if base is None else gather(slices, base)
        buckets: Dict[codec.CodecSpec, List[int]] = {}
        for i, e in enumerate(encoded):
            buckets.setdefault(e.spec.groups[gi][2], []).append(i)
        mean_g = None
        for cspec, idx in buckets.items():
            stacked = codec.stack_payloads(
                [encoded[i].payload[name] for i in idx])
            plist = [None if encoded[i].params is None
                     else encoded[i].params.get(name) for i in idx]
            if all(p is plist[0] for p in plist):
                params, pb = plist[0], False
            else:
                params = jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *plist)
                pb = True
            if len(buckets) == 1:
                mean_g = codec.decode_and_aggregate(
                    cspec, params, stacked, norm_w, base_g,
                    params_batched=pb)
                break
            s_g = sum(norm_weights[i] for i in idx)    # host float: stable
            w_g = jnp.asarray([norm_weights[i] / s_g for i in idx],
                              jnp.float32)
            part = codec.decode_and_aggregate(cspec, params, stacked, w_g,
                                              base_g, params_batched=pb)
            contrib = jnp.float32(s_g) * part
            mean_g = contrib if mean_g is None else mean_g + contrib
        group_means[name] = mean_g
    return scatter_groups(structure, group_means, spec0.size)

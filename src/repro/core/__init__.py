"""The paper's primary contribution: autoencoder-compressed weight-update
communication for federated learning, as a composable JAX library."""
from repro.core.aggregate import (  # noqa: F401
    apply_update,
    buffered_aggregate,
    distortion_weights,
    fedavg,
    normalize_weights,
    staleness_weights,
    weighted_mean,
    weighted_mean_stacked,
)
from repro.core.codec import (  # noqa: F401
    ChainSpec,
    ChunkedAESpec,
    ComposedSpec,
    EntropySpec,
    FCAESpec,
    IdentitySpec,
    KMeansSpec,
    QuantizeSpec,
    TopKSpec,
    ae_spec,
    composed_chain,
    decode_and_aggregate,
    decode_and_aggregate_sharded,
    decode_batched,
    is_shape_static,
    measured_bytes,
    stack_payloads,
    stage_ops,
    stage_out_size,
    wire_bytes,
)
from repro.core import codec  # noqa: F401
from repro.core.partition import (  # noqa: F401
    PartitionMap,
    PartitionSpec,
    by_layer_partition,
    by_leaf_partition,
    by_role_partition,
    identity_partition,
    make_partition_spec,
    role_of_path,
    wire_bytes_by_group,
)
from repro.core import partition  # noqa: F401
from repro.core.autoencoder import (  # noqa: F401
    ChunkedAEConfig,
    ConvAEConfig,
    ae_accuracy,
    ae_loss,
    ae_param_count,
    chunked_decode,
    chunked_encode,
    conv_decode,
    conv_encode,
    decoder_param_count,
    decoder_sync_bytes,
    decoder_tree,
    fc_decode,
    fc_encode,
    fc_reconstruct,
    init_chunked_ae,
    init_conv_ae,
    init_fc_ae,
    train_autoencoder,
    train_autoencoder_cohort,
    train_autoencoder_eager,
    train_autoencoder_scan,
)
from repro.core.lifecycle import AELifecycle  # noqa: F401
from repro.core.ratecontrol import (  # noqa: F401
    ByteBudget,
    DistortionTarget,
    FixedRate,
    RateController,
    RDBudget,
    fc_ae_ladder,
    partition_ladder,
)
from repro.core.compressor import (  # noqa: F401
    ChainCompressor,
    ChunkedAECompressor,
    ComposedCompressor,
    Compressor,
    FCAECompressor,
    IdentityCompressor,
    KMeansCompressor,
    PartitionedCompressor,
    QuantizeCompressor,
    TopKCompressor,
    ef_compensate,
    ef_residual,
    partitioned,
    tree_bytes,
)
from repro.core.federated import (  # noqa: F401
    FLConfig,
    FederatedRun,
    RoundRecord,
    validation_model_curve,
)
from repro.core.prepass import (  # noqa: F401
    evaluate,
    local_train,
    local_train_batched,
    run_prepass,
)
from repro.core.task import (  # noqa: F401
    ClassifierTask,
    ClientTask,
    LMDeltaTask,
)
from repro.core.scheduler import (  # noqa: F401
    AsyncBuffered,
    ClientState,
    LatencyModel,
    RoundScheduler,
    SampledSync,
    SyncFedAvg,
)
from repro.core.arrival import ArrivalEngine, pop_k_device  # noqa: F401
from repro.core.soa import ClientPool, ClientView  # noqa: F401
from repro.core.serve import (  # noqa: F401
    ServeConfig,
    init_state as init_serve_state,
    make_step as make_serve_step,
    run_serve,
)
from repro.core.savings import (  # noqa: F401
    SavingsModel,
    reconcile,
    sweep_collaborators,
    sweep_rounds,
)

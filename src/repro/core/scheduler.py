"""Pluggable round schedulers: the scalable federated runtime (DESIGN.md §6).

The seed's ``FederatedRun`` hard-wired one policy — every collaborator trains
every round, sequentially, with only uplink bytes accounted. At the paper's
target scale (hundreds-to-thousands of collaborators, Fig. 10) the levers
that make compressed-update schemes pay off are *client sampling* and
*asynchronous/buffered aggregation* (Shahid et al., 2021; Nguyen et al.,
2022), so round orchestration is now a strategy object:

* :class:`SyncFedAvg`     — the seed behavior (same bytes, params equal to
  float tolerance under the fused server path); the default scheduler of
  ``FederatedRun``.
* :class:`SampledSync`    — C-of-N cohort per round (McMahan et al., 2017's
  ``C`` fraction), with the homogeneous-cohort hot path batched through
  ``jax.vmap`` (one jitted call instead of C Python-loop invocations) and
  downlink/global-broadcast bytes accounted alongside uplink.
* :class:`AsyncBuffered`  — FedBuff-style: a simulated-latency event loop
  delivers client updates to a server buffer; the first K arrivals are
  staleness-weight aggregated, then those clients are re-dispatched with the
  new global model. Stragglers are a first-class scenario via
  :class:`LatencyModel`.

**Server decode path (DESIGN.md §7):** clients ship *encoded payloads*, not
decoded updates. Every scheduler routes the whole round's cohort through
:func:`_server_aggregate`, which stacks the payloads along a client axis and
runs **one** jitted ``codec.decode_and_aggregate`` call — batched decode +
einsum reduction generically, the fused Pallas decode→aggregate kernel for
the kernel-path chunked AE. The only per-client decode left is the
*collaborator-side* one that error feedback requires (a client must know
what the codec lost to keep its residual — that decode happens on the
client in a real deployment, and here in ``_encode_local``).

Per-client compressor state (the error-feedback residual) lives in
:class:`ClientState`, owned by the run and threaded through whichever
scheduler is active — a residual survives rounds where its client is not
sampled (DESIGN.md §6.3).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from repro.core import codec
from repro.core.aggregate import (apply_update, distortion_weights,
                                  normalize_weights, staleness_weights)
from repro.core.compressor import (codec_stats, ef_compensate, ef_residual,
                                   tree_bytes)

Pytree = Any


@dataclasses.dataclass
class ClientState:
    """Server-side bookkeeping for one collaborator.

    ``residual`` is the error-feedback compressor state (DESIGN.md §6.3);
    ``version`` is the global-model version the client last received;
    ``dispatched`` holds the global params shipped at dispatch time (async
    only — the client trains against this possibly-stale snapshot).

    The AE-lifecycle fields (DESIGN.md §8.2): ``snapshots`` is the bounded
    buffer of flat payload vectors the client's AE refits train on;
    ``last_refresh`` is the round its decoder last shipped (−1 = never, the
    initial pre-pass decoder is charged on first participation);
    ``ae_baseline`` is the post-refresh relative reconstruction error the
    drift trigger compares against. All of it persists through
    ``checkpoint.save_federated_state`` — residuals and snapshot buffers
    are run state, not round state.

    Under per-layer codec partitions (DESIGN.md §10) the lifecycle state
    splits per group: ``part_snapshots[name]`` buffers the group's own
    post-EF payload segment, and ``part_last_refresh``/``part_baseline``
    track each group's decoder independently — a drifting conv stack can
    refresh without re-shipping the head's decoder. The flat fields stay
    untouched for non-partitioned clients (checkpoint compatibility)."""

    residual: Optional[Pytree] = None
    version: int = 0
    dispatched: Optional[Pytree] = None
    snapshots: List[jax.Array] = dataclasses.field(default_factory=list)
    last_refresh: int = -1
    ae_baseline: Optional[float] = None
    part_snapshots: Dict[str, List[jax.Array]] = \
        dataclasses.field(default_factory=dict)
    part_last_refresh: Dict[str, int] = \
        dataclasses.field(default_factory=dict)
    part_baseline: Dict[str, Optional[float]] = \
        dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class EncodedUpdate:
    """What one collaborator ships for one round: the wire payload plus the
    static spec that decodes it (specs are hashable jit-static data, zero
    wire cost), the sample weight, codec byte stats, and local metrics."""

    payload: Pytree
    spec: codec.CodecSpec
    params: Optional[Pytree]           # AE decoder params (None = pointwise)
    weight: float
    stats: Dict[str, float]
    metrics: Dict[str, float]


def _client_round(run, ci: int, global_params: Pytree, round_seed: int
                  ) -> EncodedUpdate:
    """One collaborator's full local round against ``global_params``: train
    (via the run's :class:`~repro.core.task.ClientTask`), build the payload
    (weights or update), error-feedback compensate, encode. Operation order
    is identical to the seed ``FederatedRun.run`` body so ``SyncFedAvg``
    reproduces it (to float tolerance — the fused one-call server reduction
    reassociates vs the seed's op chain)."""
    cfg = run.cfg
    data = run.datasets[ci]
    state = run.clients[ci]
    local, metrics = run.task.local_update(
        global_params, data, cfg, seed=round_seed, anchor=global_params)
    return _encode_local(run, ci, local, global_params, state, metrics)


def _encode_local(run, ci: int, local: Pytree, global_params: Pytree,
                  state: ClientState, metrics: Dict[str, float]
                  ) -> EncodedUpdate:
    """Payload selection + error feedback + encode for an already-trained
    ``local`` model (shared by the loop and vmap paths). Returns the wire
    payload — decoding moved server-side into :func:`_server_aggregate`;
    only error feedback still decodes here, because the residual is
    *collaborator-side* state (the client reconstructs what the server will
    see to measure what the codec lost)."""
    cfg = run.cfg
    if cfg.payload == "weights":
        payload_tree = local               # paper §5.2 protocol
    else:
        payload_tree = jax.tree_util.tree_map(
            lambda a, b: a - b, local, global_params)
    if cfg.error_feedback:
        payload_tree = ef_compensate(payload_tree, state.residual)

    comp = run.compressors[ci]
    flat, unravel = ravel_pytree(payload_tree)
    if run.lifecycle is not None:
        # snapshot exactly what the codec is about to see (post-EF): the
        # AE refit distribution is the encode distribution (DESIGN.md §8.2)
        run.lifecycle.observe(state, comp, flat)
    rc = getattr(run, "ratecontrol", None)
    if rc is not None:
        # rate controllers need the same distribution for rung-distortion
        # decisions, including clients the lifecycle does not buffer
        # (pointwise rungs / no lifecycle attached) — DESIGN.md §9.1
        rc.observe(run, state, comp, flat)
    spec = comp.spec(flat.size)
    params = comp.codec_params()
    payload = codec.encode(spec, params, flat)
    stats = codec_stats(flat, payload, spec=spec)
    if cfg.error_feedback:
        decoded = unravel(codec.decode(spec, params, payload))
        state.residual = ef_residual(payload_tree, decoded)
    weight = run.task.data_weight(run.datasets[ci])
    return EncodedUpdate(payload=payload, spec=spec, params=params,
                         weight=weight, stats=stats, metrics=metrics)


def _fused_group(spec: codec.CodecSpec, encoded: Sequence[EncodedUpdate],
                 w: jnp.ndarray, base) -> jnp.ndarray:
    """One fused decode→aggregate dispatch for a same-spec group: stack the
    payloads (and, when they differ, the per-client AE params) along the
    client axis and reduce in one jitted call (DESIGN.md §7)."""
    stacked = codec.stack_payloads([e.payload for e in encoded])
    if all(e.params is encoded[0].params for e in encoded):
        params, params_batched = encoded[0].params, False
    else:
        params = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[e.params for e in encoded])
        params_batched = True
    return codec.decode_and_aggregate(spec, params, stacked, w, base,
                                      params_batched=params_batched)


def _server_aggregate(run, encoded: Sequence[EncodedUpdate],
                      weights: Sequence[float]) -> Pytree:
    """The aggregator's round step: fused decode→aggregate over the stacked
    cohort (DESIGN.md §7), then the server-lr update.

    Homogeneous cohorts (one spec — the common case; per-client AE params
    are fine and ride a stacked client axis) take **one** jitted call. A
    cohort mixing specs — ladder rungs under a rate controller
    (DESIGN.md §9.2), or genuinely mixed codecs — is *grouped by spec*,
    one fused call per group. Each group's weights are renormalized to
    sum 1 (``decode_and_aggregate``'s contract — the kernel-path chunked
    AE subtracts ``base`` and applies the normalizer mean exactly once on
    that assumption) and its mean is scaled back by the group's weight
    mass: ``s_g · (Σ (w_i/s_g)·row_i − base) = Σ w_i·row_i − s_g·base``,
    so the group contributions sum to the homogeneous reduction to float
    tolerance (tested against the sequential per-client oracle in
    tests/test_ratecontrol.py, kernel path included)."""
    cfg = run.cfg
    g_flat, unravel = ravel_pytree(run.global_params)
    base = g_flat if cfg.payload == "weights" else None
    norm_list = normalize_weights(weights)
    norm_w = jnp.asarray(norm_list, jnp.float32)

    from repro.kernels import ops
    grouped = ops.use_grouped_default(getattr(cfg, "use_grouped_kernel",
                                              None))
    spec0 = encoded[0].spec
    if codec.is_partitioned(spec0):
        # per-layer codec partitions (DESIGN.md §10.2): bucket the cohort
        # per (partition group, codec spec) — exactly one fused
        # decode→aggregate call per bucket (or, with the grouped kernel
        # flag, one dispatch for the whole round, DESIGN.md §11.2)
        from repro.core import partition
        mean_flat = partition.server_decode_aggregate(
            encoded, norm_list, base, use_grouped_kernel=grouped)
    elif all(e.spec == spec0 for e in encoded):
        mean_flat = _fused_group(spec0, encoded, norm_w, base)
    elif grouped:
        # heterogeneous flat cohort, one dispatch: every rung bucket —
        # kernel-path AE rungs via the single grouped ragged Pallas
        # launch — inlined into one jitted round (DESIGN.md §11.2)
        from repro.core import partition
        mean_flat = partition.grouped_flat_server_aggregate(
            encoded, norm_list, base)
    else:                             # heterogeneous cohort: group by spec
        groups: Dict[codec.CodecSpec, List[int]] = {}
        for i, e in enumerate(encoded):
            groups.setdefault(e.spec, []).append(i)
        mean_flat = None
        for spec, idx in groups.items():
            s_g = sum(norm_list[i] for i in idx)    # host float: bit-stable
            w_g = jnp.asarray([norm_list[i] / s_g for i in idx],
                              jnp.float32)
            part = _fused_group(spec, [encoded[i] for i in idx], w_g, base)
            contrib = jnp.float32(s_g) * part
            mean_flat = (contrib if mean_flat is None
                         else mean_flat + contrib)
    return apply_update(run.global_params, unravel(mean_flat), cfg.server_lr)


def _lifecycle_sync(run, r: int, participants
                    ) -> Tuple[float, Optional[list], Optional[list]]:
    """Advance the AE lifecycle (DESIGN.md §8) and then the rate controller
    (DESIGN.md §9) after the round's server aggregate. Lifecycle first, on
    purpose: the decoder that served *this* round's aggregation must be
    charged before the controller switches a client off it. Returns
    (decoder-sync bytes to charge to ``bytes_down``, synced client ids,
    rung switches) — (0.0, None, None) when neither is attached, so every
    scheduler can call it unconditionally."""
    dec_bytes, syncs = 0.0, None
    if run.lifecycle is not None:
        dec_bytes, syncs = run.lifecycle.end_of_round(run, r, participants)
    switches = None
    rc = getattr(run, "ratecontrol", None)
    if rc is not None:
        rc_bytes, rc_syncs, switches = rc.end_of_round(run, r, participants)
        dec_bytes += rc_bytes
        # multiset union: a client that ships its initial decoder AND a
        # switch re-ship in the same round counts twice — Eq. 5's
        # NumDecoders counts ships, not clients (savings.reconcile)
        syncs = sorted((syncs or []) + rc_syncs)
    return dec_bytes, syncs, switches


def _controller_name(run) -> Optional[str]:
    rc = getattr(run, "ratecontrol", None)
    return rc.name if rc is not None else None


def _measured_up(encoded: Sequence[EncodedUpdate]) -> float:
    """Round uplink under the *measured-bytes* channel (DESIGN.md §13.3):
    entropy-coded stacks price below the dense eval-shape wire size, every
    other spec measures identical to ``compressed_bytes``."""
    return sum(e.stats.get("measured_bytes", e.stats["compressed_bytes"])
               for e in encoded)


def _finish_record(run, r: int, metrics, bytes_up, bytes_raw, ratios,
                   **extra):
    """Evaluate the (already-updated) global model and build a RoundRecord."""
    from repro.core.federated import RoundRecord
    gmetrics = {}
    if run.eval_data is not None:
        gmetrics = run.task.evaluate(run.global_params, run.eval_data)
    return RoundRecord(
        round=r, collab_metrics=metrics, global_metrics=gmetrics,
        bytes_up=bytes_up, bytes_up_raw=bytes_raw,
        compression_ratio=float(jnp.mean(jnp.array(ratios))), **extra)


class RoundScheduler:
    """Strategy interface: one ``run_round`` call advances the federation by
    one aggregation and returns its ``RoundRecord``."""

    name = "base"

    def bind(self, run) -> None:
        """Attach to a ``FederatedRun`` (gives access to cfg/datasets/
        compressors/global_params/clients). Called once from its ctor —
        a scheduler instance carries per-run state (counters, buffers), so
        each run needs its own."""
        assert getattr(self, "run", None) is None, (
            "scheduler is already bound to a FederatedRun; create a fresh "
            "scheduler instance per run")
        self.run = run

    def run_round(self, r: int):
        raise NotImplementedError

    def state_dict(self) -> Optional[dict]:
        """JSON-able scheduler state for ``save_federated_state`` (None =
        stateless). ``AsyncBuffered`` persists its event loop — the clock,
        version, in-flight heap, and the dispatched-but-unrecorded downlink
        bytes — so a resumed run's byte accounting matches an uninterrupted
        one (DESIGN.md §9.3)."""
        return None

    def on_restore(self, state: Optional[dict] = None) -> None:
        """Called by ``FederatedRun.load_state`` after the run's clients/
        params are replaced: rebuild any scheduler state derived from them.
        ``state`` is what :meth:`state_dict` returned at save time (None
        for stateless schedulers or pre-§9.3 checkpoints). Sync schedulers
        hold none; ``AsyncBuffered`` restores its event loop from ``state``
        and falls back to re-dispatching everything when it is absent."""


class SyncFedAvg(RoundScheduler):
    """The seed behavior: every collaborator trains every round; FedAvg over
    all updates. Downlink accounting is new (the seed tracked uplink only)
    but the seed fields — metrics, bytes_up, compression_ratio — are
    reproduced exactly for a fixed seed (params to float tolerance).
    Aggregation is the one-call batched server path (DESIGN.md §7)."""

    name = "sync_fedavg"

    def run_round(self, r: int):
        run, cfg = self.run, self.run.cfg
        model_bytes = float(tree_bytes(run.global_params))
        encoded = [
            _client_round(run, ci, run.global_params, cfg.seed * 997 + r)
            for ci in range(len(run.datasets))]
        run.global_params = _server_aggregate(
            run, encoded, [e.weight for e in encoded])
        n = len(run.datasets)
        dec_bytes, syncs, switches = _lifecycle_sync(run, r, range(n))
        return _finish_record(
            run, r, [e.metrics for e in encoded],
            sum(e.stats["compressed_bytes"] for e in encoded),
            sum(e.stats["original_bytes"] for e in encoded),
            [e.stats["compression_ratio"] for e in encoded],
            bytes_up_measured=_measured_up(encoded),
            bytes_down=model_bytes * n + dec_bytes,
            bytes_down_raw=model_bytes * n + dec_bytes,
            bytes_decoder=dec_bytes, ae_syncs=syncs,
            spec_switches=switches, controller=_controller_name(run),
            participants=list(range(n)))


@dataclasses.dataclass
class SampledSync(RoundScheduler):
    """Partial participation: each round samples a cohort of ``cohort``-of-N
    clients without replacement (McMahan et al., 2017), broadcasts the global
    model to exactly that cohort (downlink accounted per sampled client), and
    FedAvgs their compressed updates. Unsampled clients keep their
    error-feedback residual untouched.

    With ``use_vmap`` (default) and a homogeneous cohort — every sampled
    client's dataset has identical shapes, as produced by equal-sized
    partitions — local training for the whole cohort is one jitted
    ``vmap(step)`` sweep instead of ``cohort`` sequential ``local_train``
    calls (DESIGN.md §6.4). Ragged cohorts fall back to the loop."""

    cohort: int = 2
    sample_seed: int = 0
    use_vmap: bool = True
    name: str = "sampled_sync"
    # observability: rounds that actually took the vmap fast path vs fell
    # back to the loop (ragged cohort) — asserted on in tests, reported by
    # the fl_schedulers benchmark
    vmap_rounds: int = dataclasses.field(default=0, init=False)
    loop_rounds: int = dataclasses.field(default=0, init=False)

    def sampled(self, r: int) -> List[int]:
        n = len(self.run.datasets)
        c = min(self.cohort, n)
        rng = np.random.RandomState((self.sample_seed * 100003 + r) % 2 ** 31)
        return sorted(rng.choice(n, size=c, replace=False).tolist())

    def _cohort_locals(self, cohort: List[int], r: int) -> Optional[list]:
        """vmap fast path: returns per-client trained params, or None when
        the cohort is ragged (shapes differ), the task has no batched
        path, and the loop must be used."""
        run, cfg = self.run, self.run.cfg
        if not self.use_vmap or len(cohort) < 2:
            return None
        return run.task.local_update_batched(
            run.global_params, [run.datasets[ci] for ci in cohort], cfg,
            seed=cfg.seed * 997 + r, anchor=run.global_params)

    def run_round(self, r: int):
        run, cfg = self.run, self.run.cfg
        cohort = self.sampled(r)
        model_bytes = float(tree_bytes(run.global_params))
        batched = self._cohort_locals(cohort, r)
        if batched is not None:
            self.vmap_rounds += 1
        else:
            self.loop_rounds += 1

        encoded = []
        for k, ci in enumerate(cohort):
            run.clients[ci].version = r
            if batched is not None:
                local, m = batched[k]
                encoded.append(_encode_local(
                    run, ci, local, run.global_params, run.clients[ci], m))
            else:
                encoded.append(_client_round(
                    run, ci, run.global_params, cfg.seed * 997 + r))
        run.global_params = _server_aggregate(
            run, encoded, [e.weight for e in encoded])
        c = len(cohort)
        dec_bytes, syncs, switches = _lifecycle_sync(run, r, cohort)
        return _finish_record(
            run, r, [e.metrics for e in encoded],
            sum(e.stats["compressed_bytes"] for e in encoded),
            sum(e.stats["original_bytes"] for e in encoded),
            [e.stats["compression_ratio"] for e in encoded],
            bytes_up_measured=_measured_up(encoded),
            bytes_down=model_bytes * c + dec_bytes,
            bytes_down_raw=model_bytes * c + dec_bytes,
            bytes_decoder=dec_bytes, ae_syncs=syncs,
            spec_switches=switches, controller=_controller_name(run),
            participants=cohort)


@dataclasses.dataclass(frozen=True)
class LatencyModel:
    """Deterministic per-(client, dispatch) round-trip latency: train +
    uplink time in abstract simulation units. A ``straggler_frac`` tail of
    clients is ``straggler_mult``× slower — the scenario buffered
    aggregation exists to survive. ``jitter`` is the uniform multiplicative
    half-width (0 ⇒ every dispatch of a client takes exactly ``base``)."""

    base: float = 1.0
    jitter: float = 0.0                # latency ~ base * U[1-j, 1+j]
    straggler_frac: float = 0.0        # first ceil(frac*N) clients are slow
    straggler_mult: float = 10.0
    seed: int = 0
    # bugfix compat escape hatch: the pre-§9.3 ad-hoc hash
    # ``(seed*7919 + client*104729 + dispatch) mod 2^31`` collides across
    # (client, dispatch) pairs at large N (104729 ≡ a small residue walk
    # mod 2^31 — distinct pairs land on identical RandomState streams), so
    # distinct dispatches silently drew IDENTICAL jitter. The default path
    # derives the stream from ``np.random.SeedSequence([seed, client,
    # dispatch])``, which is collision-resistant by construction; set
    # ``legacy_hash=True`` only to reproduce old simulated traces.
    legacy_hash: bool = False

    def is_straggler(self, client: int, n_clients: int) -> bool:
        return client < int(np.ceil(self.straggler_frac * n_clients))

    def sample(self, client: int, dispatch: int, n_clients: int) -> float:
        lat = self.base
        if self.jitter > 0.0:
            if self.legacy_hash:
                rng = np.random.RandomState(
                    (self.seed * 7919 + client * 104729 + dispatch) % 2 ** 31)
                u = rng.rand()
            else:
                u = np.random.default_rng(np.random.SeedSequence(
                    [self.seed, client, dispatch])).random()
            lat *= 1.0 + self.jitter * (2.0 * u - 1.0)
        if self.is_straggler(client, n_clients):
            lat *= self.straggler_mult
        return float(lat)


@dataclasses.dataclass
class AsyncBuffered(RoundScheduler):
    """FedBuff-style buffered asynchronous aggregation (Nguyen et al., 2022).

    All clients are dispatched at t=0 with the v0 global model. A simulated
    event loop (priority queue on arrival time, FIFO tie-break) delivers
    trained+compressed updates; each ``run_round`` drains the first
    ``buffer_k`` arrivals, aggregates them (one fused decode→aggregate call,
    DESIGN.md §7) with staleness-discounted weights
    ``w_i * (1 + s_i) ** -staleness_power`` where ``s_i`` is how many global
    versions elapsed while client i was training, bumps the global version,
    and re-dispatches exactly those clients with the new model (downlink
    accounted at dispatch, attributed to the next round's record).

    With ``buffer_k == n_clients`` and a zero-jitter, straggler-free
    ``LatencyModel``, every round drains all clients at staleness 0 and the
    trajectory equals :class:`SyncFedAvg` (tested). Training is computed
    lazily at arrival, against the global snapshot stored at dispatch, with
    local-train seed keyed to the dispatch version — stale clients train on
    stale models, as in a real deployment.

    ``engine`` selects the event-queue implementation (DESIGN.md §12.2):
    ``"heap"`` is the original host ``heapq`` loop — kept as the
    differential oracle — and ``"vector"`` is the struct-of-arrays
    :class:`~repro.core.arrival.ArrivalEngine` whose first-K drain is one
    vectorized selection instead of K Python pops. The two are
    order-exact (same ``(time, seq)`` lexicographic contract, same
    ``float64`` times), so trajectories and byte accounting are
    bit-identical (tests/test_arrival.py); both serialize the same
    checkpoint shape, so either engine can restore the other's runs."""

    buffer_k: int = 2
    latency: LatencyModel = dataclasses.field(default_factory=LatencyModel)
    staleness_power: float = 0.5
    # distortion-weighted staleness (DESIGN.md §15.5): with a rate
    # controller attached, each drained update is further discounted by
    # d_i = (1 + e_i) ** -distortion_power where e_i is the client's
    # probed current-rung reconstruction error — stale AND distorted
    # updates are discounted coherently, w_i * (1+s_i)^-p * d_i. The
    # distortion comes from the controller's batched probe cache
    # (RateController.distortion_of), so no extra device syncs; 0.0 (the
    # default) preserves existing trajectories bit-exactly.
    distortion_power: float = 0.0
    engine: str = "heap"               # "heap" (oracle) | "vector" (SoA)
    name: str = "async_buffered"

    def bind(self, run) -> None:
        assert self.engine in ("heap", "vector"), (
            f"unknown AsyncBuffered engine {self.engine!r}")
        super().bind(run)
        self._reset()

    def state_dict(self) -> dict:
        """The whole event loop, JSON-able: heap entries reference clients
        by index and the per-client ``dispatched`` model snapshots ride the
        checkpoint's client tree, so a resumed run continues the simulation
        exactly — same arrivals, same staleness, and (the §9.3 bugfix) the
        same downlink byte totals as an uninterrupted run. Before this,
        ``on_restore`` zeroed ``_pending_down`` and re-dispatched everyone,
        so dispatched-but-unrecorded broadcast bytes were dropped and the
        restart re-charged a full-federation broadcast the uninterrupted
        run never shipped.

        Both engines emit the same ``{"heap": [[t, seq, ci], ...]}`` shape
        (the vector engine's rows are its finite-time entries), so a
        vector-engine run restores a heap-engine checkpoint and vice
        versa."""
        return {"heap": self._entries(), "seq": self._next_seq(),
                "version": self._version,
                "clock": self._clock, "pending_down": self._pending_down,
                "to_redispatch": list(self._to_redispatch)}

    def on_restore(self, state: Optional[dict] = None) -> None:
        if state is None:
            # legacy checkpoint without scheduler state: restart the
            # simulation — every restored client re-dispatches against the
            # restored global model at version 0 (re-broadcast charged)
            self._reset()
            return
        from repro.core.arrival import ArrivalEngine
        self._bcast_cache = None
        if self.engine == "vector":
            self._arrivals = ArrivalEngine.from_entries(
                len(self.run.datasets), state["heap"], int(state["seq"]))
        else:
            self._heap = [(float(t), int(s), int(ci))
                          for t, s, ci in state["heap"]]
            heapq.heapify(self._heap)
            self._seq = int(state["seq"])
        self._version = int(state["version"])
        self._clock = float(state["clock"])
        self._pending_down = float(state["pending_down"])
        self._to_redispatch = [int(ci) for ci in state["to_redispatch"]]

    def _reset(self) -> None:
        run = self.run
        # broadcast-size cache for _dispatch (satellite of DESIGN.md §12):
        # tree_bytes(global_params) only changes when the global model is
        # replaced, i.e. exactly when _version bumps — keyed on it
        self._bcast_cache: Optional[Tuple[int, float]] = None
        if self.engine == "vector":
            from repro.core.arrival import ArrivalEngine
            self._arrivals = ArrivalEngine(len(run.datasets))
        else:
            self._heap: List[Tuple[float, int, int]] = []  # (arrival,seq,ci)
            self._seq = 0                                  # FIFO tie-break
        self._version = 0                               # server model version
        self._clock = 0.0
        self._pending_down = 0.0    # downlink dispatched, not yet recorded
        # clients whose re-dispatch is deferred to the next run_round: this
        # keeps every broadcast byte attributed to a RoundRecord (nothing is
        # shipped after the final aggregation, matching SyncFedAvg which
        # never re-broadcasts the final model)
        self._to_redispatch: List[int] = []
        for ci in range(len(run.datasets)):
            self._dispatch(ci)

    # ---- engine-neutral event-queue facade (DESIGN.md §12.2) ----------
    def _push(self, ci: int, t: float) -> None:
        if self.engine == "vector":
            self._arrivals.push(ci, t)
        else:
            heapq.heappush(self._heap, (t, self._seq, ci))
            self._seq += 1

    def _pop_k(self, k: int) -> List[Tuple[float, int]]:
        """First-K arrivals as ``(time, ci)`` in pop order. Pops happen only
        while re-dispatch is deferred (no pushes mid-drain), so one batched
        K-selection on the vector engine is equivalent to K sequential heap
        pops — the property tests/test_arrival.py exercises directly."""
        if self.engine == "vector":
            return self._arrivals.pop_k(k)
        out = []
        for _ in range(k):
            t, _, ci = heapq.heappop(self._heap)
            out.append((t, ci))
        return out

    def _in_flight(self) -> int:
        return (self._arrivals.in_flight() if self.engine == "vector"
                else len(self._heap))

    def _next_seq(self) -> int:
        return (self._arrivals.next_seq if self.engine == "vector"
                else self._seq)

    def _entries(self) -> List[List[float]]:
        if self.engine == "vector":
            return self._arrivals.entries()
        return [[float(t), int(s), int(ci)] for t, s, ci in self._heap]

    def _broadcast_bytes(self) -> float:
        """Downlink cost of one model broadcast, cached per global version:
        ``tree_bytes`` walks the whole pytree, and the eager path recomputed
        it per client per dispatch — O(population · tree) host work per
        reset for a value that is constant between aggregations."""
        if self._bcast_cache is None or self._bcast_cache[0] != self._version:
            self._bcast_cache = (
                self._version, float(tree_bytes(self.run.global_params)))
        return self._bcast_cache[1]

    def _dispatch(self, ci: int) -> None:
        run = self.run
        state = run.clients[ci]
        state.version = self._version
        state.dispatched = run.global_params
        self._pending_down += self._broadcast_bytes()
        lat = self.latency.sample(ci, self._version, len(run.datasets))
        self._push(ci, self._clock + lat)

    def run_round(self, r: int):
        run, cfg = self.run, self.run.cfg
        for ci in self._to_redispatch:     # deferred from the previous flush
            self._dispatch(ci)
        self._to_redispatch = []
        k = min(self.buffer_k, self._in_flight())
        assert k > 0, "async scheduler has no in-flight clients"
        bytes_down = self._pending_down
        self._pending_down = 0.0

        encoded, stales = [], []
        arrived: List[int] = []
        for t, ci in self._pop_k(k):
            self._clock = max(self._clock, t)
            state = run.clients[ci]
            # train lazily, against the (possibly stale) dispatched snapshot
            encoded.append(_client_round(
                run, ci, state.dispatched, cfg.seed * 997 + state.version))
            stales.append(self._version - state.version)
            arrived.append(ci)

        weights = staleness_weights([e.weight for e in encoded], stales,
                                    self.staleness_power)
        if self.distortion_power:
            rc = run.ratecontrol
            weights = distortion_weights(
                weights,
                [rc.distortion_of(ci) if rc is not None else None
                 for ci in arrived],
                self.distortion_power)
        run.global_params = _server_aggregate(run, encoded, weights)
        self._version += 1
        for ci in arrived:                 # re-dispatch with the new model,
            state = run.clients[ci]        # deferred to the next round so
            state.dispatched = None        # its downlink lands in a record
        self._to_redispatch = list(arrived)
        dec_bytes, syncs, switches = _lifecycle_sync(run, r, arrived)
        return _finish_record(
            run, r, [e.metrics for e in encoded],
            sum(e.stats["compressed_bytes"] for e in encoded),
            sum(e.stats["original_bytes"] for e in encoded),
            [e.stats["compression_ratio"] for e in encoded],
            bytes_up_measured=_measured_up(encoded),
            bytes_down=bytes_down + dec_bytes,
            bytes_down_raw=bytes_down + dec_bytes,
            bytes_decoder=dec_bytes, ae_syncs=syncs,
            spec_switches=switches, controller=_controller_name(run),
            participants=arrived, staleness=stales, sim_time=self._clock)

"""AE training lifecycle: per-round snapshot buffers, refresh scheduling,
and honest decoder-sync accounting (DESIGN.md §8).

The paper's mechanism is dynamic: each collaborator trains its autoencoder
on its *own* stream of weight-update snapshots and re-ships the decoder to
the aggregator whenever the codec is refit — that decoder traffic is the
``Cost`` term of the savings ratio (Eq. 5/6), and a scheme that never pays
it is quietly cheating the paper's own trade-off. :class:`AELifecycle` makes
the loop first-class for every scheduler:

* **snapshot buffers** — each AE-backed client keeps a bounded ring of the
  flat payload vectors it actually encoded (post error-feedback, i.e. the
  codec's true input distribution), stored in ``ClientState.snapshots`` so
  the buffer survives unsampled rounds and checkpoints with the run;
* **refresh triggers** — a round cadence (``refresh_every``) and/or a
  reconstruction-drift trigger (``drift_ratio``: refit once the relative
  reconstruction error of the newest snapshot exceeds that multiple of the
  post-refresh baseline);
* **warm-start refits** — refits run the jit-native scan trainer
  (DESIGN.md §8.1) warm-started from the current params (fresh Adam
  moments, normalizer kept unless ``refit_normalizer``); clients refitting
  in the same round with the same AE shape are grouped into ONE
  ``train_autoencoder_cohort`` dispatch;
* **decoder-sync accounting** — every shipped decoder (the initial
  pre-pass decoder on first participation, then one per refresh) is charged
  to ``RoundRecord.bytes_down``/``bytes_down_raw`` and itemized in
  ``RoundRecord.bytes_decoder``/``ae_syncs``; ``savings.reconcile``
  cross-checks those observed totals against Eq. 4–6 (DESIGN.md §8.3).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import autoencoder as ae
from repro.core import codec

Pytree = Any


@functools.partial(jax.jit, static_argnames=("spec",))
def _rel_recon_err(spec: codec.CodecSpec, params: Optional[Pytree],
                   flat: jax.Array) -> jax.Array:
    """Scale-free codec fidelity probe: MSE of an encode→decode roundtrip
    over the variance of the input. Relative, so weight-magnitude growth
    across rounds does not masquerade as drift."""
    decoded = codec.decode(spec, params, codec.encode(spec, params, flat))
    num = jnp.mean(jnp.square(flat - decoded))
    den = jnp.mean(jnp.square(flat - jnp.mean(flat))) + 1e-12
    return num / den


def buffer_snapshot(state, flat: jax.Array, buffer_size: int) -> None:
    """Append one post-EF flat payload vector to a client's bounded snapshot
    ring (``ClientState.snapshots``). The one definition shared by the AE
    lifecycle and the rate controllers (DESIGN.md §9.1) — both must see the
    codec's true input distribution, and double-buffering the same round
    would skew refit datasets."""
    state.snapshots.append(jnp.asarray(flat))
    del state.snapshots[:-buffer_size]


@dataclasses.dataclass
class AELifecycle:
    """Policy object consumed by all three schedulers (DESIGN.md §8.2).

    Stateless apart from its config: all per-client lifecycle state
    (snapshot buffer, last refresh round, drift baseline) lives in
    ``ClientState`` so it checkpoints and survives partial participation.
    At least one of ``refresh_every``/``drift_ratio`` should be set for
    refits to ever trigger; with both unset the lifecycle still ships (and
    accounts) the initial pre-pass decoders."""

    refresh_every: Optional[int] = None   # cadence: refit every k-th round
    drift_ratio: Optional[float] = None   # refit at err > ratio * baseline
    buffer_size: int = 16                 # snapshots kept per client
    min_snapshots: int = 4                # don't refit on fewer samples
    refresh_epochs: int = 40
    batch_size: int = 8
    lr: float = 3e-3
    val_fraction: float = 0.2
    refit_normalizer: bool = False        # warm starts keep norm by default
    ship_initial: bool = True             # charge the pre-pass decoder ship
    seed: int = 0

    # ------------------------------------------------------------------
    def observe(self, state, compressor, flat: jax.Array) -> None:
        """Record the flat vector a client just encoded (called from the
        schedulers' shared ``_encode_local``). Pointwise codecs have
        nothing to refit, so only AE-backed clients buffer. Partitioned
        clients (DESIGN.md §10) buffer **per group**: each AE-backed
        group's gathered segment lands in its own
        ``ClientState.part_snapshots`` ring — the group's codec sees only
        its slice of the update, so that slice is its refit distribution."""
        from repro.core.compressor import partitioned
        pc = partitioned(compressor)
        if pc is not None:
            from repro.core import partition
            for name in pc.ae_groups():
                seg = partition.gather(pc.pmap.slices_of(name), flat)
                ring = state.part_snapshots.setdefault(name, [])
                ring.append(jnp.asarray(seg))
                del ring[:-self.buffer_size]
            return
        if compressor.ae_compressor() is None:
            return
        buffer_snapshot(state, flat, self.buffer_size)

    # ------------------------------------------------------------------
    # Lanes: the unit of lifecycle bookkeeping. A lane is a client index
    # (flat codecs) or a ``(client, group_name)`` pair (per-layer codec
    # partitions, DESIGN.md §10) — one lane per decoder the server holds.
    # ------------------------------------------------------------------
    def _lane_comp(self, run, lane):
        """The refittable AE sub-compressor behind ``lane``."""
        from repro.core.compressor import partitioned
        if isinstance(lane, tuple):
            ci, name = lane
            return partitioned(run.compressors[ci]).ae_groups()[name]
        return run.compressors[lane].ae_compressor()

    def _lane_adapter(self, run, lane):
        """The full wire adapter behind ``lane`` (chains included): what
        actually encodes this lane's bytes."""
        from repro.core.compressor import partitioned
        if isinstance(lane, tuple):
            ci, name = lane
            return partitioned(run.compressors[ci]).compressors[name]
        return run.compressors[lane]

    def _lane_probe(self, run, lane):
        """Adapter whose roundtrip measures this lane's shipped fidelity:
        the full chain for chain lanes (drift is end-to-end — the AE stage
        alone never sees the wire), the AE sub otherwise (identical there:
        the AE *is* the whole wire path, and keeping it preserves the
        pre-chain drift trajectories bit-for-bit)."""
        from repro.core.compressor import ChainCompressor
        adapter = self._lane_adapter(run, lane)
        if isinstance(adapter, ChainCompressor):
            return adapter
        return self._lane_comp(run, lane)

    def _lane_snaps(self, run, lane) -> List[jax.Array]:
        if isinstance(lane, tuple):
            ci, name = lane
            return run.clients[ci].part_snapshots.get(name, [])
        return run.clients[lane].snapshots

    def _lane_baseline(self, run, lane) -> Optional[float]:
        snaps = self._lane_snaps(run, lane)
        if not snaps:
            return None
        return self._rel_err(self._lane_probe(run, lane), snaps[-1])

    # ------------------------------------------------------------------
    def end_of_round(self, run, r: int, participants: Sequence[int]
                     ) -> Tuple[float, List]:
        """Advance the lifecycle after round ``r``'s aggregation: decide
        refreshes for this round's participants, refit (cohort-batched
        where possible), and return ``(decoder_bytes, synced_lanes)`` for
        the scheduler's RoundRecord — client ids for flat clients,
        ``(client, group)`` pairs for partitioned ones (each group's
        decoder ships and refreshes on its own schedule, DESIGN.md §10.4).
        Runs *after* the server aggregate on purpose — this round's
        payloads were decoded with the decoder that encoded them; a
        refreshed decoder takes effect next round."""
        bytes_dec = 0.0
        synced: List = []
        todo: List = []
        from repro.core.compressor import partitioned
        for ci in sorted(set(participants)):
            st = run.clients[ci]
            pc = partitioned(run.compressors[ci])
            if pc is not None:
                for name, sub in sorted(pc.ae_groups().items()):
                    lane = (ci, name)
                    if st.part_last_refresh.get(name, -1) < 0:
                        # this group's first participation: charge its
                        # pre-pass decoder ship (one Eq.-5 sync per group)
                        st.part_last_refresh[name] = r
                        if self.ship_initial:
                            bytes_dec += ae.decoder_sync_bytes(
                                sub.codec_params())
                            synced.append(lane)
                        st.part_baseline[name] = \
                            self._lane_baseline(run, lane)
                        continue
                    if self._should_refresh(
                            r, self._lane_probe(run, lane),
                            self._lane_snaps(run, lane),
                            st.part_last_refresh[name],
                            st.part_baseline.get(name)):
                        todo.append(lane)
                continue
            comp = run.compressors[ci].ae_compressor()
            if comp is None:
                continue
            if st.last_refresh < 0:
                # first participation: the pre-pass decoder the server has
                # been decoding with gets charged here (one Eq.-5 sync)
                st.last_refresh = r
                if self.ship_initial:
                    bytes_dec += ae.decoder_sync_bytes(comp.codec_params())
                    synced.append(ci)
                st.ae_baseline = self._lane_baseline(run, ci)
                continue
            if self._should_refresh(r, self._lane_probe(run, ci),
                                    st.snapshots,
                                    st.last_refresh, st.ae_baseline):
                todo.append(ci)
        rc = getattr(run, "ratecontrol", None)
        for lane, new_params in self._refit(run, r, todo):
            comp = self._lane_comp(run, lane)
            comp.params = new_params
            if rc is not None:
                # the active rung's probe is honest from here on — unfit
                # gating in the rate policies keys off this (DESIGN.md
                # §15.2)
                rc.note_refit(lane)
            if isinstance(lane, tuple):
                ci, name = lane
                st = run.clients[ci]
                st.part_last_refresh[name] = r
                st.part_baseline[name] = self._lane_baseline(run, lane)
            else:
                st = run.clients[lane]
                st.last_refresh = r
                st.ae_baseline = self._lane_baseline(run, lane)
            bytes_dec += ae.decoder_sync_bytes(new_params)
            synced.append(lane)
        return bytes_dec, synced

    # ------------------------------------------------------------------
    def _should_refresh(self, r: int, comp, snaps: List[jax.Array],
                        last_refresh: int, baseline: Optional[float]
                        ) -> bool:
        if len(snaps) < self.min_snapshots:
            return False
        if (self.refresh_every is not None
                and r - last_refresh >= self.refresh_every):
            return True
        if self.drift_ratio is not None and baseline is not None:
            err = self._rel_err(comp, snaps[-1])
            return err > self.drift_ratio * baseline
        return False

    def _rel_err(self, comp, flat: jax.Array) -> float:
        spec = comp.spec(flat.size)
        return float(_rel_recon_err(spec, comp.codec_params(), flat))

    # ------------------------------------------------------------------
    def _refit_dataset(self, run, lane) -> Tuple[Any, jax.Array]:
        """(fc-config, training rows) for one lane's refit. FCAE trains
        on padded snapshot rows; the chunked AE trains its shared funnel on
        every chunk of every snapshot. Chain lanes first fold each snapshot
        through the chain's prefix stages (``codec.ae_stage_input``) so a
        sparsify→AE chain refits its AE on the top-k values it actually
        encodes, not the raw update."""
        adapter = self._lane_adapter(run, lane)
        snaps = self._lane_snaps(run, lane)
        wire_spec = adapter.spec(snaps[0].shape[0])
        params = adapter.codec_params()
        spec = codec.ae_spec(wire_spec)
        vecs = [codec.ae_stage_input(wire_spec, params, s) for s in snaps]
        stackd = jnp.stack(vecs)
        if isinstance(spec, codec.FCAESpec):
            pad = spec.cfg.input_dim - stackd.shape[1]
            if pad:
                stackd = jnp.pad(stackd, ((0, 0), (0, pad)))
            return spec.cfg, stackd
        assert isinstance(spec, codec.ChunkedAESpec)
        rows = jnp.concatenate([
            ae.chunk_vector(v, spec.cfg.chunk_size)[0] for v in vecs])
        return spec.cfg.as_fc(), rows

    def _rng(self, r: int, ci: int) -> jax.Array:
        return jax.random.PRNGKey(
            (self.seed * 1_000_003 + r * 1009 + ci) % 2 ** 31)

    def _lane_rng(self, run, r: int, lane) -> jax.Array:
        """Per-lane refit seed. Flat lanes keep the pre-§10 stream exactly
        (trajectory preservation); partition lanes fold the group's index
        in the client's partition map into the stream so two groups
        refitting the same round draw distinct shuffles."""
        if not isinstance(lane, tuple):
            return self._rng(r, lane)
        ci, name = lane
        from repro.core.compressor import partitioned
        gi = list(partitioned(run.compressors[ci]).pmap.names).index(name)
        return jax.random.PRNGKey(
            (self.seed * 1_000_003 + r * 1009 + ci + (gi + 1) * 7919)
            % 2 ** 31)

    def _refit(self, run, r: int, todo: List
               ) -> List[Tuple[Any, Pytree]]:
        """Warm-start refits for the ``todo`` lanes, grouping same-shaped
        fits — across clients AND partition groups — into one
        ``train_autoencoder_cohort`` dispatch (DESIGN.md §8.1/§10.4)."""
        groups: Dict[Tuple[Any, Tuple[int, ...]], List[Tuple[Any, jax.Array]]]
        groups = {}
        for lane in todo:
            fc_cfg, rows = self._refit_dataset(run, lane)
            groups.setdefault((fc_cfg, rows.shape), []).append((lane, rows))

        out: List[Tuple[Any, Pytree]] = []
        kw = dict(epochs=self.refresh_epochs, batch_size=self.batch_size,
                  lr=self.lr, val_fraction=self.val_fraction,
                  refit_normalizer=self.refit_normalizer)
        for (fc_cfg, _), members in groups.items():
            if len(members) == 1:
                lane, rows = members[0]
                comp = self._lane_comp(run, lane)
                params, _ = ae.train_autoencoder_scan(
                    self._lane_rng(run, r, lane), fc_cfg, rows,
                    init=comp.codec_params(), **kw)
                out.append((lane, params))
                continue
            rngs = jnp.stack([self._lane_rng(run, r, lane)
                              for lane, _ in members])
            datasets = jnp.stack([rows for _, rows in members])
            init = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs),
                *[self._lane_comp(run, lane).codec_params()
                  for lane, _ in members])
            stacked, _ = ae.train_autoencoder_cohort(
                rngs, fc_cfg, datasets, init=init, **kw)
            for k, (lane, _) in enumerate(members):
                out.append((lane, jax.tree_util.tree_map(
                    lambda x, k=k: x[k], stacked)))
        return out

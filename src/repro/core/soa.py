"""Struct-of-arrays per-client state: the million-client layout
(DESIGN.md §12.1).

The eager layout — ``Dict[int, ClientState]`` of Python objects, each
holding its own residual pytree and snapshot list — costs O(population)
host objects and O(population) Python attribute traffic per round. At the
10^5–10^6-client regime ROADMAP item 2 targets, that bookkeeping (not the
decode math) dominates. :class:`ClientPool` stores the same state as
stacked arrays indexed by client id:

* **error-feedback residuals** — one ``(N, P)`` device array plus a host
  presence mask; a sampled cohort's residuals are a ``gather``, the
  post-round writeback a ``scatter``, and the array never leaves the
  device between rounds;
* **snapshot rings** — fixed-depth ring buffers ``(N, depth, P)`` with
  ``int32`` write cursors and fill counts (one ring per lifecycle lane:
  the flat ring plus one per partition group), replacing per-client
  Python lists of device arrays;
* **lifecycle scalars** — ``version`` / ``last_refresh`` / drift
  baselines as packed host arrays (they are read per-client by host
  policy code, so keeping them in numpy avoids a device sync per access);
* **dispatched model snapshots** (async) — a host list of *references*:
  every client dispatched at the same global version shares one params
  object, so memory is O(distinct in-flight versions), not O(N · P).

Compatibility is by **views**: ``pool[ci]`` returns a
:class:`ClientView` exposing the exact ``ClientState`` attribute surface
(``residual``, ``snapshots``, ``part_snapshots``, ...), every read/write
passing through to the pooled arrays. The schedulers, AE lifecycle, rate
controllers, and savings reconciliation run unchanged on either layout,
which is what lets the SoA path be differentially tested (bytes AND
trajectory, bit-exact) against the eager layout — see
tests/test_soa_state.py. The batched accessors
(:meth:`ClientPool.gather_residuals` / :meth:`scatter_residuals` /
``RingStore.append_rows``) are the cohort-wide fast path the streaming
serve pipeline and vectorized schedulers use directly.

Checkpointing round-trips the pooled arrays *as arrays* (ring contents +
cursors + counts in one npz entry each) instead of exploding them into
per-client entries — ``ClientPool.state()`` /
``ClientPool.from_state()``, wired through
``checkpoint.save_federated_state(clients_soa=...)`` (DESIGN.md §12.4).
"""
from __future__ import annotations

from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

Pytree = Any


# =====================================================================
# ring buffers: (N, depth, p) storage for the per-lane snapshot rings
# =====================================================================
class RingStore:
    """Fixed-depth ring buffers for all N clients of one lane, allocated
    lazily on the first append (the row width ``p`` is the lane's payload
    segment length, only known when the first snapshot arrives). Logical
    index 0 is the oldest retained row; ``append`` past ``depth``
    overwrites the oldest — identical to the eager
    ``list.append`` + ``del lst[:-depth]`` discipline every consumer
    (lifecycle/ratecontrol ``buffer_snapshot``) follows."""

    def __init__(self, n: int, depth: int):
        assert depth > 0
        self.n, self.depth = int(n), int(depth)
        self.buf: Optional[jax.Array] = None        # (N, depth, p) lazily
        self.cursor = np.zeros(self.n, dtype=np.int32)
        self.count = np.zeros(self.n, dtype=np.int32)

    @property
    def p(self) -> Optional[int]:
        return None if self.buf is None else int(self.buf.shape[-1])

    def _ensure(self, p: int, dtype) -> None:
        if self.buf is None:
            self.buf = jnp.zeros((self.n, self.depth, int(p)), dtype=dtype)
        else:
            assert int(p) == self.p, (
                f"snapshot row width changed: ring holds {self.p}, got {p}")

    def append(self, ci: int, row: jax.Array) -> None:
        row = jnp.asarray(row)
        self._ensure(row.shape[0], row.dtype)
        self.buf = self.buf.at[ci, self.cursor[ci]].set(row)
        self.cursor[ci] = (self.cursor[ci] + 1) % self.depth
        self.count[ci] = min(self.count[ci] + 1, self.depth)

    def append_rows(self, cis, rows: jax.Array) -> None:
        """Cohort-wide append: one scatter for the whole batch."""
        cis = np.asarray(cis, dtype=np.int32)
        rows = jnp.asarray(rows)
        self._ensure(rows.shape[-1], rows.dtype)
        self.buf = self.buf.at[cis, self.cursor[cis]].set(rows)
        self.cursor[cis] = (self.cursor[cis] + 1) % self.depth
        self.count[cis] = np.minimum(self.count[cis] + 1, self.depth)

    def truncate(self, ci: int, keep: int) -> None:
        """Keep only the newest ``keep`` rows (``del lst[:-keep]``)."""
        self.count[ci] = min(self.count[ci], max(int(keep), 0))

    def row(self, ci: int, i: int) -> jax.Array:
        n = int(self.count[ci])
        if i < 0:
            i += n
        assert 0 <= i < n, f"ring index {i} out of range for {n} rows"
        phys = (int(self.cursor[ci]) - n + i) % self.depth
        return self.buf[ci, phys]

    def rows(self, ci: int) -> List[jax.Array]:
        return [self.row(ci, i) for i in range(int(self.count[ci]))]

    def clear(self, ci: int) -> None:
        self.count[ci] = 0


class RingView:
    """List-compatible view of one client's ring: exactly the slice of the
    ``list`` API the lifecycle/ratecontrol snapshot discipline uses
    (``append``, ``del v[:-k]``, ``len``, indexing, iteration, truthiness,
    ``jnp.stack(v)`` via iteration)."""

    __slots__ = ("_store", "_ci")

    def __init__(self, store: RingStore, ci: int):
        self._store, self._ci = store, ci

    def append(self, row) -> None:
        self._store.append(self._ci, row)

    def __delitem__(self, key) -> None:
        # the one deletion pattern in the codebase: ``del v[:-k]`` (keep
        # the newest k) and its ``del v[:]``/``del v[:0]`` edge cases
        assert isinstance(key, slice) and key.step is None and \
            key.start is None, f"unsupported ring deletion {key!r}"
        stop = key.stop
        if stop is None:                   # del v[:] → drop everything
            self._store.clear(self._ci)
        elif stop < 0:                     # del v[:-k] → keep newest k
            self._store.truncate(self._ci, -stop)
        elif stop > 0:                     # del v[:k] → drop oldest k
            self._store.truncate(self._ci, len(self) - stop)

    def __len__(self) -> int:
        return int(self._store.count[self._ci])

    def __bool__(self) -> bool:
        return len(self) > 0

    def __getitem__(self, i: int) -> jax.Array:
        return self._store.row(self._ci, i)

    def __iter__(self) -> Iterator[jax.Array]:
        return iter(self._store.rows(self._ci))


class _EmptyRing(RingView):
    """Placeholder for ``part_snapshots.get(name, [])`` on an absent lane:
    read-only empty, so accidental writes fail loudly instead of silently
    creating an unnamed ring."""

    __slots__ = ()

    def __init__(self):                    # no store
        pass

    def append(self, row) -> None:
        raise KeyError("appending to an absent partition ring — use "
                       "part_snapshots.setdefault(name, []) first")

    def __delitem__(self, key) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def __getitem__(self, i):
        raise IndexError("empty ring")

    def __iter__(self):
        return iter(())


# =====================================================================
# dict-shaped views over the per-partition SoA state
# =====================================================================
class _PartSnapshots:
    """``ClientState.part_snapshots``-compatible mapping for one client:
    ``{group_name: snapshot_ring}`` backed by one :class:`RingStore` per
    group in the pool."""

    __slots__ = ("_pool", "_ci")

    def __init__(self, pool: "ClientPool", ci: int):
        self._pool, self._ci = pool, ci

    def setdefault(self, name: str, default) -> RingView:
        store = self._pool.part_rings.get(name)
        if store is None:
            store = RingStore(self._pool.n, self._pool.ring_depth)
            self._pool.part_rings[name] = store
        return RingView(store, self._ci)

    def get(self, name: str, default=None):
        store = self._pool.part_rings.get(name)
        if store is None or store.count[self._ci] == 0:
            return default if default is not None else None
        return RingView(store, self._ci)

    def __getitem__(self, name: str) -> RingView:
        store = self._pool.part_rings[name]
        return RingView(store, self._ci)

    def __contains__(self, name: str) -> bool:
        store = self._pool.part_rings.get(name)
        return store is not None and store.count[self._ci] > 0

    def items(self):
        return [(name, RingView(store, self._ci))
                for name, store in sorted(self._pool.part_rings.items())
                if store.count[self._ci] > 0]

    def keys(self):
        return [name for name, _ in self.items()]

    def __iter__(self):
        return iter(self.keys())

    def __len__(self) -> int:
        return len(self.keys())

    def __bool__(self) -> bool:
        return len(self) > 0


class _PartScalars:
    """``part_last_refresh``/``part_baseline``-compatible mapping for one
    client, backed by pooled per-group host arrays. Key presence is
    encoded in-band (``-1`` rounds / ``NaN`` baselines mean "never set"),
    matching the eager dicts' get-with-default access pattern."""

    __slots__ = ("_pool", "_ci", "_field")

    def __init__(self, pool: "ClientPool", ci: int, field: str):
        self._pool, self._ci, self._field = pool, ci, field

    def _arrays(self) -> Dict[str, np.ndarray]:
        return getattr(self._pool, self._field)

    def _is_set(self, v) -> bool:
        if self._field == "part_last_refresh_arr":
            return v >= 0
        return True                         # baselines: NaN encodes None

    def _decode(self, v):
        if self._field == "part_baseline_arr":
            return None if np.isnan(v) else float(v)
        return int(v)

    def get(self, name: str, default=None):
        arr = self._arrays().get(name)
        if arr is None or not self._is_set(arr[self._ci]):
            return default
        return self._decode(arr[self._ci])

    def __getitem__(self, name: str):
        arr = self._arrays().get(name)
        if arr is None or not self._is_set(arr[self._ci]):
            raise KeyError(name)
        return self._decode(arr[self._ci])

    def __setitem__(self, name: str, value) -> None:
        arrays = self._arrays()
        if name not in arrays:
            if self._field == "part_last_refresh_arr":
                arrays[name] = np.full(self._pool.n, -1, dtype=np.int64)
            else:
                arrays[name] = np.full(self._pool.n, np.nan,
                                       dtype=np.float64)
        arrays[name][self._ci] = (np.nan if value is None else value)

    def items(self):
        # NaN/-1 sentinels read as "absent": a baseline explicitly set to
        # None is indistinguishable from never-set, which every consumer's
        # get-with-default access treats identically anyway
        out = []
        for name, arr in sorted(self._arrays().items()):
            v = arr[self._ci]
            if self._is_set(v) and not (self._field == "part_baseline_arr"
                                        and np.isnan(v)):
                out.append((name, self._decode(v)))
        return out

    def keys(self):
        return [k for k, _ in self.items()]

    def __iter__(self):
        return iter(self.keys())


# =====================================================================
# the pool + per-client view
# =====================================================================
class ClientView:
    """One client's window into the pool: the full ``ClientState``
    attribute surface, every access passing through to the stacked
    arrays. Cheap to construct (two slots) — ``pool[ci]`` makes a fresh
    one per access rather than caching N of them."""

    __slots__ = ("_pool", "ci")

    def __init__(self, pool: "ClientPool", ci: int):
        self._pool, self.ci = pool, ci

    # -- error-feedback residual (model-shaped pytree or None) ---------
    @property
    def residual(self) -> Optional[Pytree]:
        p = self._pool
        if not p.res_mask[self.ci]:
            return None
        return p.unravel(p.residuals[self.ci])

    @residual.setter
    def residual(self, value: Optional[Pytree]) -> None:
        p = self._pool
        if value is None:
            p.res_mask[self.ci] = False
            return
        flat, _ = ravel_pytree(value)
        p.set_residual_rows([self.ci], flat[None, :])

    # -- lifecycle scalars ---------------------------------------------
    @property
    def version(self) -> int:
        return int(self._pool.versions[self.ci])

    @version.setter
    def version(self, v: int) -> None:
        self._pool.versions[self.ci] = int(v)

    @property
    def last_refresh(self) -> int:
        return int(self._pool.last_refresh_arr[self.ci])

    @last_refresh.setter
    def last_refresh(self, v: int) -> None:
        self._pool.last_refresh_arr[self.ci] = int(v)

    @property
    def ae_baseline(self) -> Optional[float]:
        v = self._pool.baseline_arr[self.ci]
        return None if np.isnan(v) else float(v)

    @ae_baseline.setter
    def ae_baseline(self, v: Optional[float]) -> None:
        self._pool.baseline_arr[self.ci] = np.nan if v is None else float(v)

    # -- async dispatch snapshot (shared reference per version) --------
    @property
    def dispatched(self) -> Optional[Pytree]:
        return self._pool.dispatched[self.ci]

    @dispatched.setter
    def dispatched(self, value: Optional[Pytree]) -> None:
        self._pool.dispatched[self.ci] = value

    # -- snapshot rings ------------------------------------------------
    @property
    def snapshots(self) -> RingView:
        return RingView(self._pool.ring, self.ci)

    @property
    def part_snapshots(self) -> _PartSnapshots:
        return _PartSnapshots(self._pool, self.ci)

    @property
    def part_last_refresh(self) -> _PartScalars:
        return _PartScalars(self._pool, self.ci, "part_last_refresh_arr")

    @property
    def part_baseline(self) -> _PartScalars:
        return _PartScalars(self._pool, self.ci, "part_baseline_arr")


class ClientPool:
    """Struct-of-arrays storage for N clients' run state (module
    docstring). ``template`` fixes the model pytree structure P the
    residual/dispatched views ravel against; ``ring_depth`` bounds every
    snapshot ring (it must be ≥ the largest consumer ``buffer_size`` —
    ``FederatedRun`` sizes it from the attached lifecycle/controller)."""

    def __init__(self, n: int, template: Pytree, ring_depth: int = 16):
        flat, unravel = ravel_pytree(template)
        self.n = int(n)
        self.psize = int(flat.size)
        self.dtype = flat.dtype
        self.unravel = unravel
        self.ring_depth = int(ring_depth)
        self.residuals: Optional[jax.Array] = None    # (N, P) lazily
        self.res_mask = np.zeros(self.n, dtype=bool)
        self.versions = np.zeros(self.n, dtype=np.int64)
        self.last_refresh_arr = np.full(self.n, -1, dtype=np.int64)
        self.baseline_arr = np.full(self.n, np.nan, dtype=np.float64)
        self.dispatched: List[Optional[Pytree]] = [None] * self.n
        self.ring = RingStore(self.n, self.ring_depth)
        self.part_rings: Dict[str, RingStore] = {}
        self.part_last_refresh_arr: Dict[str, np.ndarray] = {}
        self.part_baseline_arr: Dict[str, np.ndarray] = {}

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return self.n

    def __getitem__(self, ci: int) -> ClientView:
        assert 0 <= ci < self.n, f"client {ci} out of range"
        return ClientView(self, ci)

    def __iter__(self) -> Iterator[ClientView]:
        return (ClientView(self, ci) for ci in range(self.n))

    # ------------------------------------------------------------------
    # cohort-wide batched accessors: the gather/scatter fast path
    # ------------------------------------------------------------------
    def _ensure_residuals(self) -> None:
        if self.residuals is None:
            self.residuals = jnp.zeros((self.n, self.psize),
                                       dtype=self.dtype)

    def gather_residuals(self, cis) -> Tuple[jax.Array, np.ndarray]:
        """Cohort residual rows ``(C, P)`` (zeros where absent) plus the
        host presence mask ``(C,)`` — one device gather."""
        cis = np.asarray(cis, dtype=np.int32)
        self._ensure_residuals()
        return self.residuals[jnp.asarray(cis)], self.res_mask[cis]

    def set_residual_rows(self, cis, rows: jax.Array) -> None:
        """Cohort writeback ``(C, P)`` — one device scatter."""
        cis_np = np.asarray(cis, dtype=np.int32)
        self._ensure_residuals()
        self.residuals = self.residuals.at[jnp.asarray(cis_np)].set(
            jnp.asarray(rows, dtype=self.dtype))
        self.res_mask[cis_np] = True

    def scatter_residuals(self, cis, rows: jax.Array) -> None:
        self.set_residual_rows(cis, rows)

    # ------------------------------------------------------------------
    # checkpointing (DESIGN.md §12.4): arrays stay arrays
    # ------------------------------------------------------------------
    def state(self) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """(array tree, JSON meta). Device-sized state — residual block,
        ring contents, dispatched rows — rides the npz tree as whole
        arrays (cursor/count as int32 arrays alongside their ring). Host
        *scalars* (versions, refresh rounds, drift baselines, presence
        mask) ride the JSON meta instead: ``load_pytree`` round-trips
        through ``jnp.asarray``, which under the repo's x64-disabled
        default would silently downcast int64/float64 — JSON preserves
        them exactly (NaN baselines encode as ``null``)."""
        tree: Dict[str, Any] = {}
        if self.residuals is not None:
            tree["residuals"] = self.residuals
        if self.ring.buf is not None:
            tree["ring"] = {"buf": self.ring.buf,
                            "cursor": self.ring.cursor,
                            "count": self.ring.count}
        parts: Dict[str, Any] = {}
        for name, store in self.part_rings.items():
            if store.buf is not None:
                parts[name] = {"buf": store.buf, "cursor": store.cursor,
                               "count": store.count}
        if parts:
            tree["part_rings"] = parts
        disp_idx = [ci for ci, d in enumerate(self.dispatched)
                    if d is not None]
        if disp_idx:
            tree["dispatched"] = jnp.stack(
                [ravel_pytree(self.dispatched[ci])[0] for ci in disp_idx])

        def _floats(arr):
            return [None if np.isnan(v) else float(v) for v in arr]

        meta = {
            "n": self.n, "psize": self.psize,
            "ring_depth": self.ring_depth,
            "has_residuals": self.residuals is not None,
            "res_mask": [bool(b) for b in self.res_mask],
            "versions": [int(v) for v in self.versions],
            "last_refresh": [int(v) for v in self.last_refresh_arr],
            "baseline": _floats(self.baseline_arr),
            "ring_p": self.ring.p,
            "part_ring_p": {name: store.p
                            for name, store in self.part_rings.items()
                            if store.buf is not None},
            "part_last_refresh": {
                name: [int(v) for v in arr]
                for name, arr in sorted(self.part_last_refresh_arr.items())},
            "part_baseline": {
                name: _floats(arr)
                for name, arr in sorted(self.part_baseline_arr.items())},
            "dispatched_idx": disp_idx,
            "dtype": str(np.dtype(self.dtype)),
        }
        return tree, meta

    @staticmethod
    def like_from_meta(meta: Dict[str, Any]) -> Dict[str, Any]:
        """Zero-filled structure matching :meth:`state`'s tree, for
        ``checkpoint.load_pytree`` shape/dtype validation."""
        n = int(meta["n"])
        dt = np.dtype(meta["dtype"])
        depth = int(meta["ring_depth"])

        def _ring_like(p):
            return {"buf": jnp.zeros((n, depth, int(p)), dtype=dt),
                    "cursor": np.zeros(n, dtype=np.int32),
                    "count": np.zeros(n, dtype=np.int32)}

        like: Dict[str, Any] = {}
        if meta["has_residuals"]:
            like["residuals"] = jnp.zeros((n, int(meta["psize"])), dtype=dt)
        if meta["ring_p"] is not None:
            like["ring"] = _ring_like(meta["ring_p"])
        parts = {name: _ring_like(p)
                 for name, p in (meta.get("part_ring_p") or {}).items()}
        if parts:
            like["part_rings"] = parts
        if meta.get("dispatched_idx"):
            like["dispatched"] = jnp.zeros(
                (len(meta["dispatched_idx"]), int(meta["psize"])), dtype=dt)
        return like

    @classmethod
    def from_state(cls, tree: Dict[str, Any], meta: Dict[str, Any],
                   template: Pytree) -> "ClientPool":
        pool = cls(int(meta["n"]), template,
                   ring_depth=int(meta["ring_depth"]))
        assert pool.psize == int(meta["psize"]), (
            f"checkpoint pool covers {meta['psize']} params, template has "
            f"{pool.psize}")

        def _floats(vals):
            return np.array([np.nan if v is None else float(v)
                             for v in vals], dtype=np.float64)

        pool.res_mask = np.asarray(meta["res_mask"], dtype=bool)
        pool.versions = np.asarray(meta["versions"], dtype=np.int64)
        pool.last_refresh_arr = np.asarray(meta["last_refresh"],
                                           dtype=np.int64)
        pool.baseline_arr = _floats(meta["baseline"])
        if meta["has_residuals"]:
            pool.residuals = jnp.asarray(tree["residuals"])
        if meta["ring_p"] is not None:
            pool.ring.buf = jnp.asarray(tree["ring"]["buf"])
            pool.ring.cursor = np.asarray(
                tree["ring"]["cursor"]).astype(np.int32)
            pool.ring.count = np.asarray(
                tree["ring"]["count"]).astype(np.int32)
        for name in (meta.get("part_ring_p") or {}):
            store = RingStore(pool.n, pool.ring_depth)
            entry = tree["part_rings"][name]
            store.buf = jnp.asarray(entry["buf"])
            store.cursor = np.asarray(entry["cursor"]).astype(np.int32)
            store.count = np.asarray(entry["count"]).astype(np.int32)
            pool.part_rings[name] = store
        for name, vals in (meta.get("part_last_refresh") or {}).items():
            pool.part_last_refresh_arr[name] = np.asarray(vals,
                                                          dtype=np.int64)
        for name, vals in (meta.get("part_baseline") or {}).items():
            pool.part_baseline_arr[name] = _floats(vals)
        for k, ci in enumerate(meta.get("dispatched_idx") or []):
            pool.dispatched[int(ci)] = pool.unravel(tree["dispatched"][k])
        return pool

"""Pre-pass round (paper §3, Fig. 2).

The server ships the global model; each collaborator trains it locally
WITHOUT aggregation, logging the flattened weight vector at the end of every
epoch — the *weights dataset*. That dataset trains the collaborator's AE; the
decoder half is then shipped to the server (its byte cost is the ``Cost``
term of the savings-ratio, Eq. 5/6).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from repro.configs.paper import AEConfig, ClassifierConfig
from repro.core import autoencoder as ae
from repro.models.classifiers import classifier_loss, init_classifier
from repro.optim.optimizers import make_optimizer
from repro.data.pipeline import batch_indices, batches

Pytree = Any


def local_train(
    params: Pytree,
    clf_cfg: ClassifierConfig,
    data: Dict[str, jnp.ndarray],
    *,
    epochs: int,
    lr: float = 1e-3,
    batch_size: int = 64,
    seed: int = 0,
    optimizer: str = "adam",
    prox_mu: float = 0.0,
    anchor: Optional[Pytree] = None,
    snapshot_every_epoch: bool = False,
) -> Tuple[Pytree, List[jnp.ndarray], List[Dict[str, float]]]:
    """Train a classifier locally. Returns (params, weight snapshots,
    per-epoch metrics). ``prox_mu`` adds the FedProx proximal term against
    ``anchor`` (the round-start global params)."""
    opt = make_optimizer(optimizer, lr)
    state = opt.init(params)

    def loss_fn(p, batch):
        loss, metrics = classifier_loss(p, clf_cfg, batch)
        if prox_mu > 0.0 and anchor is not None:
            sq = sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                jax.tree_util.tree_leaves(p),
                jax.tree_util.tree_leaves(anchor)))
            loss = loss + 0.5 * prox_mu * sq
        return loss, metrics

    @jax.jit
    def step(p, s, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, batch)
        p, s = opt.update(p, grads, s)
        return p, s, metrics

    snapshots: List[jnp.ndarray] = []
    history: List[Dict[str, float]] = []
    for epoch in range(epochs):
        last_metrics = None
        for b in batches(seed * 1000 + epoch, data, batch_size):
            params, state, last_metrics = step(params, state, b)
        if last_metrics is not None:
            history.append({k: float(v) for k, v in last_metrics.items()})
        if snapshot_every_epoch:
            flat, _ = ravel_pytree(params)
            snapshots.append(flat)
    return params, snapshots, history


# jitted cohort-step cache: without it every sampled round would rebuild
# (and XLA-recompile) vmap(step), and the §6.4 batching win would be eaten
# by compilation. Keyed on everything baked into the trace; the anchor is a
# runtime argument so a fresh global model each round is a cache HIT.
_BATCHED_STEP_CACHE: Dict[Any, Any] = {}


def _batched_step(clf_cfg: ClassifierConfig, optimizer: str, lr: float,
                  prox_mu: float):
    key = (clf_cfg, optimizer, lr, prox_mu)
    cached = _BATCHED_STEP_CACHE.get(key)
    if cached is not None:
        return cached
    opt = make_optimizer(optimizer, lr)

    def loss_fn(p, batch, anchor):
        loss, metrics = classifier_loss(p, clf_cfg, batch)
        if prox_mu > 0.0:
            sq = sum(jnp.sum(jnp.square(a - b)) for a, b in zip(
                jax.tree_util.tree_leaves(p),
                jax.tree_util.tree_leaves(anchor)))
            loss = loss + 0.5 * prox_mu * sq
        return loss, metrics

    def step(p, s, batch, anchor):
        (_, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, batch, anchor)
        p, s = opt.update(p, grads, s)
        return p, s, metrics

    vstep = jax.jit(jax.vmap(step, in_axes=(0, 0, 0, None)))
    _BATCHED_STEP_CACHE[key] = (opt, vstep)
    return opt, vstep


def local_train_batched(
    params: Pytree,
    clf_cfg: ClassifierConfig,
    stacked_data: Dict[str, jnp.ndarray],      # leaves shaped (C, n, ...)
    *,
    epochs: int,
    lr: float = 1e-3,
    batch_size: int = 64,
    seed: int = 0,
    optimizer: str = "adam",
    prox_mu: float = 0.0,
    anchor: Optional[Pytree] = None,
) -> Tuple[Pytree, List[Dict[str, float]]]:
    """``local_train`` vmapped over a homogeneous cohort (DESIGN.md §6.4).

    All C clients start from the same ``params`` (the round's global model)
    and train on their own shard of ``stacked_data``; one jitted
    ``vmap(step)`` replaces C sequential Python-loop invocations, which is
    the hot path of a sampled round. The batch *order* comes from the same
    :func:`repro.data.pipeline.batch_indices` stream the sequential path
    consumes with the same shared ``seed``, so for identical data this path
    matches the sequential one to float tolerance (tested in
    test_scheduler.py).

    Returns (stacked local params with leading client axis, per-client final
    metrics).
    """
    C, n = stacked_data["x"].shape[0], stacked_data["x"].shape[1]
    opt, vstep = _batched_step(
        clf_cfg, optimizer, lr,
        prox_mu if anchor is not None else 0.0)
    anchor_arg = anchor if anchor is not None else params

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (C,) + x.shape), params)
    states = jax.vmap(opt.init)(stacked)

    last = None
    for epoch in range(epochs):
        # same order for every client — mirrors the sequential loop, where
        # each client draws batches(seed * 1000 + epoch, ...)
        for sel in batch_indices(seed * 1000 + epoch, n, batch_size):
            batch = {k: v[:, sel] for k, v in stacked_data.items()}
            stacked, states, last = vstep(stacked, states, batch,
                                          anchor_arg)
    if last is None:
        metrics = [{} for _ in range(C)]
    else:
        metrics = [{k: float(v[ci]) for k, v in last.items()}
                   for ci in range(C)]
    return stacked, metrics


def evaluate(params: Pytree, clf_cfg: ClassifierConfig,
             data: Dict[str, jnp.ndarray]) -> Dict[str, float]:
    loss, metrics = jax.jit(
        lambda p, b: classifier_loss(p, clf_cfg, b))(params, data)
    return {k: float(v) for k, v in metrics.items()}


def run_prepass(
    rng: jax.Array,
    clf_cfg: ClassifierConfig,
    ae_cfg: AEConfig,
    data: Dict[str, jnp.ndarray],
    *,
    prepass_epochs: int = 30,
    ae_epochs: int = 150,
    lr: float = 1e-3,
    seed: int = 0,
    collect_updates: bool = False,
    init_params: Optional[Pytree] = None,
) -> Dict[str, Any]:
    """Full pre-pass for one collaborator: local training → weights dataset →
    AE training (the jit-native scan trainer, DESIGN.md §8.1).
    ``collect_updates=True`` stores per-epoch *deltas* from the initial
    weights instead of raw weights (the FL-mode codec target).
    ``init_params`` starts local training from given weights instead of a
    fresh init — the paper's Fig. 2 protocol trains each AE on the weight
    dataset of the model being federated, so a pre-pass that seeds a rate
    ladder for a run must start from THAT run's initial global params:
    weights from a foreign random init live in a different basin and the
    resulting AEs price a trajectory the run never visits
    (DESIGN.md §15.6)."""
    k_model, k_ae = jax.random.split(rng)
    params0 = (init_params if init_params is not None
               else init_classifier(k_model, clf_cfg))
    flat0, _ = ravel_pytree(params0)

    params, snaps, history = local_train(
        params0, clf_cfg, data, epochs=prepass_epochs, lr=lr, seed=seed,
        snapshot_every_epoch=True)
    dataset = jnp.stack(snaps)                       # (E, P)
    if collect_updates:
        dataset = dataset - flat0[None, :]
    pad = ae_cfg.input_dim - dataset.shape[1]
    assert pad >= 0, "AE input smaller than model parameter count"
    if pad:
        dataset = jnp.pad(dataset, ((0, 0), (0, pad)))

    ae_params, ae_history = ae.train_autoencoder(
        k_ae, ae_cfg, dataset, kind="fc", epochs=ae_epochs)
    return {
        "model_params": params,
        "weights_dataset": dataset,
        "ae_params": ae_params,
        "ae_history": ae_history,
        "train_history": history,
        "decoder_params": ae.decoder_param_count(ae_params),
    }

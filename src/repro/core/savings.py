"""Savings-ratio analytics (paper §5.3, Eq. 4–6) and break-even points.

SR = (OriginalSize * CommRounds * Collabs)
     / (CompressedSize * CommRounds * Collabs + Cost),          (Eq. 4)
Cost = DecoderSize * NumDecoders = (AutoencoderSize / 2) * NumDecoders.
                                                              (Eq. 5/6)
Sizes are in parameter counts (the paper's unit); bytes scale both sides
equally so the ratio is unit-free.

:func:`reconcile` closes the loop with the runtime (DESIGN.md §8.3): the
schedulers now *observe* every term of Eq. 4–6 — compressed/raw uplink per
round, and one decoder sync per ``ae_syncs`` entry — so the analytic model
can be cross-checked against what a run actually shipped.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Union


@dataclasses.dataclass(frozen=True)
class SavingsModel:
    original_size: int          # collaborator update size (params)
    compressed_size: int        # latent size (params)
    autoencoder_size: int       # total AE params (decoder = half)
    n_decoders: int = 1         # 1 = shared decoder (case a); C = per-collab

    def __post_init__(self):
        # bugfix guard: negative sizes turned Eq. 4's denominator negative
        # and the break-even bisections below returned meaningless
        # (negative-ratio-driven) answers — reject them at construction
        if (self.original_size < 0 or self.compressed_size < 0
                or self.autoencoder_size < 0 or self.n_decoders < 0):
            raise ValueError(
                "SavingsModel sizes/counts must be non-negative, got "
                f"original={self.original_size} "
                f"compressed={self.compressed_size} "
                f"autoencoder={self.autoencoder_size} "
                f"n_decoders={self.n_decoders}")

    @property
    def decoder_size(self) -> float:
        return self.autoencoder_size / 2.0                       # Eq. 6

    @property
    def cost(self) -> float:
        return self.decoder_size * self.n_decoders               # Eq. 5

    def savings_ratio(self, comm_rounds: int, collabs: int) -> float:
        """Eq. 4. A degenerate zero denominator — ``compressed_size == 0``
        (or zero rounds/collabs) with a zero-cost decoder — reads as free
        communication: ``inf``, not a ZeroDivisionError."""
        num = self.original_size * comm_rounds * collabs          # Eq. 4
        den = self.compressed_size * comm_rounds * collabs + self.cost
        if den == 0:
            return float("inf")
        return num / den

    def break_even_collabs(self, comm_rounds: int,
                           max_collabs: int = 10 ** 7) -> Optional[int]:
        """Smallest collaborator count with SR > 1 (Fig. 10 break-even).
        ``None`` is the documented no-break-even sentinel: a scheme whose
        compression ratio is ≤ 1 never pays for its decoder however many
        collaborators join (SR is bounded by ``asymptotic_ratio``), so the
        bisection is skipped rather than probing 10^7 collaborators of a
        ratio that cannot cross 1."""
        if self.asymptotic_ratio() <= 1.0:
            return None
        lo, hi = 1, max_collabs
        if self.savings_ratio(comm_rounds, hi) <= 1.0:
            return None
        while lo < hi:
            mid = (lo + hi) // 2
            if self.savings_ratio(comm_rounds, mid) > 1.0:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def break_even_rounds(self, collabs: int,
                          max_rounds: int = 10 ** 7) -> Optional[int]:
        """Smallest round count with SR > 1 (Fig. 11 break-even); ``None``
        = never breaks even (see :meth:`break_even_collabs`)."""
        if self.asymptotic_ratio() <= 1.0:
            return None
        lo, hi = 1, max_rounds
        if self.savings_ratio(hi, collabs) <= 1.0:
            return None
        while lo < hi:
            mid = (lo + hi) // 2
            if self.savings_ratio(mid, collabs) > 1.0:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def asymptotic_ratio(self) -> float:
        """SR as rounds*collabs → ∞ = raw compression ratio (``inf`` for a
        zero-width latent — the degenerate everything-is-free codec)."""
        if self.compressed_size == 0:
            return float("inf")
        return self.original_size / self.compressed_size


def sweep_collaborators(model: SavingsModel, comm_rounds: int,
                        collabs: List[int]) -> List[float]:
    return [model.savings_ratio(comm_rounds, c) for c in collabs]


def sweep_rounds(model: SavingsModel, collabs: int,
                 rounds: List[int]) -> List[float]:
    return [model.savings_ratio(r, collabs) for r in rounds]


def reconcile(model: Union[SavingsModel, Mapping[str, SavingsModel]],
              records: Sequence,
              *, bytes_per_param: float = 4.0) -> Dict[str, float]:
    """Reconcile a run's observed accounting with Eq. 4–6 (DESIGN.md §8.3).

    ``records`` is the run's ``RoundRecord`` history. Observed quantities
    come straight from the scheduler layer: uplink bytes (compressed and
    raw) and the decoder-sync bytes the AE lifecycle charged. Predictions
    restate Eq. 4–6 in observed-byte units — predicted decoder cost is
    ``DecoderSize × observed sync count`` (Eq. 5 with NumDecoders = the
    syncs that actually happened; under refreshes a decoder ships more than
    once, which Fig. 10/11's static Cost term underestimates), and the
    predicted savings ratio divides raw traffic by (raw / asymptotic-ratio
    + predicted cost), i.e. Eq. 4 with the model's CompressedSize.

    Under per-layer codec partitions (DESIGN.md §10.4) ``model`` is a
    ``{group_name: SavingsModel}`` mapping: each partition owns its own
    decoder size and compression ratio, so the predicted Cost term sums
    **per-partition decoder ships** — ``ae_syncs`` entries are
    ``(client, group)`` pairs, counted against their own group's
    DecoderSize — and predicted uplink apportions the observed raw bytes
    by each group's OriginalSize share before dividing by that group's
    ratio (exact whenever every participant ships every group, which every
    scheduler does). A single-unit wire model under partitioning would
    mis-price mixed ladders; this keeps the documented ≲1% structural gap.

    The small ``decoder_rel_err`` that remains is structural, not a bug:
    Eq. 6 idealizes DecoderSize as AutoencoderSize/2, while a funnel AE's
    decoder half differs from half by the bias asymmetry (output-width
    biases vs latent-width biases) plus the 2-scalar normalizer the wire
    format ships (``autoencoder.decoder_tree``)."""
    up = float(sum(r.bytes_up for r in records))
    up_raw = float(sum(r.bytes_up_raw for r in records))
    dec_bytes = float(sum(getattr(r, "bytes_decoder", 0.0) for r in records))
    sync_list = [s for r in records
                 for s in (getattr(r, "ae_syncs", None) or [])]
    syncs = len(sync_list)
    if isinstance(model, Mapping):
        syncs_by_group: Dict[str, int] = {name: 0 for name in model}
        for s in sync_list:
            assert isinstance(s, (tuple, list)) and len(s) == 2, (
                f"per-partition reconcile needs (client, group) sync "
                f"entries, got {s!r} — pass a single SavingsModel for "
                "flat runs")
            syncs_by_group[s[1]] += 1
        predicted_dec = sum(m.decoder_size * syncs_by_group[name]
                            * bytes_per_param for name, m in model.items())
        total_orig = float(sum(m.original_size for m in model.values()))
        predicted_up = sum(
            (up_raw * m.original_size / total_orig) / m.asymptotic_ratio()
            for m in model.values())
    else:
        assert not any(isinstance(s, (tuple, list)) for s in sync_list), (
            "partitioned run history ((client, group) sync entries) needs "
            "a {group: SavingsModel} mapping — a single model would count "
            "every per-group ship as a full-model decoder")
        predicted_dec = model.decoder_size * syncs * bytes_per_param
        predicted_up = up_raw / model.asymptotic_ratio()
    observed_sr = up_raw / (up + dec_bytes) if up + dec_bytes else float("inf")
    predicted_sr = (up_raw / (predicted_up + predicted_dec)
                    if predicted_up + predicted_dec else float("inf"))

    def rel(observed: float, predicted: float) -> float:
        return abs(observed - predicted) / max(abs(predicted), 1e-12)

    return {
        "rounds": float(len(records)),
        "decoder_syncs": float(syncs),
        "observed_decoder_bytes": dec_bytes,
        "predicted_decoder_bytes": predicted_dec,
        "decoder_rel_err": rel(dec_bytes, predicted_dec) if syncs else 0.0,
        "observed_savings_ratio": observed_sr,
        "predicted_savings_ratio": predicted_sr,
        "savings_rel_err": rel(observed_sr, predicted_sr),
    }

"""Savings-ratio analytics (paper §5.3, Eq. 4–6) and break-even points.

SR = (OriginalSize * CommRounds * Collabs)
     / (CompressedSize * CommRounds * Collabs + Cost),          (Eq. 4)
Cost = DecoderSize * NumDecoders = (AutoencoderSize / 2) * NumDecoders.
                                                              (Eq. 5/6)
Sizes are in parameter counts (the paper's unit); bytes scale both sides
equally so the ratio is unit-free.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional


@dataclasses.dataclass(frozen=True)
class SavingsModel:
    original_size: int          # collaborator update size (params)
    compressed_size: int        # latent size (params)
    autoencoder_size: int       # total AE params (decoder = half)
    n_decoders: int = 1         # 1 = shared decoder (case a); C = per-collab

    @property
    def decoder_size(self) -> float:
        return self.autoencoder_size / 2.0                       # Eq. 6

    @property
    def cost(self) -> float:
        return self.decoder_size * self.n_decoders               # Eq. 5

    def savings_ratio(self, comm_rounds: int, collabs: int) -> float:
        num = self.original_size * comm_rounds * collabs          # Eq. 4
        den = self.compressed_size * comm_rounds * collabs + self.cost
        return num / den

    def break_even_collabs(self, comm_rounds: int,
                           max_collabs: int = 10 ** 7) -> Optional[int]:
        """Smallest collaborator count with SR > 1 (Fig. 10 break-even)."""
        lo, hi = 1, max_collabs
        if self.savings_ratio(comm_rounds, hi) <= 1.0:
            return None
        while lo < hi:
            mid = (lo + hi) // 2
            if self.savings_ratio(comm_rounds, mid) > 1.0:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def break_even_rounds(self, collabs: int,
                          max_rounds: int = 10 ** 7) -> Optional[int]:
        """Smallest round count with SR > 1 (Fig. 11 break-even)."""
        lo, hi = 1, max_rounds
        if self.savings_ratio(hi, collabs) <= 1.0:
            return None
        while lo < hi:
            mid = (lo + hi) // 2
            if self.savings_ratio(mid, collabs) > 1.0:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def asymptotic_ratio(self) -> float:
        """SR as rounds*collabs → ∞ = raw compression ratio."""
        return self.original_size / self.compressed_size


def sweep_collaborators(model: SavingsModel, comm_rounds: int,
                        collabs: List[int]) -> List[float]:
    return [model.savings_ratio(comm_rounds, c) for c in collabs]


def sweep_rounds(model: SavingsModel, collabs: int,
                 rounds: List[int]) -> List[float]:
    return [model.savings_ratio(r, collabs) for r in rounds]

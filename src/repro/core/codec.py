"""Jit-native codec protocol: static specs + pure encode/decode functions.

The stateful ``Compressor`` classes (core/compressor.py) are host-side
adapters over this layer. A codec here is a pair of pure functions driven by
a **spec** — a small frozen (hashable) dataclass carrying everything static:
original length, bit widths, chunking, AE shapes. Specs are valid
``jax.jit`` static arguments, payloads are dicts of fixed-shape arrays, and
nothing in ``decode`` round-trips a traced value through Python. That makes
every codec:

* jit-compatible: ``jax.jit(decode, static_argnums=0)`` just works;
* vmap-compatible over a leading client axis, which is what the batched
  aggregator path needs (DESIGN.md §7);
* shard_map-compatible: the client axis splits across devices with a psum
  epilogue (DESIGN.md §7.2).

Dispatch is a **per-stage ops protocol** (DESIGN.md §13): each stage spec
registers one small ops class (``fwd`` / ``inv`` / ``inv_batched`` /
``carry_key`` / ``carry_shape`` / ``out_size``) in ``_STAGE_OPS``, and every
entry point below — ``encode``, ``decode``, ``decode_batched``,
``decode_and_aggregate``, ``wire_bytes`` — is a fold over stages instead of
an isinstance ladder. :class:`ChainSpec` composes stages (FedZip direction:
sparsify → AE → quantize → entropy-priced wire); a single-stage chain is
bit-identical to the bare codec at every entry point, and
:class:`ComposedSpec` survives as a thin alias for the 2-stage
``(AE, quantize)`` chain with its historical payload keys.

The server-side entry point is :func:`decode_and_aggregate`: stack the
cohort's payloads along a leading client axis (:func:`stack_payloads`) and
decode + FedAvg-reduce the whole cohort in **one** jitted call. The generic
path is a natively-batched decode followed by a per-element ``einsum`` over
the client axis; kernel-terminal AE stacks (``ChunkedAESpec(use_kernel)``
bare or behind pointwise suffix stages) route the final decoder layer
through the fused Pallas kernel (kernels/fused_decode_agg.py), which folds
the FedAvg weight into the matmul accumulation so per-client decoded
tensors are never materialized (memory math in DESIGN.md §7.1).
Scatter-terminal chains (top-k sparsification first) reduce by a weighted
scatter-add over the shipped indices instead of densifying per client.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.paper import AEConfig
from repro.core import autoencoder as ae
from repro.core.autoencoder import ChunkedAEConfig

Params = Any
Payload = Dict[str, jax.Array]


# =====================================================================
# specs — frozen, hashable, jit-static
# =====================================================================
@dataclasses.dataclass(frozen=True)
class IdentitySpec:
    """No compression: the flat update crosses the wire as-is."""
    size: int


@dataclasses.dataclass(frozen=True)
class QuantizeSpec:
    """Blockwise absmax int8 / packed-int4 (FedPAQ-style baseline)."""
    size: int
    bits: int = 8
    block: int = 256


@dataclasses.dataclass(frozen=True)
class TopKSpec:
    """Top-k magnitudes (DGC/STC-style); ships (values, int32 indices).

    As a chain *prefix* the values vector (length ``k``) is the carry fed to
    the next stage, and only the int32 indices ship from this stage — the
    FedZip sparsify-then-compress layout."""
    size: int
    k: int


@dataclasses.dataclass(frozen=True)
class FCAESpec:
    """Paper-faithful full FC AE; ``cfg.input_dim ≥ size`` (padded)."""
    size: int
    cfg: AEConfig


@dataclasses.dataclass(frozen=True)
class ChunkedAESpec:
    """Shared-chunk AE (DESIGN.md §3.2); ``use_kernel`` routes through the
    Pallas fused-dense / fused decode→aggregate kernels."""
    size: int
    cfg: ChunkedAEConfig
    use_kernel: bool = False

    @property
    def n_chunks(self) -> int:
        return -(-self.size // self.cfg.chunk_size)


@dataclasses.dataclass(frozen=True)
class KMeansSpec:
    """K-means codebook quantization (FedZip's clustered quantization).

    The codebook is fit on-device at encode time (``iters`` Lloyd steps,
    quantile-seeded or warm-started from ``params["codebook"]``) and ships
    with the codes — wire format is ``{"codes", "codebook"}``. Codes are
    uint8 for ``k ≤ 256``. Terminal-only stage: codes are not a vector the
    next stage could transform."""
    size: int
    k: int = 16
    iters: int = 8


@dataclasses.dataclass(frozen=True)
class EntropySpec:
    """Entropy-coded wire size, priced analytically (DESIGN.md §13.3).

    Pure pricing stage: encode stays dense on device (no payload entries),
    but :func:`measured_bytes` prices every integer payload leaf of the
    chain at its empirical Shannon entropy plus ``table_bytes_per_symbol``
    per distinct symbol. Only valid as the *last* stage of a chain; chains
    carrying it are not shape-static (``is_shape_static`` → False), so rate
    controllers keep planning with the dense :func:`wire_bytes` price while
    the measured channel reports what an entropy coder would have shipped."""
    table_bytes_per_symbol: int = 4


@dataclasses.dataclass(frozen=True)
class ComposedSpec:
    """AE latents further quantized (§4.2 "orthogonal add-on").

    Since the stage refactor this is a thin alias for the 2-stage chain
    ``ChainSpec((inner, QuantizeSpec(n_latent, bits, block)))`` — every
    entry point canonicalizes through :func:`composed_chain` — but it keeps
    its historical flat payload keys ``{"z_q", "z_scales"}`` and its
    bare-AE-params convention, so pre-refactor payloads, checkpoints and
    golden trajectories stay bit-compatible."""
    inner: Union[FCAESpec, ChunkedAESpec]
    bits: int = 8
    block: int = 64

    @property
    def size(self) -> int:
        return self.inner.size


@dataclasses.dataclass(frozen=True)
class ChainSpec:
    """Composable codec stack: ``stages`` applied left-to-right at encode.

    Every non-terminal vector stage must be *carrying* (its payload has a
    designated carry entry the next stage consumes, flattened 1-D);
    terminal-only stages (quantize, k-means) may appear once, last.
    ``EntropySpec`` may trail the vector stages as a pure pricing stage.
    Payload entries are namespaced ``{"s0": {...}, "s1": {...}}`` (stages
    that ship nothing are omitted). Frozen and hashable — a valid jit-static
    argument like every other spec, and a first-class ``CodecSpec`` union
    member accepted by ladders, partitions and the grouped server path."""
    stages: Tuple[Any, ...]

    def __post_init__(self):
        stages = tuple(self.stages)
        object.__setattr__(self, "stages", stages)
        if not stages:
            raise ValueError("ChainSpec needs at least one stage")
        for s in stages:
            if isinstance(s, (ChainSpec, ComposedSpec)):
                raise TypeError(
                    f"ChainSpec stages must be atomic, got {type(s).__name__}"
                    " (flatten nested chains; use composed_chain() for"
                    " ComposedSpec)")
            if type(s).__name__ == "PartitionSpec":
                raise TypeError("PartitionSpec cannot be a chain stage — "
                                "put chains inside partition groups instead")
        if isinstance(stages[0], EntropySpec):
            raise ValueError("EntropySpec cannot lead a chain")
        for s in stages[:-1]:
            if isinstance(s, EntropySpec):
                raise ValueError("EntropySpec only valid as the last stage")
        vs = tuple(s for s in stages if not isinstance(s, EntropySpec))
        n_ae = sum(isinstance(s, (FCAESpec, ChunkedAESpec)) for s in vs)
        if n_ae > 1:
            raise ValueError("at most one AE stage per chain")
        for i, s in enumerate(vs[:-1]):
            ops = stage_ops(s)
            if ops.carry_key is None:
                raise ValueError(
                    f"{type(s).__name__} is terminal-only (no carry) and "
                    f"cannot precede {type(vs[i + 1]).__name__}")
            out = ops.out_size(s)
            if vs[i + 1].size != out:
                raise ValueError(
                    f"chain size mismatch: {type(s).__name__} emits {out} "
                    f"values but {type(vs[i + 1]).__name__} expects "
                    f"{vs[i + 1].size}")

    @property
    def size(self) -> int:
        return self.stages[0].size

    @property
    def vector_stages(self) -> Tuple[Any, ...]:
        """The stages that transform data (everything but EntropySpec)."""
        return tuple(s for s in self.stages
                     if not isinstance(s, EntropySpec))


# ``partition.PartitionSpec`` (one frozen sub-spec per named leaf group,
# DESIGN.md §10) is also a member of this union: every entry point below
# dispatches it to the pure per-group functions in core/partition.py
# (imported lazily — partition.py imports this module at top level).
CodecSpec = Union[IdentitySpec, QuantizeSpec, TopKSpec, FCAESpec,
                  ChunkedAESpec, KMeansSpec, ComposedSpec, ChainSpec,
                  "PartitionSpec"]


def _partition_mod():
    from repro.core import partition
    return partition


def is_partitioned(spec) -> bool:
    """True for a ``partition.PartitionSpec`` (per-layer codec partitions,
    DESIGN.md §10) — the schedulers route those through the grouped fused
    server path instead of the single-spec one."""
    return isinstance(spec, _partition_mod().PartitionSpec)


# =====================================================================
# stage ops protocol — one class per stage spec, registered in _STAGE_OPS
# =====================================================================
# Each ops class defines:
#   carry_key     name of the payload entry the next chain stage consumes,
#                 or None for terminal-only stages (quantize, k-means)
#   carry_shape   natural (unbatched) shape of that carry entry
#   out_size      flattened carry length == next stage's required ``size``
#   fwd           (spec, params, x) → payload dict   [bare wire keys]
#   inv           (spec, params, payload) → x, shape (spec.size,)
#   inv_batched   (spec, params, stacked) → (C, spec.size), shared params
# The fwd/inv bodies are the pre-refactor per-codec branches verbatim, so
# bare specs (and single-stage chains) stay bit-identical across the
# refactor.
def _dequant_to(spec_bits: int, spec_block: int, n: int,
                q: jax.Array, scales: jax.Array) -> jax.Array:
    from repro.kernels import ops
    return ops.dequantize_blocks(q, scales, bits=spec_bits,
                                 block=spec_block, orig_len=n)


class _IdentityOps:
    carry_key = "flat"

    @staticmethod
    def carry_shape(spec):
        return (spec.size,)

    @staticmethod
    def out_size(spec):
        return spec.size

    @staticmethod
    def fwd(spec, params, flat):
        return {"flat": flat}

    @staticmethod
    def inv(spec, params, payload):
        return payload["flat"]

    @staticmethod
    def inv_batched(spec, params, stacked):
        return stacked["flat"]


class _QuantizeOps:
    carry_key = None

    @staticmethod
    def carry_shape(spec):
        raise TypeError("QuantizeSpec is terminal-only")

    @staticmethod
    def out_size(spec):
        return None

    @staticmethod
    def fwd(spec, params, flat):
        from repro.kernels import ops
        q, scales, _ = ops.quantize_blocks(flat, bits=spec.bits,
                                           block=spec.block)
        return {"q": q, "scales": scales}

    @staticmethod
    def inv(spec, params, payload):
        return _dequant_to(spec.bits, spec.block, spec.size,
                           payload["q"], payload["scales"])

    @staticmethod
    def inv_batched(spec, params, stacked):
        q, scales = stacked["q"], stacked["scales"]
        C = scales.shape[0]
        from repro.kernels import ops
        if spec.bits == 4:
            q = ops.unpack_nibbles(q).reshape(C, -1, spec.block)
        nb = q.shape[1]
        from repro.kernels.ops import interpret_default
        from repro.kernels.quantize import dequantize_blocks_2d
        x = dequantize_blocks_2d(q.reshape(C * nb, spec.block),
                                 scales.reshape(C * nb),
                                 block=spec.block,
                                 interpret=interpret_default())
        return x.reshape(C, -1)[:, :spec.size]


class _TopKOps:
    carry_key = "values"

    @staticmethod
    def carry_shape(spec):
        return (spec.k,)

    @staticmethod
    def out_size(spec):
        return spec.k

    @staticmethod
    def fwd(spec, params, flat):
        _, idx = jax.lax.top_k(jnp.abs(flat), spec.k)
        idx = idx.astype(jnp.int32)
        return {"values": flat[idx], "indices": idx}

    @staticmethod
    def inv(spec, params, payload):
        flat = jnp.zeros((spec.size,), payload["values"].dtype)
        return flat.at[payload["indices"]].set(payload["values"])

    @staticmethod
    def inv_batched(spec, params, stacked):
        return jax.vmap(lambda pl: _TopKOps.inv(spec, None, pl))(stacked)


class _FCAEOps:
    carry_key = "z"

    @staticmethod
    def carry_shape(spec):
        return (spec.cfg.latent_dim,)

    @staticmethod
    def out_size(spec):
        return spec.cfg.latent_dim

    @staticmethod
    def fwd(spec, params, flat):
        pad = spec.cfg.input_dim - spec.size
        assert pad >= 0, (
            f"AE input_dim {spec.cfg.input_dim} < update size {spec.size}")
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return {"z": ae.fc_encode(params, spec.cfg, flat)}

    @staticmethod
    def inv(spec, params, payload):
        flat = ae.fc_decode(params, spec.cfg, payload["z"])
        return flat[:spec.size]

    @staticmethod
    def inv_batched(spec, params, stacked):
        # fc_decode is rank-polymorphic: (C, latent) → (C, input_dim)
        return ae.fc_decode(params, spec.cfg, stacked["z"])[:, :spec.size]


class _ChunkedAEOps:
    carry_key = "z"

    @staticmethod
    def carry_shape(spec):
        return (spec.n_chunks, spec.cfg.latent_chunk)

    @staticmethod
    def out_size(spec):
        return spec.n_chunks * spec.cfg.latent_chunk

    @staticmethod
    def fwd(spec, params, flat):
        if spec.use_kernel:
            from repro.kernels import ops
            return {"z": ops.ae_encode(params, spec.cfg, flat)}
        return {"z": ae.chunked_encode(params, spec.cfg, flat)}

    @staticmethod
    def inv(spec, params, payload):
        if spec.use_kernel:
            from repro.kernels import ops
            return ops.ae_decode(params, spec.cfg, payload["z"], spec.size)
        return ae.chunked_decode(params, spec.cfg, payload["z"], spec.size)

    @staticmethod
    def inv_batched(spec, params, stacked):
        z = stacked["z"]                       # (C, n_chunks, latent)
        C = z.shape[0]
        chunks = _chunked_dec_chunks(spec, params, z)
        return chunks.reshape(C, -1)[:, :spec.size]


class _KMeansOps:
    carry_key = None

    @staticmethod
    def carry_shape(spec):
        raise TypeError("KMeansSpec is terminal-only")

    @staticmethod
    def out_size(spec):
        return None

    @staticmethod
    def fwd(spec, params, flat):
        x = flat.astype(jnp.float32)
        if params is not None and "codebook" in params:
            cb0 = params["codebook"].astype(jnp.float32)
        else:
            probs = (jnp.arange(spec.k, dtype=jnp.float32) + 0.5) / spec.k
            cb0 = jnp.quantile(x, probs)

        def lloyd(cb, _):
            a = jnp.argmin(jnp.abs(x[:, None] - cb[None, :]), axis=1)
            sums = jnp.zeros((spec.k,), jnp.float32).at[a].add(x)
            cnts = jnp.zeros((spec.k,), jnp.float32).at[a].add(1.0)
            # empty clusters keep their old centroid instead of going NaN
            cb = jnp.where(cnts > 0, sums / jnp.maximum(cnts, 1.0), cb)
            return cb, None

        cb, _ = jax.lax.scan(lloyd, cb0, None, length=spec.iters)
        codes = jnp.argmin(jnp.abs(x[:, None] - cb[None, :]), axis=1)
        dt = jnp.uint8 if spec.k <= 256 else jnp.int32
        return {"codes": codes.astype(dt), "codebook": cb}

    @staticmethod
    def inv(spec, params, payload):
        return payload["codebook"][payload["codes"].astype(jnp.int32)]

    @staticmethod
    def inv_batched(spec, params, stacked):
        return jax.vmap(lambda pl: _KMeansOps.inv(spec, None, pl))(stacked)


_STAGE_OPS = {
    IdentitySpec: _IdentityOps,
    QuantizeSpec: _QuantizeOps,
    TopKSpec: _TopKOps,
    FCAESpec: _FCAEOps,
    ChunkedAESpec: _ChunkedAEOps,
    KMeansSpec: _KMeansOps,
}


def stage_ops(spec):
    """The registered ops class for an atomic stage spec."""
    try:
        return _STAGE_OPS[type(spec)]
    except KeyError:
        raise TypeError(f"unknown codec stage {type(spec).__name__}")


def stage_out_size(spec) -> Optional[int]:
    """Flattened carry length a stage emits (next stage's ``size``), or
    None for terminal-only stages."""
    return stage_ops(spec).out_size(spec)


def stage_carry_shape(spec) -> Tuple[int, ...]:
    """Natural (unbatched) shape of a carrying stage's carry entry."""
    return stage_ops(spec).carry_shape(spec)


# =====================================================================
# chain helpers
# =====================================================================
def composed_chain(spec: ComposedSpec) -> ChainSpec:
    """The 2-stage chain a ``ComposedSpec`` canonicalizes to."""
    n_latent = 1
    for d in latent_shape(spec.inner):
        n_latent *= d
    return ChainSpec((spec.inner,
                      QuantizeSpec(size=n_latent, bits=spec.bits,
                                   block=spec.block)))


def _composed_params(params) -> Tuple[Params, None]:
    # ComposedSpec keeps the historical bare-AE-params convention
    return (params, None)


def _composed_wrap_payload(payload: Payload) -> Payload:
    """Chain payload ``{"s1": {q, scales}}`` → historical flat keys."""
    return {"z_q": payload["s1"]["q"], "z_scales": payload["s1"]["scales"]}


def _composed_unwrap_payload(payload: Payload) -> Payload:
    """Historical flat keys → chain payload for the canonical 2-stage."""
    return {"s1": {"q": payload["z_q"], "scales": payload["z_scales"]}}


def _chain_params(spec: ChainSpec, params: Optional[Params]
                  ) -> Tuple[Optional[Params], ...]:
    """Per-vector-stage params tuple (None-filled when ``params is None``)."""
    n = len(spec.vector_stages)
    if params is None:
        return (None,) * n
    if not isinstance(params, tuple) or len(params) != n:
        raise ValueError(
            f"ChainSpec params must be a tuple of {n} per-stage entries "
            f"(None for stateless stages), got {type(params).__name__}")
    return params


def _chain_encode(spec: ChainSpec, params, flat: jax.Array) -> Payload:
    vs = spec.vector_stages
    ps = _chain_params(spec, params)
    out: Payload = {}
    x = flat
    last = len(vs) - 1
    for i, st in enumerate(vs):
        ops = stage_ops(st)
        pl = ops.fwd(st, ps[i], x)
        if i < last:
            carry = pl.pop(ops.carry_key)
            if pl:                     # side entries (e.g. top-k indices)
                out[f"s{i}"] = pl
            x = carry.reshape(-1)      # mid-chain carries travel flat
        else:
            out[f"s{i}"] = pl          # terminal stage ships its carry too
    return out


def _chain_decode(spec: ChainSpec, params, payload: Payload) -> jax.Array:
    vs = spec.vector_stages
    ps = _chain_params(spec, params)
    last = len(vs) - 1
    x = stage_ops(vs[last]).inv(vs[last], ps[last], payload[f"s{last}"])
    for i in range(last - 1, -1, -1):
        st = vs[i]
        ops = stage_ops(st)
        pl = dict(payload.get(f"s{i}", {}))
        pl[ops.carry_key] = x.reshape(ops.carry_shape(st))
        x = ops.inv(st, ps[i], pl)
    return x


def _chain_decode_batched(spec: ChainSpec, params, stacked: Payload, *,
                          upto: int = 0) -> jax.Array:
    """Backward fold of ``inv_batched`` down to (and excluding) vector stage
    ``upto``. ``upto=0`` is the full batched decode → ``(C, spec.size)``;
    ``upto=i`` stops with stage ``i``'s carry, ``(C, out_size(stage i))`` —
    how the scatter and kernel aggregate paths peel pointwise suffixes."""
    vs = spec.vector_stages
    ps = _chain_params(spec, params)
    last = len(vs) - 1
    X = stage_ops(vs[last]).inv_batched(vs[last], ps[last],
                                        stacked[f"s{last}"])
    for i in range(last - 1, upto - 1, -1):
        st = vs[i]
        ops = stage_ops(st)
        C = X.shape[0]
        pl = dict(stacked.get(f"s{i}", {}))
        pl[ops.carry_key] = X.reshape((C,) + ops.carry_shape(st))
        X = ops.inv_batched(st, ps[i], pl)
    return X


def ae_spec(spec: CodecSpec) -> Optional[Union[FCAESpec, ChunkedAESpec]]:
    """The AE spec inside ``spec`` (unwrapping ``ComposedSpec`` and chain
    interiors), or None for pointwise stacks — how the AE lifecycle
    (DESIGN.md §8) finds the chunking/shape config to build refit datasets
    with."""
    if isinstance(spec, ComposedSpec):
        return ae_spec(spec.inner)
    if isinstance(spec, ChainSpec):
        for st in spec.vector_stages:
            if isinstance(st, (FCAESpec, ChunkedAESpec)):
                return st
        return None
    if isinstance(spec, (FCAESpec, ChunkedAESpec)):
        return spec
    return None


def ae_stage_params(spec: CodecSpec, params: Optional[Params]
                    ) -> Optional[Params]:
    """The AE stage's params entry inside a (possibly chained) spec — the
    object whose identity keys decoder-table slots in the grouped launch and
    whose shapes price decoder ships."""
    if isinstance(spec, ComposedSpec):
        return params
    if isinstance(spec, ChainSpec):
        ps = _chain_params(spec, params)
        for st, p in zip(spec.vector_stages, ps):
            if isinstance(st, (FCAESpec, ChunkedAESpec)):
                return p
        return None
    return params


def ae_stage_input(spec: CodecSpec, params: Optional[Params],
                   flat: jax.Array) -> jax.Array:
    """Forward-fold ``flat`` through chain prefix stages up to the AE stage:
    the vector the AE actually encodes. Identity for non-chain specs (the
    AE sees the raw update) — the lifecycle builds refit datasets from this
    so chained AEs train on what they will compress."""
    if not isinstance(spec, ChainSpec):
        return flat
    vs = spec.vector_stages
    ps = _chain_params(spec, params)
    x = flat
    for i, st in enumerate(vs):
        if isinstance(st, (FCAESpec, ChunkedAESpec)):
            return x
        ops = stage_ops(st)
        pl = ops.fwd(st, ps[i], x)
        x = pl[ops.carry_key].reshape(-1)
    return x


def kernel_terminal_ae(spec: CodecSpec) -> Optional[ChunkedAESpec]:
    """The kernel-path chunked-AE stage when ``spec`` can take the fused
    Pallas decode→aggregate launch: a bare ``ChunkedAESpec(use_kernel)``, or
    a chain whose AE expansion is the *last* decode transform (identity-only
    prefix, pointwise-quantizer-only suffix). None otherwise — e.g.
    sparsified chains, whose final decode transform is a scatter."""
    if isinstance(spec, ChunkedAESpec) and spec.use_kernel:
        return spec
    if isinstance(spec, ChainSpec):
        vs = spec.vector_stages
        idx = [i for i, s in enumerate(vs)
               if isinstance(s, (FCAESpec, ChunkedAESpec))]
        if len(idx) != 1:
            return None
        i = idx[0]
        st = vs[i]
        if not (isinstance(st, ChunkedAESpec) and st.use_kernel):
            return None
        if any(not isinstance(s, IdentitySpec) for s in vs[:i]):
            return None
        if any(not isinstance(s, (QuantizeSpec, KMeansSpec))
               for s in vs[i + 1:]):
            return None
        return st
    return None


def kernel_chain_latents(spec: CodecSpec, params: Optional[Params],
                         stacked: Payload) -> Tuple[jax.Array, Params]:
    """``(z, ae_params)`` feeding the fused kernel for a
    :func:`kernel_terminal_ae` spec: the stacked latents ``(C, n_chunks,
    latent)`` after batched-inverting any pointwise suffix stages."""
    if isinstance(spec, ChunkedAESpec):
        return stacked["z"], params
    vs = spec.vector_stages
    ps = _chain_params(spec, params)
    i = next(j for j, s in enumerate(vs) if isinstance(s, ChunkedAESpec))
    st = vs[i]
    if i == len(vs) - 1:
        return stacked[f"s{i}"]["z"], ps[i]
    Z = _chain_decode_batched(spec, params, stacked, upto=i + 1)
    C = Z.shape[0]
    return Z.reshape((C,) + stage_carry_shape(st)), ps[i]


# =====================================================================
# wire pricing
# =====================================================================
def _require_priceable(spec: CodecSpec, params: Optional[Params]) -> None:
    """AE-bearing specs cannot be priced without their parameter shapes —
    raise a clear error instead of letting ``eval_shape`` trace None."""
    if is_partitioned(spec):
        for name, _, cspec in spec.groups:
            p = None if params is None else params.get(name)
            _require_priceable(cspec, p)
        return
    if isinstance(spec, ComposedSpec):
        _require_priceable(spec.inner, params)
        return
    if isinstance(spec, ChainSpec):
        ps = _chain_params(spec, params)
        for st, p in zip(spec.vector_stages, ps):
            _require_priceable(st, p)
        return
    if isinstance(spec, (FCAESpec, ChunkedAESpec)) and params is None:
        raise ValueError(
            f"wire_bytes({type(spec).__name__}(size={spec.size})): this "
            "spec encodes through an autoencoder, so pricing needs the AE "
            "parameter shapes — pass params (e.g. "
            "compressor.codec_params()) instead of None")


def wire_bytes(spec: CodecSpec, params: Optional[Params] = None) -> int:
    """Static uplink cost of one encoded payload for ``spec``, in bytes.

    Computed by abstract evaluation (``jax.eval_shape``) of :func:`encode`,
    so nothing runs and no params are read — only their shapes. This is the
    single pricing rule the rate controllers (DESIGN.md §9.1) plan ladder
    allocations with, and it is asserted equal to ``tree_bytes`` of a real
    encode in tests/test_ratecontrol.py, so planned and observed uplink can
    never diverge. Chains ending in :class:`EntropySpec` are priced at
    their *dense* wire size here (entropy-coded sizes are data-dependent);
    :func:`measured_bytes` reports the entropy-coded price per payload."""
    _require_priceable(spec, params)
    shapes = jax.eval_shape(
        lambda f: encode(spec, params, f),
        jax.ShapeDtypeStruct((spec.size,), jnp.float32))
    total = 0
    for s in jax.tree_util.tree_leaves(shapes):
        n = 1
        for d in s.shape:
            n *= d
        total += n * s.dtype.itemsize
    return int(total)


def is_shape_static(spec: CodecSpec) -> bool:
    """True when the real wire size of every payload equals the eval-shape
    :func:`wire_bytes` price — i.e. the spec carries no entropy-coded
    stage. Rate controllers require this invariant; entropy-coded chains
    report their data-dependent size via :func:`measured_bytes` only."""
    if is_partitioned(spec):
        return all(is_shape_static(c) for _, _, c in spec.groups)
    if isinstance(spec, ChainSpec):
        return not any(isinstance(s, EntropySpec) for s in spec.stages)
    return True


def measured_bytes(spec: CodecSpec, payload: Payload) -> float:
    """Host-side measured wire size of one real payload, in bytes.

    For shape-static specs this equals ``tree_bytes(payload)`` (and hence
    :func:`wire_bytes`). For chains ending in :class:`EntropySpec`, every
    integer payload leaf (quantize codes, k-means codes, top-k indices) is
    priced at ``min(raw, n·H/8 + table_bytes_per_symbol·n_distinct)`` — its
    empirical Shannon entropy plus the code table, with the adaptive-coder
    raw fallback for incompressible leaves — while float leaves (scales,
    codebooks, raw values) ship uncoded. So measured ≤ dense always. This
    is the *measured-bytes channel*: reported alongside, never instead of,
    the shape-static plan price."""
    import numpy as np

    if is_partitioned(spec):
        return float(sum(measured_bytes(c, payload[n])
                         for n, _, c in spec.groups))
    entropy = None
    if isinstance(spec, ChainSpec) and isinstance(spec.stages[-1],
                                                  EntropySpec):
        entropy = spec.stages[-1]
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(payload):
        a = np.asarray(leaf)
        if a.size == 0:
            continue
        raw = a.size * a.dtype.itemsize
        if entropy is not None and np.issubdtype(a.dtype, np.integer):
            _, cnts = np.unique(a, return_counts=True)
            p = cnts / a.size
            H = float(-(p * np.log2(p)).sum())
            coded = (a.size * H / 8.0
                     + cnts.size * entropy.table_bytes_per_symbol)
            # an adaptive coder ships incompressible leaves raw (top-k
            # indices are near-uniform: table cost would exceed the win)
            total += min(raw, coded)
        else:
            total += raw
    return float(total)


def latent_shape(spec: Union[FCAESpec, ChunkedAESpec]) -> Tuple[int, ...]:
    """Static shape of the AE latent payload entry ``z``."""
    if isinstance(spec, FCAESpec):
        return (spec.cfg.latent_dim,)
    if isinstance(spec, ChunkedAESpec):
        return (spec.n_chunks, spec.cfg.latent_chunk)
    raise TypeError(f"no latent for {type(spec).__name__}")


# =====================================================================
# encode: flat (size,) → payload dict of fixed-shape arrays
# =====================================================================
def encode(spec: CodecSpec, params: Optional[Params],
           flat: jax.Array) -> Payload:
    """Pure collaborator-side encoder. ``params`` is the AE parameter pytree
    for the AE specs, a per-stage tuple for chains, ``None`` otherwise.
    Jit-able with ``spec`` static."""
    if is_partitioned(spec):
        return _partition_mod().encode_tree(spec, params, flat)
    if isinstance(spec, ComposedSpec):
        pl = _chain_encode(composed_chain(spec), _composed_params(params),
                           flat)
        return _composed_wrap_payload(pl)
    if isinstance(spec, ChainSpec):
        return _chain_encode(spec, params, flat)
    return stage_ops(spec).fwd(spec, params, flat)


# =====================================================================
# decode: payload → flat (size,)
# =====================================================================
def decode(spec: CodecSpec, params: Optional[Params],
           payload: Payload) -> jax.Array:
    """Pure aggregator-side decoder → flat ``(spec.size,)`` vector. No
    traced→Python casts: every length/shape is static spec data, so the
    whole function stages into one XLA computation under ``jax.jit``."""
    if is_partitioned(spec):
        return _partition_mod().decode_tree(spec, params, payload)
    if isinstance(spec, ComposedSpec):
        return _chain_decode(composed_chain(spec), _composed_params(params),
                             _composed_unwrap_payload(payload))
    if isinstance(spec, ChainSpec):
        return _chain_decode(spec, params, payload)
    return stage_ops(spec).inv(spec, params, payload)


# =====================================================================
# batched decode over a leading client axis
# =====================================================================
def stack_payloads(payloads) -> Payload:
    """Stack per-client payload dicts along a new leading client axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payloads)


def decode_batched(spec: CodecSpec, params: Optional[Params],
                   stacked: Payload, *,
                   params_batched: bool = False) -> jax.Array:
    """Decode a whole cohort at once: stacked payload ``(C, ...)`` →
    ``(C, size)``. With ``params_batched`` the AE params carry a leading
    client axis too (per-client decoders) and the decode vmaps over both;
    otherwise the shared-params fast path reshapes the client axis into the
    existing batch dimension of each kernel, which is bit-identical to
    per-client decoding for the pointwise codecs."""
    if is_partitioned(spec):
        return _partition_mod().decode_tree_batched(
            spec, params, stacked, params_batched=params_batched)
    if params_batched:
        return jax.vmap(lambda p, pl: decode(spec, p, pl))(params, stacked)
    if isinstance(spec, ComposedSpec):
        return _chain_decode_batched(composed_chain(spec),
                                     _composed_params(params),
                                     _composed_unwrap_payload(stacked))
    if isinstance(spec, ChainSpec):
        return _chain_decode_batched(spec, params, stacked)
    return stage_ops(spec).inv_batched(spec, params, stacked)


def _chunked_dec_chunks(spec: ChunkedAESpec, params: Params,
                        z: jax.Array) -> jax.Array:
    """(C, n_chunks, latent) → (C, n_chunks, chunk_size): the client axis is
    folded into the chunk batch, so the decode is one matmul chain whichever
    path (Pallas fused_dense or pure-jnp) runs."""
    C, nc, latent = z.shape
    z2 = z.reshape(C * nc, latent)
    if spec.use_kernel:
        from repro.kernels import ops
        flat = ops.ae_decode(params, spec.cfg,
                             z2, C * nc * spec.cfg.chunk_size)
    else:
        flat = ae.chunked_decode(params, spec.cfg,
                                 z2, C * nc * spec.cfg.chunk_size)
    return flat.reshape(C, nc, spec.cfg.chunk_size)


# =====================================================================
# fused decode→aggregate: the one-jitted-call-per-round server path
# =====================================================================
@functools.partial(jax.jit, static_argnames=("spec", "params_batched"))
def decode_and_aggregate(spec: CodecSpec, params: Optional[Params],
                         stacked: Payload, weights: jax.Array,
                         base: Optional[jax.Array] = None, *,
                         params_batched: bool = False) -> jax.Array:
    """One jitted call per round: decode the stacked cohort payloads and
    FedAvg-reduce along the client axis → mean flat update ``(size,)``.

    ``weights`` must already be normalized (Σ=1; use
    ``aggregate.normalize_weights`` — normalizing host-side keeps this path
    bit-identical to the sequential decode-then-``weighted_mean`` path).
    ``base`` (e.g. the flat global params under the §5.2 weights-payload
    protocol) is subtracted from each decoded row before the reduction.

    Three fused routes, picked by terminal decode transform:

    * scatter-terminal chains (top-k prefix, DESIGN.md §13.4): batched-
      invert the suffix down to the top-k carry ``(C, k)`` and reduce by
      one weighted ``scatter-add`` over the shipped indices — dense
      per-client rows are never built;
    * kernel-terminal AE stacks (:func:`kernel_terminal_ae`): hidden
      decoder layers on the folded (C·n_chunks) batch, then the fused
      Pallas kernel folds ``weights`` into the final decoder matmul
      (DESIGN.md §7.1);
    * everything else: natively-batched decode + per-element ``einsum``."""
    w = weights.astype(jnp.float32)
    if is_partitioned(spec):
        # partitioned homogeneous cohort: one fused reduction per group,
        # all inlined into this single jitted call (kernel-path chunked-AE
        # groups still take the Pallas fused branch). Heterogeneous
        # partitioned cohorts go through the scheduler's grouped path
        # (partition.server_decode_aggregate, DESIGN.md §10.2) instead.
        part = _partition_mod()
        means = {}
        for name, slices, cspec in spec.groups:
            p = None if params is None else params.get(name)
            base_g = None if base is None else part.gather(slices, base)
            means[name] = decode_and_aggregate(
                cspec, p, stacked[name], w, base_g,
                params_batched=params_batched and p is not None)
        return part.scatter_groups(spec.structure, means, spec.size)
    if not params_batched:
        if (isinstance(spec, ChainSpec)
                and isinstance(spec.vector_stages[0], TopKSpec)
                and len(spec.vector_stages) > 1):
            vals = _chain_decode_batched(spec, params, stacked, upto=1)
            idx = stacked["s0"]["indices"]              # (C, k)
            wv = vals.astype(jnp.float32) * w[:, None]
            out = jnp.zeros((spec.size,), jnp.float32)
            out = out.at[idx.reshape(-1)].add(wv.reshape(-1))
            return out if base is None else out - base  # Σw=1
        kspec = kernel_terminal_ae(spec)
        if kspec is not None:
            z, ae_prm = kernel_chain_latents(spec, params, stacked)
            mean = _fused_chunked_decode_agg(kspec, ae_prm, z, w)
            return mean if base is None else mean - base
    rows = decode_batched(spec, params, stacked,
                          params_batched=params_batched)
    if base is not None:
        rows = rows - base[None, :]
    return jnp.einsum("c,cp->p", w, rows.astype(jnp.float32))


def chunked_hidden(spec: ChunkedAESpec, params: Params,
                   z: jax.Array) -> jax.Array:
    """Kernel-path hidden decoder stack: ``(C, n_chunks, latent)`` latents →
    ``(C, n_chunks, K)`` penultimate activations, everything latent-sided.
    Shared by the per-bucket fused path below and the grouped ragged launch
    (core/partition.py, DESIGN.md §11.2) — both then expand to chunk width
    inside a weighted-accumulation kernel."""
    from repro.kernels.fused_dense import fused_dense
    from repro.kernels.ops import interpret_default
    interp = interpret_default()
    C, nc, latent = z.shape
    x = z.reshape(C * nc, latent)
    for layer in params["dec"][:-1]:           # hidden stack, act throughout
        # large bm: the folded (C·n_chunks) batch is tall and the hidden
        # widths narrow, so row-fat tiles stay far under VMEM while cutting
        # the grid-step count (which is what interpret-mode costs scale on)
        x = fused_dense(x, layer["w"], layer["b"],
                        act=spec.cfg.activation, bm=512, interpret=interp)
    return x.reshape(C, nc, x.shape[-1])


def _fused_chunked_decode_agg(spec: ChunkedAESpec, params: Params,
                              z: jax.Array, weights: jax.Array) -> jax.Array:
    """ChunkedAE fused path: per-client work stays latent-sided (the hidden
    stack output ``(C, n_chunks, hidden)``); the chunk_size-wide expansion
    happens inside the weighted-accumulation kernel, once."""
    from repro.kernels.fused_decode_agg import fused_decode_agg
    from repro.kernels.ops import interpret_default
    dec = params["dec"]
    h = chunked_hidden(spec, params, z)
    chunks = fused_decode_agg(h, weights, dec[-1]["w"], dec[-1]["b"],
                              interpret=interpret_default())
    norm = params["norm"]                             # (nc, chunk_size)
    chunks = chunks * norm["std"] + norm["mean"]      # Σw=1 ⇒ mean denorm
    return chunks.reshape(-1)[:spec.size]


# =====================================================================
# shard_map variant: client axis split across devices (DESIGN.md §7.2)
# =====================================================================
@functools.lru_cache(maxsize=None)
def _sharded_callable(spec: CodecSpec, mesh: jax.sharding.Mesh):
    """Build (once per (spec, mesh)) the jitted shard_map reduction so
    repeated rounds dispatch a cached executable instead of re-tracing."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def shard_fn(params, stacked_shard, w_shard):
        rows = decode_batched(spec, params, stacked_shard)
        partial = jnp.einsum("c,cp->p", w_shard.astype(jnp.float32),
                             rows.astype(jnp.float32))
        return jax.lax.psum(partial, "clients")

    # check_rep=False: pallas_call (the quantize/fused-dense kernels inside
    # decode_batched) has no shard_map replication rule yet
    return jax.jit(shard_map(shard_fn, mesh=mesh,
                             in_specs=(P(), P("clients"), P("clients")),
                             out_specs=P(), check_rep=False))


def decode_and_aggregate_sharded(spec: CodecSpec, params: Optional[Params],
                                 stacked: Payload, weights: jax.Array,
                                 base: Optional[jax.Array] = None,
                                 mesh: Optional[jax.sharding.Mesh] = None
                                 ) -> jax.Array:
    """Large-cohort variant: shard the client axis over a 1-D ``clients``
    device mesh; each device computes its shard's weighted *sum* (weights
    are globally pre-normalized, so no renormalization is needed; AE params
    are replicated), and a single ``psum`` produces the cohort mean. The
    cohort is zero-weight padded up to a device multiple (zero payloads
    decode to finite values for every codec, so padded rows contribute
    exactly 0). Layout notes in DESIGN.md §7.2."""
    import numpy as np

    if mesh is None:
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("clients",))
    n_dev = mesh.devices.size
    C = weights.shape[0]
    pad = (-C) % n_dev
    if pad:
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)),
            stacked)
        weights = jnp.pad(weights, (0, pad))
    mean = _sharded_callable(spec, mesh)(params, stacked, weights)
    return mean if base is None else mean - base

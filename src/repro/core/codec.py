"""Jit-native codec protocol: static specs + pure encode/decode functions.

The stateful ``Compressor`` classes (core/compressor.py) are host-side
adapters over this layer. A codec here is a pair of pure functions driven by
a **spec** — a small frozen (hashable) dataclass carrying everything static:
original length, bit widths, chunking, AE shapes. Specs are valid
``jax.jit`` static arguments, payloads are dicts of fixed-shape arrays, and
nothing in ``decode`` round-trips a traced value through Python (the old
``int(payload["orig_len"])`` host syncs are gone — ``orig_len`` is spec
data). That makes every codec:

* jit-compatible: ``jax.jit(decode, static_argnums=0)`` just works;
* vmap-compatible over a leading client axis, which is what the batched
  aggregator path needs (DESIGN.md §7);
* shard_map-compatible: the client axis splits across devices with a psum
  epilogue (DESIGN.md §7.2).

The server-side entry point is :func:`decode_and_aggregate`: stack the
cohort's payloads along a leading client axis (:func:`stack_payloads`) and
decode + FedAvg-reduce the whole cohort in **one** jitted call. The generic
path is a natively-batched decode followed by a per-element ``einsum`` over
the client axis; ``ChunkedAESpec(use_kernel=True)`` routes the final decoder
layer through the fused Pallas kernel (kernels/fused_decode_agg.py), which
folds the FedAvg weight into the matmul accumulation so per-client decoded
tensors are never materialized (memory math in DESIGN.md §7.1).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.paper import AEConfig
from repro.core import autoencoder as ae
from repro.core.autoencoder import ChunkedAEConfig

Params = Any
Payload = Dict[str, jax.Array]


# =====================================================================
# specs — frozen, hashable, jit-static
# =====================================================================
@dataclasses.dataclass(frozen=True)
class IdentitySpec:
    """No compression: the flat update crosses the wire as-is."""
    size: int


@dataclasses.dataclass(frozen=True)
class QuantizeSpec:
    """Blockwise absmax int8 / packed-int4 (FedPAQ-style baseline)."""
    size: int
    bits: int = 8
    block: int = 256


@dataclasses.dataclass(frozen=True)
class TopKSpec:
    """Top-k magnitudes (DGC/STC-style); ships (values, int32 indices)."""
    size: int
    k: int


@dataclasses.dataclass(frozen=True)
class FCAESpec:
    """Paper-faithful full FC AE; ``cfg.input_dim ≥ size`` (padded)."""
    size: int
    cfg: AEConfig


@dataclasses.dataclass(frozen=True)
class ChunkedAESpec:
    """Shared-chunk AE (DESIGN.md §3.2); ``use_kernel`` routes through the
    Pallas fused-dense / fused decode→aggregate kernels."""
    size: int
    cfg: ChunkedAEConfig
    use_kernel: bool = False

    @property
    def n_chunks(self) -> int:
        return -(-self.size // self.cfg.chunk_size)


@dataclasses.dataclass(frozen=True)
class ComposedSpec:
    """AE latents further quantized (§4.2 "orthogonal add-on")."""
    inner: Union[FCAESpec, ChunkedAESpec]
    bits: int = 8
    block: int = 64

    @property
    def size(self) -> int:
        return self.inner.size


# ``partition.PartitionSpec`` (one frozen sub-spec per named leaf group,
# DESIGN.md §10) is the seventh member of this union: every entry point
# below dispatches it to the pure per-group functions in core/partition.py
# (imported lazily — partition.py imports this module at top level).
CodecSpec = Union[IdentitySpec, QuantizeSpec, TopKSpec, FCAESpec,
                  ChunkedAESpec, ComposedSpec, "PartitionSpec"]


def _partition_mod():
    from repro.core import partition
    return partition


def is_partitioned(spec) -> bool:
    """True for a ``partition.PartitionSpec`` (per-layer codec partitions,
    DESIGN.md §10) — the schedulers route those through the grouped fused
    server path instead of the single-spec one."""
    return isinstance(spec, _partition_mod().PartitionSpec)


def ae_spec(spec: CodecSpec) -> Optional[Union[FCAESpec, ChunkedAESpec]]:
    """The AE spec inside ``spec`` (unwrapping ``ComposedSpec``), or None
    for the pointwise codecs — how the AE lifecycle (DESIGN.md §8) finds
    the chunking/shape config to build refit datasets with."""
    if isinstance(spec, ComposedSpec):
        return ae_spec(spec.inner)
    if isinstance(spec, (FCAESpec, ChunkedAESpec)):
        return spec
    return None


def wire_bytes(spec: CodecSpec, params: Optional[Params] = None) -> int:
    """Static uplink cost of one encoded payload for ``spec``, in bytes.

    Computed by abstract evaluation (``jax.eval_shape``) of :func:`encode`,
    so nothing runs and no params are read — only their shapes. This is the
    single pricing rule the rate controllers (DESIGN.md §9.1) plan ladder
    allocations with, and it is asserted equal to ``tree_bytes`` of a real
    encode in tests/test_ratecontrol.py, so planned and observed uplink can
    never diverge."""
    shapes = jax.eval_shape(
        lambda f: encode(spec, params, f),
        jax.ShapeDtypeStruct((spec.size,), jnp.float32))
    total = 0
    for s in jax.tree_util.tree_leaves(shapes):
        n = 1
        for d in s.shape:
            n *= d
        total += n * s.dtype.itemsize
    return int(total)


def latent_shape(spec: Union[FCAESpec, ChunkedAESpec]) -> Tuple[int, ...]:
    """Static shape of the AE latent payload entry ``z``."""
    if isinstance(spec, FCAESpec):
        return (spec.cfg.latent_dim,)
    if isinstance(spec, ChunkedAESpec):
        return (spec.n_chunks, spec.cfg.latent_chunk)
    raise TypeError(f"no latent for {type(spec).__name__}")


# =====================================================================
# encode: flat (size,) → payload dict of fixed-shape arrays
# =====================================================================
def encode(spec: CodecSpec, params: Optional[Params],
           flat: jax.Array) -> Payload:
    """Pure collaborator-side encoder. ``params`` is the AE parameter pytree
    for the AE specs, ``None`` otherwise. Jit-able with ``spec`` static."""
    if is_partitioned(spec):
        return _partition_mod().encode_tree(spec, params, flat)
    if isinstance(spec, IdentitySpec):
        return {"flat": flat}
    if isinstance(spec, QuantizeSpec):
        from repro.kernels import ops
        q, scales, _ = ops.quantize_blocks(flat, bits=spec.bits,
                                           block=spec.block)
        return {"q": q, "scales": scales}
    if isinstance(spec, TopKSpec):
        _, idx = jax.lax.top_k(jnp.abs(flat), spec.k)
        idx = idx.astype(jnp.int32)
        return {"values": flat[idx], "indices": idx}
    if isinstance(spec, FCAESpec):
        pad = spec.cfg.input_dim - spec.size
        assert pad >= 0, (
            f"AE input_dim {spec.cfg.input_dim} < update size {spec.size}")
        if pad:
            flat = jnp.pad(flat, (0, pad))
        return {"z": ae.fc_encode(params, spec.cfg, flat)}
    if isinstance(spec, ChunkedAESpec):
        if spec.use_kernel:
            from repro.kernels import ops
            return {"z": ops.ae_encode(params, spec.cfg, flat)}
        return {"z": ae.chunked_encode(params, spec.cfg, flat)}
    if isinstance(spec, ComposedSpec):
        from repro.kernels import ops
        z = encode(spec.inner, params, flat)["z"]
        q, scales, _ = ops.quantize_blocks(z.reshape(-1), bits=spec.bits,
                                           block=spec.block)
        return {"z_q": q, "z_scales": scales}
    raise TypeError(f"unknown spec {type(spec).__name__}")


# =====================================================================
# decode: payload → flat (size,)
# =====================================================================
def _dequant_to(spec_bits: int, spec_block: int, n: int,
                q: jax.Array, scales: jax.Array) -> jax.Array:
    from repro.kernels import ops
    return ops.dequantize_blocks(q, scales, bits=spec_bits,
                                 block=spec_block, orig_len=n)


def decode(spec: CodecSpec, params: Optional[Params],
           payload: Payload) -> jax.Array:
    """Pure aggregator-side decoder → flat ``(spec.size,)`` vector. No
    traced→Python casts: every length/shape is static spec data, so the
    whole function stages into one XLA computation under ``jax.jit``."""
    if is_partitioned(spec):
        return _partition_mod().decode_tree(spec, params, payload)
    if isinstance(spec, IdentitySpec):
        return payload["flat"]
    if isinstance(spec, QuantizeSpec):
        return _dequant_to(spec.bits, spec.block, spec.size,
                           payload["q"], payload["scales"])
    if isinstance(spec, TopKSpec):
        flat = jnp.zeros((spec.size,), payload["values"].dtype)
        return flat.at[payload["indices"]].set(payload["values"])
    if isinstance(spec, FCAESpec):
        flat = ae.fc_decode(params, spec.cfg, payload["z"])
        return flat[:spec.size]
    if isinstance(spec, ChunkedAESpec):
        if spec.use_kernel:
            from repro.kernels import ops
            return ops.ae_decode(params, spec.cfg, payload["z"], spec.size)
        return ae.chunked_decode(params, spec.cfg, payload["z"], spec.size)
    if isinstance(spec, ComposedSpec):
        n_latent = 1
        for d in latent_shape(spec.inner):
            n_latent *= d
        z = _dequant_to(spec.bits, spec.block, n_latent,
                        payload["z_q"], payload["z_scales"])
        return decode(spec.inner, params,
                      {"z": z.reshape(latent_shape(spec.inner))})
    raise TypeError(f"unknown spec {type(spec).__name__}")


# =====================================================================
# batched decode over a leading client axis
# =====================================================================
def stack_payloads(payloads) -> Payload:
    """Stack per-client payload dicts along a new leading client axis."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *payloads)


def decode_batched(spec: CodecSpec, params: Optional[Params],
                   stacked: Payload, *,
                   params_batched: bool = False) -> jax.Array:
    """Decode a whole cohort at once: stacked payload ``(C, ...)`` →
    ``(C, size)``. With ``params_batched`` the AE params carry a leading
    client axis too (per-client decoders) and the decode vmaps over both;
    otherwise the shared-params fast path reshapes the client axis into the
    existing batch dimension of each kernel, which is bit-identical to
    per-client decoding for the pointwise codecs."""
    if is_partitioned(spec):
        return _partition_mod().decode_tree_batched(
            spec, params, stacked, params_batched=params_batched)
    if params_batched:
        return jax.vmap(lambda p, pl: decode(spec, p, pl))(params, stacked)
    if isinstance(spec, IdentitySpec):
        return stacked["flat"]
    if isinstance(spec, QuantizeSpec):
        q, scales = stacked["q"], stacked["scales"]
        C = scales.shape[0]
        from repro.kernels import ops
        if spec.bits == 4:
            q = ops.unpack_nibbles(q).reshape(C, -1, spec.block)
        nb = q.shape[1]
        from repro.kernels.ops import interpret_default
        from repro.kernels.quantize import dequantize_blocks_2d
        x = dequantize_blocks_2d(q.reshape(C * nb, spec.block),
                                 scales.reshape(C * nb),
                                 block=spec.block,
                                 interpret=interpret_default())
        return x.reshape(C, -1)[:, :spec.size]
    if isinstance(spec, TopKSpec):
        return jax.vmap(lambda pl: decode(spec, None, pl))(stacked)
    if isinstance(spec, FCAESpec):
        # fc_decode is rank-polymorphic: (C, latent) → (C, input_dim)
        return ae.fc_decode(params, spec.cfg, stacked["z"])[:, :spec.size]
    if isinstance(spec, ChunkedAESpec):
        z = stacked["z"]                       # (C, n_chunks, latent)
        C = z.shape[0]
        chunks = _chunked_dec_chunks(spec, params, z)
        return chunks.reshape(C, -1)[:, :spec.size]
    if isinstance(spec, ComposedSpec):
        n_latent = 1
        for d in latent_shape(spec.inner):
            n_latent *= d
        C = stacked["z_scales"].shape[0]
        z = jax.vmap(lambda q, s: _dequant_to(spec.bits, spec.block,
                                              n_latent, q, s))(
            stacked["z_q"], stacked["z_scales"])
        return decode_batched(
            spec.inner, params,
            {"z": z.reshape((C,) + latent_shape(spec.inner))})
    raise TypeError(f"unknown spec {type(spec).__name__}")


def _chunked_dec_chunks(spec: ChunkedAESpec, params: Params,
                        z: jax.Array) -> jax.Array:
    """(C, n_chunks, latent) → (C, n_chunks, chunk_size): the client axis is
    folded into the chunk batch, so the decode is one matmul chain whichever
    path (Pallas fused_dense or pure-jnp) runs."""
    C, nc, latent = z.shape
    z2 = z.reshape(C * nc, latent)
    if spec.use_kernel:
        from repro.kernels import ops
        flat = ops.ae_decode(params, spec.cfg,
                             z2, C * nc * spec.cfg.chunk_size)
    else:
        flat = ae.chunked_decode(params, spec.cfg,
                                 z2, C * nc * spec.cfg.chunk_size)
    return flat.reshape(C, nc, spec.cfg.chunk_size)


# =====================================================================
# fused decode→aggregate: the one-jitted-call-per-round server path
# =====================================================================
@functools.partial(jax.jit, static_argnames=("spec", "params_batched"))
def decode_and_aggregate(spec: CodecSpec, params: Optional[Params],
                         stacked: Payload, weights: jax.Array,
                         base: Optional[jax.Array] = None, *,
                         params_batched: bool = False) -> jax.Array:
    """One jitted call per round: decode the stacked cohort payloads and
    FedAvg-reduce along the client axis → mean flat update ``(size,)``.

    ``weights`` must already be normalized (Σ=1; use
    ``aggregate.normalize_weights`` — normalizing host-side keeps this path
    bit-identical to the sequential decode-then-``weighted_mean`` path).
    ``base`` (e.g. the flat global params under the §5.2 weights-payload
    protocol) is subtracted from each decoded row before the reduction.

    Generic path: natively-batched decode + per-element ``einsum`` over the
    client axis. ``ChunkedAESpec(use_kernel=True)`` with shared params:
    hidden decoder layers run on the folded (C·n_chunks) batch, then the
    fused Pallas kernel folds ``weights`` into the final decoder matmul so
    the full-model-sized reconstructions are never materialized per client
    (DESIGN.md §7.1)."""
    w = weights.astype(jnp.float32)
    if is_partitioned(spec):
        # partitioned homogeneous cohort: one fused reduction per group,
        # all inlined into this single jitted call (kernel-path chunked-AE
        # groups still take the Pallas fused branch). Heterogeneous
        # partitioned cohorts go through the scheduler's grouped path
        # (partition.server_decode_aggregate, DESIGN.md §10.2) instead.
        part = _partition_mod()
        means = {}
        for name, slices, cspec in spec.groups:
            p = None if params is None else params.get(name)
            base_g = None if base is None else part.gather(slices, base)
            means[name] = decode_and_aggregate(
                cspec, p, stacked[name], w, base_g,
                params_batched=params_batched and p is not None)
        return part.scatter_groups(spec.structure, means, spec.size)
    if (isinstance(spec, ChunkedAESpec) and spec.use_kernel
            and not params_batched):
        mean = _fused_chunked_decode_agg(spec, params, stacked["z"], w)
        return mean if base is None else mean - base
    rows = decode_batched(spec, params, stacked,
                          params_batched=params_batched)
    if base is not None:
        rows = rows - base[None, :]
    return jnp.einsum("c,cp->p", w, rows.astype(jnp.float32))


def chunked_hidden(spec: ChunkedAESpec, params: Params,
                   z: jax.Array) -> jax.Array:
    """Kernel-path hidden decoder stack: ``(C, n_chunks, latent)`` latents →
    ``(C, n_chunks, K)`` penultimate activations, everything latent-sided.
    Shared by the per-bucket fused path below and the grouped ragged launch
    (core/partition.py, DESIGN.md §11.2) — both then expand to chunk width
    inside a weighted-accumulation kernel."""
    from repro.kernels.fused_dense import fused_dense
    from repro.kernels.ops import interpret_default
    interp = interpret_default()
    C, nc, latent = z.shape
    x = z.reshape(C * nc, latent)
    for layer in params["dec"][:-1]:           # hidden stack, act throughout
        # large bm: the folded (C·n_chunks) batch is tall and the hidden
        # widths narrow, so row-fat tiles stay far under VMEM while cutting
        # the grid-step count (which is what interpret-mode costs scale on)
        x = fused_dense(x, layer["w"], layer["b"],
                        act=spec.cfg.activation, bm=512, interpret=interp)
    return x.reshape(C, nc, x.shape[-1])


def _fused_chunked_decode_agg(spec: ChunkedAESpec, params: Params,
                              z: jax.Array, weights: jax.Array) -> jax.Array:
    """ChunkedAE fused path: per-client work stays latent-sided (the hidden
    stack output ``(C, n_chunks, hidden)``); the chunk_size-wide expansion
    happens inside the weighted-accumulation kernel, once."""
    from repro.kernels.fused_decode_agg import fused_decode_agg
    from repro.kernels.ops import interpret_default
    dec = params["dec"]
    h = chunked_hidden(spec, params, z)
    chunks = fused_decode_agg(h, weights, dec[-1]["w"], dec[-1]["b"],
                              interpret=interpret_default())
    norm = params["norm"]                             # (nc, chunk_size)
    chunks = chunks * norm["std"] + norm["mean"]      # Σw=1 ⇒ mean denorm
    return chunks.reshape(-1)[:spec.size]


# =====================================================================
# shard_map variant: client axis split across devices (DESIGN.md §7.2)
# =====================================================================
@functools.lru_cache(maxsize=None)
def _sharded_callable(spec: CodecSpec, mesh: jax.sharding.Mesh):
    """Build (once per (spec, mesh)) the jitted shard_map reduction so
    repeated rounds dispatch a cached executable instead of re-tracing."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def shard_fn(params, stacked_shard, w_shard):
        rows = decode_batched(spec, params, stacked_shard)
        partial = jnp.einsum("c,cp->p", w_shard.astype(jnp.float32),
                             rows.astype(jnp.float32))
        return jax.lax.psum(partial, "clients")

    # check_rep=False: pallas_call (the quantize/fused-dense kernels inside
    # decode_batched) has no shard_map replication rule yet
    return jax.jit(shard_map(shard_fn, mesh=mesh,
                             in_specs=(P(), P("clients"), P("clients")),
                             out_specs=P(), check_rep=False))


def decode_and_aggregate_sharded(spec: CodecSpec, params: Optional[Params],
                                 stacked: Payload, weights: jax.Array,
                                 base: Optional[jax.Array] = None,
                                 mesh: Optional[jax.sharding.Mesh] = None
                                 ) -> jax.Array:
    """Large-cohort variant: shard the client axis over a 1-D ``clients``
    device mesh; each device computes its shard's weighted *sum* (weights
    are globally pre-normalized, so no renormalization is needed; AE params
    are replicated), and a single ``psum`` produces the cohort mean. The
    cohort is zero-weight padded up to a device multiple (zero payloads
    decode to finite values for every codec, so padded rows contribute
    exactly 0). Layout notes in DESIGN.md §7.2."""
    import numpy as np

    if mesh is None:
        mesh = jax.sharding.Mesh(np.array(jax.devices()), ("clients",))
    n_dev = mesh.devices.size
    C = weights.shape[0]
    pad = (-C) % n_dev
    if pad:
        stacked = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)),
            stacked)
        weights = jnp.pad(weights, (0, pad))
    mean = _sharded_callable(spec, mesh)(params, stacked, weights)
    return mean if base is None else mean - base

"""Aggregation algorithms over (decoded) collaborator updates.

FedAvg (McMahan et al., 2017): sample-count-weighted mean of updates.
FedProx (Li et al., 2018): FedAvg aggregation; the proximal term lives in the
collaborator's local loss (see prepass.local_train(prox_mu=...)).

The aggregation hot path is *stacked*: :func:`weighted_mean_stacked` reduces
a pytree whose leaves carry a leading client axis with one ``einsum`` per
leaf, which is what the fused server decode→aggregate path emits
(DESIGN.md §7). The sequence API :func:`weighted_mean` is a thin wrapper
that stacks per-client pytrees and delegates.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp

Pytree = Any


def normalize_weights(weights: Sequence[float]) -> List[float]:
    """Host-side weight normalization shared by every aggregation path.

    Normalizing once in python float64 (rather than inside each jitted
    reduction) keeps the sequential, stacked, and fused server paths
    bit-identical to each other for the same weights."""
    total = float(sum(weights))
    return [float(w) / total for w in weights]


def weighted_mean_stacked(stacked: Pytree,
                          weights: Union[Sequence[float], jax.Array],
                          *, normalized: bool = False) -> Pytree:
    """Weighted mean over the leading client axis of every leaf.

    ``stacked`` leaves have shape ``(C, ...)``; the reduction is a single
    ``einsum`` per leaf instead of a per-update accumulation loop, so the
    whole cohort reduces in one XLA op (DESIGN.md §7). Weights are
    normalized unless the caller says they already are — host-side for
    python sequences (bit-stable across paths), traced for device arrays."""
    if isinstance(weights, jax.Array):
        w = weights.astype(jnp.float32)
        if not normalized:
            w = w / jnp.sum(w)
    else:
        if not normalized:
            weights = normalize_weights(weights)
        w = jnp.asarray(weights, jnp.float32)

    def combine(leaf):
        m = jnp.einsum("c,c...->...", w, leaf.astype(jnp.float32))
        return m.astype(leaf.dtype)

    return jax.tree_util.tree_map(combine, stacked)


def weighted_mean(updates: Sequence[Pytree],
                  weights: Optional[Sequence[float]] = None) -> Pytree:
    """Sequence API kept for callers holding per-client pytrees: stacks the
    leaves and delegates to :func:`weighted_mean_stacked`."""
    n = len(updates)
    if weights is None:
        weights = [1.0] * n
    stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *updates)
    return weighted_mean_stacked(stacked, normalize_weights(weights),
                                 normalized=True)


def apply_update(global_params: Pytree, mean_update: Pytree,
                 server_lr: float = 1.0) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32)
                      + server_lr * u.astype(jnp.float32)).astype(p.dtype),
        global_params, mean_update)


def fedavg(global_params: Pytree, updates: Sequence[Pytree],
           weights: Optional[Sequence[float]] = None,
           server_lr: float = 1.0) -> Pytree:
    return apply_update(global_params, weighted_mean(updates, weights),
                        server_lr)


def staleness_weights(base_weights: Sequence[float],
                      staleness: Sequence[int],
                      power: float = 0.5) -> List[float]:
    """FedBuff-style staleness discounting (Nguyen et al., 2022).

    An update computed against global version ``v`` but applied at version
    ``v + s`` is down-weighted by ``(1 + s) ** -power``; ``power=0`` recovers
    plain sample-count weighting so a zero-staleness buffered round is exactly
    FedAvg (DESIGN.md §6.2). The weights are renormalized inside
    :func:`weighted_mean`, so only the *relative* discount matters."""
    assert len(base_weights) == len(staleness)
    return [w * float(1 + s) ** (-power)
            for w, s in zip(base_weights, staleness)]


def distortion_weights(base_weights: Sequence[float],
                       distortions: Sequence[Optional[float]],
                       power: float = 1.0) -> List[float]:
    """Distortion discount for the async buffer (DESIGN.md §15.5): an
    update that rode a lossier codec carries less signal, so its weight is
    scaled by ``d_i = (1 + e_i) ** -power`` where ``e_i`` is the client's
    probed current-rung relative reconstruction error
    (``RateController.distortion_of``). Composes with
    :func:`staleness_weights` into the coherent
    ``w_i * (1 + s_i)^-p * d_i`` discount; ``None`` distortion (client not
    probed yet, or no controller) leaves the weight untouched, and
    ``power=0`` recovers plain staleness weighting. Renormalization inside
    :func:`weighted_mean` means only the relative discount matters."""
    assert len(base_weights) == len(distortions)
    return [w if e is None else w * float(1 + e) ** (-power)
            for w, e in zip(base_weights, distortions)]


def buffered_aggregate(global_params: Pytree, updates: Sequence[Pytree],
                       base_weights: Sequence[float],
                       staleness: Sequence[int], *,
                       power: float = 0.5,
                       server_lr: float = 1.0) -> Pytree:
    """One async buffer flush: staleness-discounted FedAvg over the first K
    arrivals (the buffer contents)."""
    return fedavg(global_params, updates,
                  staleness_weights(base_weights, staleness, power),
                  server_lr)

"""Aggregation algorithms over (decoded) collaborator updates.

FedAvg (McMahan et al., 2017): sample-count-weighted mean of updates.
FedProx (Li et al., 2018): FedAvg aggregation; the proximal term lives in the
collaborator's local loss (see prepass.local_train(prox_mu=...)).
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

Pytree = Any


def weighted_mean(updates: Sequence[Pytree],
                  weights: Optional[Sequence[float]] = None) -> Pytree:
    n = len(updates)
    if weights is None:
        weights = [1.0] * n
    total = float(sum(weights))
    norm = [w / total for w in weights]

    def combine(*leaves):
        acc = jnp.zeros_like(leaves[0], dtype=jnp.float32)
        for w, leaf in zip(norm, leaves):
            acc = acc + w * leaf.astype(jnp.float32)
        return acc.astype(leaves[0].dtype)

    return jax.tree_util.tree_map(combine, *updates)


def apply_update(global_params: Pytree, mean_update: Pytree,
                 server_lr: float = 1.0) -> Pytree:
    return jax.tree_util.tree_map(
        lambda p, u: (p.astype(jnp.float32)
                      + server_lr * u.astype(jnp.float32)).astype(p.dtype),
        global_params, mean_update)


def fedavg(global_params: Pytree, updates: Sequence[Pytree],
           weights: Optional[Sequence[float]] = None,
           server_lr: float = 1.0) -> Pytree:
    return apply_update(global_params, weighted_mean(updates, weights),
                        server_lr)


def staleness_weights(base_weights: Sequence[float],
                      staleness: Sequence[int],
                      power: float = 0.5) -> List[float]:
    """FedBuff-style staleness discounting (Nguyen et al., 2022).

    An update computed against global version ``v`` but applied at version
    ``v + s`` is down-weighted by ``(1 + s) ** -power``; ``power=0`` recovers
    plain sample-count weighting so a zero-staleness buffered round is exactly
    FedAvg (DESIGN.md §6.2). The weights are renormalized inside
    :func:`weighted_mean`, so only the *relative* discount matters."""
    assert len(base_weights) == len(staleness)
    return [w * float(1 + s) ** (-power)
            for w, s in zip(base_weights, staleness)]


def buffered_aggregate(global_params: Pytree, updates: Sequence[Pytree],
                       base_weights: Sequence[float],
                       staleness: Sequence[int], *,
                       power: float = 0.5,
                       server_lr: float = 1.0) -> Pytree:
    """One async buffer flush: staleness-discounted FedAvg over the first K
    arrivals (the buffer contents)."""
    return fedavg(global_params, updates,
                  staleness_weights(base_weights, staleness, power),
                  server_lr)
